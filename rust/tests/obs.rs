//! Observability integration: the E13 serving stack under the tracer.
//!
//! * **Reconciliation** — a traced E13 operating point's per-phase span
//!   sums (`traffic.wait` + `traffic.serve`) equal the report's
//!   `sum_response`, and `traffic.batch` spans retell the batch log.
//! * **Perfetto schema** — the exported Chrome trace parses, carries
//!   only `"X"`/`"M"` events with the fields ui.perfetto.dev requires,
//!   and is byte-deterministic.
//! * **Bit-identity** — enabling observation changes no output:
//!   traffic reports, shard plans, engine assembly and netsim reports
//!   all match their untraced twins exactly.
//! * **Edge cases** — `LatencyStats` on empty / single-sample inputs
//!   and at `fraction_within` boundaries, plus the histogram quantile
//!   error bound against the exact percentiles of a live run.

use ima_gnn::autotune::SettingKind;
use ima_gnn::coordinator::{LatencyProvider, LatencyStats, RoundEngine};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::experiments::{TRAFFIC_MAX_BATCH, TRAFFIC_WAIT_MS};
use ima_gnn::graph::{generate, ShardPlan};
use ima_gnn::json;
use ima_gnn::netmodel::{NetModel, Topology};
use ima_gnn::netsim::{simulate_fabric, simulate_fabric_observed, NetSimConfig, Scenario};
use ima_gnn::obs::{chrome_trace_json, Obs, Span, MAX_REL_ERROR};
use ima_gnn::testing::{assert_close, gcn_layer_binding, Rng};
use ima_gnn::traffic::{
    deployment_shape, open_loop, open_loop_observed, ArrivalProcess, BatchPolicy, TrafficReport,
};
use ima_gnn::units::Time;

/// One traced E13 operating point: the semi overlay's representative
/// queue at 60% saturation under the sweep's deadline policy.
fn traced_e13_point() -> (Obs, TrafficReport) {
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology::taxi();
    let (queues, service) =
        deployment_shape(SettingKind::Semi, LatencyProvider::Analytic, &model, topo).unwrap();
    let policy =
        BatchPolicy::Deadline { max: TRAFFIC_MAX_BATCH, max_wait: Time::ms(TRAFFIC_WAIT_MS) };
    let rate = queues.per_queue_rate(
        0.6 * queues.servers() as f64 * service.saturation_rate(TRAFFIC_MAX_BATCH),
    );
    let arrivals = ArrivalProcess::Poisson { rate }
        .generate(Time::s(2_000.0 / rate), topo.nodes, 5)
        .unwrap();
    let obs = Obs::new(1 << 16);
    let report = open_loop_observed(1, &service, policy, &arrivals, &obs).unwrap();
    assert_eq!(obs.tracer.dropped(), 0, "ring must hold the whole run");
    (obs, report)
}

fn phase_sum_s(spans: &[Span], name: &str) -> f64 {
    spans.iter().filter(|s| s.name == name).map(|s| (s.end - s.start).as_s()).sum()
}

/// Acceptance: per-phase span sums reconcile with the report's latency
/// totals, and the always-on metrics retell the same run.
#[test]
fn traced_e13_point_reconciles_spans_with_the_report() {
    let (obs, r) = traced_e13_point();
    let spans = obs.tracer.spans();
    // One wait and one serve span per request, one batch span per batch.
    assert_eq!(spans.iter().filter(|s| s.name == "traffic.wait").count(), r.offered);
    assert_eq!(spans.iter().filter(|s| s.name == "traffic.serve").count(), r.completed);
    assert_eq!(spans.iter().filter(|s| s.name == "traffic.batch").count(), r.batches);
    // Σ wait + Σ serve = Σ (done − arrival) = the report's sum_response.
    let phases = phase_sum_s(&spans, "traffic.wait") + phase_sum_s(&spans, "traffic.serve");
    assert_close(phases, r.sum_response.as_s(), 1e-9);
    // The batch spans are the batch log, span-shaped.
    let log_busy: f64 = r.batch_log.iter().map(|b| (b.done_at - b.dispatched_at).as_s()).sum();
    assert_close(phase_sum_s(&spans, "traffic.batch"), log_busy, 1e-9);
    // Metrics cross-check the report fields.
    assert_eq!(obs.metrics.counter_value("traffic.requests"), r.offered as u64);
    assert_eq!(obs.metrics.counter_value("traffic.batches"), r.batches as u64);
    assert_eq!(
        obs.metrics.gauge_value("sim.event_queue.max_depth"),
        Some(r.max_event_depth as f64)
    );
    let hist = obs.metrics.histogram("traffic.response_ms").unwrap();
    assert_eq!(hist.count(), r.offered as u64);
    assert_close(hist.mean(), r.latency.mean().as_ms(), 1e-9);
    // Log-bucket quantiles sit within the advertised relative error of
    // the exact percentiles (plus headroom for rank-rounding).
    assert_close(hist.p95(), r.latency.p95().as_ms(), 2.0 * MAX_REL_ERROR);
    assert_close(hist.p50(), r.latency.p50().as_ms(), 2.0 * MAX_REL_ERROR);
}

/// The Chrome trace export parses, satisfies the Trace Event Format
/// fields Perfetto needs, covers every retained span, and is
/// byte-deterministic.
#[test]
fn chrome_export_is_perfetto_schema_valid() {
    let (obs, _) = traced_e13_point();
    let procs = [("traffic:semi", &obs.tracer)];
    let text = chrome_trace_json(&procs);
    let doc = json::parse(&text).unwrap();
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut complete = 0usize;
    let mut metadata = 0usize;
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(ev.get("pid").unwrap().as_usize().unwrap() >= 1);
        match ph {
            "X" => {
                complete += 1;
                assert!(!ev.get("name").unwrap().as_str().unwrap().is_empty());
                assert!(ev.get("ts").unwrap().as_f64().unwrap() >= 0.0);
                assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                assert!(ev.get("tid").unwrap().as_f64().is_some());
            }
            "M" => {
                metadata += 1;
                assert_eq!(ev.get("name").unwrap().as_str(), Some("process_name"));
                let label = ev.get("args").unwrap().get("name").unwrap().as_str();
                assert_eq!(label, Some("traffic:semi"));
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert_eq!(complete, obs.tracer.len());
    assert_eq!(metadata, procs.len());
    // Byte determinism: the same spans render to the same bytes.
    assert_eq!(text, chrome_trace_json(&procs));
}

/// Observation is opt-in and output-neutral: the observed traffic run
/// matches the plain one field for field.
#[test]
fn observed_traffic_run_is_bit_identical_to_plain() {
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology::taxi();
    let (_, service) =
        deployment_shape(SettingKind::Centralized, LatencyProvider::Analytic, &model, topo)
            .unwrap();
    let policy = BatchPolicy::Deadline { max: 16, max_wait: Time::ms(TRAFFIC_WAIT_MS) };
    let rate = 0.4 * service.saturation_rate(16);
    let arrivals = ArrivalProcess::Poisson { rate }
        .generate(Time::s(1_000.0 / rate), topo.nodes, 13)
        .unwrap();
    let plain = open_loop(1, &service, policy, &arrivals).unwrap();
    let obs = Obs::new(1 << 16);
    let traced = open_loop_observed(1, &service, policy, &arrivals, &obs).unwrap();
    assert_eq!(traced.batch_log, plain.batch_log);
    assert_eq!(traced.makespan, plain.makespan);
    assert_eq!(traced.mean_wait, plain.mean_wait);
    assert_eq!(traced.sum_response, plain.sum_response);
    assert_eq!(traced.max_queue_depth, plain.max_queue_depth);
    assert_eq!(traced.max_event_depth, plain.max_event_depth);
    assert_eq!(traced.latency.p99(), plain.latency.p99());
    assert!(!obs.tracer.is_empty(), "the traced twin must actually record");
}

/// Shard planning and the round engine record spans without perturbing
/// the plan, the assembly, or the cache counters.
#[test]
fn engine_and_shard_spans_record_without_perturbing_outputs() {
    let b = gcn_layer_binding();
    let graph = generate::regular(96, 6, 3).unwrap();
    let sampler = b.sampler();
    let plain_plan = ShardPlan::build(&graph, &sampler, b.table).unwrap();
    let obs = Obs::new(4096);
    let plan = ShardPlan::build_observed(&graph, &sampler, b.table, &obs).unwrap();
    assert_eq!(plan, plain_plan);
    assert!(obs.tracer.spans().iter().any(|s| s.name == "shard.plan"));
    assert!(obs.metrics.counter_value("shard.pack_attempts") >= 1);

    let shards = plan.num_shards();
    let weights = vec![0.01f32; b.feature * b.hidden];
    let mut traced = RoundEngine::new(b.clone(), plan, weights.clone()).unwrap();
    traced.enable_tracing(4096);
    let mut plain = RoundEngine::new(b.clone(), plain_plan, weights).unwrap();
    let mut rng = Rng::new(11);
    for node in 0..graph.num_nodes() {
        let feats: Vec<f32> = (0..b.feature).map(|_| rng.f64() as f32).collect();
        traced.upload(node, &feats).unwrap();
        plain.upload(node, &feats).unwrap();
    }
    traced.end_round();
    plain.end_round();
    let all: Vec<usize> = (0..graph.num_nodes()).collect();
    assert_eq!(traced.assemble(&all).unwrap(), plain.assemble(&all).unwrap());
    // S1: the counter accessors are thin reads of the engine registry.
    assert_eq!(traced.table_builds(), shards as u64);
    assert_eq!(traced.metrics().counter_value("engine.table_builds"), traced.table_builds());
    let names: Vec<&str> = traced.tracer().spans().iter().map(|s| s.name).collect();
    for want in ["engine.round_barrier", "store.swap", "engine.assemble"] {
        assert!(names.contains(&want), "missing span {want} in {names:?}");
    }
    assert!(plain.tracer().is_empty(), "tracing must stay opt-in");
}

/// Netsim under observation returns the identical report, and its
/// packet spans / fabric counters retell the report's totals.
#[test]
fn netsim_observed_is_bit_identical_and_counts_packets() {
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology { nodes: 64, cluster_size: 8 };
    let cfg = NetSimConfig { rx_ports: Some(8), ..Default::default() };
    let plain = simulate_fabric(&model, Scenario::CentralizedStar, topo, &cfg).unwrap();
    let obs = Obs::new(1 << 16);
    let traced =
        simulate_fabric_observed(&model, Scenario::CentralizedStar, topo, &cfg, &obs).unwrap();
    assert_eq!(traced, plain);
    assert_eq!(obs.tracer.dropped(), 0);
    let spans = obs.tracer.spans();
    assert_eq!(spans.iter().filter(|s| s.name == "net.packet").count(), plain.packets);
    assert_eq!(obs.metrics.counter_value("net.packets"), plain.packets as u64);
    assert_eq!(obs.metrics.counter_value("net.contended"), plain.contended_packets as u64);
    assert_eq!(obs.metrics.counter_value("net.messages"), plain.messages as u64);
    let waits = obs.metrics.histogram("net.queue_wait_us").unwrap();
    assert_eq!(waits.count(), plain.packets as u64);
    assert_close(waits.sum(), plain.queue_wait.as_us(), 1e-9);
}

/// `LatencyStats` edge cases: empty input errors, a single sample is
/// every quantile, and `fraction_within` is boundary-inclusive.
#[test]
fn latency_stats_edge_cases() {
    assert!(LatencyStats::from_samples(Vec::new()).is_err());

    let one = LatencyStats::from_samples(vec![Time::ms(7.0)]).unwrap();
    assert_eq!(one.count(), 1);
    assert_eq!(one.quantile(0.0), Time::ms(7.0));
    assert_eq!(one.p50(), Time::ms(7.0));
    assert_eq!(one.quantile(1.0), Time::ms(7.0));
    assert_eq!(one.max(), Time::ms(7.0));
    assert_close(one.mean().as_ms(), 7.0, 1e-12);
    assert_eq!(one.fraction_within(Time::ms(7.0)), 1.0);
    assert_eq!(one.fraction_within(Time::ms(6.999)), 0.0);

    let three =
        LatencyStats::from_samples(vec![Time::ms(3.0), Time::ms(1.0), Time::ms(2.0)]).unwrap();
    // Boundary-inclusive: a sample exactly at the SLO counts as within.
    assert_eq!(three.fraction_within(Time::ms(2.0)), 2.0 / 3.0);
    assert_eq!(three.fraction_within(Time::ms(0.5)), 0.0);
    assert_eq!(three.fraction_within(Time::ms(3.0)), 1.0);
    // Nearest-rank: q ≤ 1/3 hits the first sample, the median the second.
    assert_eq!(three.quantile(0.2), Time::ms(1.0));
    assert_eq!(three.p50(), Time::ms(2.0));
    assert_eq!(three.quantile(1.0), Time::ms(3.0));
}
