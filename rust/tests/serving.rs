//! Integration: the full serving path (router → batcher → PJRT) in the
//! centralized, decentralized and semi-decentralized deployments.

use std::path::PathBuf;
use std::time::Duration;

use ima_gnn::autotune::{OperatingPoint, Partitioner};
use ima_gnn::coordinator::{
    CentralizedLeader, GcnLayerBinding, InferenceService, Request, Router, SemiCoordinator,
};
use ima_gnn::cores::{FeatureMatrix, GnnWorkload};
use ima_gnn::graph::{fixed_size, generate};
use ima_gnn::testing::Rng;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Shared skip guard (`testing::pjrt_artifacts_ready`): returns false with
/// a printed reason when the PJRT backend or the AOT artifacts are absent.
fn pjrt_ready() -> bool {
    ima_gnn::testing::pjrt_artifacts_ready(&artifact_dir())
}

fn service() -> InferenceService {
    InferenceService::start(artifact_dir()).expect("run `make artifacts` first")
}

fn binding(svc_dir: &PathBuf) -> GcnLayerBinding {
    let manifest = ima_gnn::runtime::Manifest::load(svc_dir).unwrap();
    GcnLayerBinding::from_spec(manifest.get("gcn_layer_small").unwrap()).unwrap()
}

fn leader() -> CentralizedLeader {
    let dir = artifact_dir();
    let b = binding(&dir);
    let graph = generate::regular(48, 6, 3).unwrap();
    let mut rng = Rng::new(1);
    let weights: Vec<f32> =
        (0..b.feature * b.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    CentralizedLeader::new(
        b,
        graph,
        weights,
        &GnnWorkload::gcn("itest", 64, 6),
        Duration::from_millis(50),
    )
    .unwrap()
}

#[test]
fn centralized_leader_serves_full_batches() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let mut leader = leader();
    let mut rng = Rng::new(2);
    // Devices upload their features; round barrier makes them visible.
    for node in 0..48 {
        let f: Vec<f32> = (0..64).map(|_| rng.f64_in(0.0, 1.0) as f32).collect();
        leader.upload(node, &f).unwrap();
    }
    leader.end_round();

    let mut responses = Vec::new();
    for id in 0..16u64 {
        let out = leader.submit(&svc, Request { id, node: id as usize }).unwrap();
        responses.extend(out);
    }
    // batch size is 16 → exactly one batch served, all 16 answered
    assert_eq!(responses.len(), 16);
    assert_eq!(leader.served_batches(), 1);
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert_eq!(r.output.len(), 32);
        assert!(r.output.iter().all(|v| v.is_finite() && *v >= 0.0)); // ReLU
        assert!(r.modeled.as_us() > 0.0);
    }
    // embeddings should not all be identical (features differ)
    assert_ne!(responses[0].output, responses[1].output);
}

#[test]
fn centralized_leader_drains_partial_batches() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let mut leader = leader();
    for node in 0..48 {
        leader.upload(node, &vec![0.5; 64]).unwrap();
    }
    leader.end_round();
    for id in 0..5u64 {
        assert!(leader.submit(&svc, Request { id, node: id as usize }).unwrap().is_empty());
    }
    let drained = leader.drain(&svc).unwrap();
    assert_eq!(drained.len(), 5);
}

#[test]
fn deadline_poll_serves_stale_requests() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let dir = artifact_dir();
    let b = binding(&dir);
    let graph = generate::regular(32, 4, 7).unwrap();
    let weights = vec![0.05f32; b.feature * b.hidden];
    let mut leader = CentralizedLeader::new(
        b,
        graph,
        weights,
        &GnnWorkload::gcn("poll", 64, 4),
        Duration::from_millis(1),
    )
    .unwrap();
    leader.end_round();
    assert!(leader.submit(&svc, Request { id: 1, node: 3 }).unwrap().is_empty());
    std::thread::sleep(Duration::from_millis(5));
    let served = leader.poll(&svc).unwrap();
    assert_eq!(served.len(), 1);
    assert_eq!(served[0].node, 3);
}

#[test]
fn semi_decentralized_round_covers_every_node() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let dir = artifact_dir();
    let b = binding(&dir);
    let graph = generate::regular(48, 6, 3).unwrap();
    let clustering = fixed_size(48, 8).unwrap();
    let mut rng = Rng::new(4);
    let weights: Vec<f32> =
        (0..b.feature * b.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let feature = b.feature;
    let mut semi = SemiCoordinator::new(
        b,
        graph,
        clustering,
        weights,
        &GnnWorkload::gcn("semi", 64, 8),
    )
    .unwrap();
    assert_eq!(semi.num_heads(), 6);

    let features = FeatureMatrix::from_fn(48, feature, |_, _| rng.f64_in(0.0, 1.0) as f32);
    let results = semi.round(&svc, &features).unwrap();
    assert_eq!(results.len(), 48);
    for (node, r) in results.iter().enumerate() {
        assert_eq!(r.node, node);
        assert_eq!(r.head, node / 8);
        assert_eq!(r.output.len(), 32);
        assert!(r.modeled.as_us() > 0.0);
    }
}

/// E11: a semi-decentralized round built from a tuned operating point
/// covers every node and is bit-identical to the round of a
/// hand-constructed coordinator with the same parameters.
#[test]
fn from_operating_point_round_is_bit_identical_to_hand_construction() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let dir = artifact_dir();
    let b = binding(&dir);
    let graph = generate::regular(48, 6, 3).unwrap();
    let mut rng = Rng::new(11);
    let weights: Vec<f32> =
        (0..b.feature * b.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let feature = b.feature;
    let workload = GnnWorkload::gcn("semi-tuned", 64, 8);

    let point = OperatingPoint::semi(8, 10.0, Partitioner::FixedSize);
    let mut tuned = SemiCoordinator::from_operating_point(
        binding(&dir),
        graph.clone(),
        weights.clone(),
        &workload,
        &point,
    )
    .unwrap();
    let mut hand = SemiCoordinator::new(
        b,
        graph,
        fixed_size(48, 8).unwrap(),
        weights,
        &workload,
    )
    .unwrap()
    .with_head_capacity(10.0)
    .unwrap();
    assert_eq!(tuned.num_heads(), hand.num_heads());
    assert_eq!(tuned.head_capacity(), 10.0);

    let features = FeatureMatrix::from_fn(48, feature, |_, _| rng.f64_in(0.0, 1.0) as f32);
    let a = tuned.round(&svc, &features).unwrap();
    let c = hand.round(&svc, &features).unwrap();
    assert_eq!(a.len(), 48);
    assert_eq!(c.len(), 48);
    for (node, (ra, rc)) in a.iter().zip(&c).enumerate() {
        // Every node covered, once, in order — and the embeddings (plus
        // the modeled latency) are bit-identical across constructors.
        assert_eq!(ra.node, node);
        assert_eq!(rc.node, node);
        assert_eq!(ra.head, rc.head);
        assert_eq!(ra.output, rc.output, "node {node} diverged");
        assert_eq!(ra.modeled, rc.modeled);
    }
}

#[test]
fn router_and_service_compose() {
    if !pjrt_ready() {
        return;
    }
    // Smoke: route a request stream to replicas, serve through the service.
    let svc = service();
    svc.warm("gcn_layer_small").unwrap();
    let mut router = Router::centralized(100, 2).unwrap();
    let mut counts = [0usize; 2];
    for node in 0..20 {
        let dev = router.route(node).unwrap();
        counts[dev] += 1;
        router.complete(dev);
    }
    assert_eq!(counts[0] + counts[1], 20);
    assert!(counts[0] > 0 && counts[1] > 0);
}
