//! E13 cross-validation: the traffic engine against queueing theory.
//!
//! * **M/D/1 / Pollaczek–Khinchine** — Poisson arrivals into a single
//!   queue with deterministic service (the immediate policy) must match
//!   the P–K mean-wait closed form across load levels.
//! * **Little's law** — `∫N(t)dt = Σ response` holds to round-off on
//!   every E13 sweep point and on every arrival process (the two sides
//!   count the same request-seconds through independent accumulators).
//! * **Open vs closed loop** — at ρ→0 both loops degenerate to
//!   `utilization = arrival rate × service time`.
//! * **Crossover** — the E13 sweep reports a finite centralized→semi
//!   crossover request rate (the repo's "at what load does the hybrid
//!   win?" answer).
//! * **Congestion composes** — a netsim-congested round latency fed
//!   through `LatencyProvider::Netsim` slows every traffic percentile.

use ima_gnn::coordinator::LatencyProvider;
use ima_gnn::cores::GnnWorkload;
use ima_gnn::experiments::TrafficSweep;
use ima_gnn::netmodel::{NetModel, Topology};
use ima_gnn::netsim::{simulate_fabric, NetSimConfig, Scenario};
use ima_gnn::obs::Obs;
use ima_gnn::testing::assert_close;
use ima_gnn::traffic::{
    closed_loop, md1_mean_wait, open_loop, open_loop_observed, ArrivalProcess, BatchPolicy,
    ClosedLoopConfig, ServiceModel, ThinkTime,
};
use ima_gnn::units::Time;
use ima_gnn::workload::DiurnalCurve;

fn station(service_ms: f64) -> ServiceModel {
    ServiceModel::new(Time::ms(service_ms), Time::ZERO).unwrap()
}

/// M/D/1: simulated mean queue wait vs Pollaczek–Khinchine, across low,
/// medium and heavy load.  The runs are deterministic per seed; the
/// tolerance covers the finite-sample error of ~40k-request streams.
#[test]
fn md1_mean_wait_matches_pollaczek_khinchine() {
    let s = Time::ms(2.0);
    let service = station(2.0);
    for (rho, seed) in [(0.3, 11), (0.5, 12), (0.7, 13)] {
        let rate = rho / s.as_s();
        let horizon = Time::s(40_000.0 / rate);
        let arrivals = ArrivalProcess::Poisson { rate }
            .generate(horizon, 64, seed)
            .unwrap();
        let r = open_loop(1, &service, BatchPolicy::Immediate, &arrivals).unwrap();
        let pk = md1_mean_wait(rate, s).unwrap();
        assert_close(r.mean_wait.as_s(), pk.as_s(), 0.08);
        // Utilization tracks ρ and Little's law holds to round-off.
        assert_close(r.utilization, rho, 0.05);
        assert!(r.littles_law_gap() < 1e-9, "rho {rho}: gap {}", r.littles_law_gap());
        // Response = wait + service, so the mean response cross-checks
        // the same closed form shifted by s.
        assert_close(r.latency.mean().as_s(), (pk + s).as_s(), 0.08);
    }
}

/// Little's law holds to round-off on every arrival process the engine
/// supports — not just the Poisson case the P–K test covers.
#[test]
fn littles_law_holds_on_every_arrival_process() {
    let service = ServiceModel::new(Time::ms(4.0), Time::ms(0.1)).unwrap();
    let policy = BatchPolicy::Deadline { max: 8, max_wait: Time::ms(3.0) };
    let horizon = Time::s(10.0);
    let processes = [
        ArrivalProcess::Poisson { rate: 400.0 },
        ArrivalProcess::Diurnal(DiurnalCurve::new(400.0, 0.9, Time::s(5.0)).unwrap()),
        ArrivalProcess::FlashCrowd {
            base: 200.0,
            boost: 6.0,
            at: Time::s(4.0),
            width: Time::s(1.0),
        },
    ];
    for p in processes {
        let arrivals = p.generate(horizon, 32, 21).unwrap();
        for servers in [1usize, 3] {
            let r = open_loop(servers, &service, policy, &arrivals).unwrap();
            assert!(
                r.littles_law_gap() < 1e-9,
                "{p:?} x{servers}: gap {}",
                r.littles_law_gap()
            );
        }
    }
    let r = closed_loop(
        2,
        &service,
        policy,
        &ClosedLoopConfig {
            fleet: 16,
            think: ThinkTime::Exponential { mean: Time::ms(40.0) },
            horizon,
            nodes: 32,
            seed: 7,
        },
    )
    .unwrap();
    assert!(r.littles_law_gap() < 1e-9, "closed loop: gap {}", r.littles_law_gap());
}

/// A flash crowd degrades the tail far more than the median — the SLO
/// story the one-shot round experiments cannot tell.
#[test]
fn flash_crowd_punishes_the_tail_not_the_median() {
    let service = station(4.0);
    // max 2 caps this queue's throughput at 500 req/s — the 600 req/s
    // spike genuinely oversubscribes it for half a second.
    let policy = BatchPolicy::Deadline { max: 2, max_wait: Time::ms(2.0) };
    let horizon = Time::s(10.0);
    let calm = ArrivalProcess::Poisson { rate: 100.0 }.generate(horizon, 32, 5).unwrap();
    let spiky = ArrivalProcess::FlashCrowd {
        base: 100.0,
        boost: 6.0,
        at: Time::s(4.0),
        width: Time::s(0.5),
    }
    .generate(horizon, 32, 5)
    .unwrap();
    let base = open_loop(1, &service, policy, &calm).unwrap();
    let flash = open_loop(1, &service, policy, &spiky).unwrap();
    assert!(
        flash.latency.p99() > base.latency.p99() * 2.0,
        "p99 must blow up under the spike: {} vs {}",
        flash.latency.p99(),
        base.latency.p99()
    );
    let p50_ratio = flash.latency.p50() / base.latency.p50();
    let p99_ratio = flash.latency.p99() / base.latency.p99();
    assert!(
        p99_ratio > p50_ratio,
        "the tail must degrade more than the median ({p99_ratio} vs {p50_ratio})"
    );
}

/// Open- vs closed-loop equivalence at low load: as ρ→0 both loops
/// satisfy `utilization → arrival rate × service time`, and the closed
/// loop's effective rate approaches `fleet / (think + service)`.
#[test]
fn open_and_closed_loops_agree_at_low_load() {
    let s = Time::ms(5.0);
    let service = station(5.0);
    let fleet = 8usize;
    let think = Time::s(2.0);
    // Closed loop: 8 clients cycling think(2 s) + service(5 ms).
    let closed = closed_loop(
        1,
        &service,
        BatchPolicy::Immediate,
        &ClosedLoopConfig {
            fleet,
            think: ThinkTime::Exponential { mean: think },
            horizon: Time::s(1_000.0),
            nodes: 16,
            seed: 17,
        },
    )
    .unwrap();
    // The operational identity is exact for unit batches...
    assert_close(
        closed.utilization,
        closed.throughput_per_s * s.as_s(),
        1e-9,
    );
    // ...and the measured rate approaches fleet/(think + response).
    let expected_rate = fleet as f64 / (think + s).as_s();
    assert_close(closed.throughput_per_s, expected_rate, 0.2);

    // Open loop at the closed loop's effective rate: same utilization.
    let arrivals = ArrivalProcess::Poisson { rate: expected_rate }
        .generate(Time::s(1_000.0), 16, 18)
        .unwrap();
    let open = open_loop(1, &service, BatchPolicy::Immediate, &arrivals).unwrap();
    assert_close(open.utilization, expected_rate * s.as_s(), 0.25);
    assert_close(open.utilization, closed.utilization, 0.3);
    // Both sit far below saturation, with near-zero queueing.
    assert!(open.utilization < 0.05 && closed.utilization < 0.05);
    assert!(open.mean_wait.as_s() < 0.2 * s.as_s());
}

/// E13 acceptance: the sweep reports a finite centralized→semi
/// crossover request rate for at least one Table 2 dataset, and
/// Little's law holds on every sweep point.
#[test]
fn traffic_sweep_crossover_and_littles_law() {
    let sweep = TrafficSweep::run_with_threads(200, 1_500, 2).unwrap();
    assert_eq!(sweep.rows.len(), 4);
    assert!(sweep.max_littles_gap() < 1e-9, "gap {}", sweep.max_littles_gap());
    let lj = sweep.rows.iter().find(|r| r.dataset == "LiveJournal").unwrap();
    let x = lj.crossover_per_s.expect("LiveJournal must report a crossover rate");
    assert!(x.is_finite() && x > 0.0, "crossover {x}");
    assert!(
        sweep.rows.iter().any(|r| r.crossover_per_s.is_some()),
        "at least one Table 2 dataset must flip to the hybrid under load"
    );
}

/// Netsim congestion composes with queueing: a contended star fabric's
/// round completion, fed through `LatencyProvider::Netsim`, slows every
/// percentile of the same arrival stream.
#[test]
fn netsim_congestion_composes_with_queueing() {
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology { nodes: 1_000, cluster_size: 10 };
    // A 64-port leader NIC congests the 1000-device gather.
    let cfg = NetSimConfig { rx_ports: Some(64), ..Default::default() };
    let congested = simulate_fabric(&model, Scenario::CentralizedStar, topo, &cfg).unwrap();
    let analytic = ServiceModel::centralized(LatencyProvider::Analytic, &model, topo).unwrap();
    let simulated = ServiceModel::centralized(
        LatencyProvider::Netsim(congested.completion),
        &model,
        topo,
    )
    .unwrap();
    assert!(
        simulated.per_batch > analytic.per_batch,
        "contention must price the batch barrier up"
    );
    let policy = BatchPolicy::Deadline { max: 64, max_wait: Time::ms(2.0) };
    let rate = 0.5 * analytic.saturation_rate(64);
    let arrivals = ArrivalProcess::Poisson { rate }
        .generate(Time::s(2_000.0 / rate), topo.nodes, 23)
        .unwrap();
    let fast = open_loop(1, &analytic, policy, &arrivals).unwrap();
    let slow = open_loop(1, &simulated, policy, &arrivals).unwrap();
    assert!(slow.latency.p50() > fast.latency.p50());
    assert!(slow.latency.p95() > fast.latency.p95());
    assert!(slow.latency.mean() > fast.latency.mean());
    assert!(slow.littles_law_gap() < 1e-9 && fast.littles_law_gap() < 1e-9);
}

/// The event-queue high-water mark cross-validates the report: open
/// loops preload every arrival, so the event depth must dominate both
/// the offered count and the per-server pending high-water, the
/// observed run must be bit-identical to the plain one, and the
/// `sim.event_queue.max_depth` gauge must equal the report field.
#[test]
fn event_queue_high_water_cross_validates_the_report() {
    let service = station(3.0);
    let policy = BatchPolicy::Deadline { max: 8, max_wait: Time::ms(2.0) };
    let arrivals = ArrivalProcess::Poisson { rate: 500.0 }.generate(Time::s(4.0), 32, 9).unwrap();
    let r = open_loop(1, &service, policy, &arrivals).unwrap();
    assert!(r.offered > 0);
    assert!(
        r.max_event_depth >= r.offered,
        "open loop preloads all {} arrivals but high-water was {}",
        r.offered,
        r.max_event_depth
    );
    assert!(r.max_event_depth >= r.max_queue_depth);
    let obs = Obs::new(4096);
    let o = open_loop_observed(1, &service, policy, &arrivals, &obs).unwrap();
    assert_eq!(o.max_event_depth, r.max_event_depth);
    assert_eq!(o.batch_log, r.batch_log);
    assert_eq!(
        obs.metrics.gauge_value("sim.event_queue.max_depth"),
        Some(r.max_event_depth as f64)
    );
    assert_eq!(
        obs.metrics.gauge_value("traffic.max_queue_depth"),
        Some(r.max_queue_depth as f64)
    );
}
