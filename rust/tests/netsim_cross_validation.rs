//! Integration: the packet-level fabric (`netsim`), the closed-form
//! network model (Eqs. 1–5 + E8) and the aggregate DES (`sim`) must tell
//! one consistent story — and the fabric must stay deterministic.
//!
//! The acceptance invariant: in the uncongested single-message case the
//! simulated latencies match the analytic Eq. (4)/(5) values (and the E8
//! hybrid) within 1% for all three topologies.  They actually agree to
//! float round-off; both bounds are asserted.

use ima_gnn::cores::GnnWorkload;
use ima_gnn::netmodel::{AnalyticFabric, NetModel, Setting, Topology};
use ima_gnn::netsim::{simulate_fabric, NetSim, NetSimConfig, Scenario};
use ima_gnn::sim::{simulate, SimConfig};
use ima_gnn::testing::{assert_close, forall, Rng};

fn model() -> NetModel {
    NetModel::paper(&GnnWorkload::taxi()).unwrap()
}

/// The acceptance criterion, spelled out per topology.
#[test]
fn uncongested_single_message_latencies_match_the_equations_within_1_percent() {
    let m = model();
    let topo = Topology { nodes: 1_000, cluster_size: 10 };
    let cfg = NetSimConfig::default();

    // Centralized star ↔ Eq. (5): t(L_n), concurrent transfers.
    let cent = simulate_fabric(&m, Scenario::CentralizedStar, topo, &cfg).unwrap();
    let eq5 = m.communicate_latency(Setting::Centralized, topo);
    assert_close(cent.comm_done.as_s(), eq5.as_s(), 0.01);
    assert_close(cent.comm_done.as_s(), eq5.as_s(), 1e-9);

    // Decentralized mesh ↔ Eq. (4): (tₑ + cₛ·t(L_c)) · 2.
    let dec = simulate_fabric(&m, Scenario::DecentralizedMesh, topo, &cfg).unwrap();
    let eq4 = m.communicate_latency(Setting::Decentralized, topo);
    assert_close(dec.comm_done.as_s(), eq4.as_s(), 0.01);
    assert_close(dec.comm_done.as_s(), eq4.as_s(), 1e-9);

    // Semi-decentralized overlay ↔ the E8 hybrid model.
    let semi =
        simulate_fabric(&m, Scenario::SemiOverlay { head_capacity: 10.0 }, topo, &cfg).unwrap();
    let e8 = m.semi_latency(topo, 10.0).total();
    assert_close(semi.completion.as_s(), e8.as_s(), 0.01);
    assert_close(semi.completion.as_s(), e8.as_s(), 1e-6);

    // End-to-end totals compose the same way as Eq. (1).
    assert_close(
        cent.completion.as_s(),
        m.latency(Setting::Centralized, topo).total().as_s(),
        1e-6,
    );
    assert_close(
        dec.completion.as_s(),
        m.latency(Setting::Decentralized, topo).total().as_s(),
        1e-6,
    );
}

/// The agreement is not a lucky operating point: it holds over random
/// topologies (jitter and contention off).
#[test]
fn property_fabric_equals_model_over_random_topologies() {
    let m = model();
    let cfg = NetSimConfig::default();
    forall(10, |rng: &mut Rng| {
        let topo = Topology { nodes: rng.index(300) + 2, cluster_size: rng.index(15) + 1 };
        let cent = simulate_fabric(&m, Scenario::CentralizedStar, topo, &cfg).unwrap();
        assert_close(
            cent.completion.as_s(),
            m.latency(Setting::Centralized, topo).total().as_s(),
            1e-6,
        );
        let dec = simulate_fabric(&m, Scenario::DecentralizedMesh, topo, &cfg).unwrap();
        assert_close(
            dec.completion.as_s(),
            m.latency(Setting::Decentralized, topo).total().as_s(),
            1e-6,
        );
    });
}

/// netmodel consumes the fabric through the `CommFabric` trait: the
/// analytic fabric and the uncongested packet fabric are interchangeable.
#[test]
fn commfabric_entry_point_cross_validates() {
    let m = model();
    let topo = Topology { nodes: 500, cluster_size: 10 };
    let sim_fabric = NetSim::default();
    for setting in [Setting::Centralized, Setting::Decentralized] {
        let analytic = m.latency_via(&AnalyticFabric, setting, topo).unwrap();
        let simulated = m.latency_via(&sim_fabric, setting, topo).unwrap();
        assert_close(simulated.communicate.as_s(), analytic.communicate.as_s(), 1e-9);
        assert_close(simulated.total().as_s(), analytic.total().as_s(), 1e-9);
    }
}

/// The packet fabric and the aggregate DES (`sim`) agree wherever their
/// assumptions overlap (uncongested, no jitter).
#[test]
fn packet_fabric_agrees_with_the_aggregate_des() {
    let m = model();
    let topo = Topology { nodes: 400, cluster_size: 8 };
    for (setting, scenario) in [
        (Setting::Centralized, Scenario::CentralizedStar),
        (Setting::Decentralized, Scenario::DecentralizedMesh),
    ] {
        let des = simulate(&m, setting, topo, &SimConfig::default()).unwrap();
        let fab = simulate_fabric(&m, scenario, topo, &NetSimConfig::default()).unwrap();
        assert_close(fab.completion.as_s(), des.completion.as_s(), 1e-6);
        assert_close(fab.comm_done.as_s(), des.comm_done.as_s(), 1e-6);
    }
}

/// Contention strictly degrades, and removing it recovers the equations:
/// the analytic model is the limit of the fabric as capacity → ∞.
#[test]
fn capacity_limits_degrade_monotonically_toward_the_analytic_limit() {
    let m = model();
    let topo = Topology { nodes: 300, cluster_size: 10 };
    let analytic = m.communicate_latency(Setting::Centralized, topo);
    let mut last = None;
    for ports in [1usize, 4, 16, 64] {
        let cfg = NetSimConfig { rx_ports: Some(ports), ..Default::default() };
        let r = simulate_fabric(&m, Scenario::CentralizedStar, topo, &cfg).unwrap();
        assert!(
            r.comm_done.as_s() >= analytic.as_s() - 1e-12,
            "ports={ports}: simulated beat the analytic lower bound"
        );
        if let Some(prev) = last {
            assert!(r.comm_done <= prev, "more ports must not slow the gather");
        }
        last = Some(r.comm_done);
    }
    // Enough ports for the whole fleet = the analytic assumption.
    let cfg = NetSimConfig { rx_ports: Some(topo.nodes), ..Default::default() };
    let r = simulate_fabric(&m, Scenario::CentralizedStar, topo, &cfg).unwrap();
    assert_close(r.comm_done.as_s(), analytic.as_s(), 1e-9);
}

/// Determinism (the satellite invariant): identical config + seed ⇒
/// bit-identical reports, across all three fabrics, jitter on.
#[test]
fn fabric_runs_are_bit_identical_per_seed() {
    let m = model();
    let topo = Topology { nodes: 150, cluster_size: 6 };
    for seed in [1u64, 7, 42] {
        let cfg = NetSimConfig {
            rx_ports: Some(8),
            cluster_channels: Some(2),
            link_jitter: 0.2,
            seed,
            ..Default::default()
        };
        for sc in [
            Scenario::CentralizedStar,
            Scenario::DecentralizedMesh,
            Scenario::SemiOverlay { head_capacity: 6.0 },
        ] {
            let a = simulate_fabric(&m, sc, topo, &cfg).unwrap();
            let b = simulate_fabric(&m, sc, topo, &cfg).unwrap();
            assert_eq!(a, b, "seed {seed}, {sc:?}");
        }
    }
}
