//! Integration: the AOT artifacts load, execute, and agree with the rust
//! functional crossbar model (L1 Pallas ↔ L3 rust cross-validation).
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use std::path::PathBuf;

use ima_gnn::config::{CrossbarGeometry, DeviceParams};
use ima_gnn::crossbar::MvmCrossbar;
use ima_gnn::runtime::{ArtifactStore, DType, Tensor};
use ima_gnn::testing::Rng;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Shared skip guard (`testing::pjrt_artifacts_ready`): returns false with
/// a printed reason when the PJRT backend or the AOT artifacts are absent.
fn pjrt_ready() -> bool {
    ima_gnn::testing::pjrt_artifacts_ready(&artifact_dir())
}

fn store() -> ArtifactStore {
    ArtifactStore::open(&artifact_dir()).expect("run `make artifacts` before `cargo test`")
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let names: Vec<&str> = s.manifest().artifacts().iter().map(|a| a.name.as_str()).collect();
    for required in
        ["gcn_layer_small", "gcn2_cora", "gcn2_cora_exact", "gcn_layer_citeseer", "hetgnn_taxi", "mvm_512x512"]
    {
        assert!(names.contains(&required), "missing artifact {required}");
    }
    assert_eq!(s.platform().to_lowercase().contains("cpu"), true);
}

#[test]
fn gcn_layer_small_executes_with_correct_shapes() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let mut rng = Rng::new(5);
    let spec = s.manifest().get("gcn_layer_small").unwrap().clone();
    assert_eq!(spec.inputs.len(), 4);
    let mk = |spec_idx: usize| -> Tensor {
        let t = &spec.inputs[spec_idx];
        match t.dtype {
            DType::F32 => Tensor::f32(
                &t.shape,
                (0..t.num_elements()).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect(),
            )
            .unwrap(),
            DType::I32 => Tensor::i32(
                &t.shape,
                // neighbor indices into the 64-row table, some padding
                (0..t.num_elements())
                    .map(|_| if rng.chance(0.2) { -1 } else { rng.index(64) as i32 })
                    .collect(),
            )
            .unwrap(),
        }
    };
    let inputs: Vec<Tensor> = (0..4).map(mk).collect();
    let out = s.run("gcn_layer_small", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![16, 32]);
    let vals = out[0].as_f32().unwrap();
    assert!(vals.iter().all(|v| v.is_finite()));
    // the layer ends in ReLU
    assert!(vals.iter().all(|&v| v >= 0.0));
    // and is not trivially zero
    assert!(vals.iter().any(|&v| v > 0.0));
}

#[test]
fn executor_rejects_wrong_inputs() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let exe = s.load("gcn_layer_small").unwrap();
    // wrong arity
    assert!(exe.execute(&[]).is_err());
    // wrong shape
    let bad = vec![
        Tensor::f32(&[2, 2], vec![0.0; 4]).unwrap(),
        Tensor::i32(&[16, 4], vec![0; 64]).unwrap(),
        Tensor::f32(&[64, 64], vec![0.0; 4096]).unwrap(),
        Tensor::f32(&[64, 32], vec![0.0; 2048]).unwrap(),
    ];
    assert!(exe.execute(&bad).is_err());
}

/// The heart of the three-layer claim: the Pallas bit-serial crossbar MVM
/// (AOT-compiled, executed through PJRT) must agree **bit-exactly** with
/// the rust `MvmCrossbar` functional model.
#[test]
fn pallas_mvm_artifact_matches_rust_crossbar_model() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let mut rng = Rng::new(99);
    let (batch, rows, cols) = (8usize, 512usize, 512usize);
    let xq: Vec<i32> = (0..batch * rows).map(|_| rng.u64_in(0, 255) as i32).collect();
    let gq: Vec<i32> = (0..rows * cols).map(|_| rng.i64_in(-8, 7) as i32).collect();

    let out = s
        .run(
            "mvm_512x512",
            &[
                Tensor::i32(&[batch, rows], xq.clone()).unwrap(),
                Tensor::i32(&[rows, cols], gq.clone()).unwrap(),
            ],
        )
        .unwrap();
    let pallas = out[0].as_i32().unwrap();

    // rust functional model, same geometry as the kernel default.
    let geo = CrossbarGeometry::new(rows, cols);
    let mut xbar = MvmCrossbar::new(geo, DeviceParams::default_45nm()).unwrap();
    xbar.program(&gq).unwrap();
    for b in 0..batch {
        let input: Vec<u32> = xq[b * rows..(b + 1) * rows].iter().map(|&x| x as u32).collect();
        let want = xbar.evaluate(&input).unwrap();
        for c in 0..cols {
            assert_eq!(
                pallas[b * cols + c] as i64,
                want[c],
                "mismatch at batch {b} col {c}"
            );
        }
    }
}

#[test]
fn hetgnn_taxi_artifact_runs() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let spec = s.manifest().get("hetgnn_taxi").unwrap().clone();
    let mut rng = Rng::new(3);
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|t| match t.dtype {
            DType::F32 => Tensor::f32(
                &t.shape,
                (0..t.num_elements()).map(|_| rng.f64_in(-0.5, 0.5) as f32).collect(),
            )
            .unwrap(),
            DType::I32 => Tensor::i32(
                &t.shape,
                (0..t.num_elements()).map(|_| rng.index(256) as i32).collect(),
            )
            .unwrap(),
        })
        .collect();
    let out = s.run("hetgnn_taxi", &inputs).unwrap();
    // [B=32, Q=3, Fin=128]
    assert_eq!(out[0].shape, vec![32, 3, 128]);
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn missing_artifact_and_missing_dir_fail_cleanly() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let e = s.load("not_a_model").unwrap_err().to_string();
    assert!(e.contains("not_a_model") && e.contains("gcn2_cora"), "{e}");
    let bad = ArtifactStore::open(std::path::Path::new("/nonexistent/dir"));
    let msg = bad.err().unwrap().to_string();
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn deterministic_across_executions() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let mut rng = Rng::new(12);
    let spec = s.manifest().get("gcn_layer_small").unwrap().clone();
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|t| match t.dtype {
            DType::F32 => Tensor::f32(
                &t.shape,
                (0..t.num_elements()).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect(),
            )
            .unwrap(),
            DType::I32 => Tensor::i32(
                &t.shape,
                (0..t.num_elements()).map(|_| rng.index(64) as i32).collect(),
            )
            .unwrap(),
        })
        .collect();
    let a = s.run("gcn_layer_small", &inputs).unwrap();
    let b = s.run("gcn_layer_small", &inputs).unwrap();
    assert_eq!(a, b, "PJRT execution must be deterministic");
}

#[test]
fn executables_are_cached() {
    if !pjrt_ready() {
        return;
    }
    let s = store();
    let a = s.load("gcn_layer_small").unwrap();
    let b = s.load("gcn_layer_small").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b), "second load must hit the cache");
}
