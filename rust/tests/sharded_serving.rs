//! Integration: table-sharded serving through the shared round engine.
//!
//! The assembly-level tests run everywhere (no PJRT needed): they compare
//! the engine's assembled artifact inputs bit-for-bit against the
//! unsharded seed pipeline (single shard) and against a hand-split
//! per-shard reference (multi shard).  The `pjrt_*` tests additionally
//! execute the batches and compare served outputs; they skip with a
//! printed reason when the backend or the AOT artifacts are absent.

use std::path::PathBuf;
use std::time::Duration;

use ima_gnn::coordinator::{
    CentralizedLeader, GcnLayerBinding, InferenceService, Request, SemiCoordinator,
};
use ima_gnn::cores::{FeatureMatrix, GnnWorkload};
use ima_gnn::graph::{fixed_size, generate, NeighborSampler, ShardPlan};
use ima_gnn::runtime::Tensor;
use ima_gnn::testing::{forall, gcn_layer_binding, Rng};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn pjrt_ready() -> bool {
    ima_gnn::testing::pjrt_artifacts_ready(&artifact_dir())
}

/// Deterministic per-node features for an `n × feature` graph.
fn feature_rows(n: usize, feature: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..feature).map(|_| rng.f64_in(0.0, 1.0) as f32).collect())
        .collect()
}

/// Hand-computed local slot of `node` inside `shard` — a linear search
/// over members + halo, independent of the plan's precomputed rows.
fn local_slot(plan: &ShardPlan, shard: usize, node: usize) -> i32 {
    let sh = &plan.shards()[shard];
    if let Some(p) = sh.members.iter().position(|&m| m == node) {
        return p as i32;
    }
    let h = sh
        .halo
        .iter()
        .position(|&m| m == node)
        .expect("every sampled neighbor must be resident in-shard");
    (sh.members.len() + h) as i32
}

/// Hand-built per-shard feature table: occupied slots carry their node's
/// features, the tail rows stay zero.
fn reference_table(
    plan: &ShardPlan,
    shard: usize,
    rows: &[Vec<f32>],
    table: usize,
    feature: usize,
) -> Vec<f32> {
    let sh = &plan.shards()[shard];
    let mut t = vec![0.0f32; table * feature];
    for slot in 0..sh.slots() {
        let node = sh.local_node(slot);
        t[slot * feature..(slot + 1) * feature].copy_from_slice(&rows[node]);
    }
    t
}

/// Acceptance: a graph wider than the artifact table constructs through
/// both deployments — the seed's "shard the graph" rejection is gone —
/// and the resulting plans satisfy the coverage/halo invariants.
#[test]
fn oversized_graphs_construct_in_both_deployments() {
    let b = gcn_layer_binding();
    let graph = generate::regular(256, 6, 3).unwrap();
    let weights = vec![0.02f32; b.feature * b.hidden];

    let leader = CentralizedLeader::new(
        b.clone(),
        graph.clone(),
        weights.clone(),
        &GnnWorkload::gcn("shard", 64, 6),
        Duration::ZERO,
    )
    .unwrap();
    let plan = leader.engine().plan();
    assert!(plan.num_shards() > 1, "256 nodes must shard over a 64-row table");
    assert!(plan.max_slots() <= b.table);

    let semi = SemiCoordinator::new(
        b.clone(),
        graph.clone(),
        fixed_size(256, 8).unwrap(),
        weights,
        &GnnWorkload::gcn("shard", 64, 8),
    )
    .unwrap();
    assert!(semi.engine().plan().num_shards() > 1);

    // Coverage: every node is a member of exactly one shard, and halos
    // are exactly the out-of-shard sampled neighbors (recomputed with an
    // independent sampler instance).
    let sampler = NeighborSampler::new(b.sample, 7);
    for plan in [leader.engine().plan(), semi.engine().plan()] {
        let mut seen = vec![0usize; 256];
        for shard in plan.shards() {
            for &m in &shard.members {
                seen[m] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "membership multiplicity: {seen:?}");
        for (si, shard) in plan.shards().iter().enumerate() {
            let mut expect: Vec<usize> = shard
                .members
                .iter()
                .flat_map(|&v| sampler.sample(&graph, v))
                .flatten()
                .filter(|&nb| plan.home(nb).0 != si)
                .collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(shard.halo, expect, "shard {si} halo mismatch");
        }
    }
}

/// On a single-shard graph the engine's assembled inputs are bit-identical
/// to the unsharded seed pipeline: global-id gather, global-id neighbor
/// sampling, last-node batch padding, full-table tensor.
#[test]
fn single_shard_assembly_is_bit_identical_to_the_seed_path() {
    let b = gcn_layer_binding();
    let graph = generate::regular(48, 6, 3).unwrap();
    let rows = feature_rows(48, b.feature, 2);
    let mut leader = CentralizedLeader::new(
        b.clone(),
        graph.clone(),
        vec![0.01; b.feature * b.hidden],
        &GnnWorkload::gcn("seed", 64, 6),
        Duration::ZERO,
    )
    .unwrap();
    assert!(leader.engine().plan().is_single_shard());
    for (node, f) in rows.iter().enumerate() {
        leader.upload(node, f).unwrap();
    }
    leader.end_round();

    let nodes: Vec<usize> = vec![9, 0, 31, 17, 17, 4];
    let got = leader.engine().assemble(&nodes).unwrap();
    assert_eq!(got.len(), 1);
    let sb = &got[0];

    // The seed path, reconstructed from first principles.
    let mut padded = nodes.clone();
    padded.resize(b.batch, *nodes.last().unwrap());
    let want_x: Vec<f32> = padded.iter().flat_map(|&v| rows[v].iter().copied()).collect();
    let sampler = NeighborSampler::new(b.sample, 7);
    assert_eq!(sb.x_self, want_x, "x_self diverged from the seed gather");
    assert_eq!(sb.nbr_idx, sampler.sample_batch(&graph, &padded), "nbr_idx diverged");

    let mut want_table = vec![0.0f32; b.table * b.feature];
    for (v, r) in rows.iter().enumerate() {
        want_table[v * b.feature..(v + 1) * b.feature].copy_from_slice(r);
    }
    let table = leader.engine().table_tensor(0).unwrap().as_f32().unwrap();
    assert_eq!(table, &want_table[..], "table tensor diverged from the seed gather");
}

/// Multi-shard assembly equals a hand-split per-shard reference: requests
/// group by home shard, x_self gathers home rows, neighbor indices remap
/// to hand-searched local slots, and each shard's table tensor replicates
/// members + halo rows exactly.
#[test]
fn sharded_assembly_matches_a_hand_split_reference() {
    let b = gcn_layer_binding();
    let graph = generate::regular(256, 6, 3).unwrap();
    let rows = feature_rows(256, b.feature, 5);
    let mut leader = CentralizedLeader::new(
        b.clone(),
        graph.clone(),
        vec![0.01; b.feature * b.hidden],
        &GnnWorkload::gcn("split", 64, 6),
        Duration::ZERO,
    )
    .unwrap();
    for (node, f) in rows.iter().enumerate() {
        leader.upload(node, f).unwrap();
    }
    leader.end_round();
    let plan = leader.engine().plan().clone();
    let sampler = NeighborSampler::new(b.sample, 7);

    // Requests spread over every shard, deliberately interleaved.
    let nodes: Vec<usize> = (0..plan.num_shards())
        .flat_map(|s| plan.shards()[s].members.iter().copied().take(3))
        .rev()
        .collect();
    let batches = leader.engine().assemble(&nodes).unwrap();
    assert_eq!(batches.len(), plan.num_shards(), "three requests per shard, one batch each");

    let mut answered = vec![false; nodes.len()];
    for sb in &batches {
        // Every node in the batch lives in the batch's shard.
        for (&v, &pos) in sb.nodes.iter().zip(&sb.positions) {
            assert_eq!(nodes[pos], v);
            assert_eq!(plan.home(v).0, sb.shard);
            answered[pos] = true;
        }
        // Hand-split reference for this shard.
        let mut padded = sb.nodes.clone();
        padded.resize(b.batch, *sb.nodes.last().unwrap());
        let want_x: Vec<f32> =
            padded.iter().flat_map(|&v| rows[v].iter().copied()).collect();
        assert_eq!(sb.x_self, want_x, "shard {} x_self", sb.shard);
        let mut want_nbr = Vec::with_capacity(b.batch * b.sample);
        for &v in &padded {
            for o in sampler.sample(&graph, v) {
                want_nbr.push(match o {
                    None => -1,
                    Some(g) => local_slot(&plan, sb.shard, g),
                });
            }
        }
        assert_eq!(sb.nbr_idx, want_nbr, "shard {} nbr_idx", sb.shard);
        let want_table = reference_table(&plan, sb.shard, &rows, b.table, b.feature);
        let table = leader.engine().table_tensor(sb.shard).unwrap().as_f32().unwrap();
        assert_eq!(table, &want_table[..], "shard {} table", sb.shard);
    }
    assert!(answered.iter().all(|&a| a), "every request answered exactly once");
}

/// Double-buffer semantics survive the per-shard split end to end: staged
/// uploads are invisible until the barrier, then home slots and every
/// halo replica flip together, and the round version advances once.
#[test]
fn upload_visibility_and_versioning_survive_sharding() {
    let b = gcn_layer_binding();
    let graph = generate::regular(256, 6, 3).unwrap();
    let mut leader = CentralizedLeader::new(
        b.clone(),
        graph,
        vec![0.01; b.feature * b.hidden],
        &GnnWorkload::gcn("vers", 64, 6),
        Duration::ZERO,
    )
    .unwrap();
    leader.end_round(); // round 1: all zeros
    assert_eq!(leader.engine().version(), 1);

    // Pick a node that is halo-replicated somewhere.
    let plan = leader.engine().plan().clone();
    let node = (0..256)
        .find(|&v| !plan.halo_sites(v).is_empty())
        .expect("a 6-regular graph sharded 4+ ways must have halos");
    leader.upload(node, &vec![7.5; b.feature]).unwrap();
    // Staged: neither the home row nor any replica is visible yet.
    assert_eq!(leader.engine().read(node).unwrap()[0], 0.0);
    for &(hs, slot) in plan.halo_sites(node) {
        let t = leader.engine().table_tensor(hs).unwrap().as_f32().unwrap();
        assert_eq!(t[slot * b.feature], 0.0);
    }
    leader.end_round();
    assert_eq!(leader.engine().version(), 2);
    assert_eq!(leader.engine().read(node).unwrap()[0], 7.5);
    for &(hs, slot) in plan.halo_sites(node) {
        let t = leader.engine().table_tensor(hs).unwrap().as_f32().unwrap();
        assert_eq!(t[slot * b.feature], 7.5, "halo replica out of sync after barrier");
    }
}

/// Property: for arbitrary graphs, assembling a full round through the
/// engine answers every node exactly once, within table-sized shards.
#[test]
fn property_full_round_assembly_covers_every_node_once() {
    let b = gcn_layer_binding();
    forall(12, |rng: &mut Rng| {
        let n = rng.index(300) + 1;
        let g = generate::uniform(n.max(2), n * 3, rng.next_u64()).unwrap();
        let n = g.num_nodes();
        let mut leader = CentralizedLeader::new(
            b.clone(),
            g,
            vec![0.01; b.feature * b.hidden],
            &GnnWorkload::gcn("prop", 64, 4),
            Duration::ZERO,
        )
        .unwrap();
        leader.end_round();
        let all: Vec<usize> = (0..n).collect();
        let batches = leader.engine().assemble(&all).unwrap();
        let mut seen = vec![0usize; n];
        for sb in &batches {
            assert!(sb.nodes.len() <= b.batch);
            assert_eq!(sb.x_self.len(), b.batch * b.feature);
            assert_eq!(sb.nbr_idx.len(), b.batch * b.sample);
            let slots = leader.engine().plan().shards()[sb.shard].slots();
            for &ix in &sb.nbr_idx {
                assert!(ix == -1 || (ix as usize) < slots, "sampled index escapes shard");
            }
            for &v in &sb.nodes {
                seen[v] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "coverage: {seen:?}");
    });
}

// ---------------------------------------------------------------------
// PJRT execution tests (skip with a reason when the backend is absent).
// ---------------------------------------------------------------------

fn service() -> InferenceService {
    InferenceService::start(artifact_dir()).expect("run `make artifacts` first")
}

fn real_binding() -> GcnLayerBinding {
    let manifest = ima_gnn::runtime::Manifest::load(&artifact_dir()).unwrap();
    GcnLayerBinding::from_spec(manifest.get("gcn_layer_small").unwrap()).unwrap()
}

/// Execute one hand-built batch directly against the artifact.
fn infer_reference(
    svc: &InferenceService,
    b: &GcnLayerBinding,
    x_self: Vec<f32>,
    nbr_idx: Vec<i32>,
    table: Vec<f32>,
    weights: &[f32],
) -> Vec<f32> {
    let inputs = vec![
        Tensor::f32(&[b.batch, b.feature], x_self).unwrap(),
        Tensor::i32(&[b.batch, b.sample], nbr_idx).unwrap(),
        Tensor::f32(&[b.table, b.feature], table).unwrap(),
        Tensor::f32(&[b.feature, b.hidden], weights.to_vec()).unwrap(),
    ];
    svc.infer(&b.artifact, inputs).unwrap()[0].as_f32().unwrap().to_vec()
}

/// A single-shard graph served through the refactored leader produces
/// outputs bit-identical to the seed pipeline executed by hand (gather →
/// global sampling → full table → PJRT → slice).
#[test]
fn pjrt_single_shard_serving_matches_the_hand_built_seed_pipeline() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let b = real_binding();
    let n = b.table.min(48);
    let graph = generate::regular(n, 6.min(n - 1), 3).unwrap();
    let rows = feature_rows(n, b.feature, 21);
    let mut rng = Rng::new(22);
    let weights: Vec<f32> =
        (0..b.feature * b.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let mut leader = CentralizedLeader::new(
        b.clone(),
        graph.clone(),
        weights.clone(),
        &GnnWorkload::gcn("pjrt-seed", b.feature, 6),
        Duration::from_millis(50),
    )
    .unwrap();
    assert!(leader.engine().plan().is_single_shard());
    for (node, f) in rows.iter().enumerate() {
        leader.upload(node, f).unwrap();
    }
    leader.end_round();

    let request_nodes: Vec<usize> = (0..b.batch).map(|i| (i * 3) % n).collect();
    let mut responses = Vec::new();
    for (id, &node) in request_nodes.iter().enumerate() {
        responses.extend(leader.submit(&svc, Request { id: id as u64, node }).unwrap());
    }
    assert_eq!(responses.len(), b.batch);

    // Seed pipeline by hand.
    let sampler = NeighborSampler::new(b.sample, 7);
    let x_self: Vec<f32> =
        request_nodes.iter().flat_map(|&v| rows[v].iter().copied()).collect();
    let mut table = vec![0.0f32; b.table * b.feature];
    for (v, r) in rows.iter().enumerate() {
        table[v * b.feature..(v + 1) * b.feature].copy_from_slice(r);
    }
    let flat = infer_reference(
        &svc,
        &b,
        x_self,
        sampler.sample_batch(&graph, &request_nodes),
        table,
        &weights,
    );
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(
            r.output,
            flat[i * b.hidden..(i + 1) * b.hidden].to_vec(),
            "response {i} diverged from the seed pipeline"
        );
    }
}

/// Acceptance: a graph with `num_nodes > binding.table` serves through
/// the sharded leader with outputs bit-identical to hand-split per-shard
/// PJRT executions.
#[test]
fn pjrt_sharded_leader_matches_hand_split_per_shard_inference() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let b = real_binding();
    let n = b.table * 4; // e.g. 256 nodes against the 64-row artifact
    let graph = generate::regular(n, 6, 3).unwrap();
    let rows = feature_rows(n, b.feature, 31);
    let mut rng = Rng::new(32);
    let weights: Vec<f32> =
        (0..b.feature * b.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let mut leader = CentralizedLeader::new(
        b.clone(),
        graph.clone(),
        weights.clone(),
        &GnnWorkload::gcn("pjrt-shard", b.feature, 6),
        Duration::from_millis(50),
    )
    .unwrap();
    let plan = leader.engine().plan().clone();
    assert!(plan.num_shards() > 1);
    for (node, f) in rows.iter().enumerate() {
        leader.upload(node, f).unwrap();
    }
    leader.end_round();

    // Half a batch from shard 0, half from the last shard, then drain.
    let last = plan.num_shards() - 1;
    let request_nodes: Vec<usize> = plan.shards()[0]
        .members
        .iter()
        .take(b.batch / 2)
        .chain(plan.shards()[last].members.iter().take(b.batch / 2))
        .copied()
        .collect();
    let mut responses = Vec::new();
    for (id, &node) in request_nodes.iter().enumerate() {
        responses.extend(leader.submit(&svc, Request { id: id as u64, node }).unwrap());
    }
    responses.extend(leader.drain(&svc).unwrap());
    assert_eq!(responses.len(), request_nodes.len());

    // Hand-split reference, one PJRT call per shard group.
    let sampler = NeighborSampler::new(b.sample, 7);
    let mut reference: Vec<Vec<f32>> = vec![Vec::new(); request_nodes.len()];
    for shard in [0, last] {
        let group: Vec<(usize, usize)> = request_nodes
            .iter()
            .enumerate()
            .filter(|&(_, &v)| plan.home(v).0 == shard)
            .map(|(i, &v)| (i, v))
            .collect();
        let mut padded: Vec<usize> = group.iter().map(|&(_, v)| v).collect();
        padded.resize(b.batch, group.last().unwrap().1);
        let x_self: Vec<f32> = padded.iter().flat_map(|&v| rows[v].iter().copied()).collect();
        let mut nbr = Vec::with_capacity(b.batch * b.sample);
        for &v in &padded {
            for o in sampler.sample(&graph, v) {
                nbr.push(match o {
                    None => -1,
                    Some(g) => local_slot(&plan, shard, g),
                });
            }
        }
        let table = reference_table(&plan, shard, &rows, b.table, b.feature);
        let flat = infer_reference(&svc, &b, x_self, nbr, table, &weights);
        for (k, &(pos, _)) in group.iter().enumerate() {
            reference[pos] = flat[k * b.hidden..(k + 1) * b.hidden].to_vec();
        }
    }
    for r in &responses {
        let pos = request_nodes.iter().position(|&v| v == r.node).unwrap();
        assert_eq!(r.output, reference[pos], "node {} diverged from hand split", r.node);
    }
}

/// Acceptance: the semi round on an oversized graph covers every node
/// exactly once and matches hand-split per-cluster PJRT executions.
#[test]
fn pjrt_sharded_semi_round_matches_hand_split_clusters() {
    if !pjrt_ready() {
        return;
    }
    let svc = service();
    let b = real_binding();
    let n = b.table * 4;
    let cs = 8;
    let graph = generate::regular(n, 6, 3).unwrap();
    let clustering = fixed_size(n, cs).unwrap();
    let rows = feature_rows(n, b.feature, 41);
    let mut rng = Rng::new(42);
    let weights: Vec<f32> =
        (0..b.feature * b.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let mut semi = SemiCoordinator::new(
        b.clone(),
        graph.clone(),
        clustering.clone(),
        weights.clone(),
        &GnnWorkload::gcn("pjrt-semi", b.feature, cs),
    )
    .unwrap();
    let plan = semi.engine().plan().clone();
    assert!(plan.num_shards() > 1);

    let features = FeatureMatrix::from_fn(n, b.feature, |r, c| rows[r][c]);
    let results = semi.round(&svc, &features).unwrap();
    assert_eq!(results.len(), n);
    let sampler = NeighborSampler::new(b.sample, 7);
    for (node, r) in results.iter().enumerate() {
        assert_eq!(r.node, node, "round must cover nodes in order");
        assert_eq!(r.head, clustering.assignment[node]);
        assert_eq!(r.output.len(), b.hidden);
    }
    // Hand-split reference for a few clusters (first, middle, last).
    let picks = [0, clustering.num_clusters() / 2, clustering.num_clusters() - 1];
    for &head in &picks {
        let members = &clustering.clusters[head];
        let shard = plan.home(members[0]).0;
        let mut padded = members.clone();
        padded.resize(b.batch, *members.last().unwrap());
        let x_self: Vec<f32> = padded.iter().flat_map(|&v| rows[v].iter().copied()).collect();
        let mut nbr = Vec::with_capacity(b.batch * b.sample);
        for &v in &padded {
            for o in sampler.sample(&graph, v) {
                nbr.push(match o {
                    None => -1,
                    Some(g) => local_slot(&plan, shard, g),
                });
            }
        }
        let table = reference_table(&plan, shard, &rows, b.table, b.feature);
        let flat = infer_reference(&svc, &b, x_self, nbr, table, &weights);
        for (k, &v) in members.iter().enumerate() {
            assert_eq!(
                results[v].output,
                flat[k * b.hidden..(k + 1) * b.hidden].to_vec(),
                "cluster {head} node {v} diverged"
            );
        }
    }
}
