//! Property tests for the E15 closed-loop runtime controller
//! (`controller::Controller` driven by `traffic::open_loop_controlled`):
//!
//! * hysteresis — the min-dwell contract holds under arrivals built to
//!   oscillate around the escalation threshold, so the controller never
//!   flaps;
//! * identity — a controller that can never fire leaves the run
//!   bit-identical to the plain `open_loop` path at its initial rung;
//! * reconciliation — `ctrl.switch` span durations sum *bit-exactly*
//!   to the reported `switch_downtime` (same f64 expression on both
//!   sides of the ledger).

use ima_gnn::autotune::{OperatingPoint, Partitioner};
use ima_gnn::controller::{Controller, CtrlConfig, Hysteresis};
use ima_gnn::coordinator::Arrival;
use ima_gnn::experiments::{control_cell, control_setup};
use ima_gnn::graph::datasets;
use ima_gnn::obs::Obs;
use ima_gnn::sim::FaultPlan;
use ima_gnn::testing::{forall, Rng};
use ima_gnn::traffic::{
    open_loop, open_loop_controlled, BatchPolicy, DeploymentQueues, ServiceModel, TrafficReport,
};
use ima_gnn::units::Time;

/// A synthetic ladder rung: `servers` parallel queues at
/// `per_req_ms`/request, switched into for `cost_ms`.
fn rung(servers: usize, per_req_ms: f64, cost_ms: f64) -> CtrlConfig {
    let (point, queues) = if servers == 1 {
        (OperatingPoint::centralized(), DeploymentQueues::Leader)
    } else {
        (
            OperatingPoint::semi(servers, 1.0, Partitioner::FixedSize),
            DeploymentQueues::ClusterHeads { clusters: servers },
        )
    };
    CtrlConfig {
        point,
        queues,
        service: ServiceModel::new(Time::ZERO, Time::ms(per_req_ms)).expect("valid service"),
        policy: BatchPolicy::Deadline { max: 8, max_wait: Time::ms(per_req_ms * 0.25) },
        switch_cost: Time::ms(cost_ms),
    }
}

/// Two-rung ladder: 1×1 ms/req (saturates at 1000 req/s) below
/// 4×0.5 ms/req (8000 req/s).
fn ladder(cost_ms: f64) -> Vec<CtrlConfig> {
    vec![rung(1, 1.0, cost_ms), rung(4, 0.5, cost_ms)]
}

fn hyst() -> Hysteresis {
    Hysteresis {
        window: Time::ms(100.0),
        dwell: Time::ms(400.0),
        p95_hi: Time::ms(5.0),
        depth_hi: 6.0,
        min_samples: 4,
        down_fraction: 0.7,
        util_hi: 0.5,
    }
}

/// 200 ms bursts at ~3000 req/s (3× the cheap rung's saturation)
/// alternating with 200 ms of silence — load that straddles the
/// escalation threshold every phase, the worst case for flapping.
fn oscillating(rng: &mut Rng, horizon_s: f64) -> Vec<Arrival> {
    let mut out = Vec::new();
    let mut t = 0.0;
    while t < horizon_s {
        let phase = (t / 0.2) as u64;
        if phase % 2 == 0 {
            out.push(Arrival { at: Time::s(t), node: rng.index(64) });
            t += rng.f64_in(0.8, 1.2) / 3000.0;
        } else {
            t = (phase + 1) as f64 * 0.2;
        }
    }
    out
}

/// Field-by-field bitwise comparison (TrafficReport holds f64s, so
/// `==` on the seconds' bit patterns is the strongest claim possible).
fn assert_reports_identical(a: &TrafficReport, b: &TrafficReport) {
    assert_eq!(a.servers, b.servers);
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.max_queue_depth, b.max_queue_depth);
    assert_eq!(a.fault_windows, b.fault_windows);
    let bits = [
        (a.makespan.as_s(), b.makespan.as_s()),
        (a.throughput_per_s, b.throughput_per_s),
        (a.utilization, b.utilization),
        (a.mean_wait.as_s(), b.mean_wait.as_s()),
        (a.latency.p50().as_s(), b.latency.p50().as_s()),
        (a.latency.p95().as_s(), b.latency.p95().as_s()),
        (a.latency.p99().as_s(), b.latency.p99().as_s()),
        (a.latency.mean().as_s(), b.latency.mean().as_s()),
        (a.mean_batch, b.mean_batch),
        (a.time_avg_in_system, b.time_avg_in_system),
        (a.sum_response.as_s(), b.sum_response.as_s()),
        (a.downtime.as_s(), b.downtime.as_s()),
        (a.availability, b.availability),
    ];
    for (i, (x, y)) in bits.iter().enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "field {i}: {x} != {y}");
    }
}

#[test]
fn min_dwell_is_respected_under_oscillating_arrivals() {
    forall(16, |rng| {
        let horizon_s = 4.0;
        let arrivals = oscillating(rng, horizon_s);
        let h = hyst();
        let controller = Controller::new(ladder(20.0), 0, h).expect("valid controller");
        let cr = open_loop_controlled(&controller, &arrivals, &FaultPlan::none(), &Obs::disabled())
            .expect("controlled run");
        // Dwell is measured from the end of the previous switch pause.
        for w in cr.switches.windows(2) {
            let earliest = w[0].at + w[0].cost + h.dwell;
            assert!(
                w[1].at.as_s() + 1e-12 >= earliest.as_s(),
                "flap: switch at {} before {}",
                w[1].at,
                earliest
            );
        }
        // No-flap corollary: the dwell bounds the total switch count
        // even though the load crosses the threshold every 200 ms.
        let max_switches = (horizon_s / h.dwell.as_s()).ceil() as usize + 1;
        assert!(
            cr.switches.len() <= max_switches,
            "{} switches exceed the dwell bound {max_switches}",
            cr.switches.len()
        );
    });
}

#[test]
fn never_firing_controller_is_bit_identical_to_open_loop() {
    forall(16, |rng| {
        let steps = 1 + rng.index(3);
        let mut arrivals = Vec::new();
        let mut t = 0.0;
        for _ in 0..steps * 400 {
            t += rng.f64_in(0.5, 1.5) / 600.0;
            arrivals.push(Arrival { at: Time::s(t), node: rng.index(64) });
        }
        let lad = ladder(0.0);
        let h = Hysteresis::never(Time::ms(100.0), Time::ms(400.0));
        let controller = Controller::new(lad.clone(), 0, h).expect("valid controller");
        let cr = open_loop_controlled(&controller, &arrivals, &FaultPlan::none(), &Obs::disabled())
            .expect("controlled run");
        assert!(cr.switches.is_empty(), "never-threshold controller fired");
        assert_eq!(cr.switch_downtime.as_s().to_bits(), 0f64.to_bits());
        assert_eq!(cr.switch_affected, 0);
        assert_eq!(cr.final_config, 0);
        let plain = open_loop(1, &lad[0].service, lad[0].policy, &arrivals).expect("plain run");
        assert_reports_identical(&plain, &cr.report);
    });
}

#[test]
fn switch_spans_reconcile_bit_exactly_with_downtime() {
    forall(8, |rng| {
        // Sustained 3× overload, then a quiet tail: at least one
        // escalation must fire, and a de-escalation usually follows.
        let mut arrivals = Vec::new();
        let mut t = 0.0;
        while t < 1.5 {
            arrivals.push(Arrival { at: Time::s(t), node: rng.index(64) });
            t += rng.f64_in(0.8, 1.2) / 3000.0;
        }
        while t < 3.5 {
            arrivals.push(Arrival { at: Time::s(t), node: rng.index(64) });
            t += rng.f64_in(0.8, 1.2) / 100.0;
        }
        let controller = Controller::new(ladder(25.0), 0, hyst()).expect("valid controller");
        let obs = Obs::new(4_096);
        let cr = open_loop_controlled(&controller, &arrivals, &FaultPlan::none(), &obs)
            .expect("controlled run");
        assert!(!cr.switches.is_empty(), "overload never escalated");
        assert_eq!(cr.report.dropped_spans, 0);
        let span_sum: Time = obs
            .tracer
            .spans()
            .iter()
            .filter(|s| s.name == "ctrl.switch")
            .map(|s| s.end - s.start)
            .sum();
        assert_eq!(
            span_sum.as_s().to_bits(),
            cr.switch_downtime.as_s().to_bits(),
            "span sum {span_sum} != ledger {}",
            cr.switch_downtime
        );
        assert_eq!(obs.metrics.counter_value("ctrl.switches"), cr.switches.len() as u64);
        let ledger: Time = cr.switches.iter().map(|w| w.cost).sum();
        let rel = ((ledger - cr.switch_downtime).as_s() / cr.switch_downtime.as_s()).abs();
        assert!(rel < 1e-12, "per-switch costs drift from the ledger by {rel:.3e}");
    });
}

#[test]
fn e15_cell_composes_with_link_degrade_faults() {
    // Use whichever Table 2 dataset builds the deepest capacity ladder
    // at this sample cap — the most interesting cell to exercise.
    let (d, setup) = datasets::all()
        .into_iter()
        .map(|d| {
            let s = control_setup(&d, 120).expect("control setup");
            (d, s)
        })
        .max_by_key(|(_, s)| s.ladder.len())
        .expect("at least one dataset");
    assert!(setup.slo.as_s() > 0.0);
    let cell = control_cell(&setup, "linkfault", d.nodes, 300, 7).expect("cell");
    assert!(!cell.plan.is_empty(), "linkfault cell carries no fault plan");
    let cr = open_loop_controlled(&cell.controller, &cell.arrivals, &cell.plan, &Obs::disabled())
        .expect("controlled run");
    assert_eq!(cr.report.offered, cell.arrivals.len());
    assert!(cr.report.littles_law_gap() < 1e-9, "Little's law broke under control + faults");
    for w in cr.switches.windows(2) {
        assert!(w[1].at.as_s() + 1e-12 >= (w[0].at + w[0].cost + cell.dwell).as_s());
    }
}
