//! Differential fuzz harness for the crossbar / core kernel fast paths
//! (E10): random geometries, weights, inputs and activation masks driven
//! through the seed bit-serial reference, the dispatched `evaluate`
//! paths (binary single-plane, clip-free fused, clipping fallback), the
//! dense/sparse `accumulate_rows` lanes, and the two cores that ride
//! them — with bit-identity asserted everywhere.  This is the external
//! (public-API) counterpart of the property tests inside `crossbar::mvm`:
//! it can only use what the crate exports, so it also pins that the lane
//! kernels are reachable and exact through the cores' public surface.

use ima_gnn::config::{CoreConfig, CrossbarGeometry, DeviceParams};
use ima_gnn::cores::{AggregationCore, FeatureExtractionCore, Tile};
use ima_gnn::crossbar::{MvmCrossbar, DENSE_WORD_THRESHOLD};
use ima_gnn::testing::{forall, Rng};

/// Random crossbar with random bit-widths; weights span the full
/// conductance range so clipping and clip-free regimes both arise.
fn random_xbar(rng: &mut Rng, max_rows: usize, max_cols: usize) -> MvmCrossbar {
    let rows = rng.index(max_rows) + 1;
    let cols = rng.index(max_cols) + 1;
    let mut g = CrossbarGeometry::new(rows, cols);
    g.cell_bits = rng.u64_in(2, 5) as u32;
    g.adc_bits = rng.u64_in(3, 16) as u32;
    g.input_bits = rng.u64_in(1, 8) as u32;
    let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
    let (lo, hi) = xb.weight_range();
    let weights: Vec<i32> =
        (0..rows * cols).map(|_| rng.i64_in(lo as i64, hi as i64) as i32).collect();
    xb.program(&weights).unwrap();
    xb
}

/// Every `evaluate` dispatch (binary single-plane, clip-free fused,
/// clipping bit-serial fallback) and the buffer-reusing `evaluate_into`
/// agree with `evaluate_reference` bit for bit, across input regimes
/// chosen to hit each dispatch arm.
#[test]
fn evaluate_dispatch_is_bit_identical_to_the_reference() {
    forall(32, |rng: &mut Rng| {
        let xb = random_xbar(rng, 160, 48);
        let g = *xb.geometry();
        let max_code = (1u64 << g.input_bits) - 1; // input_bits ≤ 8 here
        // Three regimes: binary (single-plane path), general multi-bit,
        // and sparse multi-bit (mostly-zero rows, the fused path's skip).
        let regime = rng.index(3);
        let input: Vec<u32> = (0..g.rows)
            .map(|_| match regime {
                0 => rng.u64_in(0, 1) as u32,
                1 => rng.u64_in(0, max_code) as u32,
                _ => {
                    if rng.index(4) == 0 {
                        rng.u64_in(0, max_code) as u32
                    } else {
                        0
                    }
                }
            })
            .collect();
        let want = xb.evaluate_reference(&input).unwrap();
        assert_eq!(
            xb.evaluate(&input).unwrap(),
            want,
            "{}x{} cell={} adc={} in={} regime={regime} clip_free={}",
            g.rows,
            g.cols,
            g.cell_bits,
            g.adc_bits,
            g.input_bits,
            xb.clip_free()
        );
        // Into a dirty reused buffer: stale contents must not leak.
        let mut out = vec![i64::MIN; g.cols];
        xb.evaluate_into(&input, &mut out).unwrap();
        assert_eq!(out, want);
    });
}

/// `accumulate_rows` agrees with the reference on masks engineered to
/// sit on, above and below `DENSE_WORD_THRESHOLD` — the dispatch
/// boundary between the sparse bit-walk and the dense word-slab lanes —
/// including empty words, full words, and ragged tail words.
#[test]
fn accumulate_rows_density_sweep_is_bit_identical() {
    forall(32, |rng: &mut Rng| {
        let xb = random_xbar(rng, 200, 40);
        let g = *xb.geometry();
        let t = DENSE_WORD_THRESHOLD as u64;
        let mut mask = vec![0u64; g.rows.div_ceil(64)];
        for (w, word) in mask.iter_mut().enumerate() {
            let slab = (g.rows - w * 64).min(64) as u64;
            // Density classes: empty, full, and popcounts right at the
            // dispatch boundary (t-1 / t / t+1, clipped to the slab).
            let ones = match rng.index(5) {
                0 => 0,
                1 => slab,
                2 => (t - 1).min(slab),
                3 => t.min(slab),
                _ => (t + 1).min(slab),
            };
            let mut bits = 0u64;
            let mut set = 0;
            while set < ones {
                let b = rng.index(slab as usize) as u64;
                if bits >> b & 1 == 0 {
                    bits |= 1 << b;
                    set += 1;
                }
            }
            *word = bits;
        }
        // The reference path: the same selection as explicit binary codes.
        let input: Vec<u32> =
            (0..g.rows).map(|r| (mask[r / 64] >> (r % 64) & 1) as u32).collect();
        let want = xb.evaluate_reference(&input).unwrap();
        let mut out = vec![0i64; g.cols];
        xb.accumulate_rows(&mask, &mut out).unwrap();
        assert_eq!(out, want, "{}x{} adc={} mask={mask:?}", g.rows, g.cols, g.adc_bits);
        // Column-group prefix (narrower `out`) on the same mask.
        let k = rng.index(g.cols) + 1;
        let mut head = vec![0i64; k];
        xb.accumulate_rows(&mask, &mut head).unwrap();
        assert_eq!(head, want[..k]);
    });
}

/// The cores ride the same lane kernels through their public surface:
/// `AggregationCore::accumulate_into` equals the scalar masked row-sum
/// (single binary plane, clamped once to the ADC range) and
/// `FeatureExtractionCore::transform` equals `relu(x @ W)` — for window
/// and input shapes where the fused paths are provably exact.
#[test]
fn cores_match_their_scalar_oracles_under_fuzz() {
    forall(24, |rng: &mut Rng| {
        // Aggregation: 256×32 default geometry (adc_bits 13) — any row
        // subset sums to at most 256·8 < 2^12, so the final clamp is the
        // identity and the oracle is the plain masked sum.  Mostly-true
        // activations push whole words over DENSE_WORD_THRESHOLD.
        let n = rng.index(256) + 1;
        let f = rng.index(24) + 1;
        let window = Tile::from_fn(n, f, |_, _| rng.i64_in(-8, 7) as i32);
        let dense = rng.bool();
        let active: Vec<bool> =
            (0..n).map(|_| if dense { rng.index(8) != 0 } else { rng.bool() }).collect();
        let mut agg =
            AggregationCore::new(CoreConfig::new(1, 256, 32), DeviceParams::default_45nm())
                .unwrap();
        let got = agg.aggregate(&window, &active).unwrap();
        for col in 0..f {
            let want: i64 = (0..n).filter(|&r| active[r]).map(|r| window.get(r, col) as i64).sum();
            assert_eq!(got[col], want, "agg col {col} (dense={dense})");
        }

        // Feature extraction: 128×32 geometry stays clip-free for any
        // 4-bit weights (|plane sum| ≤ 128·8 < 2^12), so the fused path
        // is an exact integer matmul and the oracle is relu(x @ W).
        let fin = rng.index(32) + 1;
        let fout = rng.index(16) + 1;
        let weights: Vec<i32> = (0..fin * fout).map(|_| rng.i64_in(-8, 7) as i32).collect();
        let input: Vec<u32> = (0..fin).map(|_| rng.u64_in(0, 255) as u32).collect();
        let mut fe =
            FeatureExtractionCore::new(CoreConfig::new(1, 128, 32), DeviceParams::default_45nm())
                .unwrap();
        fe.program_weights(&weights, fin, fout).unwrap();
        let got = fe.transform(&input, fout).unwrap();
        for o in 0..fout {
            let raw: i64 =
                (0..fin).map(|i| input[i] as i64 * weights[i * fout + o] as i64).sum();
            assert_eq!(got[o], raw.max(0), "fe col {o}");
        }
    });
}
