//! Integration: the analytical network model, the discrete-event simulator
//! and the functional dataflow must tell one consistent story.

use ima_gnn::config::presets;
use ima_gnn::cores::{Accelerator, GnnWorkload, Tile};
use ima_gnn::graph::{datasets, generate, Csr};
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::sim::{simulate, SimConfig};
use ima_gnn::testing::{assert_close, forall, Rng};

/// The DES and the closed-form model agree over random topologies
/// (jitter and contention off) — not just at the paper's operating point.
#[test]
fn property_sim_equals_model_over_random_topologies() {
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    forall(10, |rng: &mut Rng| {
        let topo = Topology {
            nodes: rng.index(400) + 2,
            cluster_size: rng.index(20) + 1,
        };
        for setting in [Setting::Centralized, Setting::Decentralized] {
            let r = simulate(&model, setting, topo, &SimConfig::default()).unwrap();
            let analytic = model.latency(setting, topo).total();
            assert_close(r.completion.as_s(), analytic.as_s(), 1e-6);
        }
    });
}

/// Functional dataflow (Fig. 3): CAM traversal feeds the scheduler whose
/// activation vectors drive the aggregation crossbar — the result equals a
/// direct sparse-matrix product against the adjacency.
#[test]
fn traversal_scheduler_aggregation_dataflow_is_exact() {
    let mut rng = Rng::new(42);
    let n = 60;
    let g = generate::regular(n, 5, 7).unwrap();
    let cfg = presets::decentralized();
    let mut acc = Accelerator::new(cfg).unwrap();
    acc.traversal.load_graph(&g).unwrap();
    let scheduler = acc.scheduler();

    // Node features: one row per node, 8 feature cells — one flat tile
    // shared by every destination (the node-stationary window; the
    // aggregation core programs it once and reuses it across the sweep).
    let feats = Tile::from_fn(n, 8, |_, _| rng.i64_in(-8, 7) as i32);

    for dst in 0..n {
        // Traversal core → incoming sources.
        let sources = acc.traversal.incoming(dst).unwrap();
        // Scheduler → activation vectors (single window here: n < 512).
        let av = scheduler.activation_vectors(&sources);
        let mut total = vec![0i64; 8];
        for (win, active) in av {
            assert_eq!(win, 0, "n=60 fits one window");
            let active = active[..n].to_vec();
            let sums = acc.aggregation.aggregate(&feats, &active).unwrap();
            for c in 0..8 {
                total[c] += sums[c];
            }
        }
        // Oracle: direct sum over the reverse adjacency.
        let mut want = vec![0i64; 8];
        for src in 0..n {
            if g.neighbors(src).contains(&dst) {
                for c in 0..8 {
                    want[c] += feats.get(src, c) as i64;
                }
            }
        }
        assert_eq!(total, want, "dst={dst}");
    }
    // The whole sweep shared one stationary window: exactly one program.
    assert_eq!(acc.aggregation.programs(), 1, "program-once cache missed");
}

/// Fig. 8 consistency at materialized-graph level: the synthetic datasets'
/// measured average degree drives the same ordering the stats table gives.
#[test]
fn materialized_datasets_preserve_fig8_orderings() {
    let cora = datasets::cora().materialize(usize::MAX, 3).unwrap();
    let cite = datasets::citeseer().materialize(usize::MAX, 3).unwrap();
    // Cora has more edges per node than Citeseer (Table 2: 4 vs 2).
    assert!(cora.avg_degree() > cite.avg_degree());
    let model = NetModel::fig8(&datasets::cora()).unwrap();
    let t_cora = model.communicate_latency(
        Setting::Decentralized,
        Topology { nodes: cora.num_nodes(), cluster_size: cora.avg_degree().round() as usize },
    );
    let model = NetModel::fig8(&datasets::citeseer()).unwrap();
    let t_cite = model.communicate_latency(
        Setting::Decentralized,
        Topology { nodes: cite.num_nodes(), cluster_size: cite.avg_degree().round() as usize },
    );
    // Larger cₛ → longer sequential exchange.
    assert!(t_cora > t_cite);
}

/// The shipped TOML presets in configs/ parse to exactly the in-code
/// presets — configuration and code cannot drift apart.
#[test]
fn config_files_match_code_presets() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs");
    let base = presets::decentralized();

    let raw = ima_gnn::config::parse_file(&root.join("centralized.toml")).unwrap();
    let cent = ima_gnn::config::presets::from_raw(&raw, base.clone()).unwrap();
    assert_eq!(cent, presets::centralized());

    let raw = ima_gnn::config::parse_file(&root.join("decentralized.toml")).unwrap();
    let dec = ima_gnn::config::presets::from_raw(&raw, base.clone()).unwrap();
    assert_eq!(dec, base);

    // and the parsed config still reproduces Table 1
    let acc = ima_gnn::cores::Accelerator::new(dec).unwrap();
    let b = acc.per_node(&GnnWorkload::taxi());
    assert_close(b.t2.as_us(), 14.27, 0.005);
}

/// The reverse-graph equivalence the traversal core relies on: CAM lookup
/// over CSR(CI, RP) equals neighbors() on the reversed graph.
#[test]
fn property_traversal_equals_reverse_neighbors() {
    forall(12, |rng: &mut Rng| {
        let n = rng.index(40) + 2;
        let mut edges = Vec::new();
        for s in 0..n {
            for _ in 0..rng.index(4) {
                edges.push((s, rng.index(n)));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        if edges.is_empty() || edges.len() > 500 {
            return;
        }
        let g = Csr::from_edges(n, &edges).unwrap();
        let rev = g.reverse();
        let cfg = presets::decentralized();
        let mut acc = Accelerator::new(cfg).unwrap();
        acc.traversal.load_graph(&g).unwrap();
        for dst in 0..n {
            let mut got = acc.traversal.incoming(dst).unwrap();
            got.sort_unstable();
            let mut want = rev.neighbors(dst).to_vec();
            want.sort_unstable();
            assert_eq!(got, want);
        }
    });
}
