//! Integration: the E11 autotuner's selection is machine-verified against
//! exhaustive brute force.
//!
//! The explorer (`autotune::Autotuner::explore`) enumerates, scores in
//! parallel, refines and ranks; these tests re-enumerate the same grids
//! with plain nested loops, score every candidate through the public
//! [`Autotuner::score`] entry point, take the argmin by hand (first point
//! wins ties) and require the explorer to agree exactly — for both the
//! analytic and the packet-level netsim backends, plus the degenerate
//! grids (single point, centralized-only).

use ima_gnn::autotune::{
    dominates, Autotuner, Backend, EvaluatedPoint, OperatingPoint, Partitioner, SettingKind,
    TuneGrid, TunerConfig,
};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::graph::generate;
use ima_gnn::netmodel::NetModel;
use ima_gnn::netsim::NetSimConfig;

fn model() -> NetModel {
    NetModel::paper(&GnnWorkload::taxi()).unwrap()
}

/// Independent enumeration of `grid` in canonical order: plain nested
/// loops, no call into `TuneGrid::points`.
fn enumerate_by_hand(grid: &TuneGrid) -> Vec<OperatingPoint> {
    let mut pts = Vec::new();
    for &setting in &grid.settings {
        match setting {
            SettingKind::Centralized => pts.push(OperatingPoint::centralized()),
            SettingKind::Semi => {
                for &cs in &grid.cluster_sizes {
                    for &h in &grid.head_capacities {
                        for &p in &grid.partitioners {
                            pts.push(OperatingPoint::semi(cs, h, p));
                        }
                    }
                }
            }
            SettingKind::Decentralized => {
                for &cs in &grid.cluster_sizes {
                    for &p in &grid.partitioners {
                        pts.push(OperatingPoint::decentralized(cs, p));
                    }
                }
            }
        }
    }
    pts
}

/// Brute-force argmin over `grid` through the public scoring entry point:
/// strict `<` keeps the earliest point on ties.
fn brute_force_argmin(tuner: &Autotuner<'_>, grid: &TuneGrid) -> EvaluatedPoint {
    let mut best: Option<EvaluatedPoint> = None;
    for p in enumerate_by_hand(grid) {
        let e = tuner.score(&p).unwrap();
        match &best {
            None => best = Some(e),
            Some(b) if e.score.latency < b.score.latency => best = Some(e),
            _ => {}
        }
    }
    best.expect("grid is non-empty")
}

#[test]
fn analytic_argmin_equals_brute_force() {
    let m = model();
    let g = generate::grid(10, 10).unwrap();
    let grid = TuneGrid::full(&[4, 5, 10, 20], &[2.0, 8.0, 16.0]);
    let tuner =
        Autotuner::new(&m, &g, 5_000, grid.clone(), TunerConfig::default()).unwrap();

    let want = brute_force_argmin(&tuner, &grid);
    for threads in [1, 4] {
        let out = tuner.explore_with_threads(threads).unwrap();
        let got = out.best_point();
        assert_eq!(got.point, want.point, "threads={threads}");
        assert_eq!(got.score, want.score, "threads={threads}");
        assert_eq!(got.facts, want.facts, "threads={threads}");
        // The explorer evaluated exactly the hand-enumerated grid, in
        // the same order.
        let hand = enumerate_by_hand(&grid);
        assert_eq!(out.evaluated.len(), hand.len());
        for (e, p) in out.evaluated.iter().zip(&hand) {
            assert_eq!(e.point, *p);
        }
    }
}

#[test]
fn netsim_argmin_equals_brute_force() {
    let m = model();
    let g = generate::ring(60).unwrap();
    let grid = TuneGrid::full(&[4, 6], &[2.0, 4.0]);
    let cfg = TunerConfig {
        backend: Backend::Netsim(NetSimConfig::default()),
        netsim_nodes_cap: 128,
        ..Default::default()
    };
    let tuner = Autotuner::new(&m, &g, 120, grid.clone(), cfg).unwrap();

    let want = brute_force_argmin(&tuner, &grid);
    let out = tuner.explore_with_threads(2).unwrap();
    assert_eq!(out.best_point().point, want.point);
    assert_eq!(out.best_point().score, want.score);

    // A congested fabric must still agree with its own brute force (the
    // contention changes the scores, not the selection contract).
    let congested = TunerConfig {
        backend: Backend::Netsim(NetSimConfig { rx_ports: Some(2), ..Default::default() }),
        netsim_nodes_cap: 128,
        ..Default::default()
    };
    let tuner = Autotuner::new(&m, &g, 120, grid.clone(), congested).unwrap();
    let want = brute_force_argmin(&tuner, &grid);
    let out = tuner.explore_with_threads(1).unwrap();
    assert_eq!(out.best_point().point, want.point);
    assert_eq!(out.best_point().score, want.score);
}

#[test]
fn degenerate_grids_return_their_single_point() {
    let m = model();
    let g = generate::ring(24).unwrap();

    // Centralized-only: no cluster knobs needed at all.
    let grid = TuneGrid {
        settings: vec![SettingKind::Centralized],
        cluster_sizes: vec![],
        head_capacities: vec![],
        partitioners: vec![],
    };
    let tuner = Autotuner::new(&m, &g, 1_000, grid, TunerConfig::default()).unwrap();
    let out = tuner.explore().unwrap();
    assert_eq!(out.evaluated.len(), 1);
    assert_eq!(out.best, 0);
    assert_eq!(out.pareto, vec![0]);
    assert_eq!(out.best_point().point, OperatingPoint::centralized());

    // A single semi point — for both backends.
    let grid = TuneGrid {
        settings: vec![SettingKind::Semi],
        cluster_sizes: vec![6],
        head_capacities: vec![4.0],
        partitioners: vec![Partitioner::Locality],
    };
    for backend in [Backend::Analytic, Backend::Netsim(NetSimConfig::default())] {
        let cfg = TunerConfig { backend, netsim_nodes_cap: 64, ..Default::default() };
        let tuner = Autotuner::new(&m, &g, 24, grid.clone(), cfg).unwrap();
        let out = tuner.explore().unwrap();
        assert_eq!(out.evaluated.len(), 1);
        assert_eq!(
            out.best_point().point,
            OperatingPoint::semi(6, 4.0, Partitioner::Locality)
        );
        assert_eq!(out.pareto, vec![0]);
        // ... and it equals its own one-point brute force.
        assert_eq!(out.best_point().score, brute_force_argmin(&tuner, &grid).score);
    }
}

#[test]
fn frontier_covers_every_evaluated_point() {
    let m = model();
    let g = generate::grid(8, 8).unwrap();
    let grid = TuneGrid::full(&[4, 8, 16], &[2.0, 10.0]);
    let tuner = Autotuner::new(&m, &g, 2_000, grid, TunerConfig::default()).unwrap();
    let out = tuner.explore().unwrap();
    assert!(out.pareto.contains(&out.best), "argmin must sit on the frontier");
    for (i, e) in out.evaluated.iter().enumerate() {
        if out.pareto.contains(&i) {
            continue;
        }
        assert!(
            out.pareto.iter().any(|&j| {
                let f = &out.evaluated[j].score;
                dominates(f, &e.score) || *f == e.score
            }),
            "point {i} ({}) escapes the frontier",
            e.point.label()
        );
    }
}
