//! Fault-injection integration: the E14 layer end-to-end through the
//! public API.
//!
//! * **Zero-fault identity** — installing the empty `FaultPlan` leaves
//!   every traffic output bit-identical to the unfaulted path.
//! * **Crash semantics** — hand-built windows abort the in-service
//!   batch, requeue it, bill exactly the scheduled outage as downtime
//!   and keep Little's law exact.
//! * **Obs reconciliation** — `fault.crash` span durations sum to the
//!   reported downtime, and ring-buffer evictions surface in the
//!   report (`dropped_spans`).
//! * **Head failover** — a semi-setting failover against a live
//!   `RoundEngine` promotes the fallback head, re-uploads the member
//!   rows through the barrier, and bills the cost model's total.
//! * **Per-class queues** — the 1-class fleet reproduces the PR 5
//!   representative queue bitwise; heterogeneous fleets under churn
//!   keep Little's law to round-off.
//! * **E14 sweep** — replicas never go dark and `BENCH_faults.json` is
//!   byte-identical across thread counts.

use ima_gnn::coordinator::{Arrival, RoundEngine};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::experiments::FaultSweep;
use ima_gnn::graph::{fixed_size, generate, ShardPlan};
use ima_gnn::netmodel::NetModel;
use ima_gnn::obs::Obs;
use ima_gnn::sim::{
    head_failover, CrashImpact, FailoverCostModel, FaultConfig, FaultEvent, FaultKind,
    FaultPlan, Outage,
};
use ima_gnn::testing::{assert_close, gcn_layer_binding};
use ima_gnn::traffic::{
    open_loop, open_loop_faulted, open_loop_mix, ArrivalProcess, BatchPolicy,
    DeploymentQueues, DeviceClass, FleetMix, ServiceModel,
};
use ima_gnn::units::Time;

fn service() -> ServiceModel {
    ServiceModel::new(Time::ms(2.0), Time::us(100.0)).unwrap()
}

fn policy() -> BatchPolicy {
    BatchPolicy::Deadline { max: 16, max_wait: Time::ms(2.0) }
}

fn crash(at_ms: f64, until_ms: f64) -> FaultEvent {
    FaultEvent {
        at: Time::ms(at_ms),
        until: Time::ms(until_ms),
        kind: FaultKind::Crash { server: 0 },
    }
}

fn poisson(rate: f64, horizon_s: f64, seed: u64) -> Vec<Arrival> {
    ArrivalProcess::Poisson { rate }.generate(Time::s(horizon_s), 64, seed).unwrap()
}

fn two_windows() -> FaultPlan {
    FaultPlan::from_events(vec![crash(100.0, 160.0), crash(500.0, 540.0)], 1).unwrap()
}

#[test]
fn zero_fault_plan_is_bit_identical_to_the_unfaulted_path() {
    let svc = service();
    let arrivals = poisson(400.0, 0.5, 3);
    let base = open_loop(1, &svc, policy(), &arrivals).unwrap();
    let faulted =
        open_loop_faulted(1, &svc, policy(), &arrivals, &FaultPlan::none(), &Obs::disabled())
            .unwrap();
    assert_eq!(faulted.batch_log, base.batch_log);
    assert_eq!(faulted.makespan, base.makespan);
    assert_eq!(faulted.sum_response, base.sum_response);
    assert_eq!(faulted.mean_wait, base.mean_wait);
    assert_eq!(faulted.max_queue_depth, base.max_queue_depth);
    assert_eq!(faulted.utilization.to_bits(), base.utilization.to_bits());
    assert_eq!(faulted.downtime, Time::ZERO);
    assert_eq!(faulted.availability, 1.0);
    assert_eq!(faulted.fault_windows, 0);
    assert_eq!(faulted.dropped_spans, 0);
}

#[test]
fn crash_windows_bill_exactly_their_scheduled_outage() {
    let svc = service();
    let arrivals = poisson(300.0, 1.0, 9);
    let plan = two_windows();
    let r = open_loop_faulted(1, &svc, policy(), &arrivals, &plan, &Obs::disabled()).unwrap();
    // Both windows execute; downtime is exactly the planned outage.
    assert_eq!(r.fault_windows, 2);
    assert_eq!(r.downtime, plan.total_outage());
    assert!((r.mttr.as_s() - 0.05).abs() < 1e-12, "mttr {}", r.mttr);
    assert!(r.availability > 0.0 && r.availability < 1.0);
    assert!(r.littles_law_gap() < 1e-9, "gap {}", r.littles_law_gap());
    // 100 ms of stall against a 2 ms service must show up in the mean.
    let base = open_loop(1, &svc, policy(), &arrivals).unwrap();
    assert!(r.latency.mean() > base.latency.mean());
    assert_eq!(r.offered, base.offered, "crashes must not lose requests");

    // Degraded windows (replica-served, r >= 2) slow service but never
    // go dark: zero downtime, yet strictly slower than fault-free.
    let slow = FaultPlan::from_events(
        vec![FaultEvent {
            at: Time::ZERO,
            until: Time::s(2.0),
            kind: FaultKind::Straggle { server: 0, factor: 3.0 },
        }],
        1,
    )
    .unwrap();
    let d = open_loop_faulted(1, &svc, policy(), &arrivals, &slow, &Obs::disabled()).unwrap();
    assert_eq!(d.downtime, Time::ZERO);
    assert_eq!(d.availability, 1.0);
    assert!(d.latency.mean() > base.latency.mean());
}

#[test]
fn fault_spans_reconcile_with_downtime_and_drops_surface() {
    let svc = service();
    let arrivals = poisson(300.0, 1.0, 9);
    let plan = two_windows();
    let obs = Obs::new(1 << 16);
    let r = open_loop_faulted(1, &svc, policy(), &arrivals, &plan, &obs).unwrap();
    let span_sum: Time = obs
        .tracer
        .spans()
        .iter()
        .filter(|s| s.name == "fault.crash")
        .map(|s| s.end - s.start)
        .sum();
    // Same subtractions in the same (chronological) order: bit-exact.
    assert_eq!(span_sum, r.downtime);
    assert_eq!(obs.metrics.counter_value("fault.crashes"), 2);
    assert_eq!(obs.tracer.dropped(), 0);
    assert_eq!(r.dropped_spans, 0);

    // A tiny ring under the same run must evict — and say so in the
    // report instead of silently losing spans.
    let obs2 = Obs::new(2);
    let r2 = open_loop_faulted(1, &svc, policy(), &arrivals, &plan, &obs2).unwrap();
    assert!(r2.dropped_spans > 0, "a 2-span ring cannot hold a full run");
    assert_eq!(r2.dropped_spans, obs2.tracer.dropped());
}

#[test]
fn head_failover_promotes_rebuilds_and_bills_the_cost_model() {
    let b = gcn_layer_binding();
    let graph = generate::regular(96, 6, 3).unwrap();
    let clustering = fixed_size(96, 8).unwrap();
    let plan = ShardPlan::from_clustering(&graph, &b.sampler(), b.table, &clustering).unwrap();
    let weights = vec![0.01f32; b.feature * b.hidden];
    let mut engine = RoundEngine::new(b.clone(), plan, weights).unwrap();
    for node in 0..96 {
        let feats: Vec<f32> = (0..b.feature).map(|j| (node * 31 + j) as f32).collect();
        engine.upload(node, &feats).unwrap();
    }
    engine.end_round();
    let version = engine.version();
    let members = clustering.clusters[0].clone();
    let mut before = Vec::new();
    for &v in &members {
        before.push(engine.read(v).unwrap().to_vec());
    }

    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let costs = FailoverCostModel::from_net(&model, b.feature * 4);
    let obs = Obs::new(4096);
    let out = head_failover(&mut engine, &clustering, 0, &costs, Time::s(1.0), &obs).unwrap();

    assert_eq!(out.old_head, members[0]);
    assert_eq!(out.new_head, members[1]);
    assert_eq!(out.rows_reuploaded, members.len());
    assert_eq!(out.recovered_at, Time::s(1.0) + out.cost.total());
    assert!(out.cost.total().as_s() > 0.0);
    // The barrier committed: a new serving version, same row contents.
    assert_eq!(engine.version(), version + 1);
    for (&v, old) in members.iter().zip(&before) {
        assert_eq!(engine.read(v).unwrap(), &old[..]);
    }
    // Spans retell the bill: the failover window is exactly
    // [at, recovered_at], and the rebuild phase closes the window
    // (compare to round-off — the span end associates the cost sum
    // differently than `RecoveryCost::total`).
    let spans = obs.tracer.spans();
    let fo = spans.iter().find(|s| s.name == "fault.failover").unwrap();
    assert_eq!(fo.start, Time::s(1.0));
    assert_eq!(fo.end, out.recovered_at);
    let rb = spans.iter().find(|s| s.name == "fault.rebuild").unwrap();
    assert!(rb.start >= fo.start);
    assert_close(rb.end.as_s(), fo.end.as_s(), 1e-12);
    assert_eq!(obs.metrics.counter_value("fault.failovers"), 1);

    // A singleton cluster has no fallback head to promote.
    let singletons = fixed_size(96, 1).unwrap();
    assert!(head_failover(&mut engine, &singletons, 0, &costs, Time::ZERO, &obs).is_err());
    assert!(head_failover(&mut engine, &clustering, 999, &costs, Time::ZERO, &obs).is_err());
}

#[test]
fn one_class_fleet_reproduces_the_representative_queue_bitwise() {
    let svc = service();
    let queues = DeploymentQueues::ClusterHeads { clusters: 5 };
    let m = open_loop_mix(
        &FleetMix::homogeneous(),
        queues,
        &svc,
        policy(),
        400.0,
        200,
        64,
        7,
        &FaultConfig::none(),
        &Obs::disabled(),
    )
    .unwrap();
    let queue_rate = queues.per_queue_rate(400.0);
    let arrivals = poisson(queue_rate, 200.0 / queue_rate, 7);
    let base = open_loop(1, &svc, policy(), &arrivals).unwrap();
    assert_eq!(m.classes.len(), 1);
    assert_eq!(m.classes[0].servers, 5);
    assert_eq!(m.classes[0].report.batch_log, base.batch_log);
    assert_eq!(m.classes[0].report.makespan, base.makespan);
    assert_eq!(m.classes[0].report.utilization.to_bits(), base.utilization.to_bits());
    assert_eq!(m.p95(), base.latency.p95());
    assert_eq!(m.p99(), base.latency.p99());
    assert_eq!(m.max_littles_gap().to_bits(), base.littles_law_gap().to_bits());
}

#[test]
fn heterogeneous_fleet_under_churn_keeps_littles_law() {
    let mix = FleetMix::new(vec![
        DeviceClass { name: "fast", speed: 1.0, share: 0.75 },
        DeviceClass { name: "slow", speed: 0.5, share: 0.25 },
    ])
    .unwrap();
    let cfg = FaultConfig::crashes(5.0, Outage::Fixed(Time::ms(40.0)), CrashImpact::Outage);
    let m = open_loop_mix(
        &mix,
        DeploymentQueues::Devices { nodes: 8 },
        &service(),
        policy(),
        200.0,
        160,
        64,
        11,
        &cfg,
        &Obs::disabled(),
    )
    .unwrap();
    assert!(m.fault_windows() > 0, "expected crash windows to execute");
    assert!(m.downtime() > Time::ZERO);
    assert!(m.availability() < 1.0);
    assert!(m.max_littles_gap() < 1e-9, "gap {}", m.max_littles_gap());
    assert!(m.mttr() > Time::ZERO);
}

#[test]
fn fault_sweep_replicas_never_go_dark_and_json_is_thread_stable() {
    let seq = FaultSweep::run_with_threads(150, 150, 1).unwrap();
    assert_eq!(seq.rows.len(), 4);
    for r in &seq.rows {
        assert_eq!(r.scenarios.len(), 4);
        for p in &r.scenario("baseline").points {
            assert_eq!(p.fault_windows, 0);
            assert_eq!(p.availability, 1.0);
        }
        for p in &r.scenario("faulted_r2").points {
            if p.setting != "centralized" {
                assert_eq!(p.downtime_s, 0.0, "replicas must not go dark");
            }
        }
    }
    assert!(seq.max_littles_gap() < 1e-9);
    let json = seq.to_json();
    assert!(json.contains("\"experiment\": \"fault_sweep\""));
    let par2 = FaultSweep::run_with_threads(150, 150, 2).unwrap();
    assert_eq!(json, par2.to_json());
}
