//! Integration: million-node residency — the compact CSR codec, the
//! byte-budgeted resident set, and the round engine's streaming serve
//! path (DESIGN.md §16, E16).
//!
//! The codec property tests exercise arbitrary random graphs through
//! `testing::forall`; the LRU tests pin down the determinism contract
//! (eviction order is a pure function of the fetch sequence, never of
//! the assembly thread count); and the acceptance test serves a
//! LiveJournal-shape graph at a full million nodes under an asserted
//! byte ceiling — the scale the E11/E12 sweeps cap away.

use ima_gnn::coordinator::RoundEngine;
use ima_gnn::experiments::{residency_binding, ResidencySweep, RESIDENCY_DEGREE};
use ima_gnn::graph::{generate, CompactCsr, FeatureQuant, ResidentSet, ShardPlan};
use ima_gnn::testing::{forall, gcn_layer_binding, Rng};

/// A small multi-shard engine with integer-valued features uploaded and
/// the first barrier driven — the fixture for the serve-path tests.
/// `budget_shards = 0` leaves residency off (the seed path).
fn engine_fixture(nodes: usize, budget_shards: usize, seed: u64) -> RoundEngine {
    let b = gcn_layer_binding();
    let g = generate::uniform(nodes, nodes * 4, 9).unwrap();
    let plan = ShardPlan::build(&g, &b.sampler(), b.table).unwrap();
    let feature = b.feature;
    let shard_bytes = b.table * b.feature * std::mem::size_of::<f32>();
    let mut eng = RoundEngine::new(b.clone(), plan, vec![0.01; b.feature * b.hidden]).unwrap();
    if budget_shards > 0 {
        eng.enable_residency(FeatureQuant::ExactI32, budget_shards * shard_bytes).unwrap();
    }
    let mut rng = Rng::new(seed);
    for node in 0..nodes {
        let f: Vec<f32> = (0..feature).map(|_| rng.index(512) as f32).collect();
        eng.upload(node, &f).unwrap();
    }
    eng.try_end_round().unwrap();
    eng
}

/// One full fetch scan in assemble order: every batch's shard table plus
/// its assembled inputs, flattened to comparable bytes.
fn scan(eng: &RoundEngine, nodes: &[usize], threads: usize) -> Vec<(Vec<u32>, Vec<f32>)> {
    let mut out = Vec::new();
    for b in eng.assemble_with_threads(nodes, threads).unwrap() {
        let table = eng.fetch_table(b.shard).unwrap();
        let bits: Vec<u32> = table.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        out.push((bits, b.x_self.clone()));
    }
    out
}

// ---------------------------------------------------------------------
// Codec property tests (ISSUE satellite: varint/delta, renumbering,
// quantization, neighbor-order equivalence).
// ---------------------------------------------------------------------

/// Renumbering is a permutation (every old id maps to exactly one new id
/// and back), neighbor iteration through the compact form equals the
/// seed CSR's order exactly, and the structural roundtrip is lossless —
/// over arbitrary random graphs including empty rows.
#[test]
fn property_compact_codec_roundtrips_arbitrary_graphs() {
    forall(20, |rng: &mut Rng| {
        let n = rng.index(300) + 2;
        let e = rng.index(n * 5);
        let g = generate::uniform(n, e, rng.next_u64()).unwrap();
        let c = CompactCsr::from_csr(&g).unwrap();
        assert_eq!(c.num_nodes(), g.num_nodes());
        assert_eq!(c.num_edges(), g.num_edges());

        let mut seen = vec![false; g.num_nodes()];
        for old in 0..g.num_nodes() {
            let new = c.new_id(old);
            assert!(!seen[new], "new id {new} assigned twice");
            seen[new] = true;
            assert_eq!(c.old_id(new), old, "inverse mapping broken at {old}");
        }

        let mut buf = Vec::new();
        for old in 0..g.num_nodes() {
            c.neighbors(old, &mut buf).unwrap();
            assert_eq!(buf, g.neighbors(old), "neighbor order diverged at node {old}");
        }
        assert_eq!(c.to_csr().unwrap(), g, "structural roundtrip lost information");
    });
}

/// A max-degree row (a star hub adjacent to every other node) survives
/// the delta+varint encoding and keeps the seed's sorted neighbor order.
#[test]
fn max_degree_rows_roundtrip() {
    let n = 600;
    let edges: Vec<(usize, usize)> = (1..n).flat_map(|v| [(0, v), (v, 0)]).collect();
    let g = ima_gnn::graph::Csr::from_edges(n, &edges).unwrap();
    let c = CompactCsr::from_csr(&g).unwrap();
    assert_eq!(c.new_id(0), 0, "the hub has max degree, so it renumbers first");
    let mut buf = Vec::new();
    c.neighbors(0, &mut buf).unwrap();
    assert_eq!(buf, g.neighbors(0));
    assert_eq!(c.to_csr().unwrap(), g);
    assert!(c.compression_ratio() > 1.0, "a star is maximally delta-friendly");
}

/// In-range integral features roundtrip bit-for-bit through the ExactI32
/// path — the property the engine's bit-identity contract rests on.
#[test]
fn property_exact_i32_features_roundtrip_bitwise() {
    use ima_gnn::graph::QuantizedFeatures;
    forall(20, |rng: &mut Rng| {
        let len = rng.index(200) + 1;
        let vals: Vec<f32> = (0..len)
            .map(|_| (rng.index(33_554_433) as i64 - 16_777_216) as f32)
            .collect();
        let q = QuantizedFeatures::encode(FeatureQuant::ExactI32, &vals).unwrap();
        let back = q.decode();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "ExactI32 must be bit-exact");
        }
        assert!(QuantizedFeatures::encode(FeatureQuant::ExactI32, &[0.5]).is_err());
        assert!(QuantizedFeatures::encode(FeatureQuant::ExactI32, &[16_777_218.0]).is_err());
    });
}

// ---------------------------------------------------------------------
// LRU / prefetch determinism (ISSUE satellite).
// ---------------------------------------------------------------------

/// Eviction order — and therefore every cache counter — is a pure
/// function of the fetch sequence: driving the identical round through
/// assembly at 1, 2 and 8 threads produces byte-identical resident-set
/// metrics and byte-identical served tables.
#[test]
fn eviction_order_is_independent_of_assembly_thread_count() {
    let all: Vec<usize> = (0..256).collect();
    let mut runs = Vec::new();
    for threads in [1usize, 2, 8] {
        let eng = engine_fixture(256, 2, 11);
        let served = scan(&eng, &all, threads);
        let tier = eng.resident().unwrap();
        runs.push((served, tier.metrics().to_json(), tier.peak_bytes()));
    }
    assert_eq!(runs[0], runs[1], "2-thread assembly changed the cache story");
    assert_eq!(runs[0], runs[2], "8-thread assembly changed the cache story");
    assert!(runs[0].1.contains("resident.evictions"));
}

/// Adversarial shard-size mixes never pierce the budget: random per-shard
/// row counts, random fetch sequences, `bytes_resident` checked after
/// every fetch and `peak_bytes` at the end.
#[test]
fn property_peak_bytes_respects_budget_on_adversarial_mixes() {
    forall(20, |rng: &mut Rng| {
        let shards = rng.index(6) + 2;
        let rows: Vec<usize> = (0..shards).map(|_| rng.index(64) + 1).collect();
        let max_rows = *rows.iter().max().unwrap();
        let budget = max_rows * 4 * (rng.index(3) + 1);
        let mut set = ResidentSet::new(shards, 1, FeatureQuant::ExactI32, budget).unwrap();
        for (s, &r) in rows.iter().enumerate() {
            let vals: Vec<f32> = (0..r).map(|i| ((s * 31 + i * 7) % 500) as f32).collect();
            set.store(s, &vals).unwrap();
        }
        for _ in 0..40 {
            set.fetch(rng.index(shards)).unwrap();
            assert!(
                set.bytes_resident() <= budget,
                "resident {} B over the {budget} B budget",
                set.bytes_resident()
            );
        }
        assert!(set.peak_bytes() <= budget);
    });
}

/// Cold (all misses) and warm (hit/miss mix) serve scans return
/// bit-identical tables and assembled inputs, and both match the seed
/// engine with residency off.
#[test]
fn cold_and_warm_serves_are_bit_identical_to_the_seed_path() {
    let all: Vec<usize> = (0..256).rev().collect();
    let res = engine_fixture(256, 2, 11);
    let cold = scan(&res, &all, 1);
    let warm = scan(&res, &all, 1);
    assert_eq!(cold, warm, "warm reuse changed served bytes");
    let tier = res.resident().unwrap();
    assert!(tier.metrics().counter_value("resident.hits") > 0, "warm scan never hit");

    let seed = engine_fixture(256, 0, 11);
    assert_eq!(cold, scan(&seed, &all, 1), "residency diverged from the seed path");
}

// ---------------------------------------------------------------------
// Acceptance: one million nodes under an asserted byte ceiling.
// ---------------------------------------------------------------------

/// E16 acceptance — a LiveJournal-shape (R-MAT, avg degree 9) graph at
/// 1,000,000 nodes is compacted, sharded and served through the round
/// engine while decoded shard bytes never exceed a two-shard budget that
/// is orders of magnitude below the unbounded cache's footprint.
#[test]
fn million_node_livejournal_shape_graph_serves_under_budget() {
    let nodes = 1_000_000;
    let g = generate::rmat(
        nodes,
        nodes * RESIDENCY_DEGREE,
        &generate::RmatParams::default(),
        0xE16,
    )
    .unwrap();
    assert!(g.num_nodes() >= nodes);

    let c = CompactCsr::from_csr(&g).unwrap();
    assert!(
        c.compression_ratio() > 1.5,
        "skewed million-node CSR should compress: {:.2}x",
        c.compression_ratio()
    );
    // Spot-check neighbor equivalence on a scatter of nodes (the full
    // scan is property-tested at small scale).
    let mut buf = Vec::new();
    let mut rng = Rng::new(5);
    for _ in 0..64 {
        let v = rng.index(g.num_nodes());
        c.neighbors(v, &mut buf).unwrap();
        assert_eq!(buf, g.neighbors(v), "compact neighbors diverged at node {v}");
    }

    let b = residency_binding();
    let plan = ShardPlan::build(&g, &b.sampler(), b.table).unwrap();
    assert!(plan.num_shards() >= nodes / b.table, "a 4096-row table must shard 1M nodes");
    let shard_bytes = b.table * b.feature * std::mem::size_of::<f32>();
    let budget = 2 * shard_bytes;
    let feature = b.feature;
    let mut eng = RoundEngine::new(b.clone(), plan, vec![0.01; b.feature * b.hidden]).unwrap();
    eng.enable_residency(FeatureQuant::ExactI32, budget).unwrap();

    let mut rng = Rng::new(0xE16C);
    for node in 0..g.num_nodes() {
        let f: Vec<f32> = (0..feature).map(|_| rng.index(512) as f32).collect();
        eng.upload(node, &f).unwrap();
    }
    eng.try_end_round().unwrap();
    let shards = eng.plan().num_shards();
    assert_eq!(eng.shard_encodes(), shards as u64);
    assert_eq!(eng.table_builds(), 0, "residency must not materialize unbounded tensors");

    // Serve a slice of requests end to end, then sweep every shard's
    // table in plan order — the budget has to hold at every step.
    let some: Vec<usize> = (0..4096).collect();
    for batch in eng.assemble(&some).unwrap() {
        eng.fetch_table(batch.shard).unwrap();
        assert!(eng.resident().unwrap().bytes_resident() <= budget);
    }
    for s in 0..shards {
        eng.fetch_table(s).unwrap();
        assert!(eng.resident().unwrap().bytes_resident() <= budget);
    }
    let tier = eng.resident().unwrap();
    assert!(tier.peak_bytes() <= budget, "peak {} B over {budget} B", tier.peak_bytes());
    assert!(
        tier.unbounded_bytes() >= shards * shard_bytes / 2,
        "unbounded footprint should dwarf the budget"
    );
    assert!(
        tier.metrics().counter_value("resident.prefetch_hits") > 0,
        "the plan-order sweep must ride the prefetch"
    );
}

/// The E16 sweep's smallest grid scale runs end to end through the
/// public API (`run_with_threads`, untimed) — the same entry CI's quick
/// mode uses — and its JSON artifact carries the headline fields.
#[test]
fn residency_sweep_quick_mode_emits_the_artifact_shape() {
    let sweep = ResidencySweep::run_with_threads(10_000, 1, 2, 2, false).unwrap();
    assert_eq!(sweep.rows.len(), 1);
    let json = sweep.to_json();
    for key in [
        "\"experiment\": \"residency_sweep\"",
        "\"peak_within_budget\": true",
        "\"compression_ratio\"",
        "\"prefetch_hits\"",
        "\"decode_overhead\": null",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}
