//! E4 bench — the §4.3 scaling study (performance vs crossbar count,
//! saturation once features fit, power cost) plus the double-buffering /
//! core-overlap ablations DESIGN.md calls out.
//!
//! `cargo bench --bench scaling`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::config::presets;
use ima_gnn::cores::{Accelerator, GnnWorkload};
use ima_gnn::experiments::scaling_sweep;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::report::{speedup, Table};
use ima_gnn::sim::{simulate, SimConfig};

fn main() {
    // --- the scaling table -------------------------------------------------
    let rows = scaling_sweep(&GnnWorkload::taxi()).unwrap();
    let mut t = Table::new(
        "§4.3 scaling — decentralized per-node figures vs crossbars per core",
        &["Crossbars/core", "Per-node latency", "Per-node power (mW)", "Speedup"],
    );
    let base = rows[0].1;
    for (k, lat, mw) in &rows {
        t.row(&[k.to_string(), lat.to_string(), format!("{mw:.2}"), speedup(base / *lat)]);
    }
    t.print();

    // --- ablation: core overlap (paper §2.3 parallel agg+FE) ---------------
    let acc = Accelerator::new(presets::decentralized()).unwrap();
    let bd = acc.per_node(&GnnWorkload::taxi());
    let mut t = Table::new(
        "ablation — §2.3 core overlap",
        &["Schedule", "Per-node compute", "Saving"],
    );
    t.row(&["sequential (Table 1)".into(), bd.total_latency().to_string(), "-".into()]);
    t.row(&[
        "agg ∥ FE overlap".into(),
        bd.overlapped_latency().to_string(),
        format!("{}", bd.total_latency() - bd.overlapped_latency()),
    ]);
    t.print();

    // --- ablation: shared-medium (CSMA) decentralized comm ------------------
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology { nodes: 200, cluster_size: 10 };
    let ded = simulate(&model, Setting::Decentralized, topo, &SimConfig::default()).unwrap();
    let csma = simulate(
        &model,
        Setting::Decentralized,
        topo,
        &SimConfig { shared_medium: true, ..Default::default() },
    )
    .unwrap();
    let mut t = Table::new("ablation — intra-cluster medium", &["Medium", "Completion"]);
    t.row(&["dedicated channels (Eq. 4)".into(), ded.completion.to_string()]);
    t.row(&["shared medium (CSMA)".into(), csma.completion.to_string()]);
    t.print();

    // --- timing ------------------------------------------------------------
    let mut b = Bench::new();
    b.section("scaling sweep");
    b.case("full 6-point sweep", || black_box(scaling_sweep(&GnnWorkload::taxi()).unwrap()));
    b.case("accelerator construction", || {
        black_box(Accelerator::new(presets::decentralized()).unwrap())
    });
}
