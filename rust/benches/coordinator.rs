//! P1 bench — coordinator hot path: router, batcher, feature store and the
//! end-to-end served-request throughput (§Perf, Layer 3).
//!
//! Requires `make artifacts`.  `cargo bench --bench coordinator`

use std::path::PathBuf;
use std::time::Duration;

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::coordinator::{
    Batcher, CentralizedLeader, FeatureStore, GcnLayerBinding, InferenceService, Request, Router,
};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::graph::{fixed_size, generate};
use ima_gnn::runtime::Manifest;
use ima_gnn::testing::Rng;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(4);

    b.section("router");
    let clustering = fixed_size(10_000, 10).unwrap();
    let mut router = Router::from_clustering(&clustering);
    b.case("owner route + complete", || {
        let d = router.route(black_box(4567)).unwrap();
        router.complete(d);
        black_box(d)
    });
    let mut replica = Router::centralized(10_000, 8).unwrap();
    b.case("replica route + complete (8 replicas)", || {
        let d = replica.route(black_box(123)).unwrap();
        replica.complete(d);
        black_box(d)
    });

    b.section("batcher");
    let mut batcher = Batcher::new(64, Duration::from_millis(1)).unwrap();
    let mut id = 0u64;
    b.case("push (closing every 64th)", || {
        id += 1;
        black_box(batcher.push(Request { id, node: (id % 100) as usize }))
    });

    b.section("feature store");
    let mut store = FeatureStore::new(256, 1433);
    let row = vec![0.5f32; 1433];
    b.case("write one 1433-wide row", || store.write(black_box(17), &row).unwrap());
    store.swap();
    let nodes: Vec<usize> = (0..64).map(|i| i * 3 % 256).collect();
    b.case("gather 64 rows (batch assembly)", || black_box(store.gather(&nodes).unwrap()));
    b.case("swap (round barrier, 256 nodes)", || store.swap());

    b.section("end-to-end serving (PJRT)");
    let dir = artifact_dir();
    let (svc, manifest) = match (InferenceService::start(dir.clone()), Manifest::load(&dir)) {
        (Ok(s), Ok(m)) => (s, m),
        _ => {
            eprintln!("skipping serving bench (run `make artifacts`)");
            return;
        }
    };
    let binding = GcnLayerBinding::from_spec(manifest.get("gcn_layer_small").unwrap()).unwrap();
    let graph = generate::regular(48, 6, 3).unwrap();
    let weights: Vec<f32> =
        (0..binding.feature * binding.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let mut leader = CentralizedLeader::new(
        binding,
        graph,
        weights,
        &GnnWorkload::gcn("bench", 64, 6),
        Duration::from_millis(100),
    )
    .unwrap();
    for node in 0..48 {
        let f: Vec<f32> = (0..64).map(|_| rng.f64() as f32).collect();
        leader.upload(node, &f).unwrap();
    }
    leader.end_round();
    svc.warm("gcn_layer_small").unwrap();

    let mut id = 0u64;
    let st = b.case("submit 16 requests -> 1 served batch", || {
        let mut out = Vec::new();
        for _ in 0..16 {
            id += 1;
            out = leader.submit(&svc, Request { id, node: (id % 48) as usize }).unwrap();
        }
        black_box(out.len())
    });
    println!(
        "    -> end-to-end serving throughput: {:.0} req/s",
        16.0 * 1e9 / st.median_ns
    );

    // --- tail latency under a Poisson trace (virtual-time replay over
    // measured PJRT batch walls) --------------------------------------------
    use ima_gnn::coordinator::{generate_trace, replay_trace, TraceConfig};
    use ima_gnn::report::Table;
    use ima_gnn::units::Time;
    let exe_wall = Time::ns(st.median_ns / 16.0 * 16.0); // batch wall
    let mut t = Table::new(
        "\ntail latency — Poisson trace, batch 16, 2 ms deadline",
        &["offered load (req/s)", "p50", "p99", "max"],
    );
    for rate in [1_000.0, 10_000.0, 60_000.0] {
        let trace = generate_trace(&TraceConfig {
            rate_per_s: rate,
            duration_s: 2.0,
            diurnal: false,
            nodes: 48,
            seed: 7,
        })
        .unwrap();
        let stats =
            replay_trace(&trace, 16, Time::ms(2.0), |_nodes| Ok(exe_wall)).unwrap();
        t.row(&[
            format!("{rate:.0}"),
            stats.p50().to_string(),
            stats.p99().to_string(),
            stats.max().to_string(),
        ]);
    }
    t.print();
}
