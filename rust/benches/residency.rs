//! P1 bench — the E16 residency tier: compact-CSR encode/decode, the
//! quantized feature codec, and the resident-set fetch paths
//! (DESIGN.md §16).  No PJRT needed.  `cargo bench --bench residency`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::graph::{generate, CompactCsr, FeatureQuant, QuantizedFeatures, ResidentSet};
use ima_gnn::testing::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(6);

    b.section("compact CSR (100k-node LiveJournal-shape R-MAT)");
    let g = generate::rmat(100_000, 900_000, &generate::RmatParams::default(), 0xE16).unwrap();
    let c = CompactCsr::from_csr(&g).unwrap();
    println!(
        "    -> {} edges: {} B compact vs {} B seed ({:.2}x)",
        c.num_edges(),
        c.encoded_bytes(),
        c.seed_bytes(),
        c.compression_ratio()
    );
    b.case("encode (renumber + delta + varint)", || {
        black_box(CompactCsr::from_csr(&g).unwrap().encoded_bytes())
    });
    let mut buf = Vec::new();
    let hub = c.new_id(0); // densest row after degree-descending renumbering
    b.case("decode the densest row", || {
        c.decode_row(black_box(hub), &mut buf).unwrap();
        black_box(buf.len())
    });

    b.section("feature quantization (4096x64 shard)");
    let vals: Vec<f32> = (0..4_096 * 64).map(|_| rng.index(512) as f32).collect();
    for quant in [FeatureQuant::ExactI32, FeatureQuant::U16, FeatureQuant::U8] {
        let blob = QuantizedFeatures::encode(quant, &vals).unwrap();
        b.case(&format!("encode {quant:?}"), || {
            black_box(QuantizedFeatures::encode(quant, &vals).unwrap().encoded_bytes())
        });
        let mut out = Vec::new();
        b.case(&format!("decode {quant:?}"), || {
            blob.decode_into(&mut out);
            black_box(out.len())
        });
    }

    b.section("resident-set fetch (8 shards, 2-shard budget)");
    let rows = 4_096usize;
    let feature = 64usize;
    let shard_bytes = rows * feature * std::mem::size_of::<f32>();
    let mut set = ResidentSet::new(8, feature, FeatureQuant::ExactI32, 2 * shard_bytes).unwrap();
    for s in 0..8 {
        set.store(s, &vals).unwrap();
    }
    set.fetch(0).unwrap();
    b.case("warm hit (pinned shard)", || black_box(set.fetch(0).unwrap()));
    let mut shard = 0usize;
    b.case("streaming scan (decode + evict per step)", || {
        shard = (shard + 1) % 8;
        black_box(set.fetch(shard).unwrap())
    });
    println!(
        "    -> peak {} B <= budget {} B, hit rate {:.1}%",
        set.peak_bytes(),
        set.budget_bytes(),
        set.hit_rate() * 100.0
    );
}
