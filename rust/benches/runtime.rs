//! P1 bench — the PJRT hot path: artifact execution throughput, literal
//! construction overhead, cache behaviour.  This is the §Perf instrument
//! for Layer-3's serving loop.
//!
//! Requires `make artifacts`.  `cargo bench --bench runtime`

use std::path::PathBuf;

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::runtime::{ArtifactStore, Tensor};
use ima_gnn::testing::Rng;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let store = match ArtifactStore::open(&artifact_dir()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping runtime bench (run `make artifacts`): {e}");
            return;
        }
    };
    let mut rng = Rng::new(1);

    // --- gcn_layer_small hot path -------------------------------------------
    let x_self = Tensor::f32(&[16, 64], (0..1024).map(|_| rng.f64() as f32).collect()).unwrap();
    let nbr = Tensor::i32(&[16, 4], (0..64).map(|_| rng.index(64) as i32).collect()).unwrap();
    let table = Tensor::f32(&[64, 64], (0..4096).map(|_| rng.f64() as f32).collect()).unwrap();
    let w = Tensor::f32(&[64, 32], (0..2048).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect())
        .unwrap();
    let inputs = vec![x_self, nbr, table, w];

    let mut b = Bench::new();
    b.section("PJRT execution (compiled cache hot)");
    let exe = store.load("gcn_layer_small").unwrap(); // compile outside timing
    let st = b.case("gcn_layer_small execute (batch 16)", || {
        black_box(exe.execute(&inputs).unwrap())
    });
    println!(
        "    -> {:.0} node-inferences/s at batch 16",
        16.0 * 1e9 / st.median_ns
    );

    // --- mvm artifact (the L1 kernel through the full AOT path) -------------
    let xq = Tensor::i32(&[8, 512], (0..8 * 512).map(|_| rng.u64_in(0, 255) as i32).collect())
        .unwrap();
    let gq =
        Tensor::i32(&[512, 512], (0..512 * 512).map(|_| rng.i64_in(-8, 7) as i32).collect())
            .unwrap();
    let mvm_inputs = vec![xq, gq];
    let mvm = store.load("mvm_512x512").unwrap();
    let st = b.case("mvm_512x512 execute (bit-serial emulation)", || {
        black_box(mvm.execute(&mvm_inputs).unwrap())
    });
    // effective MACs: 8 batch × 512 × 512 per call
    println!(
        "    -> {:.2} G emulated-MAC/s",
        (8.0 * 512.0 * 512.0) * 1e9 / st.median_ns / 1e9
    );

    b.section("host-side overheads");
    b.case("literal build: 4 input tensors", || {
        black_box(inputs.iter().map(|t| t.to_literal().unwrap()).count())
    });
    b.case("tensor alloc: x_table 64x64", || {
        black_box(Tensor::f32(&[64, 64], vec![0.0; 4096]).unwrap())
    });
    b.case("store.load cache hit", || black_box(store.load("gcn_layer_small").unwrap()));

    b.section("larger artifacts (hot)");
    let spec = store.manifest().get("gcn2_cora").unwrap().clone();
    let cora_inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|s| match s.dtype {
            ima_gnn::runtime::DType::F32 => Tensor::f32(
                &s.shape,
                (0..s.num_elements()).map(|_| rng.f64_in(0.0, 1.0) as f32).collect(),
            )
            .unwrap(),
            ima_gnn::runtime::DType::I32 => Tensor::i32(
                &s.shape,
                (0..s.num_elements()).map(|_| rng.index(256) as i32).collect(),
            )
            .unwrap(),
        })
        .collect();
    let cora = store.load("gcn2_cora").unwrap();
    let q_ns = b
        .case("gcn2_cora execute (batch 64, crossbar path)", || {
            black_box(cora.execute(&cora_inputs).unwrap())
        })
        .median_ns;
    println!("    -> {:.0} node-inferences/s at batch 64", 64.0 * 1e9 / q_ns);

    // Emulation roofline: the crossbar path performs input_bits (8)
    // bit-plane matmuls plus quantization where the exact path does one
    // fused matmul — the achievable ratio floor is ~8×.
    let exact = store.load("gcn2_cora_exact").unwrap();
    let e_ns = b
        .case("gcn2_cora_exact execute (batch 64, f32 path)", || {
            black_box(exact.execute(&cora_inputs).unwrap())
        })
        .median_ns;
    println!("    -> crossbar/exact wall ratio: {:.1}x (bit-serial floor ~8x)", q_ns / e_ns);
}
