//! Netsim bench — packet-fabric throughput and the cost of the E9 sweep.
//!
//! The engine itself must stay cheap enough that sweeping topology grids
//! from the CLI is interactive: the interesting output is events/second
//! for the three fabrics, uncongested vs. contended.
//!
//! `cargo bench --bench netsim`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::experiments::NetsimSweep;
use ima_gnn::netmodel::{NetModel, Topology};
use ima_gnn::netsim::{simulate_fabric, NetSimConfig, Scenario};
use ima_gnn::report::Table;

fn main() {
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology { nodes: 1000, cluster_size: 10 };
    let free = NetSimConfig::default();
    let contended = NetSimConfig {
        rx_ports: Some(16),
        cluster_channels: Some(1),
        ..Default::default()
    };

    // --- contention picture at the bench point ------------------------------
    let mut t = Table::new(
        "netsim @ N=1000, cs=10 (uncongested vs contended)",
        &["Fabric", "Free completion", "Contended completion", "Contended packets"],
    );
    for (name, sc) in [
        ("centralized star", Scenario::CentralizedStar),
        ("decentralized mesh", Scenario::DecentralizedMesh),
        ("semi overlay", Scenario::SemiOverlay { head_capacity: 10.0 }),
    ] {
        let a = simulate_fabric(&model, sc, topo, &free).unwrap();
        let b = simulate_fabric(&model, sc, topo, &contended).unwrap();
        t.row(&[
            name.into(),
            a.completion.to_string(),
            b.completion.to_string(),
            format!("{} ({:.1}%)", b.contended_packets, b.contention_fraction() * 100.0),
        ]);
    }
    t.print();

    // --- engine timing -------------------------------------------------------
    let mut b = Bench::new();
    b.section("packet fabric (N=1000, cs=10)");
    b.case("centralized star, uncongested", || {
        black_box(simulate_fabric(&model, Scenario::CentralizedStar, topo, &free).unwrap())
    });
    b.case("centralized star, 16 rx ports", || {
        black_box(simulate_fabric(&model, Scenario::CentralizedStar, topo, &contended).unwrap())
    });
    b.case("decentralized mesh, dedicated", || {
        black_box(simulate_fabric(&model, Scenario::DecentralizedMesh, topo, &free).unwrap())
    });
    b.case("decentralized mesh, CSMA", || {
        black_box(
            simulate_fabric(&model, Scenario::DecentralizedMesh, topo, &contended).unwrap(),
        )
    });
    b.case("semi overlay, heads 10x", || {
        black_box(
            simulate_fabric(
                &model,
                Scenario::SemiOverlay { head_capacity: 10.0 },
                topo,
                &free,
            )
            .unwrap(),
        )
    });

    b.section("E9 sweep (small grid)");
    b.case("sweep 3 scales x 2 cluster sizes", || {
        black_box(
            NetsimSweep::run(
                &GnnWorkload::taxi(),
                &[200, 500, 1000],
                &[5, 10],
                &free,
            )
            .unwrap(),
        )
    });
}
