//! Microbench — the functional crossbar arrays (the simulator's compute
//! hot spot): MVM evaluate at the paper's three core geometries, CAM
//! search/scan, and the modeled-vs-host-wall comparison.
//!
//! `cargo bench --bench crossbar`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::config::{presets, CrossbarGeometry, DeviceParams};
use ima_gnn::crossbar::{CamCrossbar, MvmCrossbar};
use ima_gnn::graph::generate;
use ima_gnn::testing::Rng;

fn mvm(rows: usize, cols: usize, adcs: usize) -> (MvmCrossbar, Vec<u32>) {
    let mut rng = Rng::new(7);
    let mut g = CrossbarGeometry::new(rows, cols);
    g.adcs = adcs;
    let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
    let w: Vec<i32> = (0..rows * cols).map(|_| rng.i64_in(-8, 7) as i32).collect();
    xb.program(&w).unwrap();
    let input: Vec<u32> = (0..rows).map(|_| rng.u64_in(0, 255) as u32).collect();
    (xb, input)
}

fn main() {
    let mut b = Bench::new();

    b.section("MVM crossbar evaluate (8-bit inputs; dispatched fast path)");
    let (agg, agg_in) = mvm(512, 512, 8);
    let st = b.case("aggregation geometry 512x512", || black_box(agg.evaluate(&agg_in).unwrap()));
    println!(
        "    modeled on-chip: {} per full MVM ({} per pass) vs host wall {:.1} µs",
        agg.mvm_latency(),
        agg.pass_latency(),
        st.median_ns / 1e3
    );
    let (fe, fe_in) = mvm(128, 128, 32);
    b.case("feature geometry 128x128", || black_box(fe.evaluate(&fe_in).unwrap()));
    let (tr, tr_in) = mvm(512, 32, 8);
    b.case("traversal geometry 512x32", || black_box(tr.evaluate(&tr_in).unwrap()));

    b.section("MVM fast paths vs the seed bit-serial reference (512x512)");
    let rf = b
        .case("bit-serial reference", || black_box(agg.evaluate_reference(&agg_in).unwrap()))
        .median_ns;
    let mut out = vec![0i64; 512];
    let fu = b
        .case("fused clip-free evaluate_into", || {
            agg.evaluate_into(&agg_in, &mut out).unwrap();
            black_box(out[0])
        })
        .median_ns;
    // Like-for-like: the binary path is compared against the reference
    // on the SAME binary inputs (not the 8-bit ones — that would conflate
    // the input's plane count with the dispatch win).
    let binary_in: Vec<u32> = agg_in.iter().map(|&x| x & 1).collect();
    let rf_bin = b
        .case("bit-serial reference (binary inputs)", || {
            black_box(agg.evaluate_reference(&binary_in).unwrap())
        })
        .median_ns;
    let bi = b
        .case("binary single-plane evaluate_into", || {
            agg.evaluate_into(&binary_in, &mut out).unwrap();
            black_box(out[0])
        })
        .median_ns;
    println!(
        "    fused {:.1}x over the 8-bit reference, binary {:.1}x over the binary reference",
        rf / fu.max(1e-9),
        rf_bin / bi.max(1e-9)
    );

    b.section("accumulate_rows dense/sparse dispatch (512x512)");
    let mut rng = Rng::new(11);
    let mut sparse_mask = vec![0u64; 8];
    let mut dense_mask = vec![0u64; 8];
    for r in 0..512 {
        if rng.index(16) == 0 {
            sparse_mask[r / 64] |= 1u64 << (r % 64); // ~32 rows: sparse walk
        }
        if rng.index(8) != 0 {
            dense_mask[r / 64] |= 1u64 << (r % 64); // ~7/8 dense: word lanes
        }
    }
    b.case("sparse mask (~1/16 rows)", || {
        agg.accumulate_rows(&sparse_mask, &mut out).unwrap();
        black_box(out[0])
    });
    b.case("dense mask (~7/8 rows)", || {
        agg.accumulate_rows(&dense_mask, &mut out).unwrap();
        black_box(out[0])
    });

    b.section("CAM crossbar (traversal core ops)");
    let cfg = presets::decentralized();
    let mut cam = CamCrossbar::new(cfg.traversal.geometry, cfg.device.clone()).unwrap();
    let mut rng = Rng::new(3);
    let keys: Vec<u64> = (0..512).map(|_| rng.u64_in(0, 255)).collect();
    cam.load(&keys).unwrap();
    b.case("search over 512 rows", || black_box(cam.search(42)));
    b.case("compare_le over 512 rows", || black_box(cam.compare_le(100)));
    b.case("scan_owner", || black_box(cam.scan_owner(100)));

    b.section("traversal core end-to-end lookup (Fig. 3 dataflow)");
    use ima_gnn::cores::TraversalCore;
    let g = generate::regular(256, 2, 1).unwrap();
    let mut trav = TraversalCore::new(cfg.traversal, cfg.device).unwrap();
    trav.load_graph(&g).unwrap();
    let st = b.case("incoming(dst) on 256-node graph", || black_box(trav.incoming(17).unwrap()));
    println!(
        "    modeled on-chip t1 = {} vs host wall {:.2} µs (simulation overhead, not hw)",
        trav.per_node_latency(),
        st.median_ns / 1e3
    );
}
