//! E1 bench — regenerates Table 1 and times the analytic pipeline.
//!
//! `cargo bench --bench table1`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::experiments::Table1;
use ima_gnn::netmodel::{NetModel, Setting, Topology};

fn main() {
    let t1 = Table1::new().expect("model builds");
    t1.render().print();
    println!("max relative error vs paper: {:.3}%\n", t1.max_relative_error() * 100.0);

    let mut b = Bench::new();
    b.section("Table 1 model evaluation");
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology::taxi();
    b.case("netmodel: full Table 1 (10 cells)", || {
        let t = Table1::new().unwrap();
        black_box(t.rows())
    });
    b.case("netmodel: latency both settings", || {
        black_box((
            model.latency(Setting::Centralized, topo),
            model.latency(Setting::Decentralized, topo),
        ))
    });
    b.case("netmodel: power both settings", || {
        black_box((
            model.power(Setting::Centralized, topo),
            model.power(Setting::Decentralized, topo),
        ))
    });
    b.case("accelerator: per-node breakdown", || {
        let m = NetModel::paper(&GnnWorkload::taxi()).unwrap();
        black_box(*m.breakdown())
    });
}
