//! Comm bench — the Table 1 communication row (3.3 ms vs 406 ms) and the
//! link-model sweeps behind Fig. 8's ~790× average.
//!
//! `cargo bench --bench comm`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::comm::{InterClusterLink, InterNetworkLink};
use ima_gnn::config::CommConfig;
use ima_gnn::cores::GnnWorkload;
use ima_gnn::graph::datasets;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::report::{speedup, Table};

fn main() {
    let cfg = CommConfig::paper();
    let v2x = InterNetworkLink::new(cfg.clone());
    let adhoc = InterClusterLink::new(cfg);

    // --- Table 1 communication row -----------------------------------------
    let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
    let topo = Topology::taxi();
    let mut t = Table::new(
        "Table 1 — communication (864-byte taxi message)",
        &["Setting", "Modeled", "Paper"],
    );
    t.row(&[
        "Centralized (V2X, Eq. 5)".into(),
        model.communicate_latency(Setting::Centralized, topo).to_string(),
        "3.30 ms".into(),
    ]);
    t.row(&[
        "Decentralized (802.11n ad-hoc, Eq. 4)".into(),
        model.communicate_latency(Setting::Decentralized, topo).to_string(),
        "406 ms".into(),
    ]);
    t.print();

    // --- per-dataset wire model (Fig. 8 communication series) ---------------
    let mut t = Table::new(
        "per-dataset communication (8-bit features on the wire)",
        &["Dataset", "Message", "Centralized", "Decentralized", "Cent advantage"],
    );
    for d in datasets::all() {
        let m = NetModel::fig8(&d).unwrap();
        let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
        let c = m.communicate_latency(Setting::Centralized, topo);
        let dec = m.communicate_latency(Setting::Decentralized, topo);
        t.row(&[
            d.name.to_string(),
            format!("{} B", d.feature_len),
            c.to_string(),
            dec.to_string(),
            speedup(dec / c),
        ]);
    }
    t.print();

    // --- timing --------------------------------------------------------------
    let mut b = Bench::new();
    b.section("link model evaluation");
    b.case("v2x transfer(864B)", || black_box(v2x.transfer(864)));
    b.case("adhoc hop(864B)", || black_box(adhoc.hop(864)));
    b.case("adhoc relay_chain(864B, 4 hops)", || black_box(adhoc.relay_chain(864, 4)));
    b.case("comm row both settings", || {
        black_box((
            model.communicate_latency(Setting::Centralized, topo),
            model.communicate_latency(Setting::Decentralized, topo),
        ))
    });
}
