//! E3 bench — regenerates Fig. 8 (per-dataset latency breakdown + headline
//! averages) and times the per-dataset evaluation and the DES cross-check.
//!
//! `cargo bench --bench fig8`

use ima_gnn::bench::{black_box, Bench};
use ima_gnn::experiments::Fig8;
use ima_gnn::graph::datasets;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::sim::{simulate, SimConfig};

fn main() {
    let f = Fig8::new().expect("fig8 builds");
    f.render().print();
    println!("\n{}\n", f.summary());

    let mut b = Bench::new();
    b.section("Fig. 8 evaluation");
    b.case("all four datasets, both settings", || black_box(Fig8::new().unwrap()));
    for d in datasets::all() {
        let m = NetModel::fig8(&d).unwrap();
        let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
        b.case(&format!("analytic {}", d.name), || {
            black_box((
                m.latency(Setting::Centralized, topo),
                m.latency(Setting::Decentralized, topo),
            ))
        });
    }
    b.section("DES cross-check (scaled to 1000 devices)");
    for d in datasets::all() {
        let m = NetModel::fig8(&d).unwrap();
        let topo = Topology { nodes: d.nodes.min(1000), cluster_size: d.avg_cs.min(32) };
        b.case(&format!("DES decentralized {}", d.name), || {
            black_box(simulate(&m, Setting::Decentralized, topo, &SimConfig::default()).unwrap())
        });
    }
}
