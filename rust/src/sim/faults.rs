//! Deterministic fault-and-heterogeneity layer (DESIGN.md §13).
//!
//! The paper's crossover analysis (Fig. 8, Eqs. 4/5) assumes a uniform,
//! always-up fleet; real edge fleets are neither.  This module supplies
//! the *fault side* of the E14 robustness study:
//!
//! * [`FaultConfig`] / [`FaultPlan`] — a seeded schedule of device
//!   crash/recover windows, straggler (service-multiplier) windows and
//!   link-degradation windows.  Plans are pure functions of
//!   `(config, servers, horizon, seed)`; the traffic engine executes
//!   them on its [`EventQueue`] (`traffic::open_loop_faulted`), and an
//!   empty plan schedules nothing — the zero-fault run is bit-identical
//!   to the no-fault code path.
//! * [`FailoverCostModel`] — honest recovery pricing derived from the
//!   deployment's own links: detection (missed heartbeats at the link's
//!   packet latency), re-clustering, shard-table rebuild and
//!   feature-row re-upload, per setting.  These durations are exactly
//!   the outage windows the E14 sweep charges as downtime.
//! * [`head_failover`] — the *executed* semi-setting recovery: promote
//!   the fallback head, re-upload the cluster's rows through the
//!   [`RoundEngine`] double-buffer barrier, and record `fault.failover`
//!   / `fault.rebuild` spans whose durations are the cost model's — so
//!   trace sums reconcile with the sweep's downtime accounting.
//!
//! Determinism contract: plan generation draws from split [`Rng`]
//! streams keyed by `(seed, stream, server)`, crash windows are a
//! renewal process (up-time ~ Exp, outage fixed or Exp) and therefore
//! never overlap per server, and every window is validated finite with
//! `until > at`.  Same seed ⇒ byte-identical plan ⇒ byte-identical run.
//!
//! [`EventQueue`]: crate::sim::EventQueue
//! [`RoundEngine`]: crate::coordinator::RoundEngine

use crate::coordinator::RoundEngine;
use crate::error::{Error, Result};
use crate::graph::Clustering;
use crate::netmodel::NetModel;
use crate::obs::Obs;
use crate::testing::Rng;
use crate::units::Time;

/// Duration model of one crash outage window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Outage {
    /// Every outage lasts exactly this long — the E14 convention, where
    /// the duration *is* the [`FailoverCostModel`] recovery total.
    Fixed(Time),
    /// Exponential outage durations (repair crews, not protocols).
    Exponential { mean: Time },
}

/// What a crash does to the crashed device's queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CrashImpact {
    /// The device is gone: its in-service batch aborts and redispatches
    /// after recovery (r = 1 — no replicas to serve from).
    Outage,
    /// Halo replicas (`ShardPlan` built with `replicate ≥ 2`) keep the
    /// device's rows servable: the window degrades service by `factor`
    /// (the boundary-relay detour) instead of stalling it.
    Degraded { factor: f64 },
}

/// Seeded fault-injection knobs.  All rates are per server per second
/// of virtual time; [`FaultConfig::none`] disables everything.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Crash arrival rate (renewal process: up-time ~ Exp(1/rate)).
    pub crash_rate_per_s: f64,
    /// Outage-duration model for crash windows.
    pub outage: Outage,
    /// How a crash window hits the queue (full outage vs degraded mode).
    pub impact: CrashImpact,
    /// Straggler-window arrival rate (thermal throttling, background
    /// load): service during a window is scaled by `straggle_factor`.
    pub straggle_rate_per_s: f64,
    /// Mean straggler-window duration (exponential).
    pub mean_straggle: Time,
    /// Service multiplier (≥ 1) inside a straggler window.
    pub straggle_factor: f64,
    /// Link-degradation window arrival rate (shared-medium congestion,
    /// fleet-wide — one stream, not per server).
    pub link_rate_per_s: f64,
    /// Mean link-degradation window duration (exponential).
    pub mean_link: Time,
    /// Service multiplier (≥ 1) inside a link window.
    pub link_factor: f64,
}

impl FaultConfig {
    /// No faults of any kind: `generate` returns an empty plan.
    pub fn none() -> FaultConfig {
        FaultConfig {
            crash_rate_per_s: 0.0,
            outage: Outage::Fixed(Time::ZERO),
            impact: CrashImpact::Outage,
            straggle_rate_per_s: 0.0,
            mean_straggle: Time::ZERO,
            straggle_factor: 1.0,
            link_rate_per_s: 0.0,
            mean_link: Time::ZERO,
            link_factor: 1.0,
        }
    }

    /// Crash-only config (the E14 head-failure scenarios).
    pub fn crashes(rate_per_s: f64, outage: Outage, impact: CrashImpact) -> FaultConfig {
        FaultConfig { crash_rate_per_s: rate_per_s, outage, impact, ..FaultConfig::none() }
    }

    pub fn is_none(&self) -> bool {
        self.crash_rate_per_s == 0.0
            && self.straggle_rate_per_s == 0.0
            && self.link_rate_per_s == 0.0
    }

    pub fn validate(&self) -> Result<()> {
        let rate_ok = |r: f64| r.is_finite() && r >= 0.0;
        let factor_ok = |f: f64| f.is_finite() && f >= 1.0;
        let dur_ok = |t: Time| t.is_finite() && t.as_s() >= 0.0;
        let outage_ok = match self.outage {
            Outage::Fixed(d) => dur_ok(d),
            Outage::Exponential { mean } => dur_ok(mean),
        };
        let impact_ok = match self.impact {
            CrashImpact::Outage => true,
            CrashImpact::Degraded { factor } => factor_ok(factor),
        };
        if !rate_ok(self.crash_rate_per_s)
            || !rate_ok(self.straggle_rate_per_s)
            || !rate_ok(self.link_rate_per_s)
            || !outage_ok
            || !impact_ok
            || !dur_ok(self.mean_straggle)
            || !dur_ok(self.mean_link)
            || !factor_ok(self.straggle_factor)
            || !factor_ok(self.link_factor)
        {
            return Err(Error::Sim("fault config needs finite rates >= 0, factors >= 1".into()));
        }
        Ok(())
    }
}

/// What happens inside one fault window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// `server` is down for the whole window: in-service work aborts,
    /// pending requests wait, dispatch resumes at `until`.
    Crash { server: usize },
    /// `server` serves at `factor ×` its normal service time.
    Straggle { server: usize, factor: f64 },
    /// Every server's batch barrier pays `factor ×` (shared medium).
    LinkDegrade { factor: f64 },
}

/// One scheduled fault window `[at, until)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: Time,
    pub until: Time,
    pub kind: FaultKind,
}

/// A validated, time-sorted schedule of fault windows for a fixed
/// server count.  See the module docs for the determinism contract.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    servers: usize,
}

/// Deterministic per-stream RNG split: `(seed, stream, server)` pick
/// independent xorshift streams (odd multipliers, as in `testing::Rng`'s
/// own zero-seed remap constant family).
fn stream_rng(seed: u64, stream: u64, server: usize) -> Rng {
    Rng::new(
        seed.wrapping_add(stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((server as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)),
    )
}

fn exp_draw(rng: &mut Rng, mean: Time) -> Time {
    let u = rng.f64().max(1e-12);
    mean * (-u.ln())
}

impl FaultPlan {
    /// The empty plan: injecting it is bit-identical to not injecting.
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new(), servers: 0 }
    }

    /// Generate the seeded schedule over `[0, horizon)` for `servers`
    /// queues.  Windows may *start* before the horizon and end past it;
    /// crash windows per server never overlap (renewal process).
    pub fn generate(
        cfg: &FaultConfig,
        servers: usize,
        horizon: Time,
        seed: u64,
    ) -> Result<FaultPlan> {
        cfg.validate()?;
        if !horizon.is_finite() || horizon.as_s() < 0.0 {
            return Err(Error::Sim("fault horizon must be finite and >= 0".into()));
        }
        if cfg.is_none() || servers == 0 {
            return Ok(FaultPlan::none());
        }
        let mut events = Vec::new();
        for s in 0..servers {
            if cfg.crash_rate_per_s > 0.0 {
                let mut rng = stream_rng(seed, 1, s);
                let up_mean = Time::s(1.0 / cfg.crash_rate_per_s);
                let mut t = Time::ZERO;
                loop {
                    t += exp_draw(&mut rng, up_mean);
                    if t >= horizon {
                        break;
                    }
                    let dur = match cfg.outage {
                        Outage::Fixed(d) => d,
                        Outage::Exponential { mean } => exp_draw(&mut rng, mean),
                    };
                    // A zero-length window would be a no-op event pair
                    // that still perturbs queue tie-breaking; floor it.
                    let dur = if dur.as_s() > 0.0 { dur } else { Time::us(1.0) };
                    let kind = match cfg.impact {
                        CrashImpact::Outage => FaultKind::Crash { server: s },
                        CrashImpact::Degraded { factor } => {
                            FaultKind::Straggle { server: s, factor }
                        }
                    };
                    events.push(FaultEvent { at: t, until: t + dur, kind });
                    t += dur;
                }
            }
            if cfg.straggle_rate_per_s > 0.0 && cfg.mean_straggle.as_s() > 0.0 {
                let mut rng = stream_rng(seed, 2, s);
                let gap_mean = Time::s(1.0 / cfg.straggle_rate_per_s);
                let mut t = Time::ZERO;
                loop {
                    t += exp_draw(&mut rng, gap_mean);
                    if t >= horizon {
                        break;
                    }
                    let dur = exp_draw(&mut rng, cfg.mean_straggle);
                    events.push(FaultEvent {
                        at: t,
                        until: t + dur,
                        kind: FaultKind::Straggle { server: s, factor: cfg.straggle_factor },
                    });
                    t += dur;
                }
            }
        }
        if cfg.link_rate_per_s > 0.0 && cfg.mean_link.as_s() > 0.0 {
            let mut rng = stream_rng(seed, 3, 0);
            let gap_mean = Time::s(1.0 / cfg.link_rate_per_s);
            let mut t = Time::ZERO;
            loop {
                t += exp_draw(&mut rng, gap_mean);
                if t >= horizon {
                    break;
                }
                let dur = exp_draw(&mut rng, cfg.mean_link);
                events.push(FaultEvent {
                    at: t,
                    until: t + dur,
                    kind: FaultKind::LinkDegrade { factor: cfg.link_factor },
                });
                t += dur;
            }
        }
        FaultPlan::from_events(events, servers)
    }

    /// Build a plan from explicit windows (tests, hand-crafted
    /// scenarios).  Validates every window and sorts by
    /// `(at, kind, server)`; rejects overlapping crash windows on the
    /// same server — the engine's up/down state machine needs them
    /// disjoint.
    pub fn from_events(mut events: Vec<FaultEvent>, servers: usize) -> Result<FaultPlan> {
        let rank = |k: &FaultKind| match *k {
            FaultKind::Crash { server } => (0u8, server),
            FaultKind::Straggle { server, .. } => (1, server),
            FaultKind::LinkDegrade { .. } => (2, 0),
        };
        for e in &events {
            if !e.at.is_finite() || !e.until.is_finite() || e.at.as_s() < 0.0 || e.until <= e.at
            {
                return Err(Error::Sim("fault windows need 0 <= at < until, finite".into()));
            }
            let factor = match e.kind {
                FaultKind::Crash { .. } => 1.0,
                FaultKind::Straggle { factor, .. } | FaultKind::LinkDegrade { factor } => factor,
            };
            if !factor.is_finite() || factor < 1.0 {
                return Err(Error::Sim("fault factors must be finite and >= 1".into()));
            }
            let server = match e.kind {
                FaultKind::Crash { server } | FaultKind::Straggle { server, .. } => server,
                FaultKind::LinkDegrade { .. } => 0,
            };
            if server >= servers {
                return Err(Error::Sim(format!(
                    "fault window targets server {server} of {servers}"
                )));
            }
        }
        events.sort_by(|a, b| {
            a.at.partial_cmp(&b.at)
                .expect("validated finite")
                .then_with(|| rank(&a.kind).cmp(&rank(&b.kind)))
                .then_with(|| a.until.partial_cmp(&b.until).expect("validated finite"))
        });
        for s in 0..servers {
            let mut last_end = Time::ZERO;
            for e in &events {
                if let FaultKind::Crash { server } = e.kind {
                    if server == s {
                        if e.at < last_end {
                            return Err(Error::Sim(format!(
                                "overlapping crash windows on server {s}"
                            )));
                        }
                        last_end = e.until;
                    }
                }
            }
        }
        Ok(FaultPlan { events, servers })
    }

    /// Convert every crash window into a degraded-mode window at
    /// `factor` — the r ≥ 2 halo-replication counterfactual with the
    /// *same* failure times (so r = 1 vs r = 2 compare like for like).
    pub fn degraded(&self, factor: f64) -> Result<FaultPlan> {
        let events = self
            .events
            .iter()
            .map(|e| match e.kind {
                FaultKind::Crash { server } => FaultEvent {
                    kind: FaultKind::Straggle { server, factor },
                    ..*e
                },
                _ => *e,
            })
            .collect();
        FaultPlan::from_events(events, self.servers)
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Server count the plan was generated for (0 for the empty plan).
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// The crash windows of one server, in time order.
    pub fn crash_windows(&self, server: usize) -> Vec<(Time, Time)> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::Crash { server: s } if s == server => Some((e.at, e.until)),
                _ => None,
            })
            .collect()
    }

    /// Total scheduled outage across all crash windows — the downtime
    /// the traffic engine must reproduce when every window executes.
    pub fn total_outage(&self) -> Time {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                FaultKind::Crash { .. } => Some(e.until - e.at),
                _ => None,
            })
            .sum()
    }
}

/// One recovery's cost breakdown; every term is charged as downtime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryCost {
    /// Failure detection: missed heartbeats at the link's packet
    /// latency.
    pub detect: Time,
    /// Re-clustering around the fallback head (semi only).
    pub recluster: Time,
    /// Shard-table rebuild for the rows the failed device owned.
    pub rebuild: Time,
    /// Feature-row re-upload through the double-buffer barrier.
    pub reupload: Time,
}

impl RecoveryCost {
    pub fn total(&self) -> Time {
        self.detect + self.recluster + self.rebuild + self.reupload
    }
}

/// Per-unit recovery prices derived from the deployment's own network
/// model — the sweep cannot invent cheaper recoveries than the links
/// it already charges for serving.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailoverCostModel {
    /// Detection timeout: 3 missed heartbeats on the inter-network
    /// link.
    pub detect: Time,
    /// One feature row over the centralized uplink (L_n).
    pub upload_row_inter: Time,
    /// One feature row over a cluster-local hop (L_c).
    pub upload_row_intra: Time,
    /// Table rebuild per row (the feature-extraction core re-populates
    /// its crossbar row).
    pub rebuild_row: Time,
    /// Re-clustering bookkeeping per member (traversal-core scale).
    pub recluster_member: Time,
}

impl FailoverCostModel {
    /// Price recovery with the model's own links; `row_bytes` is one
    /// feature row (`feature_dim × 4` for f32 stores).
    pub fn from_net(model: &NetModel, row_bytes: usize) -> FailoverCostModel {
        let b = model.breakdown();
        FailoverCostModel {
            detect: model.inter_link().packet_latency() * 3.0,
            upload_row_inter: model.inter_link().transfer(row_bytes),
            upload_row_intra: model.intra_link().hop(row_bytes),
            rebuild_row: b.t3,
            recluster_member: b.t1,
        }
    }

    /// Leader crash: the whole hosted table rebuilds and re-uploads
    /// over the uplink.  `rows` is the serving store's row count.
    pub fn centralized(&self, rows: usize) -> RecoveryCost {
        RecoveryCost {
            detect: self.detect,
            recluster: Time::ZERO,
            rebuild: self.rebuild_row * rows as f64,
            reupload: self.upload_row_inter * rows as f64,
        }
    }

    /// Cluster-head crash: promote the fallback head, re-cluster the
    /// members, rebuild one shard and re-upload `members` rows locally.
    pub fn semi(&self, members: usize) -> RecoveryCost {
        RecoveryCost {
            detect: self.detect,
            recluster: self.recluster_member * members as f64,
            rebuild: self.rebuild_row * members as f64,
            reupload: self.upload_row_intra * members as f64,
        }
    }

    /// Device crash: reboot and re-upload its own row from a neighbor.
    pub fn decentralized(&self) -> RecoveryCost {
        RecoveryCost {
            detect: self.detect,
            recluster: Time::ZERO,
            rebuild: self.rebuild_row,
            reupload: self.upload_row_intra,
        }
    }
}

/// Result of one executed head failover.
#[derive(Debug, Clone, PartialEq)]
pub struct FailoverOutcome {
    pub cluster: usize,
    pub old_head: usize,
    /// The promoted fallback head (the cluster's next member).
    pub new_head: usize,
    /// Member rows re-uploaded through the barrier.
    pub rows_reuploaded: usize,
    pub cost: RecoveryCost,
    /// `at + cost.total()` — when the cluster serves again.
    pub recovered_at: Time,
}

/// Execute a semi-setting head failover against a live [`RoundEngine`]:
/// promote the fallback head, re-upload every member row (reads the
/// serving buffer, writes the staging buffer) and commit through the
/// double-buffer barrier (`end_round`).  Records `fault.failover` and
/// `fault.rebuild` spans at sim times `[at, at + cost.total())` so
/// span sums reconcile with downtime accounting, and bumps
/// `fault.failovers` / observes `fault.failover_ms` in `obs.metrics`.
pub fn head_failover(
    engine: &mut RoundEngine,
    clustering: &Clustering,
    cluster: usize,
    costs: &FailoverCostModel,
    at: Time,
    obs: &Obs,
) -> Result<FailoverOutcome> {
    if clustering.assignment.len() != engine.num_nodes() {
        return Err(Error::Sim("clustering does not cover the engine's graph".into()));
    }
    let members = clustering
        .clusters
        .get(cluster)
        .ok_or_else(|| Error::Sim(format!("no cluster {cluster} to fail over")))?;
    if members.len() < 2 {
        return Err(Error::Sim(format!(
            "cluster {cluster} has no fallback head ({} member)",
            members.len()
        )));
    }
    let old_head = members[0];
    let new_head = members[1];
    let cost = costs.semi(members.len());
    // Re-seed the promoted head's store: read each member's serving row
    // and stage it again, then commit atomically at the barrier.
    for &v in members.iter() {
        let row = engine.read(v)?.to_vec();
        engine.upload(v, &row)?;
    }
    engine.end_round();
    let recovered_at = at + cost.total();
    if obs.is_enabled() {
        let rebuild_start = at + cost.detect + cost.recluster;
        obs.tracer.record_at(
            "fault.rebuild",
            cluster as u64,
            rebuild_start,
            rebuild_start + cost.rebuild + cost.reupload,
            vec![("rows", (members.len() as i64).into())],
        );
        obs.tracer.record_at(
            "fault.failover",
            cluster as u64,
            at,
            recovered_at,
            vec![
                ("old_head", (old_head as i64).into()),
                ("new_head", (new_head as i64).into()),
            ],
        );
        obs.metrics.inc("fault.failovers", 1);
        obs.metrics.observe("fault.failover_ms", cost.total().as_ms());
    }
    Ok(FailoverOutcome {
        cluster,
        old_head,
        new_head,
        rows_reuploaded: members.len(),
        cost,
        recovered_at,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, forall, Rng};

    fn crash_cfg(rate: f64, outage_s: f64) -> FaultConfig {
        FaultConfig::crashes(rate, Outage::Fixed(Time::s(outage_s)), CrashImpact::Outage)
    }

    #[test]
    fn empty_config_generates_the_empty_plan() {
        let p = FaultPlan::generate(&FaultConfig::none(), 4, Time::s(100.0), 7).unwrap();
        assert!(p.is_empty());
        assert_eq!(p.total_outage(), Time::ZERO);
        assert_eq!(FaultPlan::none(), p);
    }

    #[test]
    fn generation_is_deterministic_and_seed_sensitive() {
        let cfg = crash_cfg(0.5, 2.0);
        let a = FaultPlan::generate(&cfg, 3, Time::s(50.0), 11).unwrap();
        let b = FaultPlan::generate(&cfg, 3, Time::s(50.0), 11).unwrap();
        assert_eq!(a, b);
        let c = FaultPlan::generate(&cfg, 3, Time::s(50.0), 12).unwrap();
        assert_ne!(a, c, "seed must matter");
        assert!(!a.is_empty());
    }

    #[test]
    fn crash_windows_are_disjoint_with_fixed_outages() {
        let p = FaultPlan::generate(&crash_cfg(2.0, 1.0), 2, Time::s(40.0), 5).unwrap();
        for s in 0..2 {
            let w = p.crash_windows(s);
            assert!(!w.is_empty());
            for pair in w.windows(2) {
                assert!(pair[0].1 <= pair[1].0, "windows overlap: {pair:?}");
            }
            for &(a, b) in &w {
                assert_close((b - a).as_s(), 1.0, 1e-9);
            }
        }
    }

    /// Renewal generation never overlaps and always validates, across
    /// random rates, outage models and horizons.
    #[test]
    fn property_generated_plans_validate() {
        forall(24, |rng: &mut Rng| {
            let cfg = FaultConfig {
                crash_rate_per_s: rng.f64() * 3.0,
                outage: if rng.bool() {
                    Outage::Fixed(Time::s(rng.f64() * 2.0 + 0.01))
                } else {
                    Outage::Exponential { mean: Time::s(rng.f64() + 0.01) }
                },
                impact: CrashImpact::Outage,
                straggle_rate_per_s: rng.f64(),
                mean_straggle: Time::s(rng.f64() + 0.01),
                straggle_factor: 1.0 + rng.f64() * 4.0,
                link_rate_per_s: rng.f64() * 0.5,
                mean_link: Time::s(rng.f64() + 0.01),
                link_factor: 1.0 + rng.f64(),
            };
            let servers = rng.index(4) + 1;
            let p =
                FaultPlan::generate(&cfg, servers, Time::s(rng.f64() * 30.0), rng.next_u64())
                    .unwrap();
            // Round-trips through the validating constructor.
            let again = FaultPlan::from_events(p.events().to_vec(), servers).unwrap();
            assert_eq!(p, again);
            assert!(p.events().windows(2).all(|w| w[0].at <= w[1].at));
        });
    }

    #[test]
    fn degraded_preserves_window_times() {
        let p = FaultPlan::generate(&crash_cfg(1.0, 0.5), 1, Time::s(20.0), 3).unwrap();
        let d = p.degraded(2.5).unwrap();
        assert_eq!(p.events().len(), d.events().len());
        assert!(d.crash_windows(0).is_empty(), "crashes became degraded windows");
        for (a, b) in p.events().iter().zip(d.events()) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.until, b.until);
            match b.kind {
                FaultKind::Straggle { server: 0, factor } => assert_eq!(factor, 2.5),
                ref k => panic!("unexpected kind {k:?}"),
            }
        }
    }

    #[test]
    fn from_events_rejects_bad_windows() {
        let w = |at: f64, until: f64, kind| FaultEvent {
            at: Time::s(at),
            until: Time::s(until),
            kind,
        };
        // until <= at
        assert!(FaultPlan::from_events(
            vec![w(1.0, 1.0, FaultKind::Crash { server: 0 })],
            1
        )
        .is_err());
        // factor < 1
        assert!(FaultPlan::from_events(
            vec![w(0.0, 1.0, FaultKind::Straggle { server: 0, factor: 0.5 })],
            1
        )
        .is_err());
        // server out of range
        assert!(FaultPlan::from_events(
            vec![w(0.0, 1.0, FaultKind::Crash { server: 2 })],
            2
        )
        .is_err());
        // overlapping crash windows on one server
        assert!(FaultPlan::from_events(
            vec![
                w(0.0, 2.0, FaultKind::Crash { server: 0 }),
                w(1.0, 3.0, FaultKind::Crash { server: 0 }),
            ],
            1
        )
        .is_err());
        // same windows on different servers are fine
        assert!(FaultPlan::from_events(
            vec![
                w(0.0, 2.0, FaultKind::Crash { server: 0 }),
                w(1.0, 3.0, FaultKind::Crash { server: 1 }),
            ],
            2
        )
        .is_ok());
    }

    #[test]
    fn cost_model_orders_settings_honestly() {
        use crate::cores::GnnWorkload;
        let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
        let m = FailoverCostModel::from_net(&model, 256);
        let cent = m.centralized(200);
        let semi = m.semi(10);
        let dec = m.decentralized();
        // Full-store leader recovery dwarfs a 10-member cluster rebuild,
        // which dwarfs a single-row device reboot (net of the shared
        // detection timeout).
        assert!(cent.total() > semi.total());
        assert!(semi.total() > dec.total());
        assert!(cent.rebuild + cent.reupload > (semi.rebuild + semi.reupload) * 2.0);
        assert!(dec.recluster == Time::ZERO && cent.recluster == Time::ZERO);
        assert!(semi.recluster > Time::ZERO);
        assert_close(
            cent.reupload.as_s(),
            (m.upload_row_inter * 200.0).as_s(),
            1e-12,
        );
    }
}
