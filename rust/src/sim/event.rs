//! Deterministic discrete-event queue.
//!
//! DESIGN.md: §6 (simulation).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::units::Time;

struct Entry<E> {
    time: Time,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        // Ties break by insertion sequence for determinism.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-time event queue with deterministic FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    max_len: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0, max_len: 0 }
    }

    /// Pre-sized queue for drivers that know their event count up front
    /// (the netsim scenarios schedule a predictable number of packet and
    /// compute events per device) — avoids heap regrowth mid-simulation.
    pub fn with_capacity(capacity: usize) -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::with_capacity(capacity), seq: 0, max_len: 0 }
    }

    /// Current allocated capacity.
    pub fn capacity(&self) -> usize {
        self.heap.capacity()
    }

    /// Schedule `payload` at absolute time `at`.
    pub fn push(&mut self, at: Time, payload: E) {
        assert!(at.value().is_finite() && at.value() >= 0.0, "event time must be finite/positive");
        self.heap.push(Entry { time: at, seq: self.seq, payload });
        self.seq += 1;
        self.max_len = self.max_len.max(self.heap.len());
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever scheduled.
    pub fn scheduled(&self) -> u64 {
        self.seq
    }

    /// High-water mark: the largest [`EventQueue::len`] ever reached.
    /// `len()` is the live depth gauge; this is its max over the run.
    pub fn max_depth(&self) -> usize {
        self.max_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Time::ns(5.0), "c");
        q.push(Time::ns(1.0), "a");
        q.push(Time::ns(3.0), "b");
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(Time::ns(7.0), i);
        }
        for i in 0..10 {
            assert_eq!(q.pop().unwrap().1, i);
        }
    }

    #[test]
    fn equal_timestamps_pop_in_insertion_order_among_mixed_times() {
        // The determinism tie-break the netsim fabric relies on: ties pop
        // FIFO even when interleaved with other timestamps and partial pops.
        let mut q = EventQueue::new();
        q.push(Time::ns(2.0), "b1");
        q.push(Time::ns(1.0), "a");
        q.push(Time::ns(2.0), "b2");
        q.push(Time::ns(3.0), "c");
        assert_eq!(q.pop().unwrap().1, "a");
        // Pushing another tie after a pop keeps FIFO order within the tie.
        q.push(Time::ns(2.0), "b3");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, ["b1", "b2", "b3", "c"]);
        assert_eq!(q.scheduled(), 5);
    }

    #[test]
    fn with_capacity_preallocates() {
        let mut q: EventQueue<u32> = EventQueue::with_capacity(128);
        assert!(q.capacity() >= 128);
        let before = q.capacity();
        for i in 0..128 {
            q.push(Time::ns(i as f64), i);
        }
        assert_eq!(q.capacity(), before, "no regrowth within the hint");
        assert_eq!(q.len(), 128);
    }

    #[test]
    fn property_monotone_pop_order() {
        forall(24, |rng: &mut Rng| {
            let mut q = EventQueue::new();
            let n = rng.index(200) + 1;
            for i in 0..n {
                q.push(Time::ns(rng.f64_in(0.0, 100.0)), i);
            }
            assert_eq!(q.len(), n);
            let mut last = Time::ZERO;
            let mut count = 0;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last, "time went backwards");
                last = t;
                count += 1;
            }
            assert_eq!(count, n);
        });
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_times() {
        let mut q = EventQueue::new();
        q.push(Time::s(f64::NAN), ());
    }

    #[test]
    fn max_depth_tracks_high_water_not_current_len() {
        let mut q = EventQueue::new();
        assert_eq!(q.max_depth(), 0);
        for i in 0..4 {
            q.push(Time::ns(i as f64), i);
        }
        assert_eq!(q.max_depth(), 4);
        q.pop();
        q.pop();
        // Depth fell to 2, the high-water stays at 4...
        assert_eq!(q.len(), 2);
        assert_eq!(q.max_depth(), 4);
        // ...and only a deeper backlog moves it.
        q.push(Time::ns(10.0), 10);
        assert_eq!(q.max_depth(), 4);
        for i in 0..5 {
            q.push(Time::ns(20.0 + i as f64), i);
        }
        assert_eq!(q.max_depth(), 8);
    }
}
