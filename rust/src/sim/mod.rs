//! Discrete-event simulator: the *executed* counterpart of the analytical
//! network model (the paper's "solid bottom-up evaluation framework").
//!
//! Devices are state machines driven by a deterministic event queue; link
//! transfers, CAM/MVM core occupancy and the leader's processing pipeline
//! are explicit events.  With jitter and contention disabled the simulated
//! completion times coincide with Eqs. (1)–(5); the extra knobs
//! (`link_jitter`, `shared_medium`, `overlap_cores`) then explore effects
//! the closed-form model cannot express — they feed the ablation benches.
//!
//! DESIGN.md: §6 (simulation).

mod event;
pub mod faults;

pub use event::EventQueue;
pub use faults::{
    head_failover, CrashImpact, FailoverCostModel, FailoverOutcome, FaultConfig, FaultEvent,
    FaultKind, FaultPlan, Outage, RecoveryCost,
};

use crate::cores::CoreBreakdown;
use crate::error::{Error, Result};
use crate::netmodel::{NetModel, Setting, Topology};
use crate::testing::Rng;
use crate::units::Time;

/// Simulation knobs.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Multiplicative jitter on every link transfer, uniform in
    /// `[1, 1 + link_jitter]`.  0 = deterministic (model cross-check).
    pub link_jitter: f64,
    /// Model the intra-cluster radio as a shared medium: only one transfer
    /// per cluster at a time (CSMA-like serialization).
    pub shared_medium: bool,
    /// Overlap the aggregation and feature-extraction cores (paper §2.3's
    /// parallel operation) instead of running them back to back.
    pub overlap_cores: bool,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { link_jitter: 0.0, shared_medium: false, overlap_cores: false, seed: 1 }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Time the last device (or the leader) finished.
    pub completion: Time,
    /// Communication portion of the makespan (last comm event).
    pub comm_done: Time,
    /// Events processed.
    pub events: usize,
    /// Devices simulated.
    pub devices: usize,
    /// Leader busy fraction (centralized only).
    pub leader_utilization: f64,
}

// The `device` / `cluster` payloads are part of the event-log contract
// (useful when tracing a simulation) even where the aggregate report does
// not consume them.
#[derive(Debug, Clone, Copy)]
#[allow(dead_code)]
enum Ev {
    /// A device's uplink message reached the leader (centralized).
    UplinkArrived { device: usize },
    /// Leader finished processing one node's pipeline slot (centralized).
    LeaderSlotDone,
    /// A device finished its cluster exchange phase (decentralized).
    ExchangeDone { device: usize },
    /// One serialized medium transfer finished (decentralized, CSMA).
    MediumFree { cluster: usize },
    /// A device finished computing.
    ComputeDone { device: usize },
}

/// Simulate one full inference round of the chosen deployment.
///
/// `model` provides the calibrated per-node core figures and link models;
/// `topo` the device count / cluster size.  Centralized simulation follows
/// the paper's assumptions (concurrent uplinks, no downlink accounted);
/// decentralized devices run setup + sequential exchange + compute.
pub fn simulate(
    model: &NetModel,
    setting: Setting,
    topo: Topology,
    cfg: &SimConfig,
) -> Result<SimReport> {
    match setting {
        Setting::Centralized => simulate_centralized(model, topo, cfg),
        Setting::Decentralized => simulate_decentralized(model, topo, cfg),
    }
}

/// Simulate the semi-decentralized hybrid (E8): members upload to their
/// cluster head concurrently over V2X, heads pipeline their members'
/// nodes at `head_capacity`× a member's rate, then exchange boundary data
/// with adjacent heads over the inter-network link.
pub fn simulate_semi(
    model: &NetModel,
    topo: Topology,
    head_capacity: f64,
    cfg: &SimConfig,
) -> Result<SimReport> {
    if topo.nodes == 0 || topo.cluster_size == 0 {
        return Err(Error::Sim("need nodes and a positive cluster size".into()));
    }
    if !(head_capacity >= 1.0) {
        return Err(Error::Sim("head capacity must be >= 1".into()));
    }
    let mut rng = Rng::new(cfg.seed);
    let cs = topo.cluster_size;
    let n_clusters = topo.nodes.div_ceil(cs);
    let uplink = model.inter_link().transfer(model.message_bytes());
    let b = model.breakdown();
    let per_member = per_node_compute(b, cfg.overlap_cores) * (1.0 / head_capacity);

    // Members upload concurrently; the head starts once its cluster is in,
    // processes its peers' nodes, exchanges boundary data with adjacent
    // heads (two-way) and downlinks results — 4 V2X transfers total, the
    // E8 analytic model, here with per-transfer jitter.
    let mut completion = Time::ZERO;
    let mut comm_done = Time::ZERO;
    let mut events = 0usize;
    for cluster in 0..n_clusters {
        let members = cs.min(topo.nodes - cluster * cs);
        let mut gathered = Time::ZERO;
        for _m in 0..members {
            let t = jittered(&mut rng, uplink, cfg.link_jitter);
            gathered = gathered.max(t);
            events += 1;
        }
        comm_done = comm_done.max(gathered);
        let head_done =
            gathered + per_member * (members.saturating_sub(1)).max(1) as f64;
        let boundary = jittered(&mut rng, uplink, cfg.link_jitter) * 2.0;
        let downlink = jittered(&mut rng, uplink, cfg.link_jitter);
        let cluster_done = head_done + boundary + downlink;
        comm_done = comm_done.max(cluster_done);
        completion = completion.max(cluster_done);
        events += 3;
    }
    Ok(SimReport {
        completion,
        comm_done,
        events,
        devices: topo.nodes,
        leader_utilization: 0.0,
    })
}

fn jittered(rng: &mut Rng, base: Time, jitter: f64) -> Time {
    if jitter <= 0.0 {
        base
    } else {
        base * rng.f64_in(1.0, 1.0 + jitter)
    }
}

fn per_node_compute(b: &CoreBreakdown, overlap: bool) -> Time {
    if overlap {
        b.overlapped_latency()
    } else {
        b.total_latency()
    }
}

fn simulate_centralized(model: &NetModel, topo: Topology, cfg: &SimConfig) -> Result<SimReport> {
    if topo.nodes == 0 {
        return Err(Error::Sim("topology needs at least one node".into()));
    }
    let mut rng = Rng::new(cfg.seed);
    let mut queue = EventQueue::new();
    let uplink = model.inter_link().transfer(model.message_bytes());
    // All devices transmit concurrently over the inter-network link.
    for device in 0..topo.nodes {
        queue.push(jittered(&mut rng, uplink, cfg.link_jitter), Ev::UplinkArrived { device });
    }
    // The leader pipelines nodes at the banked-core issue rate (Eq. 3's
    // per-node slot): the other N-1 devices' data each takes one slot.
    let (m1, m2, m3) = model.capacity_ratios();
    let b = model.breakdown();
    let slot = b.t1 * (1.0 / m1) + b.t2 * (1.0 / m2) + b.t3 * (1.0 / m3);

    let mut pending: usize = 0;
    let mut remaining = topo.nodes.saturating_sub(1); // N-1 peers to process
    let mut leader_busy_until = Time::ZERO;
    let mut leader_busy_total = Time::ZERO;
    let mut comm_done = Time::ZERO;
    let mut completion = Time::ZERO;
    let mut events = 0usize;

    while let Some((now, ev)) = queue.pop() {
        events += 1;
        completion = completion.max(now);
        match ev {
            Ev::UplinkArrived { .. } => {
                comm_done = comm_done.max(now);
                if remaining > 0 {
                    remaining -= 1;
                    pending += 1;
                    if pending == 1 {
                        // leader idle → start immediately
                        let start = leader_busy_until.max(now);
                        queue.push(start + slot, Ev::LeaderSlotDone);
                        leader_busy_until = start + slot;
                        leader_busy_total += slot;
                    }
                }
            }
            Ev::LeaderSlotDone => {
                pending -= 1;
                if pending > 0 {
                    queue.push(now + slot, Ev::LeaderSlotDone);
                    leader_busy_until = now + slot;
                    leader_busy_total += slot;
                }
            }
            _ => unreachable!("decentralized event in centralized sim"),
        }
    }
    let utilization = if completion > Time::ZERO { leader_busy_total / completion } else { 0.0 };
    Ok(SimReport {
        completion,
        comm_done,
        events,
        devices: topo.nodes,
        leader_utilization: utilization,
    })
}

fn simulate_decentralized(model: &NetModel, topo: Topology, cfg: &SimConfig) -> Result<SimReport> {
    if topo.nodes == 0 || topo.cluster_size == 0 {
        return Err(Error::Sim("need nodes and a positive cluster size".into()));
    }
    let mut rng = Rng::new(cfg.seed);
    let mut queue = EventQueue::new();
    let cs = topo.cluster_size;
    let n_clusters = topo.nodes.div_ceil(cs);
    let link = model.intra_link();
    let hop = link.hop(model.message_bytes());
    let setup = link.setup();
    let b = model.breakdown();
    let compute = per_node_compute(b, cfg.overlap_cores);

    // Device exchange duration: (tₑ + cₛ·hop) out + (tₑ + cₛ·hop) back.
    let mut comm_done = Time::ZERO;
    let mut completion = Time::ZERO;
    let mut events = 0usize;

    if cfg.shared_medium {
        // CSMA: one transfer at a time per cluster → the cluster's cₛ·cs
        // directed transfers serialize; devices then compute in parallel.
        // Simulated with a per-cluster medium token.
        let mut medium_free_at: Vec<Time> = vec![Time::ZERO; n_clusters];
        for cluster in 0..n_clusters {
            let members = cs.min(topo.nodes - cluster * cs);
            for member in 0..members {
                // setup runs off-medium, transfers hold it
                let mut dev_done = setup * 2.0;
                for _x in 0..cs {
                    let tr = jittered(&mut rng, hop * 2.0, cfg.link_jitter);
                    let start = dev_done.max(medium_free_at[cluster]);
                    dev_done = start + tr;
                    medium_free_at[cluster] = dev_done;
                    queue.push(dev_done, Ev::MediumFree { cluster });
                }
                let device = cluster * cs + member;
                queue.push(dev_done + compute, Ev::ComputeDone { device });
            }
        }
    } else {
        // Dedicated channels: each device exchanges with its cₛ adjacent
        // nodes sequentially (paper Eq. 4), all devices in parallel.
        for device in 0..topo.nodes {
            let mut t = Time::ZERO;
            // outbound session + inbound session
            for _dir in 0..2 {
                t += setup;
                for _x in 0..cs {
                    t += jittered(&mut rng, hop, cfg.link_jitter);
                }
            }
            queue.push(t, Ev::ExchangeDone { device });
        }
    }

    while let Some((now, ev)) = queue.pop() {
        events += 1;
        completion = completion.max(now);
        match ev {
            Ev::ExchangeDone { device } => {
                comm_done = comm_done.max(now);
                queue.push(now + compute, Ev::ComputeDone { device });
            }
            Ev::MediumFree { .. } => {
                comm_done = comm_done.max(now);
            }
            Ev::ComputeDone { .. } => {}
            _ => unreachable!("centralized event in decentralized sim"),
        }
    }
    Ok(SimReport {
        completion,
        comm_done,
        events,
        devices: topo.nodes,
        leader_utilization: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::GnnWorkload;
    use crate::testing::assert_close;

    fn model() -> NetModel {
        NetModel::paper(&GnnWorkload::taxi()).unwrap()
    }

    fn topo() -> Topology {
        // Scaled-down taxi topology keeps the DES fast while preserving
        // the structure (1000 devices, cₛ=10).
        Topology { nodes: 1000, cluster_size: 10 }
    }

    /// Deterministic DES must coincide with the analytical model.
    #[test]
    fn centralized_matches_analytic_model() {
        let m = model();
        let t = topo();
        let r = simulate(&m, Setting::Centralized, t, &SimConfig::default()).unwrap();
        let analytic = m.latency(Setting::Centralized, t);
        assert_close(r.completion.as_s(), analytic.total().as_s(), 1e-6);
        assert_close(r.comm_done.as_s(), analytic.communicate.as_s(), 1e-9);
        assert_eq!(r.devices, 1000);
        assert!(r.leader_utilization > 0.0 && r.leader_utilization <= 1.0);
    }

    #[test]
    fn decentralized_matches_analytic_model() {
        let m = model();
        let t = topo();
        let r = simulate(&m, Setting::Decentralized, t, &SimConfig::default()).unwrap();
        let analytic = m.latency(Setting::Decentralized, t);
        assert_close(r.completion.as_s(), analytic.total().as_s(), 1e-6);
        assert_close(r.comm_done.as_s(), analytic.communicate.as_s(), 1e-9);
    }

    #[test]
    fn jitter_only_delays() {
        let m = model();
        let t = topo();
        for setting in [Setting::Centralized, Setting::Decentralized] {
            let base = simulate(&m, setting, t, &SimConfig::default()).unwrap();
            let jit = simulate(
                &m,
                setting,
                t,
                &SimConfig { link_jitter: 0.3, ..Default::default() },
            )
            .unwrap();
            assert!(jit.completion >= base.completion, "{setting:?}");
        }
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let m = model();
        let t = topo();
        let cfg = SimConfig { link_jitter: 0.2, seed: 9, ..Default::default() };
        let a = simulate(&m, Setting::Decentralized, t, &cfg).unwrap();
        let b = simulate(&m, Setting::Decentralized, t, &cfg).unwrap();
        assert_eq!(a.completion, b.completion);
        let c = simulate(
            &m,
            Setting::Decentralized,
            t,
            &SimConfig { link_jitter: 0.2, seed: 10, ..Default::default() },
        )
        .unwrap();
        assert_ne!(a.completion, c.completion);
    }

    #[test]
    fn shared_medium_serializes_and_slows_clusters() {
        let m = model();
        let t = Topology { nodes: 100, cluster_size: 10 };
        let base = simulate(&m, Setting::Decentralized, t, &SimConfig::default()).unwrap();
        let csma = simulate(
            &m,
            Setting::Decentralized,
            t,
            &SimConfig { shared_medium: true, ..Default::default() },
        )
        .unwrap();
        assert!(
            csma.completion > base.completion * 2.0,
            "CSMA {} vs dedicated {}",
            csma.completion,
            base.completion
        );
    }

    #[test]
    fn core_overlap_shaves_compute() {
        let m = model();
        let t = Topology { nodes: 50, cluster_size: 5 };
        let base = simulate(&m, Setting::Decentralized, t, &SimConfig::default()).unwrap();
        let ov = simulate(
            &m,
            Setting::Decentralized,
            t,
            &SimConfig { overlap_cores: true, ..Default::default() },
        )
        .unwrap();
        assert!(ov.completion < base.completion);
        let saving = base.completion - ov.completion;
        // overlap hides t3 behind t2
        assert_close(saving.as_us(), m.breakdown().t3.as_us(), 0.01);
    }

    #[test]
    fn event_counts_scale_with_devices() {
        let m = model();
        let small =
            simulate(&m, Setting::Decentralized, Topology { nodes: 10, cluster_size: 5 }, &SimConfig::default())
                .unwrap();
        let big =
            simulate(&m, Setting::Decentralized, Topology { nodes: 100, cluster_size: 5 }, &SimConfig::default())
                .unwrap();
        assert!(big.events > small.events);
        assert_eq!(small.events, 10 * 2); // exchange + compute per device
    }

    #[test]
    fn semi_matches_analytic_e8_model() {
        let m = model();
        let t = Topology { nodes: 1000, cluster_size: 10 };
        let r = simulate_semi(&m, t, 10.0, &SimConfig::default()).unwrap();
        let analytic = m.semi_latency(t, 10.0);
        assert_close(r.completion.as_s(), analytic.total().as_s(), 1e-6);
    }

    #[test]
    fn semi_beats_both_extremes_at_scale() {
        let m = model();
        let t = Topology { nodes: 1_000_000, cluster_size: 10 };
        let semi = simulate_semi(&m, t, 10.0, &SimConfig::default()).unwrap();
        let cent = simulate(&m, Setting::Centralized, t, &SimConfig::default()).unwrap();
        let dec = simulate(&m, Setting::Decentralized, t, &SimConfig::default()).unwrap();
        assert!(semi.completion < cent.completion);
        assert!(semi.completion < dec.completion);
    }

    #[test]
    fn semi_rejects_bad_params() {
        let m = model();
        let t = Topology { nodes: 10, cluster_size: 5 };
        assert!(simulate_semi(&m, t, 0.5, &SimConfig::default()).is_err());
        assert!(simulate_semi(
            &m,
            Topology { nodes: 0, cluster_size: 5 },
            2.0,
            &SimConfig::default()
        )
        .is_err());
    }

    #[test]
    fn rejects_empty_topologies() {
        let m = model();
        assert!(simulate(&m, Setting::Centralized, Topology { nodes: 0, cluster_size: 1 }, &SimConfig::default()).is_err());
        assert!(simulate(&m, Setting::Decentralized, Topology { nodes: 5, cluster_size: 0 }, &SimConfig::default()).is_err());
    }
}
