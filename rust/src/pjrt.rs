//! PJRT backend shim.
//!
//! With the `pjrt` cargo feature enabled this module re-exports the real
//! `xla` crate (vendored separately; not part of the offline dependency
//! set — see DESIGN.md §5).  By default it provides an API-compatible stub
//! whose client constructor fails with a clear error, so every other layer
//! — coordinator, netsim, experiments, CLI, benches — builds and tests
//! offline with zero external dependencies.  Host-side [`Literal`]
//! round-trips (the part `runtime::Tensor` exercises in unit tests) are
//! fully functional even in the stub; only device compilation/execution
//! requires the real backend.

#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    const UNAVAILABLE: &str = "PJRT backend not compiled in: rebuild with \
         `--features pjrt` and a vendored `xla` crate (DESIGN.md §5)";

    /// Error produced by the stub backend.
    #[derive(Debug, Clone)]
    pub struct Error(pub String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    /// Element types artifacts exchange, plus the common XLA ones so match
    /// arms over foreign literals keep a reachable fallback.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum ElementType {
        Pred,
        S32,
        S64,
        U32,
        F32,
        F64,
    }

    /// Typed payload of a host literal.
    #[derive(Debug, Clone, PartialEq)]
    pub enum LiteralData {
        F32(Vec<f32>),
        I32(Vec<i32>),
    }

    /// Rust scalars that map onto an [`ElementType`].
    pub trait NativeType: Copy {
        const TY: ElementType;
        fn to_data(data: &[Self]) -> LiteralData;
        fn from_data(data: &LiteralData) -> Option<Vec<Self>>;
    }

    impl NativeType for f32 {
        const TY: ElementType = ElementType::F32;
        fn to_data(data: &[f32]) -> LiteralData {
            LiteralData::F32(data.to_vec())
        }
        fn from_data(data: &LiteralData) -> Option<Vec<f32>> {
            match data {
                LiteralData::F32(v) => Some(v.clone()),
                _ => None,
            }
        }
    }

    impl NativeType for i32 {
        const TY: ElementType = ElementType::S32;
        fn to_data(data: &[i32]) -> LiteralData {
            LiteralData::I32(data.to_vec())
        }
        fn from_data(data: &LiteralData) -> Option<Vec<i32>> {
            match data {
                LiteralData::I32(v) => Some(v.clone()),
                _ => None,
            }
        }
    }

    /// Host literal: typed buffer plus dimensions.
    #[derive(Debug, Clone, PartialEq)]
    pub struct Literal {
        data: LiteralData,
        dims: Vec<i64>,
    }

    impl Literal {
        /// Rank-1 literal from a host slice.
        pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
            Literal { data: T::to_data(data), dims: vec![data.len() as i64] }
        }

        fn len(&self) -> usize {
            match &self.data {
                LiteralData::F32(v) => v.len(),
                LiteralData::I32(v) => v.len(),
            }
        }

        /// Reinterpret under new dimensions (element count must match).
        pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
            let want: i64 = dims.iter().product();
            if want < 0 || want as usize != self.len() {
                return Err(Error(format!(
                    "reshape: dims {dims:?} incompatible with {} elements",
                    self.len()
                )));
            }
            Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
        }

        /// Shape (dims + element type) of this array literal.
        pub fn array_shape(&self) -> Result<ArrayShape, Error> {
            let ty = match &self.data {
                LiteralData::F32(_) => ElementType::F32,
                LiteralData::I32(_) => ElementType::S32,
            };
            Ok(ArrayShape { dims: self.dims.clone(), ty })
        }

        /// Copy the payload out as host scalars.
        pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
            T::from_data(&self.data)
                .ok_or_else(|| Error("literal element type mismatch".into()))
        }

        /// The stub never materializes tuple literals; an empty result tells
        /// the executor the root itself is the single output.
        pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
            Ok(Vec::new())
        }
    }

    /// Dimensions + element type of an array literal.
    #[derive(Debug, Clone)]
    pub struct ArrayShape {
        dims: Vec<i64>,
        ty: ElementType,
    }

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &self.dims
        }

        pub fn ty(&self) -> ElementType {
            self.ty
        }
    }

    /// Stub PJRT client — construction always fails with a clear message.
    #[derive(Debug)]
    pub struct PjRtClient {
        _priv: (),
    }

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(Error(UNAVAILABLE.into()))
        }

        pub fn platform_name(&self) -> String {
            "stub".into()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(Error(UNAVAILABLE.into()))
        }
    }

    /// Parsed HLO module (stub: never constructible).
    #[derive(Debug)]
    pub struct HloModuleProto {
        _priv: (),
    }

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            Err(Error(UNAVAILABLE.into()))
        }
    }

    /// Computation wrapper over a parsed proto.
    #[derive(Debug)]
    pub struct XlaComputation {
        _priv: (),
    }

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation { _priv: () }
        }
    }

    /// Compiled executable handle (stub: never constructible).
    #[derive(Debug)]
    pub struct PjRtLoadedExecutable {
        _priv: (),
    }

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(Error(UNAVAILABLE.into()))
        }
    }

    /// Device buffer handle (stub: never constructible).
    #[derive(Debug)]
    pub struct PjRtBuffer {
        _priv: (),
    }

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(Error(UNAVAILABLE.into()))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn client_reports_missing_backend() {
            let e = PjRtClient::cpu().unwrap_err();
            assert!(e.to_string().contains("pjrt"), "{e}");
        }

        #[test]
        fn literal_reshape_checks_element_count() {
            let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
            assert!(lit.reshape(&[2, 2]).is_ok());
            assert!(lit.reshape(&[3, 2]).is_err());
        }

        #[test]
        fn literal_round_trips_shape_and_type() {
            let lit = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]).reshape(&[2, 3]).unwrap();
            let shape = lit.array_shape().unwrap();
            assert_eq!(shape.dims(), &[2, 3]);
            assert_eq!(shape.ty(), ElementType::S32);
            assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
            assert!(lit.to_vec::<f32>().is_err());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::*;
