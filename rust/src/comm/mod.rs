//! Communication-link models (paper §3 + §4.2).
//!
//! * [`InterNetworkLink`] — the centralized setting's fast, mature
//!   infrastructure link L_n (V2X, paper ref [19]): a measured 1.1 ms
//!   latency per 300-byte packet at 300 m; larger messages packetize.
//! * [`InterClusterLink`] — the decentralized setting's ad-hoc link L_c
//!   (IEEE 802.11n ch. 9, 2.452 GHz, −31 dBm, 20 MHz; paper ref [20]):
//!   per-hop store-and-forward delay plus serialization at the effective
//!   goodput, with a connection-establishment time tₑ per peer session.
//!
//! DESIGN.md: §4 (network model); §6 reuses these link timings.

use crate::config::CommConfig;
use crate::units::{Energy, Power, Time};

/// The centralized inter-network link L_n.
#[derive(Debug, Clone)]
pub struct InterNetworkLink {
    cfg: CommConfig,
}

impl InterNetworkLink {
    pub fn new(cfg: CommConfig) -> InterNetworkLink {
        InterNetworkLink { cfg }
    }

    /// Packets needed for `bytes` of payload.
    pub fn packets(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.cfg.v2x_packet_bytes).max(1)
    }

    /// One-way transfer latency t(L_n) for a message of `bytes`.
    /// The taxi case: 864 B → 3 packets → ≈3.3 ms (paper §4.2).
    pub fn transfer(&self, bytes: usize) -> Time {
        self.cfg.v2x_packet_latency * self.packets(bytes) as f64
    }

    /// Latency of one on-air packet — the schedulable unit the
    /// packet-level `netsim` fabric queues on this link.
    pub fn packet_latency(&self) -> Time {
        self.cfg.v2x_packet_latency
    }

    /// Link power p(L_n) while transferring (radio TX power).
    pub fn power(&self) -> Power {
        self.cfg.v2x_tx_power
    }
}

/// The decentralized inter-cluster ad-hoc link L_c.
#[derive(Debug, Clone)]
pub struct InterClusterLink {
    cfg: CommConfig,
}

impl InterClusterLink {
    pub fn new(cfg: CommConfig) -> InterClusterLink {
        InterClusterLink { cfg }
    }

    /// Connection-establishment time tₑ (association + route discovery).
    pub fn setup(&self) -> Time {
        self.cfg.adhoc_setup
    }

    /// One-hop relay latency t(L_c) for a message of `bytes`:
    /// store-and-forward fixed delay + serialization at the goodput.
    pub fn hop(&self, bytes: usize) -> Time {
        self.cfg.adhoc_hop_latency + Time::s(bytes as f64 / self.cfg.adhoc_goodput_bps)
    }

    /// Multi-hop relay chain latency: source feeds proxy nodes which
    /// forward to the next (paper §4.2's relaying configuration).
    pub fn relay_chain(&self, bytes: usize, hops: usize) -> Time {
        self.hop(bytes) * hops.max(1) as f64
    }

    /// Energy to push `bytes` through one hop (Eq. 7's E_perBit).
    pub fn hop_energy(&self, bytes: usize) -> Energy {
        self.cfg.adhoc_energy_per_bit * (bytes * 8) as f64
    }

    /// Average radiated+circuit power while a transfer is in flight.
    pub fn power(&self, bytes: usize) -> Power {
        self.hop_energy(bytes) / self.hop(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CommConfig;
    use crate::testing::assert_close;

    fn links() -> (InterNetworkLink, InterClusterLink) {
        let cfg = CommConfig::paper();
        (InterNetworkLink::new(cfg.clone()), InterClusterLink::new(cfg))
    }

    #[test]
    fn v2x_packetization_matches_paper_taxi_case() {
        let (n, _) = links();
        assert_eq!(n.packets(300), 1);
        assert_eq!(n.packets(301), 2);
        assert_eq!(n.packets(864), 3);
        // "for a packet size of 864 bytes ... ~3.3 ms" (§4.2)
        assert_close(n.transfer(864).as_ms(), 3.3, 1e-9);
        assert_close(n.transfer(300).as_ms(), 1.1, 1e-9);
    }

    #[test]
    fn v2x_zero_bytes_still_costs_one_packet() {
        let (n, _) = links();
        assert_eq!(n.packets(0), 1);
    }

    #[test]
    fn adhoc_hop_combines_fixed_and_serialization() {
        let (_, c) = links();
        // 864 B at 1 MB/s = 0.864 ms on top of the 10.8 ms hop delay.
        assert_close(c.hop(864).as_ms(), 11.664, 1e-9);
        assert!(c.hop(0) < c.hop(1000));
    }

    #[test]
    fn relay_chain_scales_linearly_in_hops() {
        let (_, c) = links();
        let one = c.hop(500);
        assert_close(c.relay_chain(500, 4).as_ms(), (one * 4.0).as_ms(), 1e-12);
        // zero hops clamp to one
        assert_close(c.relay_chain(500, 0).as_ms(), one.as_ms(), 1e-12);
    }

    #[test]
    fn hop_energy_is_per_bit() {
        let (_, c) = links();
        let e1 = c.hop_energy(100);
        let e2 = c.hop_energy(200);
        assert_close(e2.as_j(), (e1 * 2.0).as_j(), 1e-12);
        assert!(c.power(864).as_w() > 0.0);
    }

    #[test]
    fn centralized_link_is_much_faster_for_taxi_messages() {
        let (n, c) = links();
        // One full decentralized exchange (tₑ + cₛ·t(L_c)) · 2 vs t(L_n):
        let dec = (c.setup() + c.hop(864) * 10.0) * 2.0;
        let cent = n.transfer(864);
        assert!(dec / cent > 100.0, "expected >100×, got {}", dec / cent);
    }
}
