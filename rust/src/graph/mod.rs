//! Graph substrate: CSR storage, generators, the Table 2 dataset registry,
//! neighbor sampling and cluster partitioning.

mod cluster;
mod csr;
pub mod datasets;
pub mod generate;
mod sample;

pub use cluster::{fixed_size, locality, Clustering};
pub use csr::Csr;
pub use datasets::DatasetStats;
pub use sample::NeighborSampler;
