//! Graph substrate: CSR storage, generators, the Table 2 dataset registry,
//! neighbor sampling, cluster partitioning and table-sharded execution
//! plans.

mod cluster;
mod csr;
pub mod datasets;
pub mod generate;
mod sample;
mod shard;

pub use cluster::{fixed_size, locality, Clustering};
pub use csr::Csr;
pub use datasets::DatasetStats;
pub use sample::NeighborSampler;
pub use shard::{Shard, ShardPlan};
