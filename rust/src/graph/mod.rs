//! Graph substrate: CSR storage, generators, the Table 2 dataset registry,
//! neighbor sampling, cluster partitioning, table-sharded execution
//! plans, and the million-node residency tier (compressed CSR +
//! byte-budgeted shard streaming, DESIGN.md §16).

mod cluster;
mod compact;
mod csr;
pub mod datasets;
pub mod generate;
mod resident;
mod sample;
mod shard;

pub use cluster::{fixed_size, locality, Clustering};
pub use compact::{CompactCsr, FeatureQuant, QuantizedFeatures};
pub use csr::Csr;
pub use datasets::DatasetStats;
pub use resident::ResidentSet;
pub use sample::NeighborSampler;
pub use shard::{Shard, ShardPlan};
