//! Out-of-core shard residency: a byte-budgeted LRU of decoded shard
//! tables over a quantized encoded tier (DESIGN.md §16).
//!
//! [`ResidentSet`] holds every shard's feature table in *encoded* form
//! ([`QuantizedFeatures`] — the out-of-core tier) and decodes shards on
//! demand into an LRU cache whose decoded footprint never exceeds
//! `budget_bytes` (`peak_bytes() ≤ budget`, asserted in tests).  After
//! every fetch the next shard in [`ShardPlan`] order is prefetched —
//! decoded into the cache while the current shard's batch is in the
//! PJRT funnel — unless it cannot fit without evicting the shard just
//! returned (then it is skipped and counted).  All bookkeeping lives
//! behind a `RefCell`, so fetches take `&self` (matching the engine's
//! serve path) and the set is `!Sync`: the access sequence, and with it
//! the eviction order, is a deterministic function of the fetch order
//! alone — never of thread count (asserted in tests).
//!
//! Accounting surfaces as `obs` metrics: `resident.hits` /
//! `resident.misses` / `resident.evictions` /
//! `resident.prefetch_issued` / `resident.prefetch_hits` /
//! `resident.prefetch_skipped` counters and the `resident.bytes` /
//! `resident.peak_bytes` gauges.
//!
//! [`ShardPlan`]: crate::graph::ShardPlan

use std::cell::RefCell;

use crate::error::{Error, Result};
use crate::obs::MetricsRegistry;
use crate::runtime::Tensor;

use super::compact::{FeatureQuant, QuantizedFeatures};

/// LRU bookkeeping (interior-mutable so fetches take `&self`).
#[derive(Debug, Default)]
struct Lru {
    /// Decoded shard tables; tensor payloads are Arc-backed, so handing
    /// one to a serve batch is a refcount bump, not a copy.
    cached: Vec<Option<Tensor>>,
    /// Monotonic last-access stamp per shard (0 = not resident).
    stamp: Vec<u64>,
    /// Cached by prefetch and not yet served (cleared on first hit).
    speculative: Vec<bool>,
    seq: u64,
    bytes: usize,
    peak: usize,
}

/// Byte-budgeted resident tier over encoded shard tables (module docs).
#[derive(Debug)]
pub struct ResidentSet {
    quant: FeatureQuant,
    budget: usize,
    feature: usize,
    /// Encoded (out-of-core) tier, one blob per shard once stored.
    encoded: Vec<Option<QuantizedFeatures>>,
    metrics: MetricsRegistry,
    lru: RefCell<Lru>,
}

impl ResidentSet {
    /// A set over `shards` shard slots of `feature`-wide rows, holding
    /// at most `budget_bytes` of decoded f32 payload at once.
    pub fn new(
        shards: usize,
        feature: usize,
        quant: FeatureQuant,
        budget_bytes: usize,
    ) -> Result<ResidentSet> {
        if feature == 0 {
            return Err(Error::Graph("resident set needs a non-zero feature width".into()));
        }
        Ok(ResidentSet {
            quant,
            budget: budget_bytes,
            feature,
            encoded: (0..shards).map(|_| None).collect(),
            metrics: MetricsRegistry::new(),
            lru: RefCell::new(Lru {
                cached: vec![None; shards],
                stamp: vec![0; shards],
                speculative: vec![false; shards],
                ..Lru::default()
            }),
        })
    }

    pub fn num_shards(&self) -> usize {
        self.encoded.len()
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    pub fn quant(&self) -> FeatureQuant {
        self.quant
    }

    /// Encode a shard's decoded table into the out-of-core tier,
    /// invalidating any cached copy.  `values.len()` must be a multiple
    /// of the feature width, and the decoded payload must fit the
    /// budget on its own (otherwise no fetch could ever serve it).
    pub fn store(&mut self, shard: usize, values: &[f32]) -> Result<()> {
        if shard >= self.encoded.len() {
            return Err(Error::Graph(format!(
                "shard {shard} out of range ({} shards)",
                self.encoded.len()
            )));
        }
        if values.len() % self.feature != 0 {
            return Err(Error::Graph(format!(
                "shard payload {} is not a multiple of feature width {}",
                values.len(),
                self.feature
            )));
        }
        let decoded = values.len() * std::mem::size_of::<f32>();
        if decoded > self.budget {
            return Err(Error::Graph(format!(
                "shard {shard} needs {decoded} decoded bytes, over the {}-byte budget",
                self.budget
            )));
        }
        self.encoded[shard] = Some(QuantizedFeatures::encode(self.quant, values)?);
        // A stale decoded copy must not serve the old round's table.
        let lru = self.lru.get_mut();
        if let Some(old) = lru.cached[shard].take() {
            lru.bytes -= tensor_bytes(&old);
            lru.stamp[shard] = 0;
            lru.speculative[shard] = false;
        }
        Ok(())
    }

    /// Fetch a shard's decoded table, decoding on miss and prefetching
    /// its successor (`(shard + 1) % shards`).  The returned tensor is
    /// `[rows, feature]`-shaped; cloning it is a refcount bump.
    pub fn fetch(&self, shard: usize) -> Result<Tensor> {
        let blob_exists = self
            .encoded
            .get(shard)
            .map(Option::is_some)
            .unwrap_or(false);
        if !blob_exists {
            return Err(Error::Graph(format!(
                "shard {shard} has no encoded table (store before fetch)"
            )));
        }
        let mut lru = self.lru.borrow_mut();
        let tensor = if let Some(t) = lru.cached[shard].clone() {
            lru.seq += 1;
            let seq = lru.seq;
            lru.stamp[shard] = seq;
            self.metrics.inc("resident.hits", 1);
            if lru.speculative[shard] {
                lru.speculative[shard] = false;
                self.metrics.inc("resident.prefetch_hits", 1);
            }
            t
        } else {
            self.metrics.inc("resident.misses", 1);
            let t = self.decode(shard)?;
            self.insert(&mut lru, shard, t.clone(), shard, false)?;
            t
        };
        self.prefetch_next(&mut lru, shard)?;
        self.publish_gauges(&lru);
        Ok(tensor)
    }

    /// Decoded bytes currently resident in the LRU.
    pub fn bytes_resident(&self) -> usize {
        self.lru.borrow().bytes
    }

    /// High-water mark of [`Self::bytes_resident`] over the set's life.
    pub fn peak_bytes(&self) -> usize {
        self.lru.borrow().peak
    }

    /// Whether a shard is currently decoded in the cache.
    pub fn is_resident(&self, shard: usize) -> bool {
        self.lru.borrow().cached.get(shard).map(Option::is_some).unwrap_or(false)
    }

    /// Total encoded footprint of the out-of-core tier.
    pub fn encoded_bytes(&self) -> usize {
        self.encoded.iter().flatten().map(QuantizedFeatures::encoded_bytes).sum()
    }

    /// Total decoded footprint if every stored shard were resident at
    /// once — what an unbounded cache would hold.
    pub fn unbounded_bytes(&self) -> usize {
        self.encoded.iter().flatten().map(QuantizedFeatures::decoded_bytes).sum()
    }

    /// Hit/miss/prefetch counters and the bytes/peak gauges.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Fraction of fetches served from the cache (1.0 before any).
    pub fn hit_rate(&self) -> f64 {
        let hits = self.metrics.counter_value("resident.hits") as f64;
        let misses = self.metrics.counter_value("resident.misses") as f64;
        if hits + misses == 0.0 {
            return 1.0;
        }
        hits / (hits + misses)
    }

    fn decode(&self, shard: usize) -> Result<Tensor> {
        let blob = self.encoded[shard].as_ref().expect("caller checked the blob exists");
        let mut values = Vec::new();
        blob.decode_into(&mut values);
        let rows = values.len() / self.feature;
        Tensor::f32(&[rows, self.feature], values)
    }

    /// Insert a decoded tensor, evicting least-recently-used shards
    /// (never `pin`) until it fits.  Errors if it cannot fit.
    fn insert(
        &self,
        lru: &mut Lru,
        shard: usize,
        tensor: Tensor,
        pin: usize,
        speculative: bool,
    ) -> Result<()> {
        let size = tensor_bytes(&tensor);
        while lru.bytes + size > self.budget {
            let victim = lru
                .cached
                .iter()
                .enumerate()
                .filter(|(s, t)| t.is_some() && *s != pin)
                .min_by_key(|&(s, _)| lru.stamp[s])
                .map(|(s, _)| s);
            match victim {
                Some(v) => {
                    let evicted = lru.cached[v].take().expect("victim is cached");
                    lru.bytes -= tensor_bytes(&evicted);
                    lru.stamp[v] = 0;
                    lru.speculative[v] = false;
                    self.metrics.inc("resident.evictions", 1);
                }
                None => {
                    return Err(Error::Graph(format!(
                        "shard {shard} ({size} B) cannot fit the {}-byte budget \
                         without evicting the pinned shard {pin}",
                        self.budget
                    )))
                }
            }
        }
        lru.bytes += size;
        lru.peak = lru.peak.max(lru.bytes);
        lru.seq += 1;
        lru.stamp[shard] = lru.seq;
        lru.speculative[shard] = speculative;
        lru.cached[shard] = Some(tensor);
        Ok(())
    }

    /// Deterministic next-shard prefetch: decode `(shard + 1) % shards`
    /// ahead of its fetch unless that would evict `shard` itself (its
    /// batch is still in flight through the PJRT funnel).
    fn prefetch_next(&self, lru: &mut Lru, shard: usize) -> Result<()> {
        let shards = self.encoded.len();
        if shards < 2 {
            return Ok(());
        }
        let next = (shard + 1) % shards;
        if next == shard || lru.cached[next].is_some() {
            return Ok(());
        }
        let blob = match self.encoded[next].as_ref() {
            Some(b) => b,
            None => return Ok(()),
        };
        let pinned = decoded_bytes(&lru.cached, shard);
        if blob.decoded_bytes() + pinned > self.budget {
            self.metrics.inc("resident.prefetch_skipped", 1);
            return Ok(());
        }
        let t = self.decode(next)?;
        self.insert(lru, next, t, shard, true)?;
        self.metrics.inc("resident.prefetch_issued", 1);
        Ok(())
    }

    fn publish_gauges(&self, lru: &Lru) {
        self.metrics.set_gauge("resident.bytes", lru.bytes as f64);
        self.metrics.raise_gauge("resident.peak_bytes", lru.peak as f64);
    }
}

fn tensor_bytes(t: &Tensor) -> usize {
    t.as_f32().map(|v| v.len()).unwrap_or(0) * std::mem::size_of::<f32>()
}

fn decoded_bytes(cached: &[Option<Tensor>], shard: usize) -> usize {
    cached.get(shard).and_then(Option::as_ref).map(tensor_bytes).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ints(seed: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((seed * 31 + i * 7) % 512) as f32).collect()
    }

    fn set(shards: usize, rows: usize, budget_shards: usize) -> ResidentSet {
        let feature = 2;
        let budget = rows * feature * 4 * budget_shards;
        let mut s = ResidentSet::new(shards, feature, FeatureQuant::ExactI32, budget).unwrap();
        for shard in 0..shards {
            s.store(shard, &ints(shard, rows * feature)).unwrap();
        }
        s
    }

    #[test]
    fn fetch_decodes_exactly_and_counts_hits_and_misses() {
        let s = set(4, 8, 4);
        let t = s.fetch(2).unwrap();
        assert_eq!(t.as_f32().unwrap(), &ints(2, 16)[..]);
        assert_eq!(s.metrics().counter_value("resident.misses"), 1);
        let again = s.fetch(2).unwrap();
        assert_eq!(again.as_f32().unwrap(), t.as_f32().unwrap());
        assert_eq!(s.metrics().counter_value("resident.hits"), 1);
    }

    #[test]
    fn peak_never_exceeds_the_budget() {
        let s = set(6, 8, 2);
        let shard_bytes = 8 * 2 * 4;
        for shard in [0, 3, 1, 4, 2, 5, 0, 5, 3] {
            s.fetch(shard).unwrap();
            assert!(s.bytes_resident() <= s.budget_bytes());
        }
        assert!(s.peak_bytes() <= s.budget_bytes());
        assert_eq!(s.peak_bytes(), 2 * shard_bytes);
        assert!(s.metrics().counter_value("resident.evictions") > 0);
        assert!(s.unbounded_bytes() > s.budget_bytes());
    }

    #[test]
    fn sequential_order_turns_prefetches_into_hits() {
        let s = set(5, 8, 3);
        for shard in 0..5 {
            s.fetch(shard).unwrap();
        }
        // Shard 0 misses cold; 1..4 were each prefetched by the
        // previous fetch.
        assert_eq!(s.metrics().counter_value("resident.misses"), 1);
        assert_eq!(s.metrics().counter_value("resident.prefetch_hits"), 4);
        assert!(s.metrics().counter_value("resident.prefetch_issued") >= 4);
        assert!(s.hit_rate() > 0.7);
    }

    #[test]
    fn prefetch_never_evicts_the_pinned_shard() {
        // Budget of exactly one shard: the successor can never join the
        // just-fetched shard, so every prefetch is skipped and the
        // pinned shard stays resident.
        let s = set(3, 8, 1);
        for shard in [0, 1, 2, 0] {
            s.fetch(shard).unwrap();
            assert!(s.is_resident(shard));
        }
        assert_eq!(s.metrics().counter_value("resident.prefetch_issued"), 0);
        assert_eq!(s.metrics().counter_value("resident.prefetch_skipped"), 4);
        assert_eq!(s.metrics().counter_value("resident.misses"), 4);
    }

    #[test]
    fn mixed_shard_sizes_stay_under_budget() {
        // Adversarial mix: shard payloads of very different sizes.
        let feature = 1;
        let sizes = [4usize, 64, 16, 256, 8, 128];
        let budget = 300 * 4; // fits the biggest shard, not the sum
        let mut s = ResidentSet::new(6, feature, FeatureQuant::ExactI32, budget).unwrap();
        for (shard, &len) in sizes.iter().enumerate() {
            s.store(shard, &ints(shard, len)).unwrap();
        }
        for round in 0..3 {
            for shard in [3, 0, 5, 1, 4, 2, 3, 5] {
                let t = s.fetch(shard).unwrap();
                assert_eq!(t.as_f32().unwrap(), &ints(shard, sizes[shard])[..], "round {round}");
                assert!(s.bytes_resident() <= budget);
            }
        }
        assert!(s.peak_bytes() <= budget);
    }

    #[test]
    fn store_rejects_oversized_and_misaligned_payloads() {
        let mut s = ResidentSet::new(2, 4, FeatureQuant::ExactI32, 64).unwrap();
        assert!(s.store(0, &ints(0, 6)).is_err(), "not a multiple of feature width");
        assert!(s.store(0, &ints(0, 32)).is_err(), "128 B payload over a 64 B budget");
        assert!(s.store(9, &ints(0, 4)).is_err(), "shard out of range");
        assert!(s.fetch(0).is_err(), "fetch before store");
        s.store(0, &ints(0, 8)).unwrap();
        assert!(s.fetch(0).is_ok());
    }

    #[test]
    fn restoring_a_shard_invalidates_its_cached_copy() {
        let mut s = set(2, 4, 2);
        let before = s.fetch(0).unwrap().as_f32().unwrap().to_vec();
        let fresh = ints(7, 8);
        s.store(0, &fresh).unwrap();
        let after = s.fetch(0).unwrap();
        assert_eq!(after.as_f32().unwrap(), &fresh[..]);
        assert_ne!(after.as_f32().unwrap(), &before[..]);
        assert!(s.bytes_resident() <= s.budget_bytes());
    }

    #[test]
    fn eviction_order_is_a_pure_function_of_the_fetch_sequence() {
        let pattern = [0usize, 2, 4, 1, 3, 0, 4, 2, 2, 1, 0, 3];
        let run = || {
            let s = set(5, 8, 2);
            for &shard in &pattern {
                s.fetch(shard).unwrap();
            }
            s.metrics().to_json()
        };
        assert_eq!(run(), run());
    }
}
