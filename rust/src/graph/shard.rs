//! Table-sharded execution plan (DESIGN.md §10).
//!
//! The AOT serving artifacts bind a *static* feature-table dimension
//! (`GcnLayerBinding::table`), and the seed coordinators simply rejected
//! any graph larger than it ("shard the graph").  [`ShardPlan`] does the
//! sharding instead: it packs nodes — or whole clusters, so a semi
//! head's members never span shards — into table-sized shards, assigns
//! every node a home `(shard, slot)`, and appends *halo* slots that
//! replicate exactly the out-of-shard sampled neighbors.  Every neighbor
//! index a shard's members can reference therefore resolves locally, and
//! the plan pre-remaps each member's deterministic neighbor sample to
//! local slots, so a serving round never touches global ids after the
//! plan is built.
//!
//! Halo **replication** (`replicate ≥ 2`, DESIGN.md §13): the fault
//! model needs a lost device's rows to stay servable, so
//! [`ShardPlan::build_replicated`] tops the halos up until every node
//! has at least `r` distinct shard sites (its home plus `r − 1` halo
//! replicas, placed round-robin on the shards after its home).  The
//! engine's uploads already write every halo site through the
//! double-buffer barrier, so replicas stay coherent for free, and
//! [`ShardPlan::degraded_sites`] answers where each lost row is served
//! from.  `replicate = 1` adds nothing — those plans are bit-identical
//! to the unreplicated builds.
//!
//! Invariants (checked by [`ShardPlan::validate`], re-checked by the
//! property tests below):
//! * every node is a member of exactly one shard;
//! * `members + halo <= table` for every shard;
//! * every sampled neighbor index lands in-shard (member or halo slot);
//! * halos contain *all* out-of-shard sampled neighbors; with
//!   `replicate = 1` (the default) nothing else, with `replicate = r`
//!   also the round-robin replica rows that give every node
//!   `min(r, num_shards)` distinct shard sites.

use crate::error::{Error, Result};
use crate::obs::Obs;
use crate::span;

use super::cluster::Clustering;
use super::csr::Csr;
use super::sample::NeighborSampler;

/// One table-sized shard: `members` own their rows (slots `0..members`),
/// `halo` rows (slots `members..members+halo`) replicate the out-of-shard
/// sampled neighbors so boundary lookups resolve locally.
#[derive(Debug, Clone, PartialEq)]
pub struct Shard {
    /// Global node ids owning slots `0..members.len()`, in slot order.
    pub members: Vec<usize>,
    /// Global node ids of the halo rows (sorted ascending), occupying
    /// slots `members.len()..slots()`.
    pub halo: Vec<usize>,
    /// Flattened `[members.len() × sample]` neighbor-index rows in *local
    /// slot* coordinates (`-1` = padding) — the artifact's `nbr_idx`
    /// input, pre-remapped at plan time.
    pub nbr_rows: Vec<i32>,
}

impl Shard {
    /// Occupied rows of the shard's table (members + halo).
    pub fn slots(&self) -> usize {
        self.members.len() + self.halo.len()
    }

    /// Global node id behind a local slot.
    pub fn local_node(&self, slot: usize) -> usize {
        if slot < self.members.len() {
            self.members[slot]
        } else {
            self.halo[slot - self.members.len()]
        }
    }

    /// The pre-remapped neighbor row of the member in `slot`.
    pub fn member_nbr_row(&self, slot: usize, sample: usize) -> &[i32] {
        &self.nbr_rows[slot * sample..(slot + 1) * sample]
    }
}

/// A partition of a graph into artifact-table-sized shards with halo
/// replication (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    table: usize,
    sample: usize,
    num_nodes: usize,
    shards: Vec<Shard>,
    /// `home[node] = (shard, slot)` — the member slot owning the node.
    home: Vec<(usize, usize)>,
    /// `halo_sites[node]` — every `(shard, slot)` where the node is
    /// replicated as a halo row (kept in sync by the engine's uploads).
    halo_sites: Vec<Vec<(usize, usize)>>,
    /// Requested replication factor (≥ 1; 1 = exact halos only).
    replicate: usize,
}

enum PackOutcome {
    Fits(ShardPlan),
    /// Worst `members + halo` over all shards — the overflow signal the
    /// capacity loop shrinks the member budget by.
    Overflow(usize),
}

impl ShardPlan {
    /// Shard a graph in id order (the centralized leader's default): each
    /// node is its own packing unit, so shards are consecutive id ranges.
    /// A graph that fits one shard yields the identity mapping
    /// (`slot == node`), which is what keeps single-shard serving
    /// bit-identical to the unsharded seed path.
    pub fn build(graph: &Csr, sampler: &NeighborSampler, table: usize) -> Result<ShardPlan> {
        ShardPlan::build_observed(graph, sampler, table, &Obs::disabled())
    }

    /// [`ShardPlan::build`] with an observability handle: the whole
    /// capacity search runs under a `shard.plan` span and each packing
    /// attempt bumps the `shard.pack_attempts` counter.  The plan itself
    /// is byte-identical to the unobserved build.
    pub fn build_observed(
        graph: &Csr,
        sampler: &NeighborSampler,
        table: usize,
        obs: &Obs,
    ) -> Result<ShardPlan> {
        let singles: Vec<Vec<usize>> = (0..graph.num_nodes()).map(|v| vec![v]).collect();
        ShardPlan::pack(graph, sampler, table, &singles, 1, 1, obs)
    }

    /// [`ShardPlan::build`] with halo replication: every node gets at
    /// least `min(replicate, num_shards)` distinct shard sites, so a
    /// lost shard's rows stay servable in degraded mode
    /// ([`ShardPlan::degraded_sites`]).  `replicate = 1` is bit-identical
    /// to [`ShardPlan::build`].
    pub fn build_replicated(
        graph: &Csr,
        sampler: &NeighborSampler,
        table: usize,
        replicate: usize,
    ) -> Result<ShardPlan> {
        let singles: Vec<Vec<usize>> = (0..graph.num_nodes()).map(|v| vec![v]).collect();
        ShardPlan::pack(graph, sampler, table, &singles, 1, replicate, &Obs::disabled())
    }

    /// Shard a graph so whole clusters land in one shard (the semi
    /// deployment: a head batches its members against a single table).
    pub fn from_clustering(
        graph: &Csr,
        sampler: &NeighborSampler,
        table: usize,
        clustering: &Clustering,
    ) -> Result<ShardPlan> {
        ShardPlan::from_clustering_replicated(graph, sampler, table, clustering, 1)
    }

    /// [`ShardPlan::from_clustering`] with halo replication (see
    /// [`ShardPlan::build_replicated`]).
    pub fn from_clustering_replicated(
        graph: &Csr,
        sampler: &NeighborSampler,
        table: usize,
        clustering: &Clustering,
        replicate: usize,
    ) -> Result<ShardPlan> {
        if clustering.assignment.len() != graph.num_nodes() {
            return Err(Error::Graph("clustering does not cover the graph".into()));
        }
        let min_cap = clustering.clusters.iter().map(Vec::len).max().unwrap_or(0).max(1);
        ShardPlan::pack(
            graph,
            sampler,
            table,
            &clustering.clusters,
            min_cap,
            replicate,
            &Obs::disabled(),
        )
    }

    /// Capacity search: pack groups with a member budget of `cap`, shrink
    /// on halo overflow.  `cap` strictly decreases, so the loop
    /// terminates; `min_cap` is the smallest budget that keeps the
    /// packing units whole (1 for id-order, the largest cluster for
    /// cluster-preserving plans).  The deterministic neighbor samples do
    /// not depend on the member budget, so they are drawn once here and
    /// only re-packed per iteration.
    fn pack(
        graph: &Csr,
        sampler: &NeighborSampler,
        table: usize,
        groups: &[Vec<usize>],
        min_cap: usize,
        replicate: usize,
        obs: &Obs,
    ) -> Result<ShardPlan> {
        let _span = span!(obs.tracer, "shard.plan", nodes = graph.num_nodes(), table = table);
        if table == 0 {
            return Err(Error::Graph("shard table must hold at least one row".into()));
        }
        if replicate == 0 {
            return Err(Error::Graph("replication factor must be >= 1".into()));
        }
        if min_cap > table {
            return Err(Error::Graph(format!(
                "a packing unit of {min_cap} nodes cannot fit a {table}-row table"
            )));
        }
        let samples: Vec<Vec<Option<usize>>> =
            (0..graph.num_nodes()).map(|v| sampler.sample(graph, v)).collect();
        let sample = sampler.sample_size();
        let mut cap = table;
        loop {
            if obs.is_enabled() {
                obs.metrics.inc("shard.pack_attempts", 1);
            }
            match ShardPlan::try_pack(&samples, sample, table, groups, cap, replicate)? {
                PackOutcome::Fits(plan) => return Ok(plan),
                PackOutcome::Overflow(worst) => {
                    if cap == min_cap {
                        return Err(Error::Graph(format!(
                            "cannot shard: {worst} slots (members + halo) exceed the \
                             {table}-row table even at the minimum member budget {min_cap}"
                        )));
                    }
                    // Proportional shrink: the halo grows with the member
                    // count, so scale the member budget by the observed
                    // occupancy ratio — strictly decreasing (worst >
                    // table), clamped to the feasible floor.  Subtracting
                    // the raw overflow instead would overshoot straight
                    // to one-member shards on dense graphs.
                    cap = (cap * table / worst).max(min_cap).min(cap - 1);
                }
            }
        }
    }

    /// One packing attempt at member budget `cap`.  `samples[v]` is node
    /// v's pre-drawn neighbor sample (budget-independent).
    fn try_pack(
        samples: &[Vec<Option<usize>>],
        sample: usize,
        table: usize,
        groups: &[Vec<usize>],
        cap: usize,
        replicate: usize,
    ) -> Result<PackOutcome> {
        let n = samples.len();

        // Greedy bin packing of whole groups, in group order.
        let mut member_sets: Vec<Vec<usize>> = Vec::new();
        let mut current: Vec<usize> = Vec::new();
        for g in groups {
            if g.is_empty() {
                continue;
            }
            if !current.is_empty() && current.len() + g.len() > cap {
                member_sets.push(std::mem::take(&mut current));
            }
            current.extend_from_slice(g);
        }
        if !current.is_empty() {
            member_sets.push(current);
        }

        let mut home = vec![(usize::MAX, usize::MAX); n];
        for (s, ms) in member_sets.iter().enumerate() {
            for (slot, &v) in ms.iter().enumerate() {
                if v >= n || home[v].0 != usize::MAX {
                    return Err(Error::Graph(format!("node {v} misassigned in shard plan")));
                }
                home[v] = (s, slot);
            }
        }
        if home.iter().any(|&(s, _)| s == usize::MAX) {
            return Err(Error::Graph("shard plan leaves nodes unassigned".into()));
        }

        // Halos: the out-of-shard sampled neighbors of each shard's
        // members (the sampler is deterministic, so this set is exact).
        let mut halos = Vec::with_capacity(member_sets.len());
        for (s, ms) in member_sets.iter().enumerate() {
            let mut halo: Vec<usize> = ms
                .iter()
                .flat_map(|&v| samples[v].iter())
                .flatten()
                .copied()
                .filter(|&g| home[g].0 != s)
                .collect();
            halo.sort_unstable();
            halo.dedup();
            halos.push(halo);
        }

        // Replication top-up: give every node at least
        // min(replicate, shards) distinct sites by appending replica
        // rows round-robin on the shards after its home.  Skipped
        // entirely at replicate = 1, so unreplicated plans keep the
        // exact-halo bits.
        if replicate > 1 && !member_sets.is_empty() {
            let r_eff = replicate.min(member_sets.len());
            let num = member_sets.len();
            let mut extra: Vec<Vec<usize>> = vec![Vec::new(); num];
            let mut sites = vec![1usize; n];
            for halo in &halos {
                for &g in halo {
                    sites[g] += 1;
                }
            }
            for v in 0..n {
                let hs = home[v].0;
                let mut k = 1;
                while sites[v] < r_eff {
                    debug_assert!(k <= num, "replication scan must terminate");
                    let s = (hs + k) % num;
                    k += 1;
                    if s == hs || halos[s].binary_search(&v).is_ok() || extra[s].contains(&v)
                    {
                        continue;
                    }
                    extra[s].push(v);
                    sites[v] += 1;
                }
            }
            for (halo, mut add) in halos.iter_mut().zip(extra) {
                if !add.is_empty() {
                    halo.append(&mut add);
                    halo.sort_unstable();
                    halo.dedup();
                }
            }
        }

        let mut worst = 0usize;
        for (ms, halo) in member_sets.iter().zip(&halos) {
            worst = worst.max(ms.len() + halo.len());
        }
        if worst > table {
            return Ok(PackOutcome::Overflow(worst));
        }

        // Remap every member's sample row to local slots.
        let mut halo_sites: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
        let shards: Vec<Shard> = member_sets
            .into_iter()
            .zip(halos)
            .enumerate()
            .map(|(s, (members, halo))| {
                for (j, &g) in halo.iter().enumerate() {
                    halo_sites[g].push((s, members.len() + j));
                }
                let mut nbr_rows = Vec::with_capacity(members.len() * sample);
                for &v in &members {
                    for &o in &samples[v] {
                        nbr_rows.push(match o {
                            None => -1,
                            Some(g) if home[g].0 == s => home[g].1 as i32,
                            Some(g) => {
                                let j = halo.binary_search(&g).expect("halo holds the neighbor");
                                (members.len() + j) as i32
                            }
                        });
                    }
                }
                Shard { members, halo, nbr_rows }
            })
            .collect();

        let plan = ShardPlan { table, sample, num_nodes: n, shards, home, halo_sites, replicate };
        plan.validate()?;
        Ok(PackOutcome::Fits(plan))
    }

    /// Structural validation of the plan's invariants (module docs).
    pub fn validate(&self) -> Result<()> {
        let mut seen = vec![false; self.num_nodes];
        for (s, shard) in self.shards.iter().enumerate() {
            if shard.slots() > self.table {
                return Err(Error::Graph(format!(
                    "shard {s}: {} slots exceed the {}-row table",
                    shard.slots(),
                    self.table
                )));
            }
            if shard.nbr_rows.len() != shard.members.len() * self.sample {
                return Err(Error::Graph(format!("shard {s}: neighbor-row arity mismatch")));
            }
            for (slot, &v) in shard.members.iter().enumerate() {
                if v >= self.num_nodes || seen[v] || self.home[v] != (s, slot) {
                    return Err(Error::Graph(format!("node {v} misassigned in shard plan")));
                }
                seen[v] = true;
            }
            for w in shard.halo.windows(2) {
                if w[0] >= w[1] {
                    return Err(Error::Graph(format!("shard {s}: halo not sorted/distinct")));
                }
            }
            for &g in &shard.halo {
                if g >= self.num_nodes || self.home[g].0 == s {
                    return Err(Error::Graph(format!("shard {s}: bad halo node {g}")));
                }
            }
            // Every sampled index lands in-shard.
            for &ix in &shard.nbr_rows {
                if ix != -1 && !(0..shard.slots() as i32).contains(&ix) {
                    return Err(Error::Graph(format!(
                        "shard {s}: neighbor slot {ix} outside {} occupied rows",
                        shard.slots()
                    )));
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(Error::Graph("shard plan leaves nodes unassigned".into()));
        }
        // Replication: a node's distinct shard sites are its home plus
        // one halo row per (other) shard — halos are deduped and never
        // contain the home, so the count is exact.
        let need = self.replicate.min(self.shards.len()).max(1);
        for v in 0..self.num_nodes {
            let sites = 1 + self.halo_sites[v].len();
            if sites < need {
                return Err(Error::Graph(format!(
                    "node {v}: {sites} shard sites under replication factor {need}"
                )));
            }
        }
        Ok(())
    }

    pub fn table(&self) -> usize {
        self.table
    }

    pub fn sample(&self) -> usize {
        self.sample
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn is_single_shard(&self) -> bool {
        self.shards.len() <= 1
    }

    /// Largest halo over all shards (0 when the plan needs none).
    pub fn max_halo(&self) -> usize {
        self.shards.iter().map(|s| s.halo.len()).max().unwrap_or(0)
    }

    /// Worst occupied-slot count over all shards.
    pub fn max_slots(&self) -> usize {
        self.shards.iter().map(Shard::slots).max().unwrap_or(0)
    }

    /// The member `(shard, slot)` owning `node`.  Panics on an
    /// out-of-range node — callers bounds-check against
    /// [`ShardPlan::num_nodes`] first.
    pub fn home(&self, node: usize) -> (usize, usize) {
        self.home[node]
    }

    /// Every `(shard, slot)` replicating `node` as a halo row.
    pub fn halo_sites(&self, node: usize) -> &[(usize, usize)] {
        &self.halo_sites[node]
    }

    /// The requested replication factor (1 = exact halos only).
    pub fn replicate(&self) -> usize {
        self.replicate
    }

    /// Degraded-mode serving assignment after losing `lost_shard`:
    /// each of its member rows served from its first halo replica on a
    /// surviving shard, as `(node, (shard, slot))`.  Errors when a row
    /// has no replica (`replicate = 1` plans) — that row is simply
    /// unservable until recovery, which is exactly the r = 1 vs r ≥ 2
    /// SLO gap the E14 sweep measures.
    pub fn degraded_sites(&self, lost_shard: usize) -> Result<Vec<(usize, (usize, usize))>> {
        let shard = self
            .shards
            .get(lost_shard)
            .ok_or_else(|| Error::Graph(format!("no shard {lost_shard} to lose")))?;
        let mut out = Vec::with_capacity(shard.members.len());
        for &v in &shard.members {
            let site = self.halo_sites[v]
                .iter()
                .find(|&&(s, _)| s != lost_shard)
                .copied()
                .ok_or_else(|| {
                    Error::Graph(format!(
                        "node {v} has no replica outside shard {lost_shard} \
                         (replicate = {})",
                        self.replicate
                    ))
                })?;
            out.push((v, site));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{fixed_size, generate, locality};
    use crate::testing::{forall, Rng};

    fn sampler() -> NeighborSampler {
        NeighborSampler::new(4, 7)
    }

    #[test]
    fn single_shard_is_the_identity_mapping() {
        let g = generate::regular(48, 6, 3).unwrap();
        let s = sampler();
        let p = ShardPlan::build(&g, &s, 64).unwrap();
        assert!(p.is_single_shard());
        assert_eq!(p.num_shards(), 1);
        let shard = &p.shards()[0];
        assert_eq!(shard.members, (0..48).collect::<Vec<_>>());
        assert!(shard.halo.is_empty());
        for v in 0..48 {
            assert_eq!(p.home(v), (0, v));
            assert!(p.halo_sites(v).is_empty());
        }
        // Pre-remapped rows equal the global sampler rows (slot == id).
        assert_eq!(shard.nbr_rows, s.sample_batch(&g, &(0..48).collect::<Vec<_>>()));
    }

    #[test]
    fn oversized_graph_shards_and_covers_every_node_once() {
        let g = generate::regular(256, 6, 3).unwrap();
        let p = ShardPlan::build(&g, &sampler(), 64).unwrap();
        assert!(p.num_shards() >= 4, "256 nodes in 64-row tables: {}", p.num_shards());
        assert!(p.max_slots() <= 64);
        let mut seen = vec![0usize; 256];
        for shard in p.shards() {
            for &v in &shard.members {
                seen[v] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        p.validate().unwrap();
    }

    #[test]
    fn halos_are_exactly_the_out_of_shard_sampled_neighbors() {
        let g = generate::regular(200, 8, 11).unwrap();
        let s = sampler();
        let p = ShardPlan::build(&g, &s, 64).unwrap();
        for (si, shard) in p.shards().iter().enumerate() {
            let mut expect: Vec<usize> = shard
                .members
                .iter()
                .flat_map(|&v| s.sample(&g, v))
                .flatten()
                .filter(|&nb| p.home(nb).0 != si)
                .collect();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(shard.halo, expect, "shard {si}");
        }
    }

    #[test]
    fn neighbor_rows_remap_back_to_the_global_sample() {
        let g = generate::regular(200, 8, 11).unwrap();
        let s = sampler();
        let p = ShardPlan::build(&g, &s, 64).unwrap();
        for shard in p.shards() {
            for (slot, &v) in shard.members.iter().enumerate() {
                let row = shard.member_nbr_row(slot, p.sample());
                let global = s.sample(&g, v);
                assert_eq!(row.len(), global.len());
                for (&local, g_nb) in row.iter().zip(global) {
                    match g_nb {
                        None => assert_eq!(local, -1),
                        Some(nb) => assert_eq!(shard.local_node(local as usize), nb),
                    }
                }
            }
        }
    }

    #[test]
    fn from_clustering_keeps_clusters_whole() {
        let g = generate::regular(256, 6, 3).unwrap();
        let c = fixed_size(256, 8).unwrap();
        let p = ShardPlan::from_clustering(&g, &sampler(), 64, &c).unwrap();
        assert!(p.num_shards() > 1);
        for members in &c.clusters {
            let shard_of: Vec<usize> = members.iter().map(|&v| p.home(v).0).collect();
            assert!(shard_of.windows(2).all(|w| w[0] == w[1]), "cluster spans shards");
        }
        p.validate().unwrap();
    }

    #[test]
    fn halo_sites_mirror_the_halo_rows() {
        let g = generate::regular(256, 6, 3).unwrap();
        let p = ShardPlan::build(&g, &sampler(), 64).unwrap();
        for (si, shard) in p.shards().iter().enumerate() {
            for (j, &gid) in shard.halo.iter().enumerate() {
                let slot = shard.members.len() + j;
                assert!(p.halo_sites(gid).contains(&(si, slot)));
                assert_eq!(shard.local_node(slot), gid);
            }
        }
        let total_halo: usize = p.shards().iter().map(|s| s.halo.len()).sum();
        let total_sites: usize = (0..256).map(|v| p.halo_sites(v).len()).sum();
        assert_eq!(total_halo, total_sites);
    }

    #[test]
    fn degenerate_tables_are_rejected() {
        let g = generate::regular(16, 4, 1).unwrap();
        assert!(ShardPlan::build(&g, &sampler(), 0).is_err());
        // A cluster bigger than the table can never be kept whole.
        let c = fixed_size(16, 10).unwrap();
        assert!(ShardPlan::from_clustering(&g, &sampler(), 8, &c).is_err());
        // Clustering must cover the graph.
        let wrong = fixed_size(10, 5).unwrap();
        assert!(ShardPlan::from_clustering(&g, &sampler(), 64, &wrong).is_err());
    }

    #[test]
    fn empty_graph_builds_an_empty_plan() {
        let g = Csr::from_edges(0, &[]).unwrap();
        let p = ShardPlan::build(&g, &sampler(), 64).unwrap();
        assert_eq!(p.num_shards(), 0);
        assert_eq!(p.max_halo(), 0);
        p.validate().unwrap();
    }

    /// Any graph shards successfully once the table holds one member plus
    /// a full sample halo — and the resulting plan always satisfies the
    /// structural invariants.
    #[test]
    fn property_plans_are_complete_and_in_table() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(120) + 1;
            let sample = rng.index(6) + 1;
            let table = sample + 1 + rng.index(40);
            let g = generate::uniform(n.max(2), n * 3, rng.next_u64()).unwrap();
            let s = NeighborSampler::new(sample, rng.next_u64());
            let p = ShardPlan::build(&g, &s, table).unwrap();
            p.validate().unwrap();
            assert!(p.max_slots() <= table);
            let members: usize = p.shards().iter().map(|sh| sh.members.len()).sum();
            assert_eq!(members, g.num_nodes());
            // Halos are exact: recompute independently.
            for (si, shard) in p.shards().iter().enumerate() {
                let mut expect: Vec<usize> = shard
                    .members
                    .iter()
                    .flat_map(|&v| s.sample(&g, v))
                    .flatten()
                    .filter(|&nb| p.home(nb).0 != si)
                    .collect();
                expect.sort_unstable();
                expect.dedup();
                assert_eq!(shard.halo, expect);
            }
        });
    }

    /// S3: `replicate = 1` goes through the same code bits as the seed
    /// path — the plans are wholesale equal.
    #[test]
    fn replicate_one_is_bit_identical_to_the_seed_path() {
        let g = generate::regular(200, 8, 11).unwrap();
        let s = sampler();
        let base = ShardPlan::build(&g, &s, 64).unwrap();
        let r1 = ShardPlan::build_replicated(&g, &s, 64, 1).unwrap();
        assert_eq!(base, r1);
        assert_eq!(r1.replicate(), 1);
        let c = fixed_size(200, 8).unwrap();
        assert_eq!(
            ShardPlan::from_clustering(&g, &s, 64, &c).unwrap(),
            ShardPlan::from_clustering_replicated(&g, &s, 64, &c, 1).unwrap()
        );
        assert!(ShardPlan::build_replicated(&g, &s, 64, 0).is_err());
    }

    /// S3: a single-shard graph stays the identity mapping even when
    /// replication is requested — there is no second site to create.
    #[test]
    fn single_shard_replicated_is_still_the_identity() {
        let g = generate::regular(48, 6, 3).unwrap();
        let s = sampler();
        let p = ShardPlan::build_replicated(&g, &s, 64, 2).unwrap();
        assert_eq!(p, {
            let mut q = ShardPlan::build(&g, &s, 64).unwrap();
            // Only the requested factor differs on a single shard.
            q.replicate = 2;
            q
        });
        assert!(p.is_single_shard());
        for v in 0..48 {
            assert_eq!(p.home(v), (0, v));
            assert!(p.halo_sites(v).is_empty());
        }
    }

    /// S3: every node gets ≥ min(r, shards) distinct shard sites, the
    /// replicated halos stay a superset of the exact neighbor halos,
    /// and the plan is a pure function of its inputs (patched degraded
    /// serving reads the same plan a from-scratch rebuild produces).
    #[test]
    fn property_replicated_plans_give_every_node_r_sites() {
        forall(16, |rng: &mut Rng| {
            let n = rng.index(100) + 20;
            let sample = rng.index(4) + 1;
            let r = rng.index(3) + 2; // 2..=4
            let table = (sample + 2 + rng.index(40)).max(12);
            let g = generate::uniform(n, n * 2, rng.next_u64()).unwrap();
            let s = NeighborSampler::new(sample, rng.next_u64());
            let Ok(p) = ShardPlan::build_replicated(&g, &s, table, r) else {
                // Tight tables may genuinely not fit the replicas.
                return;
            };
            p.validate().unwrap();
            assert_eq!(p.replicate(), r);
            let need = r.min(p.num_shards());
            for v in 0..n {
                let mut shards_of_v: Vec<usize> = vec![p.home(v).0];
                shards_of_v.extend(p.halo_sites(v).iter().map(|&(sh, _)| sh));
                shards_of_v.sort_unstable();
                shards_of_v.dedup();
                assert!(
                    shards_of_v.len() >= need,
                    "node {v}: {} sites < r {need}",
                    shards_of_v.len()
                );
            }
            // Halos ⊇ the exact out-of-shard sampled neighbors.
            for (si, shard) in p.shards().iter().enumerate() {
                for nb in shard.members.iter().flat_map(|&v| s.sample(&g, v)).flatten() {
                    if p.home(nb).0 != si {
                        assert!(shard.halo.binary_search(&nb).is_ok());
                    }
                }
            }
            // Determinism: the rebuilt plan is the patched plan.
            let again = ShardPlan::build_replicated(&g, &s, table, r).unwrap();
            assert_eq!(p, again);
            // Degraded serving: with ≥ 2 shards every lost shard's rows
            // resolve to surviving replicas.
            if p.num_shards() >= 2 && r >= 2 {
                for lost in 0..p.num_shards() {
                    let sites = p.degraded_sites(lost).unwrap();
                    assert_eq!(sites.len(), p.shards()[lost].members.len());
                    for &(v, (sh, slot)) in &sites {
                        assert_ne!(sh, lost);
                        assert_eq!(p.shards()[sh].local_node(slot), v);
                    }
                }
            }
        });
    }

    /// S3: r = 1 plans admit no degraded serving for rows whose halo
    /// replicas don't exist — `degraded_sites` reports the unservable
    /// row instead of inventing one.
    #[test]
    fn degraded_sites_require_replicas() {
        // 40 edges touch at most 80 of the 100 nodes, so isolated nodes
        // exist: they are sampled by nobody and get no exact-halo site.
        let g = generate::uniform(100, 40, 9).unwrap();
        let s = sampler();
        let r2 = ShardPlan::build_replicated(&g, &s, 32, 2).unwrap();
        assert!(r2.num_shards() >= 2);
        for lost in 0..r2.num_shards() {
            let sites = r2.degraded_sites(lost).unwrap();
            assert_eq!(sites.len(), r2.shards()[lost].members.len());
        }
        assert!(r2.degraded_sites(r2.num_shards()).is_err(), "no such shard");
        // Without replication the isolated nodes' home shards cannot be
        // served after a loss — the plan reports it instead of guessing.
        let r1 = ShardPlan::build(&g, &s, 32).unwrap();
        let unservable = (0..r1.num_shards()).filter(|&l| r1.degraded_sites(l).is_err()).count();
        assert!(unservable > 0, "r = 1 should leave some shard unservable");
    }

    /// Cluster-preserving plans keep every cluster in one shard, under
    /// both partitioners, whenever packing is feasible.
    #[test]
    fn property_cluster_plans_never_split_clusters() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(100) + 2;
            let k = rng.index(8) + 1;
            let sample = rng.index(4) + 1;
            let table = (k + sample * k + 1 + rng.index(32)).max(sample + 2);
            let g = generate::uniform(n, n * 2, rng.next_u64()).unwrap();
            let s = NeighborSampler::new(sample, rng.next_u64());
            for c in [fixed_size(g.num_nodes(), k).unwrap(), locality(&g, k).unwrap()] {
                match ShardPlan::from_clustering(&g, &s, table, &c) {
                    Ok(p) => {
                        p.validate().unwrap();
                        for members in &c.clusters {
                            let first = p.home(members[0]).0;
                            assert!(members.iter().all(|&v| p.home(v).0 == first));
                        }
                    }
                    // Tight tables may genuinely not fit a cluster + halo.
                    Err(_) => assert!(table < k + sample * k),
                }
            }
        });
    }
}
