//! Compressed Sparse Row graph storage (paper Fig. 3(b)).
//!
//! The traversal core consumes exactly these three arrays: the Edge weight
//! array (E), the Column Index array (CI) and the Row Pointer array (RP).
//!
//! DESIGN.md: §10 (table-sharded execution); §16 (the compact encoding).

use crate::error::{Error, Result};

/// Directed graph in CSR form.  Row = source node; `column_indices` hold
/// destination ids; optional edge weights mirror the paper's E array.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    num_nodes: usize,
    /// RP: `row_pointers[i]..row_pointers[i+1]` indexes node i's out-edges.
    row_pointers: Vec<usize>,
    /// CI: destination of each edge.
    column_indices: Vec<usize>,
    /// E: weight of each edge (1.0 when unweighted).
    edge_weights: Vec<f32>,
}

impl Csr {
    /// Build from an edge list `(src, dst)`.  Edges are sorted per source;
    /// duplicates are kept (multigraph semantics are the caller's choice).
    ///
    /// Builds the CSR arrays directly — counting sort into RP/CI plus a
    /// per-row destination sort, O(V + E) with no intermediate copy of
    /// the edge list (the seed materialized a weighted `Vec` just to
    /// reuse `from_weighted_edges`).
    pub fn from_edges(num_nodes: usize, edges: &[(usize, usize)]) -> Result<Csr> {
        for &(s, d) in edges {
            if s >= num_nodes || d >= num_nodes {
                return Err(Error::Graph(format!(
                    "edge ({s}, {d}) out of range for {num_nodes} nodes"
                )));
            }
        }
        let mut row_pointers = vec![0usize; num_nodes + 1];
        for &(s, _) in edges {
            row_pointers[s + 1] += 1;
        }
        for i in 0..num_nodes {
            row_pointers[i + 1] += row_pointers[i];
        }
        let mut column_indices = vec![0usize; edges.len()];
        let mut cursor = row_pointers.clone();
        for &(s, d) in edges {
            column_indices[cursor[s]] = d;
            cursor[s] += 1;
        }
        // Deterministic order within a row (weights are uniform, so a
        // plain index sort suffices).
        for i in 0..num_nodes {
            column_indices[row_pointers[i]..row_pointers[i + 1]].sort_unstable();
        }
        let edge_weights = vec![1.0; edges.len()];
        Ok(Csr { num_nodes, row_pointers, column_indices, edge_weights })
    }

    /// Build from a weighted edge list `(src, dst, w)`.
    pub fn from_weighted_edges(num_nodes: usize, edges: &[(usize, usize, f32)]) -> Result<Csr> {
        for &(s, d, _) in edges {
            if s >= num_nodes || d >= num_nodes {
                return Err(Error::Graph(format!(
                    "edge ({s}, {d}) out of range for {num_nodes} nodes"
                )));
            }
        }
        // Counting sort by source: O(V + E).
        let mut degree = vec![0usize; num_nodes];
        for &(s, _, _) in edges {
            degree[s] += 1;
        }
        let mut row_pointers = vec![0usize; num_nodes + 1];
        for i in 0..num_nodes {
            row_pointers[i + 1] = row_pointers[i] + degree[i];
        }
        let mut column_indices = vec![0usize; edges.len()];
        let mut edge_weights = vec![0f32; edges.len()];
        let mut cursor = row_pointers.clone();
        for &(s, d, w) in edges {
            let at = cursor[s];
            column_indices[at] = d;
            edge_weights[at] = w;
            cursor[s] += 1;
        }
        // Deterministic order within a row.
        for i in 0..num_nodes {
            let span = row_pointers[i]..row_pointers[i + 1];
            let mut pairs: Vec<(usize, f32)> = column_indices[span.clone()]
                .iter()
                .copied()
                .zip(edge_weights[span.clone()].iter().copied())
                .collect();
            pairs.sort_by_key(|(d, _)| *d);
            for (k, (d, w)) in pairs.into_iter().enumerate() {
                column_indices[span.start + k] = d;
                edge_weights[span.start + k] = w;
            }
        }
        Ok(Csr { num_nodes, row_pointers, column_indices, edge_weights })
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.column_indices.len()
    }

    /// RP array.
    pub fn row_pointers(&self) -> &[usize] {
        &self.row_pointers
    }

    /// CI array.
    pub fn column_indices(&self) -> &[usize] {
        &self.column_indices
    }

    /// E array.
    pub fn edge_weights(&self) -> &[f32] {
        &self.edge_weights
    }

    /// Out-neighbors of `node`.
    pub fn neighbors(&self, node: usize) -> &[usize] {
        let span = self.row_pointers[node]..self.row_pointers[node + 1];
        &self.column_indices[span]
    }

    /// Out-degree of `node`.
    pub fn degree(&self, node: usize) -> usize {
        self.row_pointers[node + 1] - self.row_pointers[node]
    }

    /// Average degree — the paper's "Average Cₛ" statistic.
    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes == 0 {
            return 0.0;
        }
        self.num_edges() as f64 / self.num_nodes as f64
    }

    /// Reverse graph (in-edges become out-edges) — what the traversal
    /// core's destination-major lookup effectively computes.
    pub fn reverse(&self) -> Csr {
        let mut edges = Vec::with_capacity(self.num_edges());
        for src in 0..self.num_nodes {
            for (k, &dst) in self.neighbors(src).iter().enumerate() {
                let w = self.edge_weights[self.row_pointers[src] + k];
                edges.push((dst, src, w));
            }
        }
        Csr::from_weighted_edges(self.num_nodes, &edges).expect("reverse edges are in range")
    }

    /// Structural validation: monotone RP, in-range CI, matching lengths.
    pub fn validate(&self) -> Result<()> {
        if self.row_pointers.len() != self.num_nodes + 1 {
            return Err(Error::Graph("RP length must be num_nodes + 1".into()));
        }
        if self.row_pointers[0] != 0 || *self.row_pointers.last().unwrap() != self.num_edges() {
            return Err(Error::Graph("RP must span [0, num_edges]".into()));
        }
        if self.row_pointers.windows(2).any(|w| w[0] > w[1]) {
            return Err(Error::Graph("RP must be non-decreasing".into()));
        }
        if self.column_indices.iter().any(|&c| c >= self.num_nodes) {
            return Err(Error::Graph("CI entry out of range".into()));
        }
        if self.edge_weights.len() != self.column_indices.len() {
            return Err(Error::Graph("E/CI length mismatch".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    /// The adjacency of paper Fig. 3(a) (4 nodes).
    fn fig3() -> Csr {
        Csr::from_edges(4, &[(0, 1), (0, 3), (1, 2), (2, 0), (2, 3), (3, 1)]).unwrap()
    }

    #[test]
    fn csr_arrays_match_hand_computation() {
        let g = fig3();
        assert_eq!(g.row_pointers(), &[0, 2, 3, 5, 6]);
        assert_eq!(g.column_indices(), &[1, 3, 2, 0, 3, 1]);
        assert_eq!(g.num_edges(), 6);
        g.validate().unwrap();
    }

    #[test]
    fn neighbors_and_degree() {
        let g = fig3();
        assert_eq!(g.neighbors(0), &[1, 3]);
        assert_eq!(g.neighbors(1), &[2]);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.degree(1), 1);
        assert!((g.avg_degree() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn unweighted_build_matches_the_weighted_path() {
        // The direct builder must agree with `from_weighted_edges` at
        // weight 1.0 — arrays and all.
        forall(24, |rng: &mut Rng| {
            let n = rng.index(25) + 1;
            let m = rng.index(60);
            let edges: Vec<(usize, usize)> =
                (0..m).map(|_| (rng.index(n), rng.index(n))).collect();
            let direct = Csr::from_edges(n, &edges).unwrap();
            let weighted: Vec<(usize, usize, f32)> =
                edges.iter().map(|&(s, d)| (s, d, 1.0)).collect();
            let via = Csr::from_weighted_edges(n, &weighted).unwrap();
            assert_eq!(direct, via);
            assert!(direct.edge_weights().iter().all(|&w| w == 1.0));
        });
    }

    #[test]
    fn isolated_nodes_have_empty_rows() {
        let g = Csr::from_edges(5, &[(0, 4)]).unwrap();
        assert_eq!(g.degree(1), 0);
        assert_eq!(g.neighbors(2), &[] as &[usize]);
        g.validate().unwrap();
    }

    #[test]
    fn weights_follow_their_edges() {
        let g = Csr::from_weighted_edges(3, &[(0, 2, 0.5), (0, 1, 2.0), (2, 0, 7.0)]).unwrap();
        // row 0 sorted by destination: (1, 2.0), (2, 0.5)
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.edge_weights()[0], 2.0);
        assert_eq!(g.edge_weights()[1], 0.5);
        assert_eq!(g.edge_weights()[2], 7.0);
    }

    #[test]
    fn reverse_flips_every_edge() {
        let g = fig3();
        let r = g.reverse();
        assert_eq!(r.num_edges(), g.num_edges());
        for src in 0..4 {
            for &dst in g.neighbors(src) {
                assert!(r.neighbors(dst).contains(&src), "{src}->{dst} missing in reverse");
            }
        }
        // double reverse = original connectivity
        let rr = r.reverse();
        for n in 0..4 {
            assert_eq!(rr.neighbors(n), g.neighbors(n));
        }
    }

    #[test]
    fn property_csr_roundtrips_edge_list() {
        forall(32, |rng: &mut Rng| {
            let n = rng.index(30) + 1;
            let m = rng.index(80);
            let mut edges: Vec<(usize, usize)> =
                (0..m).map(|_| (rng.index(n), rng.index(n))).collect();
            let g = Csr::from_edges(n, &edges).unwrap();
            g.validate().unwrap();
            assert_eq!(g.num_edges(), m);
            // Every input edge appears exactly as many times as given.
            let mut got: Vec<(usize, usize)> = (0..n)
                .flat_map(|s| g.neighbors(s).iter().map(move |&d| (s, d)))
                .collect();
            got.sort_unstable();
            edges.sort_unstable();
            assert_eq!(got, edges);
            // Degree sums to edge count.
            let deg_sum: usize = (0..n).map(|i| g.degree(i)).sum();
            assert_eq!(deg_sum, m);
        });
    }

    #[test]
    fn rejects_out_of_range_edges() {
        assert!(Csr::from_edges(2, &[(0, 2)]).is_err());
        assert!(Csr::from_edges(2, &[(5, 0)]).is_err());
    }

    #[test]
    fn empty_graph_is_valid() {
        let g = Csr::from_edges(0, &[]).unwrap();
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.avg_degree(), 0.0);
    }
}
