//! Compressed CSR and quantized feature storage for million-node graph
//! residency (DESIGN.md §16).
//!
//! [`CompactCsr`] renumbers nodes degree-descending (stable: degree
//! desc, then old id asc — a pure function of the input graph), sorts
//! each neighbor list in the new id space and stores it delta-encoded
//! as LEB128 varints.  On skewed graphs the hubs land on small ids, so
//! both absolute first values and the gaps between sorted neighbors
//! stay short and most varints collapse to one or two bytes.  The
//! encoding is structure-exact: [`CompactCsr::to_csr`] rebuilds the
//! original graph bit-for-bit, multigraph duplicates included (edge
//! weights are not encoded — the decoded graph is uniform-weight, like
//! every generator output).
//!
//! [`QuantizedFeatures`] packs f32 feature blocks at u8 / u16
//! precision (affine `offset + q·step`, error ≤ step/2 up to f32
//! rounding) or as [`FeatureQuant::ExactI32`] — bit-exact for integral
//! values with |v| ≤ 2²⁴, the path the resident serving tier
//! (`graph::resident`) rides to stay bit-identical to the uncompressed
//! engine.

use crate::error::{Error, Result};

use super::csr::Csr;

/// Append `v` as an LEB128 varint (7 payload bits per byte, high bit =
/// continuation).
fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read one LEB128 varint starting at `*at`, advancing `*at` past it.
fn read_varint(bytes: &[u8], at: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *bytes
            .get(*at)
            .ok_or_else(|| Error::Graph("varint ran off the encoded buffer".into()))?;
        *at += 1;
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            return Err(Error::Graph("varint longer than 64 bits".into()));
        }
    }
}

/// Degree-renumbered, delta+varint compressed CSR (module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct CompactCsr {
    num_nodes: usize,
    num_edges: usize,
    /// `new_of_old[old] = new` — the degree-rank permutation.
    new_of_old: Vec<u32>,
    /// `old_of_new[new] = old` — its inverse.
    old_of_new: Vec<u32>,
    /// Byte offset of each new-id row in `bytes` (`num_nodes + 1`
    /// entries; empty rows occupy zero bytes).
    row_offsets: Vec<usize>,
    /// Per-row: first neighbor absolute, then non-negative gaps (gap 0
    /// keeps multigraph duplicates), all in new-id space, LEB128.
    bytes: Vec<u8>,
}

impl CompactCsr {
    /// Encode a seed [`Csr`].  Deterministic: the renumbering and the
    /// byte stream are pure functions of the graph structure.
    pub fn from_csr(g: &Csr) -> Result<CompactCsr> {
        let n = g.num_nodes();
        if n > u32::MAX as usize {
            return Err(Error::Graph(format!("{n} nodes exceed the u32 id space")));
        }
        // Degree-descending renumbering, stable on old id: hubs first.
        let mut old_of_new: Vec<u32> = (0..n as u32).collect();
        old_of_new.sort_by_key(|&v| (std::cmp::Reverse(g.degree(v as usize)), v));
        let mut new_of_old = vec![0u32; n];
        for (new, &old) in old_of_new.iter().enumerate() {
            new_of_old[old as usize] = new as u32;
        }
        let mut row_offsets = Vec::with_capacity(n + 1);
        row_offsets.push(0usize);
        let mut bytes = Vec::new();
        let mut row: Vec<u32> = Vec::new();
        for &old in &old_of_new {
            row.clear();
            row.extend(g.neighbors(old as usize).iter().map(|&d| new_of_old[d]));
            row.sort_unstable();
            let mut prev = 0u64;
            for (k, &d) in row.iter().enumerate() {
                let d = u64::from(d);
                let delta = if k == 0 { d } else { d - prev };
                push_varint(&mut bytes, delta);
                prev = d;
            }
            row_offsets.push(bytes.len());
        }
        Ok(CompactCsr {
            num_nodes: n,
            num_edges: g.num_edges(),
            new_of_old,
            old_of_new,
            row_offsets,
            bytes,
        })
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    /// New (degree-rank) id of an old node.
    pub fn new_id(&self, old: usize) -> usize {
        self.new_of_old[old] as usize
    }

    /// Old id of a new (degree-rank) id — the inverse permutation.
    pub fn old_id(&self, new: usize) -> usize {
        self.old_of_new[new] as usize
    }

    /// Decode one row in new-id space into `out` (cleared on entry):
    /// ascending new ids, duplicates kept.
    pub fn decode_row(&self, new: usize, out: &mut Vec<usize>) -> Result<()> {
        if new >= self.num_nodes {
            return Err(Error::Graph(format!("row {new} out of range ({} nodes)", self.num_nodes)));
        }
        out.clear();
        let mut at = self.row_offsets[new];
        let end = self.row_offsets[new + 1];
        let mut prev = 0u64;
        while at < end {
            let delta = read_varint(&self.bytes, &mut at)?;
            prev = if out.is_empty() { delta } else { prev + delta };
            if prev >= self.num_nodes as u64 {
                return Err(Error::Graph("decoded neighbor out of range".into()));
            }
            out.push(prev as usize);
        }
        Ok(())
    }

    /// Neighbors of an *old* node id into `out` — ascending old id with
    /// duplicates kept, i.e. exactly the seed [`Csr::neighbors`] order.
    pub fn neighbors(&self, old: usize, out: &mut Vec<usize>) -> Result<()> {
        if old >= self.num_nodes {
            return Err(Error::Graph(format!(
                "node {old} out of range ({} nodes)",
                self.num_nodes
            )));
        }
        self.decode_row(self.new_of_old[old] as usize, out)?;
        for v in out.iter_mut() {
            *v = self.old_of_new[*v] as usize;
        }
        out.sort_unstable();
        Ok(())
    }

    /// Exact structural roundtrip: rebuild the original graph (uniform
    /// edge weights — the encoding stores structure only).
    pub fn to_csr(&self) -> Result<Csr> {
        let mut edges = Vec::with_capacity(self.num_edges);
        let mut row = Vec::new();
        for new in 0..self.num_nodes {
            self.decode_row(new, &mut row)?;
            let src = self.old_of_new[new] as usize;
            for &d in &row {
                edges.push((src, self.old_of_new[d] as usize));
            }
        }
        Csr::from_edges(self.num_nodes, &edges)
    }

    /// Heap footprint of the encoding: neighbor bytes + row offsets +
    /// both permutation arrays.
    pub fn encoded_bytes(&self) -> usize {
        self.bytes.len()
            + self.row_offsets.len() * std::mem::size_of::<usize>()
            + (self.new_of_old.len() + self.old_of_new.len()) * std::mem::size_of::<u32>()
    }

    /// Heap footprint of the seed [`Csr`] arrays (RP + CI as usize, E
    /// as f32) for the same graph.
    pub fn seed_bytes(&self) -> usize {
        (self.num_nodes + 1 + self.num_edges) * std::mem::size_of::<usize>()
            + self.num_edges * std::mem::size_of::<f32>()
    }

    /// Structure compression ratio: seed footprint / encoded footprint.
    pub fn compression_ratio(&self) -> f64 {
        self.seed_bytes() as f64 / self.encoded_bytes() as f64
    }
}

/// Feature storage precision of the encoded tier (DESIGN.md §16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureQuant {
    /// 8-bit affine (256 levels): 4× smaller than f32, lossy
    /// (error ≤ step/2 up to f32 rounding).
    U8,
    /// 16-bit affine (65 536 levels): 2× smaller, lossy.
    U16,
    /// 32-bit integer: same size as f32, *bit-exact* roundtrip for
    /// integral values with |v| ≤ 2²⁴ (rejects anything else) — the
    /// resident path that stays bit-identical to the seed engine.
    ExactI32,
}

impl FeatureQuant {
    /// Bytes per encoded value.
    pub fn value_bytes(self) -> usize {
        match self {
            FeatureQuant::U8 => 1,
            FeatureQuant::U16 => 2,
            FeatureQuant::ExactI32 => 4,
        }
    }

    /// Quantization levels of the affine modes (0 for ExactI32).
    fn levels(self) -> f32 {
        match self {
            FeatureQuant::U8 => 255.0,
            FeatureQuant::U16 => 65_535.0,
            FeatureQuant::ExactI32 => 0.0,
        }
    }
}

/// One encoded feature block (a shard's table, in the resident tier).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedFeatures {
    quant: FeatureQuant,
    len: usize,
    /// Affine dequantization `v = offset + q·step` (U8/U16; step 0
    /// when the block is constant, so every value decodes to `offset`).
    offset: f32,
    step: f32,
    data: Vec<u8>,
}

impl QuantizedFeatures {
    /// Encode a block.  Deterministic; the affine modes derive
    /// (offset, step) from the block's min/max, ExactI32 rejects
    /// non-integral or out-of-range values.
    pub fn encode(quant: FeatureQuant, values: &[f32]) -> Result<QuantizedFeatures> {
        if let FeatureQuant::ExactI32 = quant {
            let mut data = Vec::with_capacity(values.len() * 4);
            for &v in values {
                if v.fract() != 0.0 || v.abs() > 16_777_216.0 {
                    return Err(Error::Graph(format!(
                        "ExactI32 requires integral values with |v| <= 2^24, got {v}"
                    )));
                }
                data.extend_from_slice(&(v as i32).to_le_bytes());
            }
            return Ok(QuantizedFeatures {
                quant,
                len: values.len(),
                offset: 0.0,
                step: 0.0,
                data,
            });
        }
        let levels = quant.levels();
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in values {
            if !v.is_finite() {
                return Err(Error::Graph("cannot quantize non-finite features".into()));
            }
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if values.is_empty() {
            lo = 0.0;
            hi = 0.0;
        }
        let step = if hi > lo { (hi - lo) / levels } else { 0.0 };
        let mut data = Vec::with_capacity(values.len() * quant.value_bytes());
        for &v in values {
            let q = if step > 0.0 { ((v - lo) / step).round().clamp(0.0, levels) } else { 0.0 };
            match quant {
                FeatureQuant::U8 => data.push(q as u8),
                FeatureQuant::U16 => data.extend_from_slice(&(q as u16).to_le_bytes()),
                FeatureQuant::ExactI32 => unreachable!("handled above"),
            }
        }
        Ok(QuantizedFeatures { quant, len: values.len(), offset: lo, step, data })
    }

    pub fn quant(&self) -> FeatureQuant {
        self.quant
    }

    /// Number of encoded values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Affine dequantization offset (the block minimum for U8/U16).
    pub fn offset(&self) -> f32 {
        self.offset
    }

    /// Affine dequantization step — the worst-case absolute error of
    /// the lossy modes is step/2 (up to f32 rounding); 0 for ExactI32
    /// and for constant blocks.
    pub fn step(&self) -> f32 {
        self.step
    }

    /// Decode the full block into `out` (cleared on entry).
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len);
        match self.quant {
            FeatureQuant::ExactI32 => {
                for c in self.data.chunks_exact(4) {
                    out.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32);
                }
            }
            FeatureQuant::U8 => {
                for &b in &self.data {
                    out.push(self.offset + f32::from(b) * self.step);
                }
            }
            FeatureQuant::U16 => {
                for c in self.data.chunks_exact(2) {
                    out.push(self.offset + f32::from(u16::from_le_bytes([c[0], c[1]])) * self.step);
                }
            }
        }
    }

    /// [`Self::decode_into`] into a fresh buffer.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_into(&mut out);
        out
    }

    /// Encoded heap footprint in bytes.
    pub fn encoded_bytes(&self) -> usize {
        self.data.len()
    }

    /// Decoded (f32) footprint in bytes.
    pub fn decoded_bytes(&self) -> usize {
        self.len * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::testing::{forall, Rng};

    #[test]
    fn varint_roundtrips_across_the_width_boundaries() {
        let probes = [
            0u64,
            1,
            127,
            128,
            16_383,
            16_384,
            u64::from(u32::MAX),
            u64::MAX - 1,
            u64::MAX,
        ];
        let mut buf = Vec::new();
        for &v in &probes {
            push_varint(&mut buf, v);
        }
        let mut at = 0;
        for &v in &probes {
            assert_eq!(read_varint(&buf, &mut at).unwrap(), v);
        }
        assert_eq!(at, buf.len());
        // A dangling continuation bit fails loudly.
        assert!(read_varint(&[0x80], &mut 0).is_err());
        // More than 64 payload bits fails loudly.
        let too_long = [0x80u8; 10];
        assert!(read_varint(&too_long, &mut 0).is_err());
    }

    #[test]
    fn renumbering_is_degree_descending_and_stable() {
        // Star: node 0 has degree 4, everyone else 1 (back-edges).
        let edges = [(0, 1), (0, 2), (0, 3), (0, 4), (1, 0), (2, 0), (3, 0), (4, 0)];
        let g = Csr::from_edges(5, &edges).unwrap();
        let c = CompactCsr::from_csr(&g).unwrap();
        assert_eq!(c.new_id(0), 0, "the hub must get rank 0");
        // Equal degrees keep old-id order.
        for old in 1..4 {
            assert!(c.new_id(old) < c.new_id(old + 1));
        }
        for new in 0..5 {
            assert_eq!(c.new_id(c.old_id(new)), new);
        }
    }

    #[test]
    fn empty_rows_occupy_zero_bytes() {
        let g = Csr::from_edges(6, &[(0, 5)]).unwrap();
        let c = CompactCsr::from_csr(&g).unwrap();
        let mut out = Vec::new();
        for old in 1..5 {
            c.neighbors(old, &mut out).unwrap();
            assert!(out.is_empty(), "node {old} must decode empty");
        }
        c.neighbors(0, &mut out).unwrap();
        assert_eq!(out, vec![5]);
        assert_eq!(c.to_csr().unwrap(), g);
    }

    #[test]
    fn multigraph_duplicates_survive_the_roundtrip() {
        let g = Csr::from_edges(3, &[(0, 1), (0, 1), (0, 1), (2, 0), (2, 0)]).unwrap();
        let c = CompactCsr::from_csr(&g).unwrap();
        assert_eq!(c.to_csr().unwrap(), g);
        let mut out = Vec::new();
        c.neighbors(0, &mut out).unwrap();
        assert_eq!(out, vec![1, 1, 1]);
    }

    #[test]
    fn property_compact_roundtrips_random_graphs() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(40) + 1;
            let m = rng.index(120);
            let edges: Vec<(usize, usize)> =
                (0..m).map(|_| (rng.index(n), rng.index(n))).collect();
            let g = Csr::from_edges(n, &edges).unwrap();
            let c = CompactCsr::from_csr(&g).unwrap();
            assert_eq!(c.num_nodes(), n);
            assert_eq!(c.num_edges(), m);
            assert_eq!(c.to_csr().unwrap(), g);
            let mut out = Vec::new();
            for old in 0..n {
                c.neighbors(old, &mut out).unwrap();
                assert_eq!(out, g.neighbors(old), "node {old}");
            }
        });
    }

    #[test]
    fn skewed_graphs_compress_below_the_seed_footprint() {
        let g = generate::rmat(1 << 12, 9 << 12, &generate::RmatParams::default(), 5).unwrap();
        let c = CompactCsr::from_csr(&g).unwrap();
        assert!(
            c.compression_ratio() > 1.5,
            "ratio {:.2} (encoded {} vs seed {})",
            c.compression_ratio(),
            c.encoded_bytes(),
            c.seed_bytes()
        );
        assert_eq!(c.to_csr().unwrap(), g);
    }

    #[test]
    fn exact_i32_roundtrips_bit_for_bit_and_rejects_out_of_range() {
        let vals = vec![0.0f32, 1.0, -1.0, 513.0, -16_777_216.0, 16_777_216.0];
        let q = QuantizedFeatures::encode(FeatureQuant::ExactI32, &vals).unwrap();
        assert_eq!(q.decode(), vals);
        assert_eq!(q.step(), 0.0);
        assert!(QuantizedFeatures::encode(FeatureQuant::ExactI32, &[0.5]).is_err());
        assert!(QuantizedFeatures::encode(FeatureQuant::ExactI32, &[16_777_218.0]).is_err());
    }

    #[test]
    fn affine_modes_bound_error_by_half_a_step() {
        forall(16, |rng: &mut Rng| {
            let n = rng.index(200) + 1;
            let lo = rng.f64_in(-50.0, 50.0);
            let hi = lo + rng.f64_in(0.0, 100.0);
            let vals: Vec<f32> = (0..n).map(|_| rng.f64_in(lo, hi) as f32).collect();
            for quant in [FeatureQuant::U8, FeatureQuant::U16] {
                let q = QuantizedFeatures::encode(quant, &vals).unwrap();
                assert_eq!(q.encoded_bytes(), n * quant.value_bytes());
                let dec = q.decode();
                let tol = 0.51 * q.step() + 1e-4;
                for (a, b) in vals.iter().zip(&dec) {
                    assert!((a - b).abs() <= tol, "{a} vs {b} (step {})", q.step());
                }
            }
        });
    }

    #[test]
    fn constant_and_empty_blocks_decode_exactly() {
        let q = QuantizedFeatures::encode(FeatureQuant::U8, &[3.25; 9]).unwrap();
        assert_eq!(q.step(), 0.0);
        assert_eq!(q.decode(), vec![3.25f32; 9]);
        let e = QuantizedFeatures::encode(FeatureQuant::U16, &[]).unwrap();
        assert!(e.is_empty());
        assert!(e.decode().is_empty());
        assert!(QuantizedFeatures::encode(FeatureQuant::U8, &[f32::NAN]).is_err());
    }
}
