//! Cluster partitioning for the decentralized setting (paper Fig. 4(b)).
//!
//! Each edge device exchanges messages only with the adjacent nodes in its
//! cluster; the cluster size cₛ drives Eq. (4)'s communication latency.
//! Two partitioners are provided: fixed-size blocking (the paper's taxi
//! study uses a uniform cₛ = 10) and locality-greedy growth (BFS from
//! unassigned seeds), which keeps intra-cluster edges high on structured
//! graphs.
//!
//! DESIGN.md: §10 (shard plans pack whole clusters via `from_clustering`).

use std::collections::VecDeque;

use crate::error::{Error, Result};

use super::csr::Csr;

/// A partition of nodes into clusters.
#[derive(Debug, Clone, PartialEq)]
pub struct Clustering {
    /// `assignment[node] = cluster id`.
    pub assignment: Vec<usize>,
    /// Nodes per cluster.
    pub clusters: Vec<Vec<usize>>,
}

impl Clustering {
    pub fn num_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Average cluster size (the model's cₛ).
    pub fn avg_size(&self) -> f64 {
        if self.clusters.is_empty() {
            return 0.0;
        }
        self.assignment.len() as f64 / self.clusters.len() as f64
    }

    /// Largest cluster — the straggler that closes a communication round
    /// (the cₛ the E11 autotuner scores a partition at).
    pub fn max_size(&self) -> usize {
        self.clusters.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Fraction of edges staying inside a cluster.
    pub fn intra_edge_fraction(&self, graph: &Csr) -> f64 {
        if graph.num_edges() == 0 {
            return 1.0;
        }
        let intra = (0..graph.num_nodes())
            .flat_map(|s| graph.neighbors(s).iter().map(move |&d| (s, d)))
            .filter(|&(s, d)| self.assignment[s] == self.assignment[d])
            .count();
        intra as f64 / graph.num_edges() as f64
    }

    fn validate(&self, num_nodes: usize) -> Result<()> {
        if self.assignment.len() != num_nodes {
            return Err(Error::Graph("assignment length mismatch".into()));
        }
        let mut seen = vec![false; num_nodes];
        for (cid, members) in self.clusters.iter().enumerate() {
            for &m in members {
                if m >= num_nodes || seen[m] {
                    return Err(Error::Graph(format!("node {m} misassigned")));
                }
                if self.assignment[m] != cid {
                    return Err(Error::Graph(format!("node {m} assignment mismatch")));
                }
                seen[m] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(Error::Graph("unassigned nodes".into()));
        }
        Ok(())
    }
}

/// Fixed-size blocking: consecutive ids, every cluster exactly
/// `cluster_size` nodes (last one possibly smaller).
pub fn fixed_size(num_nodes: usize, cluster_size: usize) -> Result<Clustering> {
    if cluster_size == 0 {
        return Err(Error::Graph("cluster size must be > 0".into()));
    }
    let mut assignment = vec![0usize; num_nodes];
    let mut clusters = Vec::new();
    for start in (0..num_nodes).step_by(cluster_size) {
        let cid = clusters.len();
        let end = (start + cluster_size).min(num_nodes);
        for node in start..end {
            assignment[node] = cid;
        }
        clusters.push((start..end).collect());
    }
    let c = Clustering { assignment, clusters };
    c.validate(num_nodes)?;
    Ok(c)
}

/// Locality-greedy clustering: BFS-grow clusters of up to `cluster_size`
/// nodes from unassigned seeds; keeps neighbors together on structured
/// graphs (road grids), falling back to id order for disconnected parts.
pub fn locality(graph: &Csr, cluster_size: usize) -> Result<Clustering> {
    if cluster_size == 0 {
        return Err(Error::Graph("cluster size must be > 0".into()));
    }
    let n = graph.num_nodes();
    let mut assignment = vec![usize::MAX; n];
    let mut clusters: Vec<Vec<usize>> = Vec::new();
    for seed in 0..n {
        if assignment[seed] != usize::MAX {
            continue;
        }
        let cid = clusters.len();
        let mut members = Vec::with_capacity(cluster_size);
        let mut queue = VecDeque::from([seed]);
        assignment[seed] = cid;
        while let Some(node) = queue.pop_front() {
            members.push(node);
            if members.len() + queue.len() >= cluster_size {
                continue;
            }
            for &nb in graph.neighbors(node) {
                if assignment[nb] == usize::MAX && members.len() + queue.len() < cluster_size {
                    assignment[nb] = cid;
                    queue.push_back(nb);
                }
            }
        }
        clusters.push(members);
    }
    let c = Clustering { assignment, clusters };
    c.validate(n)?;
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::testing::{forall, Rng};

    #[test]
    fn fixed_size_partitions_exactly() {
        let c = fixed_size(25, 10).unwrap();
        assert_eq!(c.num_clusters(), 3);
        assert_eq!(c.clusters[0].len(), 10);
        assert_eq!(c.clusters[2].len(), 5);
        assert!((c.avg_size() - 25.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn paper_taxi_clustering() {
        // 10 000 taxis, cₛ = 10 → 1000 clusters of exactly 10.
        let c = fixed_size(10_000, 10).unwrap();
        assert_eq!(c.num_clusters(), 1000);
        assert!(c.clusters.iter().all(|m| m.len() == 10));
    }

    #[test]
    fn locality_beats_blocking_on_grids() {
        let g = generate::grid(16, 16).unwrap();
        let blocked = fixed_size(g.num_nodes(), 8).unwrap();
        let local = locality(&g, 8).unwrap();
        assert!(
            local.intra_edge_fraction(&g) >= blocked.intra_edge_fraction(&g),
            "locality {} < blocked {}",
            local.intra_edge_fraction(&g),
            blocked.intra_edge_fraction(&g)
        );
    }

    #[test]
    fn property_partitions_are_complete_and_disjoint() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(100) + 1;
            let k = rng.index(12) + 1;
            let g = generate::uniform(n.max(2), n * 2, rng.next_u64()).unwrap();
            for c in [fixed_size(g.num_nodes(), k).unwrap(), locality(&g, k).unwrap()] {
                // validate() ran inside; additionally sizes never exceed k.
                assert!(c.clusters.iter().all(|m| m.len() <= k));
                let total: usize = c.clusters.iter().map(Vec::len).sum();
                assert_eq!(total, g.num_nodes());
            }
        });
    }

    /// E11 satellite: every node lands in exactly one cluster, under both
    /// partitioners, on arbitrary random graphs.
    #[test]
    fn property_every_node_assigned_exactly_once() {
        forall(32, |rng: &mut Rng| {
            let n = rng.index(120) + 1;
            let k = rng.index(15) + 1;
            let g = generate::uniform(n.max(2), n * 3, rng.next_u64()).unwrap();
            for c in [fixed_size(g.num_nodes(), k).unwrap(), locality(&g, k).unwrap()] {
                let mut seen = vec![0usize; g.num_nodes()];
                for (cid, members) in c.clusters.iter().enumerate() {
                    for &m in members {
                        seen[m] += 1;
                        assert_eq!(c.assignment[m], cid, "assignment/cluster disagree");
                    }
                }
                assert!(seen.iter().all(|&s| s == 1), "multiplicity: {seen:?}");
            }
        });
    }

    /// E11 satellite: cluster count / size bounds hold even when the
    /// cluster size does not divide the node count.
    #[test]
    fn property_count_and_size_bounds_for_non_dividing_sizes() {
        forall(32, |rng: &mut Rng| {
            let n = rng.index(150) + 1;
            let k = rng.index(17) + 1;
            let g = generate::uniform(n.max(2), n * 2, rng.next_u64()).unwrap();
            let n = g.num_nodes();

            let f = fixed_size(n, k).unwrap();
            assert_eq!(f.num_clusters(), n.div_ceil(k));
            assert!(f.clusters.iter().all(|m| !m.is_empty() && m.len() <= k));
            // All blocks but the last are exactly k.
            for m in f.clusters.iter().take(f.num_clusters().saturating_sub(1)) {
                assert_eq!(m.len(), k);
            }
            assert!(f.max_size() <= k);

            let l = locality(&g, k).unwrap();
            // BFS growth can fragment (disconnected parts) but never
            // produces fewer clusters than perfect packing or more than n.
            assert!(l.num_clusters() >= n.div_ceil(k));
            assert!(l.num_clusters() <= n);
            assert!(l.clusters.iter().all(|m| !m.is_empty() && m.len() <= k));
            assert!(l.max_size() <= k && l.max_size() >= 1);
        });
    }

    /// E11 satellite: `intra_edge_fraction` is a proper fraction for any
    /// clustering of any graph.
    #[test]
    fn property_intra_edge_fraction_in_unit_interval() {
        forall(32, |rng: &mut Rng| {
            let n = rng.index(80) + 2;
            let k = rng.index(12) + 1;
            let g = generate::uniform(n, rng.index(4 * n) + 1, rng.next_u64()).unwrap();
            for c in [fixed_size(g.num_nodes(), k).unwrap(), locality(&g, k).unwrap()] {
                let f = c.intra_edge_fraction(&g);
                assert!((0.0..=1.0).contains(&f), "fraction {f} out of range");
            }
        });
    }

    /// E11 satellite: on ring graphs the locality partitioner never keeps
    /// fewer edges inside clusters than id-order blocking.
    #[test]
    fn property_locality_never_worse_than_fixed_on_rings() {
        forall(32, |rng: &mut Rng| {
            let n = rng.index(80) + 3;
            let k = rng.index(12) + 1;
            let g = generate::ring(n).unwrap();
            let blocked = fixed_size(n, k).unwrap().intra_edge_fraction(&g);
            let local = locality(&g, k).unwrap().intra_edge_fraction(&g);
            assert!(
                local >= blocked - 1e-12,
                "n={n} k={k}: locality {local} < blocked {blocked}"
            );
        });
    }

    #[test]
    fn max_size_tracks_the_largest_cluster() {
        assert_eq!(fixed_size(25, 10).unwrap().max_size(), 10);
        assert_eq!(fixed_size(7, 3).unwrap().max_size(), 3);
        assert_eq!(fixed_size(0, 3).unwrap().max_size(), 0);
        let g = generate::ring(9).unwrap();
        assert!(locality(&g, 4).unwrap().max_size() <= 4);
    }

    #[test]
    fn empty_graph_yields_no_clusters() {
        let c = fixed_size(0, 4).unwrap();
        assert_eq!(c.num_clusters(), 0);
        assert_eq!(c.avg_size(), 0.0);
        assert!(c.assignment.is_empty());

        let g = Csr::from_edges(0, &[]).unwrap();
        let lc = locality(&g, 4).unwrap();
        assert_eq!(lc.num_clusters(), 0);
        assert!(lc.assignment.is_empty());
    }

    #[test]
    fn non_dividing_cluster_size_assigns_every_node_exactly_once() {
        // 7 nodes, cₛ = 3 → 3 + 3 + 1.
        let c = fixed_size(7, 3).unwrap();
        assert_eq!(c.num_clusters(), 3);
        assert_eq!(c.clusters[2], vec![6]);
        assert_eq!(c.clusters.iter().map(Vec::len).sum::<usize>(), 7);
        for (node, &cid) in c.assignment.iter().enumerate() {
            assert!(c.clusters[cid].contains(&node), "node {node} not in cluster {cid}");
        }
        // cₛ larger than the graph: one cluster holding everything.
        let one = fixed_size(5, 100).unwrap();
        assert_eq!(one.num_clusters(), 1);
        assert_eq!(one.clusters[0].len(), 5);
    }

    #[test]
    fn disconnected_components_keep_every_node_assigned_exactly_once() {
        // Two 4-cliques plus two isolated nodes (8, 9).
        let mut edges = Vec::new();
        for base in [0usize, 4] {
            for i in 0..4 {
                for j in 0..4 {
                    if i != j {
                        edges.push((base + i, base + j));
                    }
                }
            }
        }
        let g = Csr::from_edges(10, &edges).unwrap();
        let c = locality(&g, 3).unwrap();
        let mut seen = vec![0usize; 10];
        for members in &c.clusters {
            assert!(!members.is_empty() && members.len() <= 3);
            for &n in members {
                seen[n] += 1;
            }
        }
        assert!(seen.iter().all(|&s| s == 1), "every node exactly once: {seen:?}");
        // The isolated nodes still land in clusters of their own.
        assert_eq!(c.assignment.len(), 10);
        assert_ne!(c.assignment[8], c.assignment[0]);
        assert_ne!(c.assignment[9], c.assignment[4]);
    }

    #[test]
    fn zero_cluster_size_rejected() {
        assert!(fixed_size(10, 0).is_err());
        let g = generate::grid(2, 2).unwrap();
        assert!(locality(&g, 0).is_err());
    }

    #[test]
    fn intra_fraction_bounds() {
        let g = generate::grid(4, 4).unwrap();
        let one = fixed_size(g.num_nodes(), g.num_nodes()).unwrap();
        assert!((one.intra_edge_fraction(&g) - 1.0).abs() < 1e-12);
        let singles = fixed_size(g.num_nodes(), 1).unwrap();
        assert_eq!(singles.intra_edge_fraction(&g), 0.0);
    }
}
