//! Synthetic graph generators.
//!
//! The paper's datasets (Table 2) are not redistributable here, so we
//! generate graphs matching their published statistics (DESIGN.md §2):
//! a *configuration-model* generator reproduces (N, E) with a chosen degree
//! profile, and an *R-MAT* generator reproduces the power-law structure of
//! LiveJournal-class social graphs.

use crate::error::{Error, Result};
use crate::testing::Rng;

use super::csr::Csr;

/// Uniform configuration model: `num_edges` directed edges with endpoints
/// drawn uniformly (self-loops excluded, duplicates allowed — matching how
/// edge *counts* enter the paper's model).
pub fn uniform(num_nodes: usize, num_edges: usize, seed: u64) -> Result<Csr> {
    if num_nodes < 2 && num_edges > 0 {
        return Err(Error::Graph("need >= 2 nodes for edges".into()));
    }
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let s = rng.index(num_nodes);
        let mut d = rng.index(num_nodes);
        if d == s {
            d = (d + 1) % num_nodes;
        }
        edges.push((s, d));
    }
    Csr::from_edges(num_nodes, &edges)
}

/// R-MAT generator (Chakrabarti et al.) — recursive quadrant sampling with
/// probabilities (a, b, c, d); defaults (0.57, 0.19, 0.19, 0.05) give the
/// skewed degree distribution of social graphs.
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19 }
    }
}

pub fn rmat(num_nodes: usize, num_edges: usize, params: &RmatParams, seed: u64) -> Result<Csr> {
    if num_nodes == 0 {
        return Err(Error::Graph("rmat needs at least one node".into()));
    }
    let d = 1.0 - params.a - params.b - params.c;
    if !(d >= 0.0 && params.a >= 0.0 && params.b >= 0.0 && params.c >= 0.0) {
        return Err(Error::Graph("rmat probabilities must be a valid distribution".into()));
    }
    let scale = (num_nodes as f64).log2().ceil() as u32;
    let side = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_edges);
    while edges.len() < num_edges {
        let (mut r0, mut r1) = (0usize, side);
        let (mut c0, mut c1) = (0usize, side);
        while r1 - r0 > 1 {
            let u = rng.f64();
            let (top, left) = if u < params.a {
                (true, true)
            } else if u < params.a + params.b {
                (true, false)
            } else if u < params.a + params.b + params.c {
                (false, true)
            } else {
                (false, false)
            };
            let rm = (r0 + r1) / 2;
            let cm = (c0 + c1) / 2;
            if top {
                r1 = rm;
            } else {
                r0 = rm;
            }
            if left {
                c1 = cm;
            } else {
                c0 = cm;
            }
        }
        let (s, t) = (r0 % num_nodes, c0 % num_nodes);
        if s != t {
            edges.push((s, t));
        }
    }
    Csr::from_edges(num_nodes, &edges)
}

/// 2-D grid graph with 4-neighborhood (road-network-like substrate for the
/// taxi workload).
pub fn grid(rows: usize, cols: usize) -> Result<Csr> {
    let n = rows * cols;
    if n == 0 {
        return Err(Error::Graph("grid must be non-empty".into()));
    }
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            if c + 1 < cols {
                edges.push((i, i + 1));
                edges.push((i + 1, i));
            }
            if r + 1 < rows {
                edges.push((i, i + cols));
                edges.push((i + cols, i));
            }
        }
    }
    Csr::from_edges(n, &edges)
}

/// Ring (cycle) graph: node `i` connects to `i±1 (mod n)` — the 1-D
/// structured substrate the E11 clustering property tests use (any
/// contiguous arc of `m` nodes keeps exactly `2(m−1)` of its edges
/// internal, so intra-edge fractions are analytically checkable).
pub fn ring(num_nodes: usize) -> Result<Csr> {
    if num_nodes < 3 {
        return Err(Error::Graph("ring needs at least 3 nodes".into()));
    }
    let mut edges = Vec::with_capacity(2 * num_nodes);
    for i in 0..num_nodes {
        let j = (i + 1) % num_nodes;
        edges.push((i, j));
        edges.push((j, i));
    }
    Csr::from_edges(num_nodes, &edges)
}

/// Regular random graph: every node gets exactly `degree` out-edges to
/// distinct non-self targets — matches the paper's fixed-size uniform
/// neighbor sampling (§4.3).
pub fn regular(num_nodes: usize, degree: usize, seed: u64) -> Result<Csr> {
    if degree >= num_nodes && num_nodes > 0 {
        return Err(Error::Graph(format!(
            "degree {degree} needs at least {} nodes",
            degree + 1
        )));
    }
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_nodes * degree);
    for s in 0..num_nodes {
        // sample `degree` distinct targets != s
        let mut picked = rng.sample_distinct(num_nodes - 1, degree);
        for t in picked.iter_mut() {
            if *t >= s {
                *t += 1;
            }
        }
        for t in picked {
            edges.push((s, t));
        }
    }
    Csr::from_edges(num_nodes, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_hits_exact_counts() {
        let g = uniform(100, 450, 7).unwrap();
        assert_eq!(g.num_nodes(), 100);
        assert_eq!(g.num_edges(), 450);
        g.validate().unwrap();
        // no self loops
        for s in 0..100 {
            assert!(!g.neighbors(s).contains(&s));
        }
    }

    #[test]
    fn uniform_is_deterministic_per_seed() {
        assert_eq!(uniform(50, 100, 3).unwrap(), uniform(50, 100, 3).unwrap());
        assert_ne!(uniform(50, 100, 3).unwrap(), uniform(50, 100, 4).unwrap());
    }

    #[test]
    fn rmat_is_skewed() {
        let g = rmat(1 << 10, 8 << 10, &RmatParams::default(), 11).unwrap();
        assert_eq!(g.num_edges(), 8 << 10);
        let mut degrees: Vec<usize> = (0..g.num_nodes()).map(|i| g.degree(i)).collect();
        degrees.sort_unstable_by(|a, b| b.cmp(a));
        // top 1% of nodes own far more than 1% of edges (power law).
        let top: usize = degrees.iter().take(degrees.len() / 100).sum();
        assert!(
            top as f64 > 0.10 * g.num_edges() as f64,
            "top-1% share {top} of {} edges",
            g.num_edges()
        );
    }

    #[test]
    fn rmat_rejects_bad_probs() {
        assert!(rmat(16, 16, &RmatParams { a: 0.9, b: 0.9, c: 0.9 }, 1).is_err());
    }

    #[test]
    fn grid_has_interior_degree_four() {
        let g = grid(5, 5).unwrap();
        assert_eq!(g.num_nodes(), 25);
        assert_eq!(g.degree(12), 4); // center
        assert_eq!(g.degree(0), 2); // corner
        assert_eq!(g.degree(2), 3); // edge
        g.validate().unwrap();
    }

    #[test]
    fn ring_is_two_regular_and_cyclic() {
        let g = ring(12).unwrap();
        assert_eq!(g.num_nodes(), 12);
        assert_eq!(g.num_edges(), 24);
        for i in 0..12 {
            assert_eq!(g.degree(i), 2);
            let ns = g.neighbors(i);
            assert!(ns.contains(&((i + 1) % 12)) && ns.contains(&((i + 11) % 12)));
        }
        g.validate().unwrap();
        assert!(ring(2).is_err());
    }

    #[test]
    fn regular_has_exact_degree_no_self_loops_no_dups() {
        let g = regular(40, 7, 5).unwrap();
        for s in 0..40 {
            assert_eq!(g.degree(s), 7);
            let ns = g.neighbors(s);
            assert!(!ns.contains(&s));
            let mut sorted = ns.to_vec();
            sorted.dedup();
            assert_eq!(sorted.len(), 7, "duplicate targets for node {s}");
        }
    }

    #[test]
    fn regular_rejects_impossible_degree() {
        assert!(regular(5, 5, 1).is_err());
    }
}
