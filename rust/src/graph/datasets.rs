//! Dataset registry: the Table 2 graphs.
//!
//! | Dataset     | Nodes     | Edges      | Feature length | Avg Cₛ |
//! |-------------|-----------|------------|----------------|--------|
//! | LiveJournal | 4,847,571 | 68,993,773 | 1              | 9      |
//! | Collab      | 372,475   | 24,574,995 | 496            | 263    |
//! | Cora        | 2,708     | 5,429      | 1433           | 4      |
//! | Citeseer    | 3,327     | 4,732      | 3703           | 2      |
//!
//! The analytical model (netmodel / Fig. 8) consumes only these statistics;
//! `materialize` additionally generates a stat-matched synthetic graph for
//! the functional / simulator paths (DESIGN.md §2 substitution).

use crate::error::{Error, Result};

use super::csr::Csr;
use super::generate;

/// Published statistics of one dataset (Table 2).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    pub name: &'static str,
    pub nodes: usize,
    pub edges: usize,
    /// Local feature vector length.
    pub feature_len: usize,
    /// Average cluster size (average degree) — the paper's Cₛ.
    pub avg_cs: usize,
    /// Power-law degree structure (drives the generator choice).
    pub skewed: bool,
}

/// LiveJournal social network.
pub fn livejournal() -> DatasetStats {
    DatasetStats {
        name: "LiveJournal",
        nodes: 4_847_571,
        edges: 68_993_773,
        feature_len: 1,
        avg_cs: 9,
        skewed: true,
    }
}

/// OGB-Collab collaboration network.
pub fn collab() -> DatasetStats {
    DatasetStats {
        name: "Collab",
        nodes: 372_475,
        edges: 24_574_995,
        feature_len: 496,
        avg_cs: 263,
        skewed: false,
    }
}

/// Cora citation network.
pub fn cora() -> DatasetStats {
    DatasetStats { name: "Cora", nodes: 2_708, edges: 5_429, feature_len: 1433, avg_cs: 4, skewed: false }
}

/// Citeseer citation network.
pub fn citeseer() -> DatasetStats {
    DatasetStats {
        name: "Citeseer",
        nodes: 3_327,
        edges: 4_732,
        feature_len: 3703,
        avg_cs: 2,
        skewed: false,
    }
}

/// The four Table 2 datasets in paper order.
pub fn all() -> Vec<DatasetStats> {
    vec![livejournal(), collab(), cora(), citeseer()]
}

/// Look a dataset up by (case-insensitive) name.
pub fn by_name(name: &str) -> Result<DatasetStats> {
    let lower = name.to_ascii_lowercase();
    all()
        .into_iter()
        .find(|d| d.name.to_ascii_lowercase() == lower)
        .ok_or_else(|| {
            Error::Graph(format!(
                "unknown dataset `{name}` (expected one of LiveJournal, Collab, Cora, Citeseer)"
            ))
        })
}

impl DatasetStats {
    /// Generate a synthetic graph with these statistics.
    ///
    /// `max_nodes` caps the materialized size (LiveJournal at full scale
    /// does not fit a functional CAM model); scaling preserves the average
    /// degree so per-node workloads stay faithful.
    pub fn materialize(&self, max_nodes: usize, seed: u64) -> Result<Csr> {
        let nodes = self.nodes.min(max_nodes).max(2);
        let edges =
            ((self.edges as f64 * nodes as f64 / self.nodes as f64).round() as usize).max(1);
        if self.skewed {
            generate::rmat(nodes, edges, &generate::RmatParams::default(), seed)
        } else {
            generate::uniform(nodes, edges, seed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_statistics_are_exact() {
        let lj = livejournal();
        assert_eq!((lj.nodes, lj.edges, lj.feature_len, lj.avg_cs), (4_847_571, 68_993_773, 1, 9));
        let co = collab();
        assert_eq!((co.nodes, co.edges, co.feature_len, co.avg_cs), (372_475, 24_574_995, 496, 263));
        let c = cora();
        assert_eq!((c.nodes, c.edges, c.feature_len, c.avg_cs), (2_708, 5_429, 1433, 4));
        let cs = citeseer();
        assert_eq!((cs.nodes, cs.edges, cs.feature_len, cs.avg_cs), (3_327, 4_732, 3703, 2));
    }

    #[test]
    fn registry_order_matches_paper() {
        let names: Vec<&str> = all().iter().map(|d| d.name).collect();
        assert_eq!(names, vec!["LiveJournal", "Collab", "Cora", "Citeseer"]);
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert_eq!(by_name("cora").unwrap().name, "Cora");
        assert_eq!(by_name("LIVEJOURNAL").unwrap().name, "LiveJournal");
        assert!(by_name("imaginary").is_err());
    }

    #[test]
    fn materialize_scales_preserving_avg_degree() {
        let lj = livejournal();
        let g = lj.materialize(10_000, 42).unwrap();
        assert!(g.num_nodes() <= 10_000);
        let want_avg = lj.edges as f64 / lj.nodes as f64;
        let got_avg = g.avg_degree();
        assert!(
            (got_avg - want_avg).abs() / want_avg < 0.05,
            "avg degree drifted: {got_avg} vs {want_avg}"
        );
    }

    #[test]
    fn materialize_small_graph_exactly() {
        let c = cora();
        let g = c.materialize(usize::MAX, 1).unwrap();
        assert_eq!(g.num_nodes(), 2_708);
        assert_eq!(g.num_edges(), 5_429);
        g.validate().unwrap();
    }

    #[test]
    fn avg_cs_consistent_with_edge_counts() {
        // Table 2's Avg Cs ~ E/N (within rounding of the paper's values).
        for d in all() {
            let ratio = d.edges as f64 / d.nodes as f64;
            // Collab's published Cs=263 reflects the undirected expansion;
            // allow a generous envelope, but the order must hold.
            assert!(
                ratio > 0.5 * d.avg_cs as f64 / 4.0,
                "{}: E/N {ratio} vs Cs {}",
                d.name,
                d.avg_cs
            );
        }
    }
}
