//! Fixed-size uniform neighbor sampling (paper §4.3: "a given vertex is
//! mapped deterministically to a fixed-sized, uniform sample of its
//! neighbors").
//!
//! Deterministic: the sample of a node depends only on (graph, node,
//! sample size, seed) — re-sampling yields the same neighbors, as required
//! for reproducible inference and for matching the AOT artifact's `[B, S]`
//! neighbor-index input.
//!
//! DESIGN.md: §10 (sampling feeds the shard plan and the round engine).

use crate::testing::Rng;

use super::csr::Csr;

/// Deterministic uniform neighbor sampler.
#[derive(Debug, Clone)]
pub struct NeighborSampler {
    sample_size: usize,
    seed: u64,
}

impl NeighborSampler {
    pub fn new(sample_size: usize, seed: u64) -> NeighborSampler {
        NeighborSampler { sample_size, seed }
    }

    pub fn sample_size(&self) -> usize {
        self.sample_size
    }

    /// Sample up to `sample_size` distinct neighbors of `node`; nodes with
    /// fewer neighbors yield them all.  Output is padded with `None`.
    pub fn sample(&self, graph: &Csr, node: usize) -> Vec<Option<usize>> {
        let neighbors = graph.neighbors(node);
        let mut out = Vec::with_capacity(self.sample_size);
        if neighbors.len() <= self.sample_size {
            out.extend(neighbors.iter().map(|&n| Some(n)));
        } else {
            // Node-keyed RNG makes the mapping deterministic per vertex.
            let mut rng = Rng::new(self.seed ^ (node as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let picks = rng.sample_distinct(neighbors.len(), self.sample_size);
            out.extend(picks.into_iter().map(|i| Some(neighbors[i])));
        }
        out.resize(self.sample_size, None);
        out
    }

    /// Sample as an `i32` index row (`-1` = padding) — the exact input
    /// format of the AOT artifacts' `nbr_idx` parameter.
    pub fn sample_row(&self, graph: &Csr, node: usize) -> Vec<i32> {
        self.sample(graph, node)
            .into_iter()
            .map(|o| o.map(|n| n as i32).unwrap_or(-1))
            .collect()
    }

    /// Sample a batch of nodes into a flattened `[batch, sample_size]`
    /// row-major index matrix.
    pub fn sample_batch(&self, graph: &Csr, nodes: &[usize]) -> Vec<i32> {
        let mut out = Vec::with_capacity(nodes.len() * self.sample_size);
        for &n in nodes {
            out.extend(self.sample_row(graph, n));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::testing::{forall, Rng};

    fn line_graph(n: usize) -> Csr {
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        Csr::from_edges(n, &edges).unwrap()
    }

    #[test]
    fn undersized_neighborhoods_pad() {
        let g = line_graph(4);
        let s = NeighborSampler::new(3, 1);
        assert_eq!(s.sample(&g, 0), vec![Some(1), None, None]);
        assert_eq!(s.sample_row(&g, 3), vec![-1, -1, -1]); // last node: no out-edges
    }

    #[test]
    fn sampling_is_deterministic() {
        let g = generate::regular(50, 10, 3).unwrap();
        let s = NeighborSampler::new(4, 9);
        for node in 0..50 {
            assert_eq!(s.sample(&g, node), s.sample(&g, node));
        }
    }

    #[test]
    fn different_seeds_differ_somewhere() {
        let g = generate::regular(50, 10, 3).unwrap();
        let a = NeighborSampler::new(4, 1);
        let b = NeighborSampler::new(4, 2);
        assert!((0..50).any(|n| a.sample(&g, n) != b.sample(&g, n)));
    }

    #[test]
    fn property_samples_are_distinct_valid_neighbors() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(30) + 5;
            let deg = rng.index(n - 2) + 1;
            let g = generate::regular(n, deg, rng.next_u64()).unwrap();
            let k = rng.index(8) + 1;
            let s = NeighborSampler::new(k, rng.next_u64());
            for node in 0..n {
                let sample = s.sample(&g, node);
                let picked: Vec<usize> = sample.iter().flatten().copied().collect();
                assert_eq!(picked.len(), k.min(deg));
                let mut dedup = picked.clone();
                dedup.sort_unstable();
                dedup.dedup();
                assert_eq!(dedup.len(), picked.len(), "duplicates for node {node}");
                for p in picked {
                    assert!(g.neighbors(node).contains(&p));
                }
            }
        });
    }

    #[test]
    fn batch_layout_is_row_major() {
        let g = line_graph(5);
        let s = NeighborSampler::new(2, 1);
        let batch = s.sample_batch(&g, &[0, 1]);
        assert_eq!(batch.len(), 4);
        assert_eq!(&batch[..2], &s.sample_row(&g, 0)[..]);
        assert_eq!(&batch[2..], &s.sample_row(&g, 1)[..]);
    }
}
