//! PJRT runtime: load and execute the AOT artifacts from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module makes the
//! compiled HLO-text models callable as plain rust functions.  One PJRT CPU
//! client is shared; compiled executables are cached per artifact name.
//!
//! DESIGN.md: §5 (runtime).

mod executor;
mod manifest;
mod tensor;

pub use executor::Executor;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use tensor::{DType, Tensor, TensorData};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::error::Result;
use crate::pjrt as xla;

/// Default artifact directory relative to the repo root.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("IMA_GNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Loads, compiles and caches artifacts on a shared PJRT CPU client.
pub struct ArtifactStore {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executor>>>,
}

impl std::fmt::Debug for ArtifactStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactStore")
            .field("artifacts", &self.manifest.artifacts().len())
            .finish()
    }
}

impl ArtifactStore {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: &Path) -> Result<ArtifactStore> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactStore { manifest, client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling and caching on first use) the executor for `name`.
    pub fn load(&self, name: &str) -> Result<Rc<Executor>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let spec = self.manifest.get(name)?;
        let exe = Executor::compile(&self.client, spec, &self.manifest.path_of(spec))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Convenience: load + execute in one call.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.execute(inputs)
    }
}
