//! Host-side tensors exchanged with the PJRT runtime.
//!
//! DESIGN.md: §5 (runtime).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::pjrt as xla;

/// Element type of a tensor (the subset our artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => Err(Error::Runtime(format!("unsupported dtype `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
        }
    }
}

/// Tensor payload.  The buffer sits behind an `Arc`, so cloning a
/// tensor — the engine hands its round-constant table/weight caches to
/// every served batch — is a refcount bump, not a data copy; `PartialEq`
/// still compares the pointed-to values.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Arc<Vec<f32>>),
    I32(Arc<Vec<i32>>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }
}

/// A shaped host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let t = Tensor { shape: shape.to_vec(), data: TensorData::F32(Arc::new(data)) };
        t.check()?;
        Ok(t)
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor> {
        let t = Tensor { shape: shape.to_vec(), data: TensorData::I32(Arc::new(data)) };
        t.check()?;
        Ok(t)
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: TensorData::F32(Arc::new(vec![0.0; shape.iter().product()])),
        }
    }

    fn check(&self) -> Result<()> {
        let want: usize = self.shape.iter().product();
        if want != self.data.len() {
            return Err(Error::Runtime(format!(
                "tensor shape {:?} needs {want} elements, got {}",
                self.shape,
                self.data.len()
            )));
        }
        Ok(())
    }

    pub fn num_elements(&self) -> usize {
        self.data.len()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v.as_slice()),
            _ => Err(Error::Runtime("tensor is not f32".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v.as_slice()),
            _ => Err(Error::Runtime("tensor is not i32".into())),
        }
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(Arc::new(lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => TensorData::I32(Arc::new(lit.to_vec::<i32>()?)),
            other => {
                return Err(Error::Runtime(format!("unsupported literal type {other:?}")))
            }
        };
        let t = Tensor { shape: dims, data };
        t.check()?;
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_arity_is_enforced() {
        assert!(Tensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(&[0], vec![]).is_ok());
    }

    #[test]
    fn dtype_round_trip() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
        assert_eq!(DType::F32.name(), "float32");
    }

    #[test]
    fn accessors_enforce_types() {
        let t = Tensor::f32(&[2], vec![1.0, 2.0]).unwrap();
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.num_elements(), 2);
    }

    #[test]
    fn zeros_builder() {
        let t = Tensor::zeros_f32(&[3, 4]);
        assert_eq!(t.num_elements(), 12);
        assert!(t.as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn literal_round_trip_f32() {
        let t = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_round_trip_i32() {
        let t = Tensor::i32(&[3], vec![-1, 0, 7]).unwrap();
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    /// Clones share the payload allocation (refcount bump, no copy) —
    /// the contract that makes the engine's per-batch cache handoff
    /// cheap — while an independently built tensor with equal contents
    /// compares equal without sharing.
    #[test]
    fn clone_is_a_cheap_handle_over_shared_data() {
        let a = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(
            a.as_f32().unwrap().as_ptr(),
            b.as_f32().unwrap().as_ptr(),
            "clone must alias the buffer"
        );
        let c = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(a, c);
        assert_ne!(a.as_f32().unwrap().as_ptr(), c.as_f32().unwrap().as_ptr());
        let i = Tensor::i32(&[1], vec![5]).unwrap();
        assert_eq!(i.as_i32().unwrap().as_ptr(), i.clone().as_i32().unwrap().as_ptr());
    }
}
