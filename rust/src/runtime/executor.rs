//! PJRT execution of one AOT artifact.
//!
//! Follows the reference wiring (/opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Compilation happens once per
//! artifact; the hot path is `execute` only.
//!
//! DESIGN.md: §5 (runtime).

use std::path::Path;

use crate::error::{Error, Result};
use crate::pjrt as xla;

use super::manifest::ArtifactSpec;
use super::tensor::Tensor;

/// A compiled, loaded artifact ready to run.
pub struct Executor {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor").field("artifact", &self.spec.name).finish()
    }
}

impl Executor {
    /// Compile `spec`'s HLO text on `client`.
    pub fn compile(client: &xla::PjRtClient, spec: &ArtifactSpec, hlo_path: &Path) -> Result<Executor> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {hlo_path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Executor { spec: spec.clone(), exe })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Validate inputs against the manifest spec.
    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape != s.shape || t.dtype() != s.dtype {
                return Err(Error::Runtime(format!(
                    "{} input {i}: expected {:?} {}, got {:?} {}",
                    self.spec.name,
                    s.shape,
                    s.dtype.name(),
                    t.shape,
                    t.dtype().name()
                )));
            }
        }
        Ok(())
    }

    /// Execute with host tensors; returns the artifact's outputs.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the raw result is a
    /// tuple literal which we decompose into the declared outputs.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let literals = inputs.iter().map(Tensor::to_literal).collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime(format!("{}: empty result", self.spec.name)))?;
        let mut root = first.to_literal_sync()?;
        let parts = root.decompose_tuple()?;
        let parts = if parts.is_empty() { vec![root] } else { parts };
        if parts.len() != self.spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&self.spec.outputs) {
            let t = Tensor::from_literal(lit)?;
            if t.shape != spec.shape {
                return Err(Error::Runtime(format!(
                    "{}: output shape {:?} != declared {:?}",
                    self.spec.name, t.shape, spec.shape
                )));
            }
            out.push(t);
        }
        Ok(out)
    }
}
