//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! DESIGN.md: §5 (runtime).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::{self, Json};

use super::tensor::DType;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .require("shape")?
            .as_arr()
            .ok_or_else(|| Error::Runtime("`shape` must be an array".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Runtime("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            v.require("dtype")?
                .as_str()
                .ok_or_else(|| Error::Runtime("`dtype` must be a string".into()))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled model.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Model configuration recorded at lowering time (free-form numbers).
    pub config: BTreeMap<String, f64>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    root: PathBuf,
    artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Manifest::parse(dir, &text)
    }

    /// Parse manifest text (root used to resolve artifact files).
    pub fn parse(root: &Path, text: &str) -> Result<Manifest> {
        let doc = json::parse(text)?;
        let version = doc.require("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(Error::Runtime(format!("unsupported manifest version {version}")));
        }
        let mut artifacts = Vec::new();
        for a in doc
            .require("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Runtime("`artifacts` must be an array".into()))?
        {
            let name = a
                .require("name")?
                .as_str()
                .ok_or_else(|| Error::Runtime("artifact name must be a string".into()))?
                .to_string();
            let file = a
                .require("file")?
                .as_str()
                .ok_or_else(|| Error::Runtime("artifact file must be a string".into()))?
                .to_string();
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.require(key)?
                    .as_arr()
                    .ok_or_else(|| Error::Runtime(format!("`{key}` must be an array")))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let mut config = BTreeMap::new();
            if let Some(Json::Obj(map)) = a.get("config") {
                for (k, v) in map {
                    if let Some(n) = v.as_f64() {
                        config.insert(k.clone(), n);
                    }
                }
            }
            artifacts.push(ArtifactSpec {
                name,
                file,
                inputs: specs("inputs")?,
                outputs: specs("outputs")?,
                config,
            });
        }
        Ok(Manifest { root: root.to_path_buf(), artifacts })
    }

    pub fn artifacts(&self) -> &[ArtifactSpec] {
        &self.artifacts
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name).ok_or_else(|| {
            let known: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
            Error::Runtime(format!("unknown artifact `{name}` (have: {})", known.join(", ")))
        })
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.root.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "gcn_layer_small", "file": "gcn_layer_small.hlo.txt",
         "inputs": [
            {"shape": [16, 64], "dtype": "float32"},
            {"shape": [16, 4], "dtype": "int32"},
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64, 32], "dtype": "float32"}],
         "outputs": [{"shape": [16, 32], "dtype": "float32"}],
         "config": {"batch": 16, "hidden": 32, "use_crossbar": 1}}
      ]}"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(Path::new("/tmp/arts"), DOC).unwrap();
        assert_eq!(m.artifacts().len(), 1);
        let a = m.get("gcn_layer_small").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].shape, vec![16, 32]);
        assert_eq!(a.config["hidden"], 32.0);
        assert_eq!(m.path_of(a), PathBuf::from("/tmp/arts/gcn_layer_small.hlo.txt"));
    }

    #[test]
    fn unknown_artifact_lists_known_names() {
        let m = Manifest::parse(Path::new("/x"), DOC).unwrap();
        let e = m.get("nope").unwrap_err().to_string();
        assert!(e.contains("nope") && e.contains("gcn_layer_small"));
    }

    #[test]
    fn rejects_bad_version_and_shape() {
        let bad = DOC.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(Path::new("/x"), &bad).is_err());
        let bad = DOC.replace("\"float32\"", "\"float64\"");
        assert!(Manifest::parse(Path::new("/x"), &bad).is_err());
    }

    #[test]
    fn tensor_spec_num_elements() {
        let m = Manifest::parse(Path::new("/x"), DOC).unwrap();
        assert_eq!(m.get("gcn_layer_small").unwrap().inputs[0].num_elements(), 1024);
    }
}
