//! Zero-dependency deterministic parallel map (`std::thread::scope`).
//!
//! The experiment sweeps (E9 netsim grid, Fig. 8, the §4.3 scaling study)
//! are embarrassingly parallel: every grid point builds its own RNG from
//! the config seed, so points are independent pure functions.  This
//! driver fans items over a fixed worker pool through an atomic work
//! index and writes each result into the slot of its item — the output
//! is **order-stable and bit-identical** to the sequential
//! `items.iter().map(f)` regardless of thread count or OS scheduling.
//! Worker panics are re-raised on the caller.
//!
//! DESIGN.md: §8 (threading and determinism).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count the auto variants use: the machine's logical CPUs.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel map over `items` with `threads` workers; `threads <= 1`
/// degenerates to the plain sequential loop (no threads spawned).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.min(n);
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    // Dynamic load balancing: workers pull the next unclaimed index, so a
    // slow item (a big grid point) does not stall the rest of its stripe.
    let next = AtomicUsize::new(0);
    let next = &next;
    let f = &f;
    let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(part) => parts.push(part),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    // Results land by slot index — order-stable merge.
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    for (i, r) in parts.into_iter().flatten() {
        slots[i] = Some(r);
    }
    slots.into_iter().map(|o| o.expect("every slot filled exactly once")).collect()
}

/// [`par_map`] over all available cores.
pub fn par_map_auto<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map(items, available_threads(), f)
}

/// Fallible [`par_map`]: runs every item (no short-circuit — workers are
/// already in flight), then returns the first error in *item order* or
/// the full result vector.  The sweep drivers (Fig. 8, E9, E11) share
/// this instead of each re-collecting `Vec<Result<_>>`.
pub fn par_try_map<T, R, E, F>(items: &[T], threads: usize, f: F) -> std::result::Result<Vec<R>, E>
where
    T: Sync,
    R: Send,
    E: Send,
    F: Fn(&T) -> std::result::Result<R, E> + Sync,
{
    par_map(items, threads, f).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn matches_sequential_map_at_every_thread_count() {
        let items: Vec<u64> = (0..97).collect();
        let want: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for threads in [0, 1, 2, 3, 8, 64, 200] {
            let got = par_map(&items, threads, |x| x * x + 1);
            assert_eq!(got, want, "threads={threads}");
        }
        assert_eq!(par_map_auto(&items, |x| x * x + 1), want);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(&empty, 8, |x| *x).is_empty());
        assert_eq!(par_map(&[7u32], 8, |x| x + 1), vec![8]);
    }

    #[test]
    fn every_item_runs_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..50).map(|_| AtomicUsize::new(0)).collect();
        par_map(&(0..50usize).collect::<Vec<_>>(), 4, |&i| {
            hits[i].fetch_add(1, Ordering::Relaxed)
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn results_may_be_fallible() {
        let items: Vec<i32> = (0..20).collect();
        let out: Vec<Result<i32, String>> =
            par_map(&items, 4, |&x| if x == 13 { Err("unlucky".into()) } else { Ok(x) });
        assert_eq!(out.iter().filter(|r| r.is_err()).count(), 1);
        assert!(out[13].is_err());
        assert_eq!(out[12], Ok(12));
    }

    #[test]
    fn try_map_returns_first_error_in_item_order() {
        let items: Vec<i32> = (0..20).collect();
        let ok: Result<Vec<i32>, String> = par_try_map(&items, 4, |&x| Ok(x * 2));
        assert_eq!(ok.unwrap()[19], 38);
        let err: Result<Vec<i32>, String> =
            par_try_map(&items, 4, |&x| if x >= 13 { Err(format!("bad {x}")) } else { Ok(x) });
        // Items 13..19 all fail; the *earliest* failing item wins.
        assert_eq!(err.unwrap_err(), "bad 13");
    }

    #[test]
    fn worker_panics_propagate() {
        let r = std::panic::catch_unwind(|| {
            par_map(&(0..32usize).collect::<Vec<_>>(), 4, |&i| {
                assert!(i != 17, "boom at 17");
                i
            })
        });
        assert!(r.is_err());
    }
}
