//! Micro-benchmark harness (offline `criterion` substitute).
//!
//! Warmup + timed iterations with median / MAD / min / mean reporting and a
//! `black_box` to defeat constant folding.  Every `rust/benches/*.rs` target
//! (declared `harness = false`) drives this.
//!
//! DESIGN.md: §8 (fast paths and the perf trajectory this harness times).

use std::hint;
use std::time::{Duration, Instant};

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Result statistics of one benchmark case, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iterations: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Median absolute deviation — robust spread estimate.
    pub mad_ns: f64,
}

impl Stats {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.median_ns > 0.0 {
            1e9 / self.median_ns
        } else {
            f64::INFINITY
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{:.1} ns", ns)
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12}  mad {:>10}  min {:>12}  iters {}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            fmt_ns(self.min_ns),
            self.iterations
        )
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // Modest budgets: the suite runs on a single shared core.
        Bench {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(750),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, warmup: Duration, measure: Duration) -> Bench {
        self.warmup = warmup;
        self.measure = measure;
        self
    }

    /// Time `f` and record the statistics under `name`.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // Warmup until the budget elapses (at least one call).
        let start = Instant::now();
        let mut warm_iters: usize = 0;
        loop {
            black_box(f());
            warm_iters += 1;
            if start.elapsed() >= self.warmup || warm_iters >= self.max_iters {
                break;
            }
        }
        // Measure individual iterations.
        let mut samples_ns: Vec<f64> = Vec::with_capacity(1024);
        let start = Instant::now();
        while start.elapsed() < self.measure && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Stats::from_samples(name, &mut samples_ns);
        println!("{stats}");
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All recorded cases.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Print a section header the way criterion groups cases.
    pub fn section(&self, title: &str) {
        println!("\n== {title} ==");
    }
}

impl Stats {
    pub fn from_samples(name: &str, samples_ns: &mut [f64]) -> Stats {
        assert!(!samples_ns.is_empty(), "no samples for {name}");
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let median = samples_ns[n / 2];
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let mut devs: Vec<f64> = samples_ns.iter().map(|s| (s - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            name: name.to_string(),
            iterations: n,
            median_ns: median,
            mean_ns: mean,
            min_ns: samples_ns[0],
            max_ns: samples_ns[n - 1],
            mad_ns: devs[n / 2],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let mut s = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let st = Stats::from_samples("k", &mut s);
        assert_eq!(st.median_ns, 3.0);
        assert_eq!(st.min_ns, 1.0);
        assert_eq!(st.max_ns, 5.0);
        assert_eq!(st.iterations, 5);
        assert!((st.mean_ns - 3.0).abs() < 1e-12);
        assert_eq!(st.mad_ns, 1.0);
    }

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new().with_budget(Duration::from_millis(5), Duration::from_millis(20));
        let st = b.case("sum", || (0..1000u64).sum::<u64>());
        assert!(st.iterations > 0);
        assert!(st.median_ns > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_inverse_of_median() {
        let st = Stats {
            name: "x".into(),
            iterations: 1,
            median_ns: 1000.0,
            mean_ns: 1000.0,
            min_ns: 1000.0,
            max_ns: 1000.0,
            mad_ns: 0.0,
        };
        assert!((st.throughput_per_sec() - 1e6).abs() < 1e-6);
    }

    #[test]
    fn display_scales_units() {
        let mut s = vec![2_500_000.0];
        let st = Stats::from_samples("ms-case", &mut s);
        assert!(st.to_string().contains("ms"));
    }
}
