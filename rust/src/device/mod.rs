//! Circuit-level behavioral models (the HSPICE/NVSIM/MNSIM substitute).
//!
//! The paper extracts per-component delay/power from SPICE (Ag-Si memristor
//! [21] + NCSU 45 nm PDK [22]) and feeds them upward (Fig. 5).  This module
//! plays that role: each peripheral exposes `latency()` / `energy()`
//! (per-operation) and the RRAM cell exposes its electrical quantities so
//! array-level models can compose physically meaningful roll-ups.
//!
//! Constants live in [`crate::config::DeviceParams`]; the values are
//! calibrated so the composed per-core figures land on Table 1 (see
//! `cores::calibration` tests).
//!
//! DESIGN.md: §2 (circuit level).

pub mod area;

use crate::config::DeviceParams;
use crate::units::{Energy, Power, Time};

/// Ag-Si RRAM cell (1T1R for MVM arrays, 2T2R pairs for TCAM).
#[derive(Debug, Clone)]
pub struct RramCell<'p> {
    params: &'p DeviceParams,
}

impl<'p> RramCell<'p> {
    pub fn new(params: &'p DeviceParams) -> Self {
        RramCell { params }
    }

    /// Conductance of the fully-ON state (S).
    pub fn g_on(&self) -> f64 {
        1.0 / self.params.r_on_ohm
    }

    /// Conductance of the fully-OFF state (S).
    pub fn g_off(&self) -> f64 {
        1.0 / self.params.r_off_ohm
    }

    /// Conductance representing quantized level `level` of `levels` total.
    /// Level 0 maps to G_off, the top level to G_on, linearly in between —
    /// the analog-weight mapping of paper ref [21].
    pub fn conductance(&self, level: u32, levels: u32) -> f64 {
        assert!(levels >= 2, "need at least 2 levels");
        let l = level.min(levels - 1) as f64 / (levels - 1) as f64;
        self.g_off() + l * (self.g_on() - self.g_off())
    }

    /// Read current of one cell at `v_read` for a given level (A).
    pub fn read_current(&self, level: u32, levels: u32) -> f64 {
        self.params.v_read * self.conductance(level, levels)
    }

    /// Dynamic energy of one cell participating in one evaluate pass.
    pub fn read_energy(&self) -> Energy {
        self.params.cell_read_energy
    }

    /// Cell leakage (access transistor included).
    pub fn leakage(&self) -> Power {
        self.params.cell_leakage
    }

    /// ON/OFF ratio — sanity metric for level separability.
    pub fn on_off_ratio(&self) -> f64 {
        self.params.r_off_ohm / self.params.r_on_ohm
    }
}

macro_rules! peripheral {
    ($(#[$doc:meta])* $name:ident, $lat:ident, $en:ident) => {
        $(#[$doc])*
        #[derive(Debug, Clone)]
        pub struct $name<'p> {
            params: &'p DeviceParams,
        }

        impl<'p> $name<'p> {
            pub fn new(params: &'p DeviceParams) -> Self {
                Self { params }
            }

            /// Latency of one operation.
            pub fn latency(&self) -> Time {
                self.params.$lat
            }

            /// Dynamic energy of one operation.
            pub fn energy(&self) -> Energy {
                self.params.$en
            }
        }
    };
}

peripheral!(
    /// Digital-to-analog converter: drives one input bit-plane onto the
    /// bit-lines (paper Fig. 2(b), DAC).
    Dac, dac_latency, dac_energy
);
peripheral!(
    /// Analog-to-digital converter: one conversion of one source-line
    /// sample (shared across columns, see `CrossbarGeometry::adcs`).
    Adc, adc_latency, adc_energy
);
peripheral!(
    /// Sample & hold: captures all source-line currents of one pass.
    SampleHold, sh_latency, sh_energy
);
peripheral!(
    /// Shift & add: recombines per-bit partial products.
    ShiftAdd, shift_add_latency, shift_add_energy
);
peripheral!(
    /// Match-line sense amplifier of the CAM arrays (paper Fig. 2(c)).
    MatchLineSense, mlsa_latency, mlsa_energy
);
peripheral!(
    /// Search-data / word-line driver.
    Driver, driver_latency, driver_energy
);
peripheral!(
    /// Activation unit shared by the feature-extraction crossbars.
    Activation, activation_latency, activation_energy
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;

    fn p() -> DeviceParams {
        DeviceParams::default_45nm()
    }

    #[test]
    fn conductance_interpolates_monotonically() {
        let params = p();
        let cell = RramCell::new(&params);
        let levels = 16;
        let mut prev = -1.0;
        for l in 0..levels {
            let g = cell.conductance(l, levels);
            assert!(g > prev, "conductance must increase with level");
            prev = g;
        }
        assert!((cell.conductance(0, levels) - cell.g_off()).abs() < 1e-15);
        assert!((cell.conductance(levels - 1, levels) - cell.g_on()).abs() < 1e-15);
    }

    #[test]
    fn read_current_scales_with_voltage() {
        let mut params = p();
        let i1 = RramCell::new(&params).read_current(15, 16);
        params.v_read *= 2.0;
        let i2 = RramCell::new(&params).read_current(15, 16);
        assert!((i2 / i1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn on_off_ratio_is_large() {
        let params = p();
        assert!(RramCell::new(&params).on_off_ratio() >= 100.0);
    }

    #[test]
    fn level_clamps_at_top() {
        let params = p();
        let cell = RramCell::new(&params);
        assert_eq!(cell.conductance(99, 16), cell.conductance(15, 16));
    }

    #[test]
    #[should_panic(expected = "levels")]
    fn rejects_single_level() {
        let params = p();
        RramCell::new(&params).conductance(0, 1);
    }

    #[test]
    fn peripherals_expose_params() {
        let params = p();
        assert_eq!(Adc::new(&params).latency(), params.adc_latency);
        assert_eq!(Adc::new(&params).energy(), params.adc_energy);
        assert_eq!(Dac::new(&params).latency(), params.dac_latency);
        assert_eq!(MatchLineSense::new(&params).latency(), params.mlsa_latency);
        assert_eq!(Driver::new(&params).energy(), params.driver_energy);
        assert_eq!(SampleHold::new(&params).latency(), params.sh_latency);
        assert_eq!(ShiftAdd::new(&params).energy(), params.shift_add_energy);
        assert_eq!(Activation::new(&params).latency(), params.activation_latency);
    }
}
