//! Silicon-area roll-up (the NVSIM/MNSIM area report substitute).
//!
//! 45 nm-class constants: a 1T1R RRAM cell is ~12F² (access transistor
//! dominated), a 2T2R TCAM cell twice that; peripheral blocks use
//! published NVSIM-class footprints.  Areas feed deployment cost analysis
//! (a decentralized node must be small; the centralized bank need not).
//!
//! DESIGN.md: §2 (circuit level).

use crate::config::{AcceleratorConfig, CoreConfig, CrossbarGeometry};
use crate::units::Area;

/// Technology feature size (45 nm PDK, paper ref [22]).
pub const FEATURE_NM: f64 = 45.0;

fn f2() -> Area {
    // one F² in m²
    Area::um2((FEATURE_NM * 1e-3) * (FEATURE_NM * 1e-3))
}

/// 1T1R MVM cell area (~12 F²).
pub fn mvm_cell() -> Area {
    f2() * 12.0
}

/// 2T2R TCAM cell area (~24 F²).
pub fn cam_cell() -> Area {
    f2() * 24.0
}

/// One SAR ADC (8-bit class) at 45 nm.
pub fn adc() -> Area {
    Area::um2(1500.0)
}

/// One bit-line DAC/driver.
pub fn dac() -> Area {
    Area::um2(15.0)
}

/// Sample & hold per column.
pub fn sample_hold() -> Area {
    Area::um2(6.0)
}

/// Shift & add block per crossbar.
pub fn shift_add() -> Area {
    Area::um2(180.0)
}

/// Match-line sense amp per CAM row.
pub fn mlsa() -> Area {
    Area::um2(8.0)
}

/// MVM crossbar: cells + per-row DACs + per-column S&H + shared ADCs +
/// shift & add.
pub fn mvm_crossbar(g: &CrossbarGeometry) -> Area {
    mvm_cell() * g.cells() as f64
        + dac() * g.rows as f64
        + sample_hold() * g.cols as f64
        + adc() * g.adcs as f64
        + shift_add()
}

/// CAM crossbar: TCAM cells + search drivers + MLSAs.
pub fn cam_crossbar(g: &CrossbarGeometry) -> Area {
    cam_cell() * g.cells() as f64 + dac() * g.cols as f64 + mlsa() * g.rows as f64
}

/// A full core (bank of crossbars), CAM or MVM.
pub fn core(cfg: &CoreConfig, cam: bool) -> Area {
    let one = if cam { cam_crossbar(&cfg.geometry) } else { mvm_crossbar(&cfg.geometry) };
    one * cfg.crossbars as f64
}

/// Accelerator totals: (traversal, aggregation, feature extraction, total).
/// The traversal core holds a search + scan CAM pair per unit.
pub fn accelerator(cfg: &AcceleratorConfig) -> (Area, Area, Area, Area) {
    let t = core(&cfg.traversal, true) * 2.0;
    let a = core(&cfg.aggregation, false);
    let f = core(&cfg.feature, false);
    (t, a, f, t + a + f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn cell_areas_are_feature_scaled() {
        // 12 F² at 45 nm = 12 * 2.025e-3 µm² ≈ 0.0243 µm².
        assert!((mvm_cell().as_um2() - 0.0243).abs() < 1e-3);
        assert!((cam_cell().as_um2() / mvm_cell().as_um2() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decentralized_node_is_millimeter_scale() {
        let (_, _, _, total) = accelerator(&presets::decentralized());
        // one node: a few mm² at most — deployable at the edge
        assert!(total.as_mm2() > 0.01, "{}", total);
        assert!(total.as_mm2() < 20.0, "{}", total);
    }

    #[test]
    fn centralized_bank_scales_with_m_factors() {
        let cent = accelerator(&presets::centralized());
        let dec = accelerator(&presets::decentralized());
        // traversal bank = 2000 units
        assert!((cent.0.as_mm2() / dec.0.as_mm2() - 2000.0).abs() < 1.0);
        assert!((cent.1.as_mm2() / dec.1.as_mm2() - 1000.0).abs() < 1.0);
        assert!((cent.2.as_mm2() / dec.2.as_mm2() - 256.0).abs() < 1.0);
        assert!(cent.3 > dec.3);
    }

    #[test]
    fn adc_sharing_saves_area() {
        let mut few = crate::config::CrossbarGeometry::new(512, 512);
        few.adcs = 8;
        let mut many = few;
        many.adcs = 512;
        assert!(mvm_crossbar(&few) < mvm_crossbar(&many));
    }

    #[test]
    fn node_area_structure() {
        let cfg = presets::decentralized();
        let (t, a, f, total) = accelerator(&cfg);
        // aggregation's 512×512 cell array dwarfs the CAM pair…
        assert!(a > t);
        // …but the FE core's latency-oriented 32-ADC bank makes it the
        // area hot spot of a node — an explicit area-for-latency trade
        // (4 ADC rounds per pass, see the t₃ calibration).
        assert!(f > a);
        assert!((total.as_mm2() - (t + a + f).as_mm2()).abs() < 1e-12);
    }
}
