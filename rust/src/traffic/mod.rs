//! Closed-loop traffic engine (E13, DESIGN.md §11): arrival-driven
//! request scheduling, dynamic batching and SLO accounting over the
//! deployment shapes.
//!
//! Every experiment before this module measured one unloaded round; the
//! taxi case study is a *traffic* workload — requests arrive
//! continuously, queue at the leader's NIC or at cluster heads, and the
//! winning deployment flips with load.  This engine drives that regime
//! deterministically:
//!
//! * **Arrivals** ([`ArrivalProcess`], `arrivals.rs`) — open-loop
//!   Poisson, the diurnal taxi-demand curve, a bursty flash crowd; or a
//!   closed loop of `fleet` clients with think time ([`ThinkTime`]).
//! * **Queues** ([`DeploymentQueues`]) — a single leader queue
//!   (centralized), one queue per cluster head (semi-decentralized), one
//!   per device (decentralized).  Requests route by `node % servers`;
//!   servers are independent, so splitting a Poisson stream uniformly
//!   over the queues is *exact* — a representative-queue simulation at
//!   the split rate reproduces the full system's latency distribution.
//! * **Batching** ([`BatchPolicy`]) — immediate, size-triggered or
//!   deadline-triggered dynamic batching.  Batches form at *dispatch
//!   time* (the Triton-style work-conserving rule): a freed server takes
//!   up to a full batch from its pending queue at once, so batch sizes
//!   adapt to backlog and capacity converges to the full-batch rate
//!   under load; the deadline only bounds how long an idle server waits
//!   for companions.  Dispatched node lists are exactly what
//!   [`RoundEngine::assemble`] consumes (asserted in tests).
//! * **Service** ([`ServiceModel`]) — a batch of `k` requests costs
//!   `per_batch + k·per_request`, derived from the paper's closed forms
//!   through the PR-4 [`LatencyProvider`] (Analytic, Clustered, Netsim),
//!   so netsim congestion composes with queueing.
//!
//! Everything is scheduled on [`sim::EventQueue`]; runs are pure
//! functions of (arrivals, policy, service, seed), so reports are
//! bit-identical across thread counts and per seed.  Batch composition
//! is additionally independent of event-queue tie order: open-loop
//! streams are canonicalized by `(time, node)` before scheduling, and
//! tied arrivals always join the pending queue before a same-instant
//! deadline fires (property-tested with the FIFO-tie pattern from
//! `sim::event`).
//!
//! Cross-validation: with Poisson arrivals, a single queue and the
//! immediate policy, the engine is an M/D/1 station — the simulated mean
//! wait matches the Pollaczek–Khinchine closed form
//! ([`md1_mean_wait`]), and Little's law (`∫N(t)dt = Σ response`) holds
//! to round-off on *every* run ([`TrafficReport::littles_law_gap`]);
//! both are asserted in `rust/tests/traffic_cross_validation.rs`.
//!
//! **Faults and heterogeneity** (E14, DESIGN.md §13): a seeded
//! [`FaultPlan`] executes on the same event queue — crash windows abort
//! the in-service batch (its requests rejoin the queue head and
//! redispatch after recovery, so their waits keep growing), straggler
//! and link-degradation windows scale service times at dispatch.
//! Downtime, availability and MTTR land in the [`TrafficReport`];
//! Little's law still holds exactly because crashes never remove a
//! request from the system.  An empty plan pushes no events and takes
//! no degraded branches, so the zero-fault run is bit-identical to the
//! no-fault path.  Heterogeneous fleets run as one representative queue
//! per capability class ([`FleetMix`], [`open_loop_mix`]): uniform
//! routing splits the Poisson stream exactly per class, and the 1-class
//! mix degenerates bitwise to the homogeneous PR 5 path.
//!
//! **Closed-loop control** (E15, DESIGN.md §14): [`open_loop_controlled`]
//! runs the same engine under a [`Controller`] that watches windowed
//! p95 / depth / utilization / rate on the sim-time axis and switches
//! the deployment shape, batching policy and service model mid-run.  A
//! switch is a *graceful drain* through the double-buffer barrier:
//! in-service batches complete on the old shape, pending requests
//! re-route to the new one, and new dispatches pause for the target
//! rung's priced rebuild + re-upload cost.  The pause is billed as
//! `switch_downtime` and emitted as a `ctrl.switch` span whose duration
//! is the *same f64 expression* (`resume − start`), so span sums
//! reconcile bit-exactly with the report.  A controller that never
//! fires leaves the run bit-identical to [`open_loop`] at its initial
//! rung (property-tested in `rust/tests/controller.rs`).
//!
//! [`Controller`]: crate::controller::Controller
//! [`RoundEngine::assemble`]: crate::coordinator::RoundEngine::assemble
//! [`LatencyProvider`]: crate::coordinator::LatencyProvider
//! [`sim::EventQueue`]: crate::sim::EventQueue

mod arrivals;

pub use arrivals::{ArrivalProcess, ThinkTime};

use std::collections::VecDeque;

use crate::controller::{ControlledReport, Controller, CtrlView, SwitchRecord};
use crate::coordinator::{Arrival, LatencyProvider, LatencyStats};
use crate::error::{Error, Result};
use crate::netmodel::{NetModel, Topology};
use crate::obs::{Obs, WindowedStats};
use crate::sim::faults::{FaultConfig, FaultKind, FaultPlan};
use crate::sim::EventQueue;
use crate::testing::Rng;
use crate::units::Time;

/// Dynamic-batching policy at each queue (batches form at dispatch
/// time — module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchPolicy {
    /// Every request dispatches alone (no batching) — the M/D/1 case.
    Immediate,
    /// Only full batches of `max` dispatch; partial tails wait (and
    /// flush when the run drains).
    Size { max: usize },
    /// Dispatch `max` requests as soon as they are pending; otherwise an
    /// idle server waits at most `max_wait` past the oldest pending
    /// arrival before dispatching whatever is there.
    Deadline { max: usize, max_wait: Time },
}

impl BatchPolicy {
    fn validate(&self) -> Result<()> {
        match *self {
            BatchPolicy::Immediate => Ok(()),
            BatchPolicy::Size { max } | BatchPolicy::Deadline { max, .. } if max == 0 => {
                Err(Error::Sim("batch size must be > 0".into()))
            }
            BatchPolicy::Deadline { max_wait, .. }
                if !(max_wait.as_s() >= 0.0) || !max_wait.is_finite() =>
            {
                Err(Error::Sim("deadline wait must be finite and >= 0".into()))
            }
            _ => Ok(()),
        }
    }

    /// Largest batch the policy dispatches (for saturation math).
    pub fn max_batch(&self) -> usize {
        match *self {
            BatchPolicy::Immediate => 1,
            BatchPolicy::Size { max } | BatchPolicy::Deadline { max, .. } => max,
        }
    }
}

/// Queue topology of a deployment shape (module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeploymentQueues {
    /// One queue at the centralized leader's NIC.
    Leader,
    /// One queue per cluster head (the semi overlay).
    ClusterHeads { clusters: usize },
    /// One queue per device (decentralized: every node serves itself).
    Devices { nodes: usize },
}

impl DeploymentQueues {
    pub fn servers(&self) -> usize {
        match *self {
            DeploymentQueues::Leader => 1,
            DeploymentQueues::ClusterHeads { clusters } => clusters.max(1),
            DeploymentQueues::Devices { nodes } => nodes.max(1),
        }
    }

    /// The share of a system-wide open-loop rate one queue sees.
    /// Uniform splitting of a Poisson process is exact, so simulating a
    /// single representative queue at this rate reproduces the per-queue
    /// latency distribution of the full fleet.
    pub fn per_queue_rate(&self, system_rate_per_s: f64) -> f64 {
        system_rate_per_s / self.servers() as f64
    }
}

/// Batch service-time model: `service(k) = per_batch + k·per_request`.
/// `per_batch` is the communication round the batch barrier pays (one
/// gather / exchange per dispatched batch); `per_request` the marginal
/// per-node compute slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceModel {
    pub per_batch: Time,
    pub per_request: Time,
}

impl ServiceModel {
    pub fn new(per_batch: Time, per_request: Time) -> Result<ServiceModel> {
        let ok = |t: Time| t.is_finite() && t.as_s() >= 0.0;
        if !ok(per_batch) || !ok(per_request) || (per_batch + per_request).as_s() <= 0.0 {
            return Err(Error::Sim("service model needs non-negative, positive-sum terms".into()));
        }
        Ok(ServiceModel { per_batch, per_request })
    }

    /// Service time of a batch of `k` requests.
    pub fn service(&self, k: usize) -> Time {
        self.per_batch + self.per_request * k as f64
    }

    /// Requests/second one queue sustains at full `max_batch` batches —
    /// the saturation rate the E13 sweep normalizes against (the
    /// work-conserving dispatcher converges to full batches under load).
    pub fn saturation_rate(&self, max_batch: usize) -> f64 {
        let b = max_batch.max(1);
        b as f64 / self.service(b).as_s()
    }

    /// Centralized leader: one uplink gather per batch (Eq. 5 — or the
    /// netsim round completion under contention), one Eq. 3 pipeline
    /// slot per request.  The provider-variant dispatch lives on
    /// [`LatencyProvider`] so the pricing cannot drift from the engine's.
    pub fn centralized(
        provider: LatencyProvider,
        model: &NetModel,
        topo: Topology,
    ) -> Result<ServiceModel> {
        let b = model.breakdown();
        let (m1, m2, m3) = model.capacity_ratios();
        let slot = b.t1 * (1.0 / m1) + b.t2 * (1.0 / m2) + b.t3 * (1.0 / m3);
        ServiceModel::new(provider.centralized_comm(model, topo), slot)
    }

    /// Semi-decentralized cluster head: one E8 overlay exchange per
    /// batch (boundary-aware under `Clustered`), one member-compute slot
    /// at `head_capacity`× rate per request.
    pub fn semi(
        provider: LatencyProvider,
        model: &NetModel,
        topo: Topology,
        head_capacity: f64,
    ) -> Result<ServiceModel> {
        let h = head_capacity.max(1.0);
        let slot = model.breakdown().total_latency() * (1.0 / h);
        ServiceModel::new(provider.semi_comm(model, topo, h), slot)
    }

    /// Decentralized device: one Eq. 4 cluster exchange per batch
    /// (boundary-aware under `Clustered`), one full per-node compute per
    /// request.
    pub fn decentralized(
        provider: LatencyProvider,
        model: &NetModel,
        topo: Topology,
    ) -> Result<ServiceModel> {
        let slot = model.breakdown().total_latency();
        ServiceModel::new(provider.decentralized_comm(model, topo), slot)
    }
}

/// The canonical queue topology + service model of one deployment
/// setting at one operating point: the centralized leader, the semi
/// overlay (heads at `cₛ×` capacity, one queue per cluster — the
/// E9/E12 convention), or the per-device decentralized mesh.
/// `provider` prices the semi / decentralized exchanges (the
/// centralized gather has no cluster structure, so `Clustered`
/// coincides with `Analytic` there).  Shared by the E13 sweep, the
/// `ima-gnn traffic` CLI and the examples so the shape definitions
/// cannot drift apart.
pub fn deployment_shape(
    setting: crate::autotune::SettingKind,
    provider: LatencyProvider,
    model: &NetModel,
    topo: Topology,
) -> Result<(DeploymentQueues, ServiceModel)> {
    use crate::autotune::SettingKind;
    Ok(match setting {
        SettingKind::Centralized => (
            DeploymentQueues::Leader,
            ServiceModel::centralized(provider, model, topo)?,
        ),
        SettingKind::Semi => (
            DeploymentQueues::ClusterHeads {
                clusters: topo.nodes.div_ceil(topo.cluster_size.max(1)),
            },
            ServiceModel::semi(provider, model, topo, topo.cluster_size as f64)?,
        ),
        SettingKind::Decentralized => (
            DeploymentQueues::Devices { nodes: topo.nodes },
            ServiceModel::decentralized(provider, model, topo)?,
        ),
    })
}

/// Pollaczek–Khinchine mean queue wait of an M/D/1 station: Poisson
/// arrivals at `rate_per_s`, deterministic `service` per request,
/// `W_q = ρ·s / (2·(1 − ρ))`.  The closed form the cross-validation
/// test holds the engine against.
pub fn md1_mean_wait(rate_per_s: f64, service: Time) -> Result<Time> {
    let rho = rate_per_s * service.as_s();
    if !(rho >= 0.0) || rho >= 1.0 {
        return Err(Error::Sim(format!("M/D/1 needs 0 <= rho < 1, got {rho}")));
    }
    Ok(Time::s(rho * service.as_s() / (2.0 * (1.0 - rho))))
}

/// One dispatched batch, as executed: the node list is exactly what
/// `RoundEngine::assemble` takes.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRecord {
    pub server: usize,
    pub nodes: Vec<usize>,
    /// Dispatch instant (batch formation and service start coincide —
    /// the work-conserving rule).
    pub dispatched_at: Time,
    pub done_at: Time,
}

/// Aggregate outcome of one traffic run (per simulated queue set).
#[derive(Debug, Clone)]
pub struct TrafficReport {
    pub servers: usize,
    /// Requests that entered the system (all complete — runs drain).
    pub offered: usize,
    pub completed: usize,
    /// Last completion time.
    pub makespan: Time,
    /// Completions per second of virtual time.
    pub throughput_per_s: f64,
    /// Mean busy fraction across the simulated servers.
    pub utilization: f64,
    /// Mean wait from arrival to dispatch (queueing + batch fill).
    pub mean_wait: Time,
    /// Response-latency distribution (arrival → batch completion).
    pub latency: LatencyStats,
    pub batches: usize,
    pub mean_batch: f64,
    /// Max requests pending (not yet dispatched) at any single server.
    pub max_queue_depth: usize,
    /// High-water mark of the discrete-event queue driving the run
    /// ([`EventQueue::max_depth`]).  Counts scheduled events (arrivals,
    /// deadlines, completions), so it always dominates
    /// `max_queue_depth`; not part of the serialized sweep artifacts.
    pub max_event_depth: usize,
    /// Time-average number of requests in the system (∫N(t)dt / T).
    pub time_avg_in_system: f64,
    /// Σ response times — Little's law cross-check numerator.
    pub sum_response: Time,
    /// Total server downtime across executed crash windows
    /// (`Time::ZERO` on fault-free runs).
    pub downtime: Time,
    /// `1 − downtime / (servers × makespan)`, clamped to `[0, 1]` —
    /// 1.0 on fault-free runs.
    pub availability: f64,
    /// Crash windows that executed (crash *and* recover inside the
    /// run).
    pub fault_windows: usize,
    /// Mean time to recovery: `downtime / fault_windows`
    /// (`Time::ZERO` when no window executed).
    pub mttr: Time,
    /// Spans the obs ring buffer evicted during the run — long fault
    /// runs must not silently truncate traces, so reconciliation
    /// reports check this is 0 before summing span durations.  Always
    /// 0 with a disabled obs handle.
    pub dropped_spans: u64,
    /// The dispatched batches in execution order.
    pub batch_log: Vec<BatchRecord>,
}

impl TrafficReport {
    /// Relative Little's-law residual: `∫N(t)dt` must equal
    /// `Σ response` exactly (both count request-seconds in the system),
    /// so this is float round-off on a correct engine.
    pub fn littles_law_gap(&self) -> f64 {
        let area = self.time_avg_in_system * self.makespan.as_s();
        let sum = self.sum_response.as_s();
        (area - sum).abs() / sum.abs().max(1e-30)
    }

    /// Fraction of responses within `slo` (SLO attainment).
    pub fn slo_attainment(&self, slo: Time) -> f64 {
        self.latency.fraction_within(slo)
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Open-loop arrival: index into the canonicalized arrival list.
    Arrive { req: usize },
    /// Closed-loop client finished thinking; issues its next request.
    ClientArrive { client: usize },
    /// Idle-wait deadline of the request at the front of server
    /// `server`'s pending queue; stale when `oldest` is no longer the
    /// front (it dispatched earlier).
    Deadline { server: usize, oldest: usize },
    /// Server finished its in-service batch.  `epoch` is the server's
    /// crash epoch at dispatch — stale (the batch was aborted by a
    /// crash) when it no longer matches.
    Done { server: usize, epoch: u64 },
    /// Fault-plan crash window opens: the server goes down.
    Crash { server: usize },
    /// Fault-plan crash window closes: the server comes back up.
    Recover { server: usize },
    /// A controller switch's dispatch pause ends: every active queue
    /// re-evaluates dispatch.
    Resume,
}

struct ServerState {
    /// Pending requests, FIFO in arrival order.
    pending: VecDeque<usize>,
    /// (batch, dispatched_at, service duration) currently in service —
    /// the duration lets a crash refund the unfinished remainder.
    in_service: Option<(Vec<usize>, Time, Time)>,
    busy_total: Time,
    /// False inside an executing crash window.
    up: bool,
    /// Bumped on every crash; stamps `Done` events so completions of
    /// aborted batches are recognized as stale.
    epoch: u64,
    down_since: Time,
    down_total: Time,
}

/// Live controller state carried by a controlled engine run: the
/// decision windows (on the sim-time axis), the dwell anchors, and the
/// honest switch ledger.
struct CtrlState<'a> {
    controller: &'a Controller,
    /// Index of the active rung in the controller's ladder.
    current: usize,
    /// Windowed response times (seconds), sampled at batch completion.
    resp_w: WindowedStats,
    /// Windowed total pending depth, sampled at batch completion.
    depth_w: WindowedStats,
    /// Windowed busy fraction of the active fleet.
    util_w: WindowedStats,
    /// Arrival markers — `len / window` is the windowed arrival rate.
    /// Kept across switches (arrivals are shape-independent truth).
    rate_w: WindowedStats,
    last_switch_resume: Option<Time>,
    last_down_resume: Option<Time>,
    switches: Vec<SwitchRecord>,
    switch_downtime: Time,
    switch_affected: usize,
}

struct Engine<'a> {
    policy: BatchPolicy,
    service: ServiceModel,
    obs: &'a Obs,
    servers: Vec<ServerState>,
    queue: EventQueue<Ev>,
    // Per-request records (index = request id).
    arrival: Vec<Time>,
    node: Vec<usize>,
    start: Vec<Time>,
    done: Vec<Time>,
    client_of: Vec<usize>,
    // Closed-loop generation state (unused in open-loop runs).
    closed: Option<ClosedLoop>,
    // Accounting.
    now: Time,
    last_done: Time,
    in_system: usize,
    area_last_t: Time,
    area_s: f64,
    max_depth: usize,
    batch_log: Vec<BatchRecord>,
    // Controller state (None on static runs; `active == servers.len()`
    // and `pause_until == ZERO` then, so the static hot path is
    // bit-identical to the pre-controller engine).
    /// Queues currently serving: requests route `node % active`.
    active: usize,
    /// New dispatches are blocked until this instant (switch barrier).
    pause_until: Time,
    ctrl: Option<CtrlState<'a>>,
    // Fault state (all empty / false on fault-free runs, so the hot
    // path takes no degraded branches).
    faulted: bool,
    /// Per-server straggler windows `(from, until, factor)`, sorted by
    /// start time.
    slow: Vec<Vec<(Time, Time, f64)>>,
    /// Global link-degradation windows `(from, until, factor)`.
    link: Vec<(Time, Time, f64)>,
    fault_windows: usize,
}

struct ClosedLoop {
    think: ThinkTime,
    horizon: Time,
    nodes: usize,
    rng: Rng,
}

impl<'a> Engine<'a> {
    fn new(
        servers: usize,
        service: ServiceModel,
        policy: BatchPolicy,
        obs: &'a Obs,
    ) -> Result<Engine<'a>> {
        policy.validate()?;
        if servers == 0 {
            return Err(Error::Sim("traffic needs at least one server".into()));
        }
        Ok(Engine {
            policy,
            service,
            obs,
            servers: (0..servers)
                .map(|_| ServerState {
                    pending: VecDeque::new(),
                    in_service: None,
                    busy_total: Time::ZERO,
                    up: true,
                    epoch: 0,
                    down_since: Time::ZERO,
                    down_total: Time::ZERO,
                })
                .collect(),
            queue: EventQueue::new(),
            arrival: Vec::new(),
            node: Vec::new(),
            start: Vec::new(),
            done: Vec::new(),
            client_of: Vec::new(),
            closed: None,
            now: Time::ZERO,
            last_done: Time::ZERO,
            in_system: 0,
            area_last_t: Time::ZERO,
            area_s: 0.0,
            max_depth: 0,
            batch_log: Vec::new(),
            active: servers,
            pause_until: Time::ZERO,
            ctrl: None,
            faulted: false,
            slow: Vec::new(),
            link: Vec::new(),
            fault_windows: 0,
        })
    }

    /// Schedule a fault plan's events.  Must run *after* the arrival
    /// stream is scheduled, so a crash tied with an arrival processes
    /// the arrival first (the pre-scheduled-stream convention the
    /// tie-order property test pins down).  An empty plan is a strict
    /// no-op — no events, no flags — which is what makes the zero-fault
    /// run bit-identical to the no-fault path.
    fn install_faults(&mut self, plan: &FaultPlan) -> Result<()> {
        if plan.is_empty() {
            return Ok(());
        }
        self.faulted = true;
        self.slow = vec![Vec::new(); self.servers.len()];
        let check = |server: usize| -> Result<()> {
            if server >= self.servers.len() {
                return Err(Error::Sim(format!(
                    "fault plan targets server {server} of a {}-server run",
                    self.servers.len()
                )));
            }
            Ok(())
        };
        for e in plan.events() {
            match e.kind {
                FaultKind::Crash { server } => {
                    check(server)?;
                    self.queue.push(e.at, Ev::Crash { server });
                    self.queue.push(e.until, Ev::Recover { server });
                }
                FaultKind::Straggle { server, factor } => {
                    check(server)?;
                    self.slow[server].push((e.at, e.until, factor));
                }
                FaultKind::LinkDegrade { factor } => {
                    self.link.push((e.at, e.until, factor));
                }
            }
        }
        Ok(())
    }

    /// Service-time multiplier at dispatch: the worst active straggler
    /// window on this server × the worst active link window.  Windows
    /// are sorted by start, so the scan stops at the first future one.
    fn service_factor(&self, s: usize, now: Time) -> f64 {
        let active_max = |windows: &[(Time, Time, f64)]| {
            let mut f = 1.0f64;
            for &(from, until, x) in windows {
                if from > now {
                    break;
                }
                if now < until {
                    f = f.max(x);
                }
            }
            f
        };
        active_max(&self.slow[s]) * active_max(&self.link)
    }

    /// Advance the ∫N(t)dt integral to `now` (call before N changes).
    fn tick_area(&mut self, now: Time) {
        self.area_s += self.in_system as f64 * (now - self.area_last_t).as_s();
        self.area_last_t = now;
    }

    fn route(&self, node: usize) -> usize {
        node % self.active
    }

    /// A request (already recorded) joins its server's pending queue.
    fn on_request(&mut self, req: usize, now: Time) {
        self.tick_area(now);
        self.in_system += 1;
        if let Some(st) = self.ctrl.as_mut() {
            st.rate_w.push(now, 1.0);
            // An arrival landing inside a switch pause waits it out —
            // it counts against the switch's honest blast radius.
            if now < self.pause_until {
                st.switch_affected += 1;
            }
        }
        let s = self.route(self.node[req]);
        self.servers[s].pending.push_back(req);
        self.max_depth = self.max_depth.max(self.servers[s].pending.len());
        // Re-evaluate dispatch only on the transitions that can change
        // the decision: the queue just became non-empty, or it just
        // reached a full batch (avoids duplicate deadline arming).
        let len = self.servers[s].pending.len();
        if len == 1 || len >= self.policy.max_batch() {
            self.maybe_dispatch(s, now);
        }
    }

    /// Work-conserving dispatcher: an idle server takes up to a full
    /// batch at once; the deadline policy arms an idle-wait timer when
    /// the pending tail is short and fresh.
    fn maybe_dispatch(&mut self, s: usize, now: Time) {
        // Inside a switch pause no new batch may form; the queued
        // `Resume` event re-evaluates every active queue at pause end.
        if now < self.pause_until
            || !self.servers[s].up
            || self.servers[s].in_service.is_some()
            || self.servers[s].pending.is_empty()
        {
            return;
        }
        let pend = self.servers[s].pending.len();
        let take = match self.policy {
            BatchPolicy::Immediate => 1,
            BatchPolicy::Size { max } => {
                if pend >= max {
                    max
                } else {
                    return; // tail waits for more (flushes at drain)
                }
            }
            BatchPolicy::Deadline { max, max_wait } => {
                if pend >= max {
                    max
                } else {
                    let oldest = *self.servers[s].pending.front().expect("pend > 0");
                    if now - self.arrival[oldest] >= max_wait {
                        pend
                    } else {
                        self.queue.push(
                            self.arrival[oldest] + max_wait,
                            Ev::Deadline { server: s, oldest },
                        );
                        return;
                    }
                }
            }
        };
        self.dispatch(s, now, take);
    }

    fn dispatch(&mut self, s: usize, now: Time, take: usize) {
        let factor = if self.faulted { self.service_factor(s, now) } else { 1.0 };
        let srv = &mut self.servers[s];
        let reqs: Vec<usize> = srv.pending.drain(..take).collect();
        let base = self.service.service(reqs.len());
        // Guarded so fault-free runs (and degraded runs outside any
        // window) keep the exact base-duration bits.
        let dur = if factor == 1.0 { base } else { base * factor };
        srv.busy_total += dur;
        for &r in &reqs {
            self.start[r] = now;
        }
        if self.obs.is_enabled() {
            // Queue phase closes at dispatch: arrival → service start.
            for &r in &reqs {
                self.obs.tracer.record_at(
                    "traffic.wait",
                    s as u64,
                    self.arrival[r],
                    now,
                    vec![("node", self.node[r].into())],
                );
            }
        }
        let epoch = srv.epoch;
        srv.in_service = Some((reqs, now, dur));
        self.queue.push(now + dur, Ev::Done { server: s, epoch });
    }

    fn on_done(&mut self, s: usize, now: Time) {
        let (reqs, dispatched_at, _dur) =
            self.servers[s].in_service.take().expect("Done without an in-service batch");
        self.tick_area(now);
        self.last_done = self.last_done.max(now);
        self.in_system -= reqs.len();
        for &r in &reqs {
            self.done[r] = now;
        }
        // Closed loop: each completed request's client thinks, then
        // issues its next request (draw order: batch order).
        if let Some(cl) = &mut self.closed {
            for &r in &reqs {
                let next = now + cl.think.sample(&mut cl.rng);
                if next < cl.horizon {
                    self.queue.push(next, Ev::ClientArrive { client: self.client_of[r] });
                }
            }
        }
        if self.obs.is_enabled() {
            // Service phase per request, plus one batch-close span —
            // both in sim time, so span sums reconcile with the
            // report's latency totals exactly.
            for &r in &reqs {
                let started = self.start[r];
                self.obs.tracer.record_at("traffic.serve", s as u64, started, now, Vec::new());
            }
            self.obs.tracer.record_at(
                "traffic.batch",
                s as u64,
                dispatched_at,
                now,
                vec![("size", reqs.len().into()), ("server", s.into())],
            );
        }
        self.batch_log.push(BatchRecord {
            server: s,
            nodes: reqs.iter().map(|&r| self.node[r]).collect(),
            dispatched_at,
            done_at: now,
        });
        // Controller sampling happens *before* the redispatch below, so
        // the depth sample sees the post-completion backlog; the
        // decision runs after it, on up-to-date windows.
        if self.ctrl.is_some() {
            let total_pending: usize = self.servers.iter().map(|v| v.pending.len()).sum();
            let busy = self.servers[..self.active]
                .iter()
                .filter(|v| v.in_service.is_some())
                .count();
            let active = self.active;
            let st = self.ctrl.as_mut().expect("checked above");
            for &r in &reqs {
                st.resp_w.push(now, (now - self.arrival[r]).as_s());
            }
            st.depth_w.push(now, total_pending as f64);
            st.util_w.push(now, busy as f64 / active as f64);
        }
        self.maybe_dispatch(s, now);
        if self.ctrl.is_some() {
            self.ctrl_tick(now);
        }
    }

    /// Build the controller's observation snapshot and execute its
    /// decision, if any.  Runs after every completed batch.
    fn ctrl_tick(&mut self, now: Time) {
        let decision = {
            let st = self.ctrl.as_ref().expect("ctrl_tick without a controller");
            let total_pending: usize = self.servers.iter().map(|v| v.pending.len()).sum();
            let window_s = st.controller.hysteresis().window.as_s();
            let view = CtrlView {
                now,
                current: st.current,
                windowed_p95: Time::s(st.resp_w.quantile(0.95)),
                resp_samples: st.resp_w.len(),
                mean_depth: st.depth_w.mean(),
                utilization: st.util_w.mean(),
                arrival_rate_per_s: st.rate_w.len() as f64 / window_s,
                total_pending,
                last_switch_resume: st.last_switch_resume,
                last_down_resume: st.last_down_resume,
            };
            st.controller.decide(&view)
        };
        if let Some(to) = decision {
            self.execute_switch(to, now);
        }
    }

    /// Execute a controller switch as a graceful drain through the
    /// double-buffer barrier: in-service batches complete on the old
    /// shape, pending requests re-route to the new one in arrival
    /// order, and new dispatches pause for the target rung's priced
    /// rebuild + re-upload cost.  The accrued `switch_downtime` adds
    /// `resume − now` — the identical f64 expression as the
    /// `ctrl.switch` span's duration — so the two reconcile bit-exactly.
    fn execute_switch(&mut self, to: usize, now: Time) {
        let (from, cfg) = {
            let st = self.ctrl.as_ref().expect("switch without a controller");
            (st.current, st.controller.configs()[to])
        };
        let mut moved: Vec<usize> = Vec::new();
        for srv in &mut self.servers {
            moved.extend(srv.pending.drain(..));
        }
        // Open-loop request ids are assigned in (arrival, node) order,
        // so index order *is* arrival order across queues.
        moved.sort_unstable();
        self.active = cfg.queues.servers();
        self.service = cfg.service;
        self.policy = cfg.policy;
        for &r in &moved {
            let s = self.node[r] % self.active;
            self.servers[s].pending.push_back(r);
        }
        for srv in &self.servers[..self.active] {
            self.max_depth = self.max_depth.max(srv.pending.len());
        }
        let resume = now + cfg.switch_cost;
        self.pause_until = resume;
        self.queue.push(resume, Ev::Resume);
        if self.obs.is_enabled() {
            self.obs.tracer.record_at(
                "ctrl.switch",
                0,
                now,
                resume,
                vec![("from", from.into()), ("to", to.into()), ("moved", moved.len().into())],
            );
            self.obs.metrics.inc("ctrl.switches", 1);
            self.obs.metrics.observe("ctrl.switch_ms", (resume - now).as_ms());
        }
        let st = self.ctrl.as_mut().expect("switch without a controller");
        st.current = to;
        st.switch_downtime += resume - now;
        st.switch_affected += moved.len();
        st.switches.push(SwitchRecord {
            at: now,
            from,
            to,
            cost: cfg.switch_cost,
            moved: moved.len(),
        });
        st.last_switch_resume = Some(resume);
        if to < from {
            st.last_down_resume = Some(resume);
        }
        // Post-switch decisions must only see the new shape's samples;
        // the arrival-rate window survives (arrivals are shape-
        // independent truth).
        st.resp_w.clear();
        st.depth_w.clear();
        st.util_w.clear();
    }

    /// A crash window opens: the server goes down and its in-service
    /// batch aborts.  Only the time actually spent counts as busy (the
    /// unfinished remainder is refunded), the aborted requests rejoin
    /// the queue *head* in order and redispatch after recovery — their
    /// waits keep growing, which is the honest cost of a crash.  `N`
    /// does not change, so Little's law survives exactly; no area tick.
    fn on_crash(&mut self, s: usize, now: Time) {
        let srv = &mut self.servers[s];
        debug_assert!(srv.up, "crash windows are disjoint per server");
        srv.up = false;
        srv.down_since = now;
        srv.epoch += 1;
        if let Some((reqs, dispatched_at, dur)) = srv.in_service.take() {
            srv.busy_total = srv.busy_total - dur + (now - dispatched_at);
            for &r in reqs.iter().rev() {
                srv.pending.push_front(r);
            }
            let depth = srv.pending.len();
            self.max_depth = self.max_depth.max(depth);
        }
    }

    /// A crash window closes: account the outage, record the
    /// `fault.crash` span (its duration is exactly this window's
    /// downtime, so span sums reconcile with the report), and
    /// redispatch whatever queued up while down.
    fn on_recover(&mut self, s: usize, now: Time) {
        debug_assert!(!self.servers[s].up, "recover without a crash");
        let down_since = self.servers[s].down_since;
        self.servers[s].up = true;
        self.servers[s].down_total += now - down_since;
        self.fault_windows += 1;
        if self.obs.is_enabled() {
            self.obs.tracer.record_at(
                "fault.crash",
                s as u64,
                down_since,
                now,
                vec![("server", s.into())],
            );
            self.obs.metrics.inc("fault.crashes", 1);
            self.obs.metrics.observe("fault.outage_ms", (now - down_since).as_ms());
        }
        self.maybe_dispatch(s, now);
    }

    fn handle(&mut self, ev: Ev, now: Time) {
        self.now = now;
        match ev {
            Ev::Arrive { req } => self.on_request(req, now),
            Ev::ClientArrive { client } => {
                let cl = self.closed.as_mut().expect("client event in an open-loop run");
                let node = cl.rng.index(cl.nodes);
                let req = self.arrival.len();
                self.arrival.push(now);
                self.node.push(node);
                self.start.push(Time::ZERO);
                self.done.push(Time::ZERO);
                self.client_of.push(client);
                self.on_request(req, now);
            }
            Ev::Deadline { server, oldest } => {
                // Stale unless the armed request still fronts the queue
                // and the server is still idle and up (a busy server
                // re-checks the deadline itself at its next Done; a
                // down server redispatches at recovery; a paused engine
                // redispatches at its Resume).
                if now >= self.pause_until
                    && self.servers[server].up
                    && self.servers[server].in_service.is_none()
                    && self.servers[server].pending.front() == Some(&oldest)
                {
                    let take =
                        self.servers[server].pending.len().min(self.policy.max_batch());
                    self.dispatch(server, now, take);
                }
            }
            Ev::Done { server, epoch } => {
                // Stale when the batch it announced was crash-aborted.
                if self.servers[server].epoch == epoch {
                    self.on_done(server, now);
                }
            }
            Ev::Crash { server } => self.on_crash(server, now),
            Ev::Recover { server } => self.on_recover(server, now),
            Ev::Resume => {
                for s in 0..self.active {
                    self.maybe_dispatch(s, now);
                }
            }
        }
    }

    /// Drain the event queue; flush any pending tails at the last event
    /// time (the size-triggered policy's partial batches) and keep
    /// draining until everything completed.
    fn run_to_completion(&mut self) {
        loop {
            while let Some((t, ev)) = self.queue.pop() {
                self.handle(ev, t);
            }
            let t = self.now;
            let mut flushed = false;
            for s in 0..self.servers.len() {
                // Every crash window schedules its Recover and every
                // switch its Resume, so by drain time all servers are
                // back up, no pause is active, and the flush reaches
                // every pending tail.
                if t >= self.pause_until
                    && self.servers[s].up
                    && self.servers[s].in_service.is_none()
                    && !self.servers[s].pending.is_empty()
                {
                    let take = self.servers[s].pending.len().min(self.policy.max_batch());
                    self.dispatch(s, t, take);
                    flushed = true;
                }
            }
            if !flushed {
                break;
            }
        }
    }

    fn report(self) -> Result<TrafficReport> {
        let n = self.arrival.len();
        if n == 0 {
            return Err(Error::Sim("traffic run produced no requests".into()));
        }
        debug_assert_eq!(self.in_system, 0, "run must drain");
        // Last completion — stale deadline events popping later must not
        // stretch the horizon.
        let makespan = self.last_done;
        let responses: Vec<Time> =
            (0..n).map(|i| self.done[i] - self.arrival[i]).collect();
        let sum_response: Time = responses.iter().copied().sum();
        let mean_wait: Time = (0..n)
            .map(|i| self.start[i] - self.arrival[i])
            .sum::<Time>()
            * (1.0 / n as f64);
        let busy: Time = self.servers.iter().map(|s| s.busy_total).sum();
        let batches = self.batch_log.len();
        // Capacity counts the *active* queues — the final rung on a
        // controlled run; identical to `servers.len()` on static runs
        // (a controlled run's engine is sized to its largest rung, and
        // inactive queues never accrue busy time).
        let capacity_s = (self.active as f64 * makespan.as_s()).max(1e-30);
        let downtime: Time = self.servers.iter().map(|s| s.down_total).sum();
        let availability = (1.0 - downtime.as_s() / capacity_s).clamp(0.0, 1.0);
        let mttr = if self.fault_windows > 0 {
            downtime * (1.0 / self.fault_windows as f64)
        } else {
            Time::ZERO
        };
        if self.obs.is_enabled() {
            let m = &self.obs.metrics;
            m.inc("traffic.requests", n as u64);
            m.inc("traffic.batches", batches as u64);
            m.set_gauge("traffic.utilization", busy.as_s() / capacity_s);
            m.raise_gauge("traffic.max_queue_depth", self.max_depth as f64);
            m.set_gauge("sim.event_queue.depth", self.queue.len() as f64);
            m.raise_gauge("sim.event_queue.max_depth", self.queue.max_depth() as f64);
            m.set_gauge("traffic.availability", availability);
            m.set_gauge("obs.tracer.dropped", self.obs.tracer.dropped() as f64);
            for i in 0..n {
                m.observe("traffic.wait_ms", (self.start[i] - self.arrival[i]).as_ms());
                m.observe("traffic.response_ms", responses[i].as_ms());
            }
        }
        Ok(TrafficReport {
            servers: self.active,
            offered: n,
            completed: n,
            makespan,
            throughput_per_s: n as f64 / makespan.as_s().max(1e-30),
            utilization: busy.as_s() / capacity_s,
            mean_wait,
            latency: LatencyStats::from_samples(responses)?,
            batches,
            mean_batch: n as f64 / batches.max(1) as f64,
            max_queue_depth: self.max_depth,
            max_event_depth: self.queue.max_depth(),
            time_avg_in_system: self.area_s / makespan.as_s().max(1e-30),
            sum_response,
            downtime,
            availability,
            fault_windows: self.fault_windows,
            mttr,
            dropped_spans: self.obs.tracer.dropped(),
            batch_log: self.batch_log,
        })
    }
}

/// Run an open-loop arrival list against `servers` queues.
///
/// The list is canonicalized by `(time, node)` before scheduling, so
/// batch composition is independent of the caller's push order even
/// under exact timestamp ties (the determinism audit's contract).
pub fn open_loop(
    servers: usize,
    service: &ServiceModel,
    policy: BatchPolicy,
    arrivals: &[Arrival],
) -> Result<TrafficReport> {
    let obs = Obs::disabled();
    open_loop_observed(servers, service, policy, arrivals, &obs)
}

/// [`open_loop`] with observability: when `obs` is enabled, every
/// request records `traffic.wait` / `traffic.serve` spans and every
/// dispatched batch a `traffic.batch` span — all at sim times, on track
/// = server index — plus wait/response histograms and queue-depth
/// gauges in `obs.metrics`.  With a disabled handle the run is
/// bit-identical to [`open_loop`].
pub fn open_loop_observed(
    servers: usize,
    service: &ServiceModel,
    policy: BatchPolicy,
    arrivals: &[Arrival],
    obs: &Obs,
) -> Result<TrafficReport> {
    open_loop_faulted(servers, service, policy, arrivals, &FaultPlan::none(), obs)
}

/// [`open_loop_observed`] with a [`FaultPlan`] executing on the same
/// event queue (module docs): crash windows abort and requeue the
/// in-service batch, straggler/link windows scale service at dispatch.
/// Arrivals are scheduled before fault events, so a crash tied with an
/// arrival processes the arrival first.  With [`FaultPlan::none`] the
/// run is bit-identical to [`open_loop`].
pub fn open_loop_faulted(
    servers: usize,
    service: &ServiceModel,
    policy: BatchPolicy,
    arrivals: &[Arrival],
    faults: &FaultPlan,
    obs: &Obs,
) -> Result<TrafficReport> {
    if arrivals.is_empty() {
        return Err(Error::Sim("open-loop run needs at least one arrival".into()));
    }
    let mut eng = Engine::new(servers, *service, policy, obs)?;
    schedule_open_loop(&mut eng, arrivals)?;
    eng.install_faults(faults)?;
    eng.run_to_completion();
    eng.report()
}

/// Canonicalize and schedule an open-loop arrival stream (shared by the
/// static and controlled entry points, so they cannot drift).
fn schedule_open_loop(eng: &mut Engine<'_>, arrivals: &[Arrival]) -> Result<()> {
    for a in arrivals {
        if !(a.at.as_s() >= 0.0) || !a.at.is_finite() {
            return Err(Error::Sim("arrival times must be finite and >= 0".into()));
        }
    }
    let mut sorted: Vec<Arrival> = arrivals.to_vec();
    sorted.sort_by(|a, b| {
        a.at.partial_cmp(&b.at).expect("arrival times are finite").then(a.node.cmp(&b.node))
    });
    for (i, a) in sorted.iter().enumerate() {
        eng.arrival.push(a.at);
        eng.node.push(a.node);
        eng.start.push(Time::ZERO);
        eng.done.push(Time::ZERO);
        eng.client_of.push(usize::MAX);
        eng.queue.push(a.at, Ev::Arrive { req: i });
    }
    Ok(())
}

/// Run an open-loop arrival list under a closed-loop
/// [`Controller`](crate::controller::Controller) (module docs): the
/// engine is sized to the ladder's largest rung, requests route over
/// the *active* rung's queues, and every switch is billed as a paused
/// graceful drain.  Only [`FaultKind::LinkDegrade`] plans compose with
/// controlled runs — per-server crash/straggle targets are meaningless
/// across a shape change, so such plans are rejected rather than
/// silently misattributed.
pub fn open_loop_controlled(
    controller: &Controller,
    arrivals: &[Arrival],
    faults: &FaultPlan,
    obs: &Obs,
) -> Result<ControlledReport> {
    if arrivals.is_empty() {
        return Err(Error::Sim("controlled run needs at least one arrival".into()));
    }
    for e in faults.events() {
        if !matches!(e.kind, FaultKind::LinkDegrade { .. }) {
            return Err(Error::Sim(
                "controlled runs compose only with link-degrade faults: per-server \
                 crash/straggle targets do not survive a deployment switch"
                    .into(),
            ));
        }
    }
    let cfgs = controller.configs();
    let init = cfgs[controller.initial()];
    let max_servers =
        cfgs.iter().map(|c| c.queues.servers()).max().expect("ladder is non-empty");
    let mut eng = Engine::new(max_servers, init.service, init.policy, obs)?;
    eng.active = init.queues.servers();
    let window = controller.hysteresis().window;
    eng.ctrl = Some(CtrlState {
        controller,
        current: controller.initial(),
        resp_w: WindowedStats::new(window),
        depth_w: WindowedStats::new(window),
        util_w: WindowedStats::new(window),
        rate_w: WindowedStats::new(window),
        last_switch_resume: None,
        last_down_resume: None,
        switches: Vec::new(),
        switch_downtime: Time::ZERO,
        switch_affected: 0,
    });
    schedule_open_loop(&mut eng, arrivals)?;
    eng.install_faults(faults)?;
    eng.run_to_completion();
    let st = eng.ctrl.take().expect("controlled run keeps its ctrl state");
    let report = eng.report()?;
    Ok(ControlledReport {
        report,
        switches: st.switches,
        switch_downtime: st.switch_downtime,
        switch_affected: st.switch_affected,
        final_config: st.current,
    })
}

/// Closed-loop workload: a fixed fleet of clients, each cycling
/// think → request → response until `horizon` (no new requests issue
/// past it; in-flight ones drain).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClosedLoopConfig {
    pub fleet: usize,
    pub think: ThinkTime,
    pub horizon: Time,
    /// Nodes requests target (uniform per request).
    pub nodes: usize,
    pub seed: u64,
}

/// Run a closed loop of `cfg.fleet` clients against `servers` queues.
pub fn closed_loop(
    servers: usize,
    service: &ServiceModel,
    policy: BatchPolicy,
    cfg: &ClosedLoopConfig,
) -> Result<TrafficReport> {
    let obs = Obs::disabled();
    closed_loop_observed(servers, service, policy, cfg, &obs)
}

/// [`closed_loop`] with observability (see [`open_loop_observed`]).
pub fn closed_loop_observed(
    servers: usize,
    service: &ServiceModel,
    policy: BatchPolicy,
    cfg: &ClosedLoopConfig,
    obs: &Obs,
) -> Result<TrafficReport> {
    if cfg.fleet == 0 || cfg.nodes == 0 || !(cfg.horizon.as_s() > 0.0) {
        return Err(Error::Sim("closed loop needs fleet, nodes and a positive horizon".into()));
    }
    let mut eng = Engine::new(servers, *service, policy, obs)?;
    let mut rng = Rng::new(cfg.seed);
    for client in 0..cfg.fleet {
        let at = cfg.think.sample(&mut rng);
        if at < cfg.horizon {
            eng.queue.push(at, Ev::ClientArrive { client });
        }
    }
    eng.closed =
        Some(ClosedLoop { think: cfg.think, horizon: cfg.horizon, nodes: cfg.nodes, rng });
    eng.run_to_completion();
    eng.report()
}

/// One device capability class: a fraction `share` of the fleet whose
/// crossbar geometry / clock runs service at `speed ×` the baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceClass {
    pub name: &'static str,
    /// Service-rate multiplier (1.0 = baseline, 0.5 = half speed — the
    /// class's service *times* scale by `1 / speed`).
    pub speed: f64,
    /// Fraction of the fleet — and, by uniform routing, of the arrival
    /// stream — in this class.  Shares sum to 1.
    pub share: f64,
}

/// A fleet's capability mix.  The E13 representative-queue trick
/// generalizes exactly: uniform routing thins a Poisson stream into
/// independent per-class Poisson streams (`share × rate`), and each
/// class's queues split that uniformly again — so one representative
/// queue per class at `share × rate / servers_c` reproduces the
/// heterogeneous fleet's per-queue latency mixture.  A 1-class mix at
/// speed 1 is bit-identical to the homogeneous PR 5 path
/// (property-tested as the degenerate case).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetMix {
    classes: Vec<DeviceClass>,
}

impl FleetMix {
    pub fn new(classes: Vec<DeviceClass>) -> Result<FleetMix> {
        if classes.is_empty() {
            return Err(Error::Sim("fleet mix needs at least one class".into()));
        }
        let mut total = 0.0;
        for c in &classes {
            if !c.speed.is_finite() || c.speed <= 0.0 {
                return Err(Error::Sim(format!(
                    "class '{}' needs a positive, finite speed",
                    c.name
                )));
            }
            if !c.share.is_finite() || c.share <= 0.0 {
                return Err(Error::Sim(format!(
                    "class '{}' needs a positive, finite share",
                    c.name
                )));
            }
            total += c.share;
        }
        if (total - 1.0).abs() > 1e-9 {
            return Err(Error::Sim(format!("class shares must sum to 1, got {total}")));
        }
        Ok(FleetMix { classes })
    }

    /// The homogeneous fleet: one baseline class at share 1 — the PR 5
    /// degenerate case every mix result is validated against.
    pub fn homogeneous() -> FleetMix {
        FleetMix { classes: vec![DeviceClass { name: "uniform", speed: 1.0, share: 1.0 }] }
    }

    pub fn classes(&self) -> &[DeviceClass] {
        &self.classes
    }

    pub fn is_homogeneous(&self) -> bool {
        self.classes.len() == 1 && self.classes[0].speed == 1.0
    }

    /// Split `total` queues across classes by share (largest-remainder
    /// apportionment, remainder ties by class order), giving every
    /// class at least one queue.  Deterministic, exact: the counts sum
    /// to `total`.
    pub fn split_servers(&self, total: usize) -> Result<Vec<usize>> {
        let k = self.classes.len();
        if total < k {
            return Err(Error::Sim(format!("{total} queue(s) cannot host {k} classes")));
        }
        let mut counts: Vec<usize> = Vec::with_capacity(k);
        let mut rems: Vec<(f64, usize)> = Vec::with_capacity(k);
        let mut assigned = 0usize;
        for (i, c) in self.classes.iter().enumerate() {
            let exact = c.share * total as f64;
            let floor = exact.floor() as usize;
            counts.push(floor);
            assigned += floor;
            rems.push((exact - floor as f64, i));
        }
        rems.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).expect("shares are finite").then(a.1.cmp(&b.1))
        });
        // Σ remainders = total − Σ floors < k, so one pass suffices.
        let mut left = total - assigned;
        for &(_, i) in &rems {
            if left == 0 {
                break;
            }
            counts[i] += 1;
            left -= 1;
        }
        // A zero-queue class steals from the (first) largest; total ≥ k
        // guarantees a donor with ≥ 2 by pigeonhole.
        for i in 0..k {
            if counts[i] == 0 {
                let donor = (0..k)
                    .max_by(|&a, &b| counts[a].cmp(&counts[b]).then(b.cmp(&a)))
                    .expect("k > 0");
                counts[donor] -= 1;
                counts[i] += 1;
            }
        }
        Ok(counts)
    }
}

/// One class's representative-queue outcome inside a [`MixReport`].
#[derive(Debug, Clone)]
pub struct ClassOutcome {
    pub class: DeviceClass,
    /// Queues of the full shape assigned to this class.
    pub servers: usize,
    /// The exact Poisson split each of this class's queues sees.
    pub queue_rate_per_s: f64,
    pub report: TrafficReport,
}

/// Per-class representative-queue reports plus share-weighted merges
/// over the heterogeneous fleet.
#[derive(Debug, Clone)]
pub struct MixReport {
    pub classes: Vec<ClassOutcome>,
}

impl MixReport {
    /// Share-weighted nearest-rank quantile of the merged response
    /// distribution.  One class delegates to its own
    /// [`LatencyStats::quantile`] — bit-identical to the homogeneous
    /// path, including its exact `ceil(n·q)` float boundary.  For k > 1
    /// each class sample weighs `share / n_c` and the first sorted
    /// sample whose cumulative weight reaches `q` answers: the mixture
    /// distribution's nearest rank.
    pub fn latency_quantile(&self, q: f64) -> Time {
        if self.classes.len() == 1 {
            return self.classes[0].report.latency.quantile(q);
        }
        let q = q.clamp(0.0, 1.0);
        let mut pts: Vec<(Time, f64)> = Vec::new();
        let mut total = 0.0;
        for c in &self.classes {
            let w = c.class.share / c.report.latency.count() as f64;
            for &v in c.report.latency.samples() {
                pts.push((v, w));
            }
            total += c.class.share;
        }
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("latencies are finite"));
        let target = q * total;
        let mut cum = 0.0;
        for &(v, w) in &pts {
            cum += w;
            if cum >= target {
                return v;
            }
        }
        pts.last().expect("class reports are non-empty").0
    }

    pub fn p50(&self) -> Time {
        self.latency_quantile(0.50)
    }

    pub fn p95(&self) -> Time {
        self.latency_quantile(0.95)
    }

    pub fn p99(&self) -> Time {
        self.latency_quantile(0.99)
    }

    /// Share-weighted SLO attainment across classes.
    pub fn slo_attainment(&self, slo: Time) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for c in &self.classes {
            num += c.class.share * c.report.slo_attainment(slo);
            den += c.class.share;
        }
        num / den.max(1e-30)
    }

    /// Share-weighted availability of the representative queues.
    pub fn availability(&self) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for c in &self.classes {
            num += c.class.share * c.report.availability;
            den += c.class.share;
        }
        num / den.max(1e-30)
    }

    /// Total downtime across the simulated representative queues.
    pub fn downtime(&self) -> Time {
        self.classes.iter().map(|c| c.report.downtime).sum()
    }

    /// Crash windows executed across the simulated queues.
    pub fn fault_windows(&self) -> usize {
        self.classes.iter().map(|c| c.report.fault_windows).sum()
    }

    /// Downtime / windows over all simulated queues (`ZERO` when no
    /// window executed).
    pub fn mttr(&self) -> Time {
        let w = self.fault_windows();
        if w == 0 {
            Time::ZERO
        } else {
            self.downtime() * (1.0 / w as f64)
        }
    }

    /// Requests simulated across all classes.
    pub fn offered(&self) -> usize {
        self.classes.iter().map(|c| c.report.offered).sum()
    }

    /// Worst Little's-law residual across the class runs.
    pub fn max_littles_gap(&self) -> f64 {
        self.classes.iter().map(|c| c.report.littles_law_gap()).fold(0.0, f64::max)
    }

    /// Spans the shared ring buffer evicted by the end of the run.  The
    /// class runs share one tracer and `dropped` is cumulative, so the
    /// max (= the last class's reading) is the run's total.
    pub fn dropped_spans(&self) -> u64 {
        self.classes.iter().map(|c| c.report.dropped_spans).max().unwrap_or(0)
    }
}

/// Drive one representative queue per capability class (docs on
/// [`FleetMix`]).  Class `c` gets `split_servers` queues, each seeing
/// the exact Poisson split `share_c × rate / servers_c`; serves at
/// `1 / speed_c ×` the base service times; simulates `share_c ×
/// requests` arrivals over its own horizon; and executes a per-class
/// seeded [`FaultPlan`] generated from `faults` for its single
/// representative queue.  With [`FleetMix::homogeneous`] and
/// [`FaultConfig::none`] the single class's report is bit-identical to
/// the PR 5 representative-queue path at `seed`.
#[allow(clippy::too_many_arguments)]
pub fn open_loop_mix(
    mix: &FleetMix,
    queues: DeploymentQueues,
    service: &ServiceModel,
    policy: BatchPolicy,
    system_rate_per_s: f64,
    requests: usize,
    nodes: usize,
    seed: u64,
    faults: &FaultConfig,
    obs: &Obs,
) -> Result<MixReport> {
    if !system_rate_per_s.is_finite() || system_rate_per_s <= 0.0 {
        return Err(Error::Sim("mix run needs a positive, finite system rate".into()));
    }
    if requests == 0 || nodes == 0 {
        return Err(Error::Sim("mix run needs requests and nodes".into()));
    }
    let splits = mix.split_servers(queues.servers())?;
    let mut out = Vec::with_capacity(mix.classes().len());
    for (c, class) in mix.classes().iter().enumerate() {
        let servers_c = splits[c];
        // share × rate is exact at share = 1.0 (IEEE ×1.0 identity), so
        // the homogeneous split reproduces per_queue_rate bitwise.
        let queue_rate = class.share * system_rate_per_s / servers_c as f64;
        let n_c = ((requests as f64) * class.share).round().max(1.0) as usize;
        let horizon = Time::s(n_c as f64 / queue_rate);
        let class_seed = seed.wrapping_add(c as u64);
        let arrivals =
            ArrivalProcess::Poisson { rate: queue_rate }.generate(horizon, nodes, class_seed)?;
        let service_c = if class.speed == 1.0 {
            *service
        } else {
            ServiceModel {
                per_batch: service.per_batch * (1.0 / class.speed),
                per_request: service.per_request * (1.0 / class.speed),
            }
        };
        // Distinct fault stream per class (offset keeps it disjoint
        // from the arrival stream's seed).
        let plan = FaultPlan::generate(faults, 1, horizon, class_seed ^ 0xFA17_5EED_0000_0001)?;
        let report = open_loop_faulted(1, &service_c, policy, &arrivals, &plan, obs)?;
        out.push(ClassOutcome {
            class: *class,
            servers: servers_c,
            queue_rate_per_s: queue_rate,
            report,
        });
    }
    Ok(MixReport { classes: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, forall, gcn_layer_binding, Rng};

    fn svc(batch_ms: f64, req_ms: f64) -> ServiceModel {
        ServiceModel::new(Time::ms(batch_ms), Time::ms(req_ms)).unwrap()
    }

    fn at(ms: f64, node: usize) -> Arrival {
        Arrival { at: Time::ms(ms), node }
    }

    #[test]
    fn immediate_policy_is_a_fifo_station() {
        // Three arrivals at t=0 into one queue, service 2 ms each:
        // responses 2/4/6 ms — the M/D/1 backlog by hand.
        let r = open_loop(
            1,
            &svc(2.0, 0.0),
            BatchPolicy::Immediate,
            &[at(0.0, 0), at(0.0, 1), at(0.0, 2)],
        )
        .unwrap();
        assert_eq!(r.offered, 3);
        assert_eq!(r.batches, 3);
        assert_close(r.latency.max().as_ms(), 6.0, 1e-12);
        assert_close(r.latency.p50().as_ms(), 4.0, 1e-12);
        assert_close(r.mean_wait.as_ms(), 2.0, 1e-12);
        assert_close(r.makespan.as_ms(), 6.0, 1e-12);
        assert_close(r.utilization, 1.0, 1e-12);
        assert!(r.littles_law_gap() < 1e-12, "gap {}", r.littles_law_gap());
    }

    #[test]
    fn size_policy_dispatches_full_batches_and_flushes_the_tail() {
        // 5 arrivals, size-4 batches: one full batch at t=0, the tail
        // flushes at drain time.
        let arrivals: Vec<Arrival> = (0..5).map(|i| at(0.0, i)).collect();
        let r = open_loop(1, &svc(1.0, 0.5), BatchPolicy::Size { max: 4 }, &arrivals).unwrap();
        assert_eq!(r.batches, 2);
        assert_eq!(r.batch_log[0].nodes, vec![0, 1, 2, 3]);
        assert_eq!(r.batch_log[1].nodes, vec![4]);
        // Full batch: 1 + 4·0.5 = 3 ms; the tail flushes at 3 ms and
        // serves 1 + 0.5 = 1.5 ms → makespan 4.5 ms.
        assert_close(r.batch_log[0].done_at.as_ms(), 3.0, 1e-12);
        assert_close(r.batch_log[1].dispatched_at.as_ms(), 3.0, 1e-12);
        assert_close(r.makespan.as_ms(), 4.5, 1e-12);
        assert_close(r.mean_batch, 2.5, 1e-12);
        assert!(r.littles_law_gap() < 1e-12);
    }

    #[test]
    fn deadline_policy_bounds_the_idle_wait() {
        let r = open_loop(
            1,
            &svc(1.0, 0.0),
            BatchPolicy::Deadline { max: 64, max_wait: Time::ms(5.0) },
            &[at(0.0, 0), at(4.0, 1), at(100.0, 2)],
        )
        .unwrap();
        // First two share the batch dispatched at the first arrival's
        // 5 ms deadline; the third waits its own deadline at 105 ms.
        assert_eq!(r.batches, 2);
        assert_eq!(r.batch_log[0].nodes, vec![0, 1]);
        assert_close(r.batch_log[0].dispatched_at.as_ms(), 5.0, 1e-12);
        assert_close(r.batch_log[0].done_at.as_ms(), 6.0, 1e-12);
        assert_close(r.batch_log[1].dispatched_at.as_ms(), 105.0, 1e-12);
        assert!(r.littles_law_gap() < 1e-12);
    }

    #[test]
    fn deadline_dispatch_is_work_conserving_under_backlog() {
        // Backlog present when the server frees → a full batch
        // dispatches immediately, no idle deadline wait: capacity stays
        // at the full-batch rate (the batching-collapse guard).
        let arrivals: Vec<Arrival> = (0..12).map(|i| at(0.0, i)).collect();
        let r = open_loop(
            1,
            &svc(1.0, 0.0),
            BatchPolicy::Deadline { max: 4, max_wait: Time::ms(50.0) },
            &arrivals,
        )
        .unwrap();
        // Three full batches back to back: 1 ms each, no deadline waits.
        assert_eq!(r.batches, 3);
        assert!(r.batch_log.iter().all(|b| b.nodes.len() == 4));
        assert_close(r.makespan.as_ms(), 3.0, 1e-12);
        assert_close(r.utilization, 1.0, 1e-12);
        assert!(r.littles_law_gap() < 1e-12);
    }

    #[test]
    fn requests_route_to_per_shape_queues() {
        // 4 servers: node % 4 picks the queue; two tied arrivals on the
        // same queue serialize, others run in parallel.
        let r = open_loop(
            4,
            &svc(2.0, 0.0),
            BatchPolicy::Immediate,
            &[at(0.0, 0), at(0.0, 4), at(0.0, 1), at(0.0, 2)],
        )
        .unwrap();
        assert_eq!(r.servers, 4);
        assert_close(r.makespan.as_ms(), 4.0, 1e-12);
        // Queue 0 busy 4 ms of 4; queues 1/2 busy 2 ms; queue 3 idle.
        assert_close(r.utilization, (4.0 + 2.0 + 2.0 + 0.0) / (4.0 * 4.0), 1e-12);
        // Immediate dispatch drains the queue as it fills: at most one
        // request ever waits behind the in-service one here.
        assert_eq!(r.max_queue_depth, 1);
        assert!(r.littles_law_gap() < 1e-12);
    }

    /// The determinism audit (the FIFO-tie pattern from `sim::event`):
    /// batch composition must not depend on the order tied arrivals were
    /// pushed in — only on the (time, node) content of the stream.
    #[test]
    fn property_batch_composition_is_independent_of_tie_order() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(60) + 2;
            // Coarse time grid guarantees heavy timestamp ties.
            let arrivals: Vec<Arrival> = (0..n)
                .map(|_| Arrival {
                    at: Time::ms(rng.index(6) as f64),
                    node: rng.index(12),
                })
                .collect();
            let mut shuffled = arrivals.clone();
            let perm = rng.permutation(n);
            for (i, &j) in perm.iter().enumerate() {
                shuffled[i] = arrivals[j];
            }
            let policy = match rng.index(3) {
                0 => BatchPolicy::Immediate,
                1 => BatchPolicy::Size { max: rng.index(4) + 1 },
                _ => BatchPolicy::Deadline {
                    max: rng.index(4) + 1,
                    // Deadline on the same grid as the arrivals, so
                    // deadline-vs-arrival ties genuinely occur.
                    max_wait: Time::ms(rng.index(3) as f64),
                },
            };
            let servers = rng.index(3) + 1;
            let service = svc(1.0, 0.25);
            let a = open_loop(servers, &service, policy, &arrivals).unwrap();
            let b = open_loop(servers, &service, policy, &shuffled).unwrap();
            assert_eq!(a.batch_log, b.batch_log, "policy {policy:?}");
            assert_eq!(a.latency.count(), b.latency.count());
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.mean_wait, b.mean_wait);
        });
    }

    #[test]
    fn tied_arrivals_join_before_a_same_instant_deadline_fires() {
        // Deadline at t=2 ms ties with an arrival at t=2 ms: the arrival
        // joins the pending queue first (open-loop arrivals are
        // pre-scheduled, so they pop before later-pushed deadline events
        // — the EventQueue FIFO tie-break), then the deadline dispatches
        // both together.
        let r = open_loop(
            1,
            &svc(1.0, 0.0),
            BatchPolicy::Deadline { max: 8, max_wait: Time::ms(2.0) },
            &[at(0.0, 0), at(2.0, 1)],
        )
        .unwrap();
        assert_eq!(r.batches, 1);
        assert_eq!(r.batch_log[0].nodes, vec![0, 1]);
        assert_close(r.batch_log[0].dispatched_at.as_ms(), 2.0, 1e-12);
    }

    #[test]
    fn closed_loop_fixed_think_is_a_clockwork_cycle() {
        // One client, fixed 10 ms think, 3 ms service, horizon 50 ms:
        // requests at 10/23/36/49 ms — the 49 ms one still issues
        // (< horizon) and drains past it.
        let r = closed_loop(
            1,
            &svc(3.0, 0.0),
            BatchPolicy::Immediate,
            &ClosedLoopConfig {
                fleet: 1,
                think: ThinkTime::Fixed(Time::ms(10.0)),
                horizon: Time::ms(50.0),
                nodes: 4,
                seed: 9,
            },
        )
        .unwrap();
        assert_eq!(r.offered, 4);
        assert_close(r.makespan.as_ms(), 52.0, 1e-9);
        assert_close(r.latency.max().as_ms(), 3.0, 1e-12);
        assert_close(r.mean_wait.as_ms(), 0.0, 1e-12);
        assert!(r.littles_law_gap() < 1e-12);
    }

    #[test]
    fn closed_loop_is_deterministic_per_seed() {
        let run = |seed| {
            closed_loop(
                2,
                &svc(1.0, 0.2),
                BatchPolicy::Deadline { max: 4, max_wait: Time::ms(2.0) },
                &ClosedLoopConfig {
                    fleet: 6,
                    think: ThinkTime::Exponential { mean: Time::ms(8.0) },
                    horizon: Time::s(1.0),
                    nodes: 16,
                    seed,
                },
            )
            .unwrap()
        };
        let a = run(5);
        let b = run(5);
        assert_eq!(a.batch_log, b.batch_log);
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.makespan, b.makespan);
        let c = run(6);
        assert_ne!(a.batch_log, c.batch_log, "seed must matter");
        assert!(a.littles_law_gap() < 1e-9, "gap {}", a.littles_law_gap());
    }

    #[test]
    fn utilization_equals_throughput_times_service_for_unit_batches() {
        // With the immediate policy every batch is one request, so
        // busy = completed·s exactly: util == tput·s to round-off — the
        // ρ→0 operational identity the open/closed equivalence test
        // builds on.
        let arrivals = ArrivalProcess::Poisson { rate: 50.0 }
            .generate(Time::s(4.0), 8, 3)
            .unwrap();
        let service = svc(2.0, 0.0);
        let r = open_loop(1, &service, BatchPolicy::Immediate, &arrivals).unwrap();
        assert_close(
            r.utilization,
            r.throughput_per_s * service.service(1).as_s(),
            1e-9,
        );
        assert!(r.littles_law_gap() < 1e-9);
    }

    #[test]
    fn slo_attainment_counts_the_distribution_tail() {
        let r = open_loop(
            1,
            &svc(2.0, 0.0),
            BatchPolicy::Immediate,
            &[at(0.0, 0), at(0.0, 1), at(0.0, 2), at(0.0, 3)],
        )
        .unwrap();
        // Responses 2/4/6/8 ms.
        assert_close(r.slo_attainment(Time::ms(5.0)), 0.5, 1e-12);
        assert_close(r.slo_attainment(Time::ms(1.0)), 0.0, 1e-12);
        assert_close(r.slo_attainment(Time::ms(100.0)), 1.0, 1e-12);
    }

    #[test]
    fn batches_feed_round_engine_assemble() {
        // The engine's dispatched batches are RoundEngine input: every
        // batch node list assembles into padded shard batches without
        // PJRT.
        use crate::coordinator::RoundEngine;
        use crate::graph::{generate, ShardPlan};
        let b = gcn_layer_binding();
        let g = generate::regular(48, 6, 3).unwrap();
        let plan = ShardPlan::build(&g, &b.sampler(), b.table).unwrap();
        let batch = b.batch;
        let mut engine =
            RoundEngine::new(b.clone(), plan, vec![0.01; b.feature * b.hidden]).unwrap();
        for node in 0..48 {
            engine.upload(node, &vec![0.5; 64]).unwrap();
        }
        engine.end_round();

        let arrivals = ArrivalProcess::Poisson { rate: 2_000.0 }
            .generate(Time::s(0.1), 48, 11)
            .unwrap();
        let r = open_loop(
            1,
            &svc(1.0, 0.01),
            BatchPolicy::Deadline { max: batch, max_wait: Time::ms(3.0) },
            &arrivals,
        )
        .unwrap();
        assert!(r.batches > 1);
        for record in &r.batch_log {
            assert!(record.nodes.len() <= batch, "policy respects the artifact batch");
            let shard_batches = engine.assemble(&record.nodes).unwrap();
            let served: usize = shard_batches.iter().map(|sb| sb.nodes.len()).sum();
            assert_eq!(served, record.nodes.len(), "assemble answers every batched node");
        }
    }

    #[test]
    fn service_model_constructors_match_the_closed_forms() {
        use crate::cores::GnnWorkload;
        use crate::netmodel::Setting;
        let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
        let topo = Topology::taxi();
        let b = model.breakdown();
        let (m1, m2, m3) = model.capacity_ratios();

        let cent =
            ServiceModel::centralized(LatencyProvider::Analytic, &model, topo).unwrap();
        assert_eq!(cent.per_batch, model.communicate_latency(Setting::Centralized, topo));
        let want_slot = b.t1 * (1.0 / m1) + b.t2 * (1.0 / m2) + b.t3 * (1.0 / m3);
        assert_close(cent.per_request.as_s(), want_slot.as_s(), 1e-12);
        // N-1 slots reconstruct the Eq. 3 pipeline exactly.
        assert_close(
            (cent.per_request * 9_999.0).as_s(),
            model.compute_latency(Setting::Centralized, topo).as_s(),
            1e-9,
        );

        let semi = ServiceModel::semi(LatencyProvider::Analytic, &model, topo, 10.0).unwrap();
        assert_eq!(semi.per_batch, model.semi_latency(topo, 10.0).communicate);
        assert_close(semi.per_request.as_s(), (b.total_latency() * 0.1).as_s(), 1e-12);

        let dec =
            ServiceModel::decentralized(LatencyProvider::Analytic, &model, topo).unwrap();
        assert_eq!(dec.per_batch, model.communicate_latency(Setting::Decentralized, topo));
        assert_eq!(dec.per_request, b.total_latency());

        // Clustered at f = 1 coincides with Analytic; f < 1 only raises
        // the batch term (the boundary relay), never the compute slot.
        let f1 = LatencyProvider::Clustered { intra_fraction: 1.0 };
        assert_eq!(ServiceModel::semi(f1, &model, topo, 10.0).unwrap(), semi);
        assert_eq!(ServiceModel::decentralized(f1, &model, topo).unwrap(), dec);
        let f0 = LatencyProvider::Clustered { intra_fraction: 0.25 };
        let semi_f0 = ServiceModel::semi(f0, &model, topo, 10.0).unwrap();
        assert!(semi_f0.per_batch > semi.per_batch);
        assert_eq!(semi_f0.per_request, semi.per_request);

        // Netsim pins the batch barrier verbatim — congestion composes.
        let pin = LatencyProvider::Netsim(Time::ms(7.0));
        assert_eq!(
            ServiceModel::centralized(pin, &model, topo).unwrap().per_batch,
            Time::ms(7.0)
        );

        // Saturation rate: more batching always helps when per_batch
        // dominates.
        assert!(cent.saturation_rate(64) > cent.saturation_rate(1));
        assert_close(
            cent.saturation_rate(64),
            64.0 / cent.service(64).as_s(),
            1e-12,
        );
    }

    #[test]
    fn md1_closed_form_and_degenerate_inputs() {
        // ρ = 0.5, s = 2 ms → W_q = 0.5·2/(2·0.5) = 1 ms.
        let w = md1_mean_wait(250.0, Time::ms(2.0)).unwrap();
        assert_close(w.as_ms(), 1.0, 1e-12);
        assert_eq!(md1_mean_wait(0.0, Time::ms(2.0)).unwrap(), Time::ZERO);
        assert!(md1_mean_wait(500.0, Time::ms(2.0)).is_err(), "rho = 1 diverges");
        assert!(md1_mean_wait(-1.0, Time::ms(2.0)).is_err());
    }

    #[test]
    fn deployment_queues_split_rates_exactly() {
        assert_eq!(DeploymentQueues::Leader.servers(), 1);
        assert_eq!(DeploymentQueues::ClusterHeads { clusters: 40 }.servers(), 40);
        assert_eq!(DeploymentQueues::Devices { nodes: 10_000 }.servers(), 10_000);
        assert_close(
            DeploymentQueues::ClusterHeads { clusters: 40 }.per_queue_rate(4_000.0),
            100.0,
            1e-12,
        );
    }

    #[test]
    fn rejects_degenerate_runs() {
        let s = svc(1.0, 0.0);
        assert!(open_loop(0, &s, BatchPolicy::Immediate, &[at(0.0, 0)]).is_err());
        assert!(open_loop(1, &s, BatchPolicy::Immediate, &[]).is_err());
        assert!(open_loop(1, &s, BatchPolicy::Size { max: 0 }, &[at(0.0, 0)]).is_err());
        assert!(open_loop(
            1,
            &s,
            BatchPolicy::Deadline { max: 4, max_wait: Time::s(f64::NAN) },
            &[at(0.0, 0)]
        )
        .is_err());
        assert!(ServiceModel::new(Time::ZERO, Time::ZERO).is_err());
        assert!(ServiceModel::new(Time::ms(-1.0), Time::ms(2.0)).is_err());
        assert!(closed_loop(
            1,
            &s,
            BatchPolicy::Immediate,
            &ClosedLoopConfig {
                fleet: 0,
                think: ThinkTime::Fixed(Time::ms(1.0)),
                horizon: Time::s(1.0),
                nodes: 4,
                seed: 1,
            },
        )
        .is_err());
    }

    use crate::sim::faults::{CrashImpact, FaultEvent, Outage};

    fn crash_window(ms_from: f64, ms_until: f64, server: usize) -> FaultEvent {
        FaultEvent {
            at: Time::ms(ms_from),
            until: Time::ms(ms_until),
            kind: FaultKind::Crash { server },
        }
    }

    #[test]
    fn crash_aborts_the_batch_requeues_and_counts_downtime() {
        // One server, 2 ms service, requests at t=0 for nodes 0/1; a
        // crash window [1, 5) ms aborts the in-service request after
        // 1 ms of work.  By hand: r0 redispatches at recovery (done
        // 7 ms), r1 follows (done 9 ms); busy = 1 + 2 + 2 = 5 ms,
        // downtime 4 ms.
        let plan = FaultPlan::from_events(vec![crash_window(1.0, 5.0, 0)], 1).unwrap();
        let r = open_loop_faulted(
            1,
            &svc(2.0, 0.0),
            BatchPolicy::Immediate,
            &[at(0.0, 0), at(0.0, 1)],
            &plan,
            &Obs::disabled(),
        )
        .unwrap();
        assert_eq!(r.completed, 2, "aborted requests still complete");
        assert_eq!(r.batches, 2, "the aborted dispatch never logs a batch");
        assert_close(r.batch_log[0].dispatched_at.as_ms(), 5.0, 1e-12);
        assert_close(r.batch_log[0].done_at.as_ms(), 7.0, 1e-12);
        assert_close(r.batch_log[1].done_at.as_ms(), 9.0, 1e-12);
        assert_close(r.makespan.as_ms(), 9.0, 1e-12);
        assert_close(r.downtime.as_ms(), 4.0, 1e-12);
        assert_eq!(r.fault_windows, 1);
        assert_close(r.mttr.as_ms(), 4.0, 1e-12);
        assert_close(r.availability, 1.0 - 4.0 / 9.0, 1e-12);
        assert_close(r.utilization, 5.0 / 9.0, 1e-12);
        // Crashes keep every request in the system until its real
        // completion, so Little's law holds exactly.
        assert!(r.littles_law_gap() < 1e-12, "gap {}", r.littles_law_gap());
        assert_eq!(r.downtime, plan.total_outage(), "every window executed");
    }

    #[test]
    fn zero_fault_plan_is_bit_identical_to_the_no_fault_path() {
        let arrivals = ArrivalProcess::Poisson { rate: 300.0 }
            .generate(Time::s(0.5), 8, 7)
            .unwrap();
        let service = svc(1.0, 0.2);
        let policy = BatchPolicy::Deadline { max: 8, max_wait: Time::ms(2.0) };
        let a = open_loop(2, &service, policy, &arrivals).unwrap();
        let b = open_loop_faulted(
            2,
            &service,
            policy,
            &arrivals,
            &FaultPlan::none(),
            &Obs::disabled(),
        )
        .unwrap();
        assert_eq!(a.batch_log, b.batch_log);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.mean_wait, b.mean_wait);
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        assert_eq!(a.latency.p95(), b.latency.p95());
        assert_eq!(b.downtime, Time::ZERO);
        assert_eq!(b.availability, 1.0);
        assert_eq!(b.fault_windows, 0);
        assert_eq!(b.mttr, Time::ZERO);
        assert_eq!(b.dropped_spans, 0);
    }

    #[test]
    fn straggler_and_link_windows_scale_service_at_dispatch() {
        let arrivals = [at(0.0, 0), at(10.0, 1)];
        let service = svc(1.0, 0.0);
        // Straggler window [9, 20) at 3×: the t=10 dispatch serves 3 ms.
        let slow = FaultPlan::from_events(
            vec![FaultEvent {
                at: Time::ms(9.0),
                until: Time::ms(20.0),
                kind: FaultKind::Straggle { server: 0, factor: 3.0 },
            }],
            1,
        )
        .unwrap();
        let r = open_loop_faulted(
            1,
            &service,
            BatchPolicy::Immediate,
            &arrivals,
            &slow,
            &Obs::disabled(),
        )
        .unwrap();
        assert_close(r.batch_log[0].done_at.as_ms(), 1.0, 1e-12);
        assert_close(r.batch_log[1].done_at.as_ms(), 13.0, 1e-12);
        assert_eq!(r.downtime, Time::ZERO, "degraded windows are not outages");
        assert_eq!(r.fault_windows, 0);
        // Link window [0, 2) at 2×: only the t=0 dispatch pays it.
        let link = FaultPlan::from_events(
            vec![FaultEvent {
                at: Time::ZERO,
                until: Time::ms(2.0),
                kind: FaultKind::LinkDegrade { factor: 2.0 },
            }],
            1,
        )
        .unwrap();
        let r = open_loop_faulted(
            1,
            &service,
            BatchPolicy::Immediate,
            &arrivals,
            &link,
            &Obs::disabled(),
        )
        .unwrap();
        assert_close(r.batch_log[0].done_at.as_ms(), 2.0, 1e-12);
        assert_close(r.batch_log[1].done_at.as_ms(), 11.0, 1e-12);
        assert!(r.littles_law_gap() < 1e-12);
    }

    #[test]
    fn fault_crash_spans_reconcile_with_reported_downtime() {
        let plan = FaultPlan::from_events(
            vec![crash_window(5.0, 9.0, 0), crash_window(20.0, 26.0, 0)],
            1,
        )
        .unwrap();
        let arrivals: Vec<Arrival> = (0..30).map(|i| at(i as f64 * 2.0, i)).collect();
        let obs = Obs::new(4096);
        let r = open_loop_faulted(
            1,
            &svc(1.0, 0.0),
            BatchPolicy::Immediate,
            &arrivals,
            &plan,
            &obs,
        )
        .unwrap();
        assert_eq!(r.fault_windows, 2);
        assert_eq!(r.dropped_spans, 0, "ring kept every span");
        // Σ fault.crash span durations == reported downtime, exactly:
        // both sum the same (recover − crash) values in event order.
        let span_sum: Time = obs
            .tracer
            .spans()
            .iter()
            .filter(|s| s.name == "fault.crash")
            .map(|s| s.end - s.start)
            .sum();
        assert_eq!(span_sum, r.downtime);
        assert_eq!(r.downtime, plan.total_outage());
        assert_eq!(obs.metrics.counter_value("fault.crashes"), 2);
    }

    #[test]
    fn degraded_windows_beat_outages_at_the_same_failure_times() {
        // r ≥ 2 halo replication turns a crash into degraded service at
        // the boundary-relay factor.  Same windows, same arrivals: the
        // degraded run must dominate on tail latency and availability.
        let plan = FaultPlan::from_events(
            vec![crash_window(200.0, 450.0, 0), crash_window(600.0, 800.0, 0)],
            1,
        )
        .unwrap();
        let degraded = plan.degraded(2.0).unwrap();
        let arrivals = ArrivalProcess::Poisson { rate: 100.0 }
            .generate(Time::s(1.0), 16, 21)
            .unwrap();
        let service = svc(2.0, 0.0);
        let run = |p: &FaultPlan| {
            open_loop_faulted(
                1,
                &service,
                BatchPolicy::Immediate,
                &arrivals,
                p,
                &Obs::disabled(),
            )
            .unwrap()
        };
        let out = run(&plan);
        let deg = run(&degraded);
        assert!(out.downtime > Time::ZERO);
        assert_eq!(deg.downtime, Time::ZERO);
        assert_eq!(deg.availability, 1.0);
        assert!(
            deg.latency.p95() < out.latency.p95(),
            "degraded p95 {} vs outage p95 {}",
            deg.latency.p95().as_ms(),
            out.latency.p95().as_ms()
        );
        assert!(out.littles_law_gap() < 1e-9 && deg.littles_law_gap() < 1e-9);
    }

    #[test]
    fn fleet_mix_validates_and_splits_servers_exactly() {
        let mk = |specs: &[(f64, f64)]| {
            FleetMix::new(
                specs
                    .iter()
                    .map(|&(speed, share)| DeviceClass { name: "c", speed, share })
                    .collect(),
            )
        };
        assert!(FleetMix::new(Vec::new()).is_err());
        assert!(mk(&[(1.0, 0.5)]).is_err(), "shares must sum to 1");
        assert!(mk(&[(0.0, 1.0)]).is_err(), "speed must be positive");
        assert!(mk(&[(1.0, 0.5), (0.5, 0.5000001)]).is_err());
        assert!(FleetMix::homogeneous().is_homogeneous());

        let mix = mk(&[(1.0, 0.75), (0.5, 0.25)]).unwrap();
        assert_eq!(mix.split_servers(8).unwrap(), vec![6, 2]);
        // 5 queues: exact 3.75 / 1.25 → floors 3/1, the larger
        // remainder (0.75) takes the leftover.
        assert_eq!(mix.split_servers(5).unwrap(), vec![4, 1]);
        assert!(mix.split_servers(1).is_err(), "fewer queues than classes");
        // A tiny class still gets a queue (stolen from the largest).
        let skew = mk(&[(1.0, 0.95), (0.5, 0.05)]).unwrap();
        assert_eq!(skew.split_servers(2).unwrap(), vec![1, 1]);
        let total: usize = mix.split_servers(41).unwrap().iter().sum();
        assert_eq!(total, 41, "apportionment is exact");
    }

    /// S4: the 1-class mix is the PR 5 representative-queue path,
    /// bitwise — same split rate, same arrivals, same report.
    #[test]
    fn single_class_mix_reproduces_the_representative_queue_bitwise() {
        let queues = DeploymentQueues::ClusterHeads { clusters: 5 };
        let service = svc(1.0, 0.1);
        let policy = BatchPolicy::Deadline { max: 16, max_wait: Time::ms(2.0) };
        let (rate, requests, nodes, seed) = (400.0, 200, 16, 42u64);

        let queue_rate = queues.per_queue_rate(rate);
        let horizon = Time::s(requests as f64 / queue_rate);
        let arrivals = ArrivalProcess::Poisson { rate: queue_rate }
            .generate(horizon, nodes, seed)
            .unwrap();
        let base = open_loop(1, &service, policy, &arrivals).unwrap();

        let mix = open_loop_mix(
            &FleetMix::homogeneous(),
            queues,
            &service,
            policy,
            rate,
            requests,
            nodes,
            seed,
            &FaultConfig::none(),
            &Obs::disabled(),
        )
        .unwrap();
        assert_eq!(mix.classes.len(), 1);
        let c = &mix.classes[0];
        assert_eq!(c.servers, 5);
        assert_eq!(c.queue_rate_per_s.to_bits(), queue_rate.to_bits());
        assert_eq!(c.report.batch_log, base.batch_log);
        assert_eq!(c.report.makespan, base.makespan);
        assert_eq!(c.report.mean_wait, base.mean_wait);
        assert_eq!(c.report.utilization.to_bits(), base.utilization.to_bits());
        assert_eq!(c.report.latency.p95(), base.latency.p95());
        // The merged quantile delegates at k = 1 — including the exact
        // ceil(n·q) float boundary of LatencyStats.
        assert_eq!(mix.p95(), base.latency.p95());
        assert_eq!(mix.p99(), base.latency.p99());
        assert_eq!(mix.offered(), base.offered);
        assert_eq!(mix.max_littles_gap(), base.littles_law_gap());
    }

    /// S4: Little's law holds per class to round-off even with crash
    /// churn and a heterogeneous mix.
    #[test]
    fn mix_littles_law_gap_stays_tiny_under_churn() {
        let mix = FleetMix::new(vec![
            DeviceClass { name: "fast", speed: 1.0, share: 0.75 },
            DeviceClass { name: "slow", speed: 0.5, share: 0.25 },
        ])
        .unwrap();
        let faults = FaultConfig {
            straggle_rate_per_s: 2.0,
            mean_straggle: Time::ms(50.0),
            straggle_factor: 2.0,
            ..FaultConfig::crashes(
                5.0,
                Outage::Fixed(Time::ms(40.0)),
                CrashImpact::Outage,
            )
        };
        let m = open_loop_mix(
            &mix,
            DeploymentQueues::Devices { nodes: 8 },
            &svc(1.0, 0.2),
            BatchPolicy::Deadline { max: 8, max_wait: Time::ms(2.0) },
            200.0,
            160,
            8,
            11,
            &faults,
            &Obs::disabled(),
        )
        .unwrap();
        assert_eq!(m.classes.len(), 2);
        assert!(m.fault_windows() > 0, "churn must actually happen");
        assert!(m.downtime() > Time::ZERO);
        assert!(m.availability() < 1.0);
        assert!(m.mttr() > Time::ZERO);
        assert!(
            m.max_littles_gap() < 1e-9,
            "worst gap {} under churn",
            m.max_littles_gap()
        );
        // The slow class's representative queue is strictly slower.
        assert!(m.classes[1].report.latency.p50() > m.classes[0].report.latency.p50());
        // Merged quantiles are monotone and bracketed by the classes.
        assert!(m.p50() <= m.p95() && m.p95() <= m.p99());
        assert!(m.slo_attainment(Time::s(1e6)) > 0.999);
    }

    use crate::controller::{CtrlConfig, Hysteresis};

    fn ladder_2() -> Vec<CtrlConfig> {
        use crate::autotune::{OperatingPoint, Partitioner};
        vec![
            CtrlConfig {
                point: OperatingPoint::centralized(),
                queues: DeploymentQueues::Leader,
                service: svc(10.0, 0.01),
                policy: BatchPolicy::Deadline { max: 16, max_wait: Time::ms(2.5) },
                switch_cost: Time::ms(5.0),
            },
            CtrlConfig {
                point: OperatingPoint::semi(10, 2.0, Partitioner::FixedSize),
                queues: DeploymentQueues::ClusterHeads { clusters: 8 },
                service: svc(30.0, 0.01),
                policy: BatchPolicy::Deadline { max: 16, max_wait: Time::ms(7.5) },
                switch_cost: Time::ms(20.0),
            },
        ]
    }

    #[test]
    fn controlled_run_rejects_per_server_fault_plans() {
        let h = Hysteresis::never(Time::ms(100.0), Time::ms(300.0));
        let c = Controller::new(ladder_2(), 0, h).unwrap();
        let arrivals = [at(0.0, 0), at(1.0, 1)];
        let crash =
            FaultPlan::from_events(vec![crash_window(1.0, 5.0, 0)], 8).unwrap();
        let err = open_loop_controlled(&c, &arrivals, &crash, &Obs::disabled());
        assert!(err.is_err(), "crash plans don't survive re-shaping");
        let link = FaultPlan::from_events(
            vec![FaultEvent {
                at: Time::ZERO,
                until: Time::ms(2.0),
                kind: FaultKind::LinkDegrade { factor: 2.0 },
            }],
            8,
        )
        .unwrap();
        assert!(open_loop_controlled(&c, &arrivals, &link, &Obs::disabled()).is_ok());
    }

    #[test]
    fn switch_is_a_priced_graceful_drain() {
        // Overload the centralized rung with a 2 kHz burst: the
        // controller escalates exactly once, the in-service batch
        // completes on the old shape, and every pending request
        // migrates to the 8-queue rung behind a 20 ms pause.
        let h = Hysteresis {
            window: Time::ms(100.0),
            dwell: Time::ms(300.0),
            p95_hi: Time::ms(50.0),
            depth_hi: 16.0,
            min_samples: 8,
            down_fraction: 0.0, // never de-escalate in this test
            util_hi: 0.5,
        };
        let c = Controller::new(ladder_2(), 0, h).unwrap();
        let arrivals: Vec<Arrival> =
            (0..600).map(|i| at(100.0 + 0.5 * i as f64, i)).collect();
        let r = open_loop_controlled(&c, &arrivals, &FaultPlan::none(), &Obs::disabled())
            .unwrap();
        assert_eq!(r.switches.len(), 1, "one escalation, no flap");
        let sw = r.switches[0];
        assert_eq!((sw.from, sw.to), (0, 1));
        assert_eq!(sw.cost, Time::ms(20.0));
        assert!(sw.moved > 0, "pending requests migrate");
        assert_eq!(r.switch_downtime, Time::ms(20.0));
        assert!(r.switch_affected >= sw.moved);
        assert_eq!(r.final_config, 1);
        assert_eq!(r.report.servers, 8, "report reflects the final rung");
        // Graceful drain: exactly one batch completes after the switch
        // started but dispatched before it (the old shape's in-flight
        // work), and no batch dispatches inside the pause.
        let resume = sw.at + sw.cost;
        for b in &r.report.batch_log {
            assert!(
                b.dispatched_at <= sw.at || b.dispatched_at >= resume,
                "no dispatch inside the pause"
            );
        }
        let in_flight = r
            .report
            .batch_log
            .iter()
            .filter(|b| b.dispatched_at <= sw.at && b.done_at > sw.at)
            .count();
        assert_eq!(in_flight, 1, "the old shape's in-service batch completed");
        assert!(r.report.littles_law_gap() < 1e-9, "Little's law survives switches");
    }
}

