//! Arrival processes for the traffic engine (E13).
//!
//! Open-loop streams are pre-generated as sorted [`Arrival`] lists —
//! homogeneous Poisson, the diurnal taxi-demand curve
//! ([`crate::workload::DiurnalCurve`], thinned against its peak rate) and
//! a bursty flash-crowd profile.  Every stream is a pure function of
//! (process, horizon, nodes, seed), so traffic runs are deterministic per
//! seed and byte-identical across thread counts (the `BENCH_traffic.json`
//! contract).  The closed-loop process (fixed fleet + think time) cannot
//! be pre-generated — each client's next arrival depends on its previous
//! completion — so it lives inside the engine's event loop
//! ([`super::closed_loop`]); [`ThinkTime`] here only samples the think
//! delays.
//!
//! DESIGN.md: §11 (traffic engine).

use crate::coordinator::Arrival;
use crate::error::{Error, Result};
use crate::testing::Rng;
use crate::units::Time;
use crate::workload::DiurnalCurve;

/// An open-loop arrival process: requests arrive whether or not earlier
/// ones completed (the load does not back off under congestion).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate` requests/second.
    Poisson { rate: f64 },
    /// Non-homogeneous Poisson following the taxi demand curve (thinning
    /// against the curve's peak rate).
    Diurnal(DiurnalCurve),
    /// Flash crowd: Poisson at `base` except during the spike window
    /// `[at, at + width)`, where the rate multiplies by `boost`.
    FlashCrowd { base: f64, boost: f64, at: Time, width: Time },
}

impl ArrivalProcess {
    /// Peak instantaneous rate — the thinning envelope.
    pub fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Diurnal(curve) => curve.peak_rate(),
            ArrivalProcess::FlashCrowd { base, boost, .. } => base * boost.max(1.0),
        }
    }

    /// Instantaneous rate at `t`.
    pub fn rate_at(&self, t: Time) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Diurnal(curve) => curve.rate(t),
            ArrivalProcess::FlashCrowd { base, boost, at, width } => {
                if t >= at && t < at + width {
                    base * boost.max(1.0)
                } else {
                    base
                }
            }
        }
    }

    /// Generate the sorted arrival stream over `[0, horizon)`, each
    /// request targeting a uniform node in `0..nodes`.
    ///
    /// Draw order per candidate (part of the determinism contract the
    /// cross-validation replica mirrors): inter-arrival exponential at
    /// the peak rate, then the thinning acceptance draw (skipped for the
    /// homogeneous case), then the node draw for accepted arrivals.
    pub fn generate(&self, horizon: Time, nodes: usize, seed: u64) -> Result<Vec<Arrival>> {
        if !(self.peak_rate() > 0.0) || !self.peak_rate().is_finite() {
            return Err(Error::Sim("arrival process needs a positive finite rate".into()));
        }
        if !(horizon.as_s() > 0.0) || nodes == 0 {
            return Err(Error::Sim("arrivals need a positive horizon and nodes".into()));
        }
        let peak = self.peak_rate();
        let homogeneous = matches!(self, ArrivalProcess::Poisson { .. });
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        let mut t = 0.0f64;
        loop {
            let u = rng.f64().max(1e-12);
            t += -u.ln() / peak;
            if t >= horizon.as_s() {
                break;
            }
            if !homogeneous {
                // Thinning: accept with the relative instantaneous rate.
                let accept = self.rate_at(Time::s(t)) / peak;
                if !rng.chance(accept) {
                    continue;
                }
            }
            out.push(Arrival { at: Time::s(t), node: rng.index(nodes) });
        }
        Ok(out)
    }
}

/// Think-time distribution for the closed-loop fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ThinkTime {
    /// Exponential with the given mean (the classic interactive model —
    /// the fleet's aggregate offered load stays Poisson-like).
    Exponential { mean: Time },
    /// Fixed think time (periodic probing clients).
    Fixed(Time),
}

impl ThinkTime {
    pub fn mean(&self) -> Time {
        match *self {
            ThinkTime::Exponential { mean } => mean,
            ThinkTime::Fixed(t) => t,
        }
    }

    /// Draw one think delay.
    pub fn sample(&self, rng: &mut Rng) -> Time {
        match *self {
            ThinkTime::Exponential { mean } => {
                let u = rng.f64().max(1e-12);
                mean * (-u.ln())
            }
            ThinkTime::Fixed(t) => t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_and_ordering() {
        let p = ArrivalProcess::Poisson { rate: 1_000.0 };
        let a = p.generate(Time::s(4.0), 32, 7).unwrap();
        let expected = 4_000.0;
        assert!(
            (a.len() as f64 - expected).abs() < 0.1 * expected,
            "got {} arrivals, expected ~{expected}",
            a.len()
        );
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "arrivals must be sorted");
        assert!(a.iter().all(|x| x.node < 32 && x.at < Time::s(4.0)));
    }

    #[test]
    fn streams_are_deterministic_per_seed() {
        for p in [
            ArrivalProcess::Poisson { rate: 500.0 },
            ArrivalProcess::Diurnal(DiurnalCurve::new(500.0, 0.8, Time::s(1.0)).unwrap()),
            ArrivalProcess::FlashCrowd {
                base: 200.0,
                boost: 5.0,
                at: Time::s(1.0),
                width: Time::s(0.5),
            },
        ] {
            let a = p.generate(Time::s(2.0), 16, 3).unwrap();
            let b = p.generate(Time::s(2.0), 16, 3).unwrap();
            assert_eq!(a, b, "{p:?} must be deterministic per seed");
            let c = p.generate(Time::s(2.0), 16, 4).unwrap();
            assert_ne!(a, c, "{p:?} must vary with the seed");
        }
    }

    #[test]
    fn diurnal_thinning_tracks_the_curve() {
        let curve = DiurnalCurve::new(2_000.0, 1.0, Time::s(2.0)).unwrap();
        let a = ArrivalProcess::Diurnal(curve).generate(Time::s(2.0), 8, 11).unwrap();
        // Volume over one full period ≈ base·period.
        let expected = 2_000.0 * 2.0;
        assert!((a.len() as f64 - expected).abs() < 0.1 * expected, "{}", a.len());
        // First half-period (rising sine) must carry far more arrivals
        // than the second (the trough clamps near zero).
        let first = a.iter().filter(|x| x.at < Time::s(1.0)).count();
        let second = a.len() - first;
        assert!(first > 2 * second, "diurnal skew missing: {first} vs {second}");
    }

    #[test]
    fn flash_crowd_concentrates_in_the_spike_window() {
        let p = ArrivalProcess::FlashCrowd {
            base: 500.0,
            boost: 10.0,
            at: Time::s(1.0),
            width: Time::s(0.2),
        };
        let a = p.generate(Time::s(2.0), 8, 5).unwrap();
        let in_spike =
            a.iter().filter(|x| x.at >= Time::s(1.0) && x.at < Time::s(1.2)).count();
        // Spike: 0.2 s at 5000/s = 1000; background: 1.8 s at 500/s = 900.
        let outside = a.len() - in_spike;
        assert!(in_spike > outside, "spike must dominate: {in_spike} vs {outside}");
        assert!((in_spike as f64 - 1_000.0).abs() < 150.0, "{in_spike}");
        // boost < 1 clamps to the base rate (a flash crowd never thins).
        let calm = ArrivalProcess::FlashCrowd {
            base: 500.0,
            boost: 0.1,
            at: Time::s(1.0),
            width: Time::s(0.2),
        };
        assert_eq!(calm.peak_rate(), 500.0);
        assert_eq!(calm.rate_at(Time::s(1.1)), 500.0);
    }

    #[test]
    fn think_time_sampling() {
        let mut rng = Rng::new(9);
        let exp = ThinkTime::Exponential { mean: Time::ms(10.0) };
        let n = 4_000;
        let mean: Time =
            (0..n).map(|_| exp.sample(&mut rng)).sum::<Time>() * (1.0 / n as f64);
        assert!(
            (mean.as_ms() - 10.0).abs() < 0.8,
            "exponential mean drifted: {} ms",
            mean.as_ms()
        );
        let fixed = ThinkTime::Fixed(Time::ms(3.0));
        assert_eq!(fixed.sample(&mut rng), Time::ms(3.0));
        assert_eq!(fixed.mean(), Time::ms(3.0));
        assert_eq!(exp.mean(), Time::ms(10.0));
    }

    #[test]
    fn generation_rejects_degenerate_parameters() {
        let p = ArrivalProcess::Poisson { rate: 100.0 };
        assert!(p.generate(Time::ZERO, 8, 1).is_err());
        assert!(p.generate(Time::s(1.0), 0, 1).is_err());
        assert!(ArrivalProcess::Poisson { rate: 0.0 }.generate(Time::s(1.0), 8, 1).is_err());
    }
}
