//! Workload descriptions: what one GNN inference asks of each core.
//!
//! The paper evaluates two workloads: the hetGNN-LSTM taxi model (§4.2,
//! Table 1 — P=12 frames, 3 edge types, 864-byte node messages) and
//! GCN-style inference over the four §4.3 datasets.  A workload maps to
//! crossbar *passes* per node in the aggregation / feature-extraction cores
//! and CAM lookups in the traversal core.
//!
//! DESIGN.md: §3 (architecture level).

/// Per-node GNN workload parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct GnnWorkload {
    /// Name for reports.
    pub name: String,
    /// Features per node (the message payload).
    pub feature_len: usize,
    /// Bits per stored feature value.
    pub feature_bits: u32,
    /// Temporal frames aggregated per inference (P for the taxi model).
    pub frames: usize,
    /// Edge types aggregated per frame (3 for the taxi hetGNN).
    pub edge_types: usize,
    /// Neighbors contributing to one aggregation (cluster size cₛ).
    pub neighbors: usize,
    /// Feature-extraction input width (the aggregated representation).
    pub fe_in: usize,
    /// Feature-extraction output width.
    pub fe_out: usize,
    /// Bits per feature-extraction weight.
    pub fe_weight_bits: u32,
    /// Dense layers executed by the feature-extraction core.
    pub fe_layers: usize,
    /// GNN depth X (drives inter-layer communication, Eq. 7).
    pub gnn_layers: usize,
}

impl GnnWorkload {
    /// The §4.2 taxi case study: hetGNN-LSTM, 864-byte messages
    /// (432 features × 16 bit), P = 12 frames × 3 edge types, per-frame
    /// embedding 128 → 64 executed by the feature-extraction core.
    pub fn taxi() -> GnnWorkload {
        GnnWorkload {
            name: "taxi-hetgnn".into(),
            feature_len: 432,
            feature_bits: 16,
            frames: 12,
            edge_types: 3,
            neighbors: 10,
            fe_in: 128,
            fe_out: 64,
            fe_weight_bits: 16,
            fe_layers: 1,
            gnn_layers: 2,
        }
    }

    /// GCN-style single-relation workload over a dataset with the given
    /// feature length and average cluster size (Table 2 statistics).
    pub fn gcn(name: &str, feature_len: usize, neighbors: usize) -> GnnWorkload {
        GnnWorkload {
            name: format!("gcn-{name}"),
            feature_len,
            feature_bits: 16,
            frames: 1,
            edge_types: 1,
            neighbors,
            fe_in: 128,
            fe_out: 64,
            fe_weight_bits: 16,
            fe_layers: 1,
            gnn_layers: 2,
        }
    }

    /// Bytes of one node's feature message (what travels on the links).
    /// The paper's taxi payload: 864 bytes.
    pub fn message_bytes(&self) -> usize {
        self.feature_len * self.feature_bits as usize / 8
    }

    /// RRAM cells needed to store one node's features at `cell_bits` per
    /// cell (bit-sliced across adjacent columns).
    pub fn feature_cells(&self, cell_bits: u32) -> usize {
        self.feature_len * (self.feature_bits as usize).div_ceil(cell_bits as usize)
    }

    /// Cells per feature-extraction weight column group.
    pub fn fe_weight_cells(&self, cell_bits: u32) -> usize {
        self.fe_out * (self.fe_weight_bits as usize).div_ceil(cell_bits as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taxi_message_is_864_bytes() {
        assert_eq!(GnnWorkload::taxi().message_bytes(), 864);
    }

    #[test]
    fn taxi_feature_cells_span_four_aggregation_passes() {
        let w = GnnWorkload::taxi();
        // 432 features × (16/4) cells = 1728 cells → 4 passes over 512 cols.
        assert_eq!(w.feature_cells(4), 1728);
        assert_eq!(w.feature_cells(4).div_ceil(512), 4);
    }

    #[test]
    fn taxi_fe_weight_cells_span_two_column_groups() {
        let w = GnnWorkload::taxi();
        // 64 outputs × 4 cells = 256 cells → 2 passes over 128 cols.
        assert_eq!(w.fe_weight_cells(4), 256);
        assert_eq!(w.fe_weight_cells(4).div_ceil(128), 2);
    }

    #[test]
    fn gcn_workload_uses_table2_stats() {
        let w = GnnWorkload::gcn("cora", 1433, 4);
        assert_eq!(w.feature_len, 1433);
        assert_eq!(w.neighbors, 4);
        assert_eq!(w.frames, 1);
        assert_eq!(w.edge_types, 1);
    }

    #[test]
    fn feature_cells_rounds_up_bit_slices() {
        let w = GnnWorkload { feature_bits: 6, ..GnnWorkload::gcn("x", 10, 1) };
        // 6 bits / 4-bit cells → 2 cells per feature.
        assert_eq!(w.feature_cells(4), 20);
    }
}
