//! Flat row-major matrix storage for the compute hot paths.
//!
//! The seed APIs passed node features as `&[Vec<i32>]`: one heap
//! allocation per row, pointer chasing on every access, and a defensive
//! ragged-row check inside every consumer.  [`Mat`] stores one contiguous
//! row-major buffer; shape is validated once at construction and every
//! consumer takes slice views.  [`Tile`] (quantized i32 conductance
//! levels) feeds the aggregation window and the feature-extraction
//! weights; [`FeatureMatrix`] (f32) carries raw device features through
//! the coordinator.
//!
//! DESIGN.md: §8 (flat memory layout).

use crate::error::{Error, Result};

/// A dense row-major `rows × cols` matrix in one contiguous allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat<T> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

/// Quantized conductance-level matrix (aggregation windows, FE weights).
pub type Tile = Mat<i32>;

/// Floating-point feature matrix (one device/node per row).
pub type FeatureMatrix = Mat<f32>;

impl<T: Copy> Mat<T> {
    /// All-`fill` matrix.
    pub fn filled(rows: usize, cols: usize, fill: T) -> Mat<T> {
        Mat { rows, cols, data: vec![fill; rows * cols] }
    }

    /// Build element-wise: `f(row, col)` in row-major order (so a stateful
    /// generator — an RNG — visits cells in the same order a nested
    /// `rows × cols` loop would).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Mat<T> {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Adopt a flat row-major buffer.
    pub fn from_flat(rows: usize, cols: usize, data: Vec<T>) -> Result<Mat<T>> {
        if data.len() != rows * cols {
            return Err(Error::Hardware(format!(
                "flat buffer holds {} values, shape {rows}x{cols} needs {}",
                data.len(),
                rows * cols
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Migrate a ragged-capable `Vec<Vec<T>>` shape; rejects ragged rows
    /// once here instead of at every consumer.
    pub fn from_rows(rows: &[Vec<T>]) -> Result<Mat<T>> {
        let cols = rows.first().map(Vec::len).unwrap_or(0);
        if let Some(bad) = rows.iter().find(|r| r.len() != cols) {
            return Err(Error::Hardware(format!(
                "ragged rows: expected {cols} columns, found {}",
                bad.len()
            )));
        }
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            data.extend_from_slice(r);
        }
        Ok(Mat { rows: rows.len(), cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `r` as a slice view.
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn get(&self, r: usize, c: usize) -> T {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: T) {
        self.data[r * self.cols + c] = v;
    }

    /// The whole matrix as one contiguous row-major slice.
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Iterate rows as slices.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[T]> + '_ {
        (0..self.rows).map(move |r| self.row(r))
    }
}

impl Tile {
    /// All-zero tile.
    pub fn zeros(rows: usize, cols: usize) -> Tile {
        Tile::filled(rows, cols, 0)
    }
}

impl FeatureMatrix {
    /// All-zero feature matrix.
    pub fn zeros(rows: usize, cols: usize) -> FeatureMatrix {
        FeatureMatrix::filled(rows, cols, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_views() {
        let mut m = Tile::zeros(3, 2);
        assert_eq!((m.rows(), m.cols()), (3, 2));
        m.set(1, 1, 7);
        m.row_mut(2).copy_from_slice(&[4, 5]);
        assert_eq!(m.row(0), &[0, 0]);
        assert_eq!(m.row(1), &[0, 7]);
        assert_eq!(m.get(2, 0), 4);
        assert_eq!(m.as_slice(), &[0, 0, 0, 7, 4, 5]);
        assert_eq!(m.iter_rows().count(), 3);
    }

    #[test]
    fn from_fn_is_row_major() {
        let m = Tile::from_fn(2, 3, |r, c| (10 * r + c) as i32);
        assert_eq!(m.as_slice(), &[0, 1, 2, 10, 11, 12]);
    }

    #[test]
    fn from_rows_roundtrips_and_rejects_ragged() {
        let m = Tile::from_rows(&[vec![1, 2], vec![3, 4]]).unwrap();
        assert_eq!(m.row(1), &[3, 4]);
        assert!(Tile::from_rows(&[vec![1, 2], vec![3]]).is_err());
        let empty = Tile::from_rows(&[]).unwrap();
        assert_eq!((empty.rows(), empty.cols()), (0, 0));
        assert!(empty.is_empty());
        assert_eq!(empty.iter_rows().count(), 0);
    }

    #[test]
    fn from_flat_checks_shape() {
        assert!(FeatureMatrix::from_flat(2, 2, vec![0.0; 4]).is_ok());
        assert!(FeatureMatrix::from_flat(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn zero_width_rows_iterate() {
        let m = Tile::zeros(4, 0);
        assert_eq!(m.iter_rows().count(), 4);
        assert!(m.iter_rows().all(|r| r.is_empty()));
    }
}
