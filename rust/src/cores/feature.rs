//! Feature-extraction core: MVM crossbars programmed with the GNN layer
//! weights plus a shared activation unit (paper Fig. 2(a), step 4).
//!
//! The aggregated representation Z streams through bit-serial passes
//! against the stationary weight matrix; the activation unit applies the
//! non-linearity once per node.

use crate::config::{CoreConfig, DeviceParams};
use crate::crossbar::MvmCrossbar;
use crate::device::Activation;
use crate::error::{Error, Result};
use crate::units::{Energy, Time};

use super::workload::GnnWorkload;

/// The feature-extraction core.
#[derive(Debug)]
pub struct FeatureExtractionCore {
    config: CoreConfig,
    device: DeviceParams,
    xbar: MvmCrossbar,
}

impl FeatureExtractionCore {
    pub fn new(config: CoreConfig, device: DeviceParams) -> Result<FeatureExtractionCore> {
        config.validate()?;
        Ok(FeatureExtractionCore {
            xbar: MvmCrossbar::new(config.geometry, device.clone())?,
            config,
            device,
        })
    }

    pub fn config(&self) -> &CoreConfig {
        &self.config
    }

    /// Evaluate passes for one node: input bit-planes × column groups to
    /// cover the bit-sliced weight matrix × row windows to cover the input
    /// dimension × layers.
    pub fn passes_per_node(&self, w: &GnnWorkload) -> usize {
        let g = &self.config.geometry;
        let col_groups = w.fe_weight_cells(g.cell_bits).div_ceil(g.cols).max(1);
        let row_windows = w.fe_in.div_ceil(g.rows).max(1);
        g.input_bits as usize * col_groups * row_windows * w.fe_layers
    }

    /// Per-node transformation latency (t₃ of Eq. 2): passes + one
    /// activation-unit application.
    pub fn per_node_latency(&self, w: &GnnWorkload) -> Time {
        self.xbar.pass_latency() * self.passes_per_node(w) as f64
            + Activation::new(&self.device).latency()
    }

    /// Per-node transformation dynamic energy.
    pub fn per_node_energy(&self, w: &GnnWorkload) -> Energy {
        self.xbar.pass_energy() * self.passes_per_node(w) as f64
            + Activation::new(&self.device).energy()
    }

    /// Program the layer weights (row-major `fe_in × fe_out` levels).
    pub fn program_weights(&mut self, weights: &[i32], fe_in: usize, fe_out: usize) -> Result<()> {
        self.xbar.program_tile(weights, fe_in, fe_out)
    }

    /// Functional transform: `relu(x @ W)` in the quantized domain.
    /// `input` are unsigned DAC codes of the aggregated features.
    pub fn transform(&self, input: &[u32], fe_out: usize) -> Result<Vec<i64>> {
        let g = self.config.geometry;
        if input.len() > g.rows {
            return Err(Error::Hardware(format!(
                "{} inputs exceed {} crossbar rows",
                input.len(),
                g.rows
            )));
        }
        let mut padded = vec![0u32; g.rows];
        padded[..input.len()].copy_from_slice(input);
        let out = self.xbar.evaluate(&padded)?;
        // Activation unit: ReLU.
        Ok(out[..fe_out.min(g.cols)].iter().map(|&v| v.max(0)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::testing::{forall, Rng};

    fn core() -> FeatureExtractionCore {
        let cfg = presets::decentralized();
        FeatureExtractionCore::new(cfg.feature, cfg.device).unwrap()
    }

    #[test]
    fn taxi_passes_match_calibration() {
        // 8 input bits × 2 column groups × 1 row window × 1 layer = 16.
        assert_eq!(core().passes_per_node(&GnnWorkload::taxi()), 16);
    }

    #[test]
    fn taxi_latency_is_table1_t3() {
        let t = core().per_node_latency(&GnnWorkload::taxi());
        crate::testing::assert_close(t.as_us(), 0.37, 0.001);
    }

    #[test]
    fn taxi_power_is_table1() {
        let c = core();
        let w = GnnWorkload::taxi();
        let p = c.per_node_energy(&w) / c.per_node_latency(&w);
        crate::testing::assert_close(p.as_mw(), 3.68, 0.001);
    }

    #[test]
    fn wide_inputs_need_row_windows() {
        let c = core();
        let mut w = GnnWorkload::taxi();
        let base = c.passes_per_node(&w);
        w.fe_in = 1433; // Cora features: ceil(1433/128) = 12 windows
        assert_eq!(c.passes_per_node(&w), base * 12);
    }

    #[test]
    fn transform_is_relu_of_matmul() {
        let mut c = core();
        // W = [[1, -2], [3, 4]] (2 in, 2 out)
        c.program_weights(&[1, -2, 3, 4], 2, 2).unwrap();
        let out = c.transform(&[5, 1], 2).unwrap();
        // x@W = [5+3, -10+4] = [8, -6] → relu → [8, 0]
        assert_eq!(out, vec![8, 0]);
    }

    #[test]
    fn property_transform_matches_oracle() {
        forall(16, |rng: &mut Rng| {
            let fin = rng.index(16) + 1;
            let fout = rng.index(8) + 1;
            let weights: Vec<i32> =
                (0..fin * fout).map(|_| rng.i64_in(-8, 7) as i32).collect();
            let input: Vec<u32> = (0..fin).map(|_| rng.u64_in(0, 255) as u32).collect();
            let mut c = core();
            c.program_weights(&weights, fin, fout).unwrap();
            let got = c.transform(&input, fout).unwrap();
            for o in 0..fout {
                let raw: i64 = (0..fin)
                    .map(|i| input[i] as i64 * weights[i * fout + o] as i64)
                    .sum();
                assert_eq!(got[o], raw.max(0), "col {o}");
            }
        });
    }

    #[test]
    fn rejects_oversized_input() {
        let c = core();
        assert!(c.transform(&vec![0u32; 129], 4).is_err());
    }
}
