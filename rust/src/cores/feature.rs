//! Feature-extraction core: MVM crossbars programmed with the GNN layer
//! weights plus a shared activation unit (paper Fig. 2(a), step 4).
//!
//! The aggregated representation Z streams through bit-serial passes
//! against the stationary weight matrix; the activation unit applies the
//! non-linearity once per node.
//!
//! DESIGN.md: §3 (architecture level).

use crate::config::{CoreConfig, DeviceParams};
use crate::crossbar::MvmCrossbar;
use crate::device::Activation;
use crate::error::{Error, Result};
use crate::units::{Energy, Time};

use super::workload::GnnWorkload;

/// The feature-extraction core.
#[derive(Debug)]
pub struct FeatureExtractionCore {
    config: CoreConfig,
    device: DeviceParams,
    xbar: MvmCrossbar,
    /// Scratch: zero-padded DAC codes (geometry rows).
    padded: Vec<u32>,
    /// Live prefix of `padded` (the previous call's input length):
    /// everything past it is already zero, so `transform_into` zeroes
    /// only the stale delta instead of the whole row dimension.
    padded_live: usize,
    /// Scratch: full-width crossbar output (geometry cols).
    full_out: Vec<i64>,
    /// Shape of the last programmed layer — the cache gate that makes
    /// `tile_resident`'s outside-the-block-is-zero assumption hold (a
    /// previous *wider* program would otherwise leak stale columns into
    /// `transform` outputs beyond a narrower layer's `fe_out`).
    resident_shape: Option<(usize, usize)>,
    /// Cache misses: how often the RRAM array was actually written
    /// (residency is tested against the array itself, no copy kept).
    programs: u64,
}

impl FeatureExtractionCore {
    pub fn new(config: CoreConfig, device: DeviceParams) -> Result<FeatureExtractionCore> {
        config.validate()?;
        let (rows, cols) = (config.geometry.rows, config.geometry.cols);
        Ok(FeatureExtractionCore {
            xbar: MvmCrossbar::new(config.geometry, device.clone())?,
            config,
            device,
            padded: vec![0u32; rows],
            padded_live: 0,
            full_out: vec![0i64; cols],
            resident_shape: None,
            programs: 0,
        })
    }

    pub fn config(&self) -> &CoreConfig {
        &self.config
    }

    /// Evaluate passes for one node: input bit-planes × column groups to
    /// cover the bit-sliced weight matrix × row windows to cover the input
    /// dimension × layers.
    pub fn passes_per_node(&self, w: &GnnWorkload) -> usize {
        let g = &self.config.geometry;
        let col_groups = w.fe_weight_cells(g.cell_bits).div_ceil(g.cols).max(1);
        let row_windows = w.fe_in.div_ceil(g.rows).max(1);
        g.input_bits as usize * col_groups * row_windows * w.fe_layers
    }

    /// Per-node transformation latency (t₃ of Eq. 2): passes + one
    /// activation-unit application.
    pub fn per_node_latency(&self, w: &GnnWorkload) -> Time {
        self.xbar.pass_latency() * self.passes_per_node(w) as f64
            + Activation::new(&self.device).latency()
    }

    /// Per-node transformation dynamic energy.
    pub fn per_node_energy(&self, w: &GnnWorkload) -> Energy {
        self.xbar.pass_energy() * self.passes_per_node(w) as f64
            + Activation::new(&self.device).energy()
    }

    /// Program the layer weights (row-major `fe_in × fe_out` levels).
    /// The GNN layer is round-invariant, so when the same weights (shape
    /// *and* contents) are already resident the RRAM write is skipped —
    /// the same program-once / evaluate-many contract as
    /// `AggregationCore::program_window`.
    pub fn program_weights(&mut self, weights: &[i32], fe_in: usize, fe_out: usize) -> Result<()> {
        // The shape gate is load-bearing: `transform` evaluates the FULL
        // array, so a hit is only a true no-op when the last program had
        // this exact shape (guaranteeing every cell outside the compared
        // block is zero).  A failed program leaves both the array and
        // the recorded shape untouched (`program_tile` validates before
        // writing).
        let shape = (fe_in, fe_out);
        if self.resident_shape == Some(shape) && self.xbar.tile_resident(weights, fe_in, fe_out)
        {
            return Ok(());
        }
        self.xbar.program_tile(weights, fe_in, fe_out)?;
        self.programs += 1;
        self.resident_shape = Some(shape);
        Ok(())
    }

    /// How often the crossbar was actually (re)programmed — cache misses
    /// of the program-once path.
    pub fn programs(&self) -> u64 {
        self.programs
    }

    /// Functional transform: `relu(x @ W)` in the quantized domain, into
    /// the caller's buffer (cleared + refilled; `fe_out.min(cols)` values).
    /// `input` are unsigned DAC codes of the aggregated features.  The
    /// padding and crossbar-output buffers are reused scratch; with a
    /// clip-free geometry (the presets) the crossbar's fused path makes
    /// the whole call allocation-free.
    pub fn transform_into(
        &mut self,
        input: &[u32],
        fe_out: usize,
        out: &mut Vec<i64>,
    ) -> Result<()> {
        let g = self.config.geometry;
        if input.len() > g.rows {
            return Err(Error::Hardware(format!(
                "{} inputs exceed {} crossbar rows",
                input.len(),
                g.rows
            )));
        }
        self.padded[..input.len()].copy_from_slice(input);
        // Zero only the stale tail a previous longer input left behind
        // (rows past `padded_live` never held data).
        if self.padded_live > input.len() {
            self.padded[input.len()..self.padded_live].fill(0);
        }
        self.padded_live = input.len();
        self.xbar.evaluate_into(&self.padded, &mut self.full_out)?;
        // Activation unit: ReLU.
        out.clear();
        out.extend(self.full_out[..fe_out.min(g.cols)].iter().map(|&v| v.max(0)));
        Ok(())
    }

    /// Allocating convenience wrapper over [`Self::transform_into`].
    pub fn transform(&mut self, input: &[u32], fe_out: usize) -> Result<Vec<i64>> {
        let mut out = Vec::new();
        self.transform_into(input, fe_out, &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::testing::{forall, Rng};

    fn core() -> FeatureExtractionCore {
        let cfg = presets::decentralized();
        FeatureExtractionCore::new(cfg.feature, cfg.device).unwrap()
    }

    #[test]
    fn taxi_passes_match_calibration() {
        // 8 input bits × 2 column groups × 1 row window × 1 layer = 16.
        assert_eq!(core().passes_per_node(&GnnWorkload::taxi()), 16);
    }

    #[test]
    fn taxi_latency_is_table1_t3() {
        let t = core().per_node_latency(&GnnWorkload::taxi());
        crate::testing::assert_close(t.as_us(), 0.37, 0.001);
    }

    #[test]
    fn taxi_power_is_table1() {
        let c = core();
        let w = GnnWorkload::taxi();
        let p = c.per_node_energy(&w) / c.per_node_latency(&w);
        crate::testing::assert_close(p.as_mw(), 3.68, 0.001);
    }

    #[test]
    fn wide_inputs_need_row_windows() {
        let c = core();
        let mut w = GnnWorkload::taxi();
        let base = c.passes_per_node(&w);
        w.fe_in = 1433; // Cora features: ceil(1433/128) = 12 windows
        assert_eq!(c.passes_per_node(&w), base * 12);
    }

    #[test]
    fn transform_is_relu_of_matmul() {
        let mut c = core();
        // W = [[1, -2], [3, 4]] (2 in, 2 out)
        c.program_weights(&[1, -2, 3, 4], 2, 2).unwrap();
        let out = c.transform(&[5, 1], 2).unwrap();
        // x@W = [5+3, -10+4] = [8, -6] → relu → [8, 0]
        assert_eq!(out, vec![8, 0]);
    }

    #[test]
    fn property_transform_matches_oracle() {
        forall(16, |rng: &mut Rng| {
            let fin = rng.index(16) + 1;
            let fout = rng.index(8) + 1;
            let weights: Vec<i32> =
                (0..fin * fout).map(|_| rng.i64_in(-8, 7) as i32).collect();
            let input: Vec<u32> = (0..fin).map(|_| rng.u64_in(0, 255) as u32).collect();
            let mut c = core();
            c.program_weights(&weights, fin, fout).unwrap();
            let got = c.transform(&input, fout).unwrap();
            for o in 0..fout {
                let raw: i64 = (0..fin)
                    .map(|i| input[i] as i64 * weights[i * fout + o] as i64)
                    .sum();
                assert_eq!(got[o], raw.max(0), "col {o}");
            }
        });
    }

    #[test]
    fn rejects_oversized_input() {
        let mut c = core();
        assert!(c.transform(&vec![0u32; 129], 4).is_err());
    }

    #[test]
    fn unchanged_weights_program_once() {
        let mut c = core();
        assert_eq!(c.programs(), 0);
        c.program_weights(&[1, -2, 3, 4], 2, 2).unwrap();
        assert_eq!(c.programs(), 1);
        // Same layer, many rounds: no reprogramming.
        for _ in 0..5 {
            c.program_weights(&[1, -2, 3, 4], 2, 2).unwrap();
        }
        assert_eq!(c.programs(), 1);
        assert_eq!(c.transform(&[5, 1], 2).unwrap(), vec![8, 0]);
        // Changed contents or shape rewrite the array.
        c.program_weights(&[1, -2, 3, 5], 2, 2).unwrap();
        assert_eq!(c.programs(), 2);
        c.program_weights(&[1, -2, 3, 5], 4, 1).unwrap();
        assert_eq!(c.programs(), 3);
        // A rejected program leaves the array untouched (validated before
        // writing), so the prior layer is still resident afterwards.
        assert!(c.program_weights(&[100, 0, 0, 0], 2, 2).is_err());
        assert_eq!(c.programs(), 3);
        c.program_weights(&[1, -2, 3, 5], 4, 1).unwrap();
        assert_eq!(c.programs(), 3, "array-backed residency survives a failed program");
    }

    #[test]
    fn narrowing_the_layer_reprograms_stale_columns() {
        let mut c = core();
        c.program_weights(&[1, 2, 3, 4], 2, 2).unwrap();
        // A narrower layer whose single column matches the old column 0
        // must NOT be treated as resident: transform evaluates the full
        // array, so the old column 1 would leak into outputs beyond the
        // new layer's width.
        c.program_weights(&[1, 3], 2, 1).unwrap();
        assert_eq!(c.programs(), 2);
        assert_eq!(c.transform(&[1, 1], 2).unwrap(), vec![4, 0]);
    }

    #[test]
    fn transform_into_reuses_buffers_and_clears_stale_padding() {
        let mut c = core();
        c.program_weights(&[1, 0, 0, 1], 2, 2).unwrap();
        let mut out = vec![99i64; 7];
        c.transform_into(&[3, 4], 2, &mut out).unwrap();
        assert_eq!(out, vec![3, 4]);
        // A longer input must not survive into a shorter one's padding.
        c.transform_into(&[5], 2, &mut out).unwrap();
        assert_eq!(out, vec![5, 0]);
    }

    /// The delta-zeroing of the padded scratch survives arbitrary
    /// grow/shrink sequences of the input length — every call must see
    /// zeros past its own input, regardless of history.
    #[test]
    fn padding_stays_clean_across_length_changes() {
        let mut c = core();
        // W = I₂ padded: out mirrors the first two inputs.
        c.program_weights(&[1, 0, 0, 1], 2, 2).unwrap();
        let mut out = Vec::new();
        for len in [2usize, 1, 2, 1, 1, 2] {
            let input = vec![9u32; len];
            c.transform_into(&input, 2, &mut out).unwrap();
            let want = if len >= 2 { vec![9, 9] } else { vec![9, 0] };
            assert_eq!(out, want, "len {len}");
            assert!(c.padded[len..].iter().all(|&v| v == 0), "stale padding at len {len}");
        }
    }
}
