//! Aggregation core: resistive MVM crossbars accumulating neighbor
//! features (paper §2.3, step 3).
//!
//! Node-stationary dataflow: a window of node features is programmed into
//! the crossbar (features bit-sliced across columns, one node per row); the
//! vector generator & scheduler renders a binary row-activation vector from
//! the traversal core's output, and one evaluate pass accumulates all
//! active neighbors per column — the in-situ Σ of the Z matrix (Fig. 1).

use crate::config::{CoreConfig, DeviceParams};
use crate::crossbar::MvmCrossbar;
use crate::error::{Error, Result};
use crate::units::{Energy, Time};

use super::workload::GnnWorkload;

/// The aggregation core: a bank of identical MVM crossbars.
#[derive(Debug)]
pub struct AggregationCore {
    config: CoreConfig,
    xbar: MvmCrossbar,
}

impl AggregationCore {
    pub fn new(config: CoreConfig, device: DeviceParams) -> Result<AggregationCore> {
        config.validate()?;
        Ok(AggregationCore { xbar: MvmCrossbar::new(config.geometry, device)?, config })
    }

    pub fn config(&self) -> &CoreConfig {
        &self.config
    }

    /// Evaluate passes needed for one node's aggregation under `w`:
    /// column groups to cover the bit-sliced features × frames × edge
    /// types × row windows to cover the contributing neighbors.
    pub fn passes_per_node(&self, w: &GnnWorkload) -> usize {
        let g = &self.config.geometry;
        let col_groups = w.feature_cells(g.cell_bits).div_ceil(g.cols).max(1);
        let row_windows = w.neighbors.div_ceil(g.rows).max(1);
        col_groups * row_windows * w.frames * w.edge_types
    }

    /// Per-node aggregation latency (t₂ of Eq. 2).
    pub fn per_node_latency(&self, w: &GnnWorkload) -> Time {
        self.xbar.pass_latency() * self.passes_per_node(w) as f64
    }

    /// Per-node aggregation dynamic energy.
    pub fn per_node_energy(&self, w: &GnnWorkload) -> Energy {
        self.xbar.pass_energy() * self.passes_per_node(w) as f64
    }

    /// Functional aggregation of one column group: program `features`
    /// (quantized levels, one row per node) and accumulate the rows
    /// selected by `active` (the scheduler's row-activation vector).
    ///
    /// Returns per-column sums — exactly `Σ_{active r} features[r][c]`,
    /// which is what a 1-bit input pass of the crossbar computes.
    pub fn aggregate(&mut self, features: &[Vec<i32>], active: &[bool]) -> Result<Vec<i64>> {
        let g = self.config.geometry;
        if features.len() > g.rows {
            return Err(Error::Hardware(format!(
                "{} nodes exceed {} crossbar rows",
                features.len(),
                g.rows
            )));
        }
        if active.len() != features.len() {
            return Err(Error::Hardware("activation vector length mismatch".into()));
        }
        let cols = features.first().map(Vec::len).unwrap_or(0);
        if cols > g.cols {
            return Err(Error::Hardware(format!("{cols} feature cells exceed {} columns", g.cols)));
        }
        if features.iter().any(|f| f.len() != cols) {
            return Err(Error::Hardware("ragged feature rows".into()));
        }
        // Program the window.
        let mut tile = vec![0i32; features.len() * cols];
        for (r, f) in features.iter().enumerate() {
            tile[r * cols..(r + 1) * cols].copy_from_slice(f);
        }
        self.xbar.program_tile(&tile, features.len(), cols)?;
        // 1-bit activation input: adjacency row as DAC codes.
        let mut input = vec![0u32; g.rows];
        for (r, &a) in active.iter().enumerate() {
            input[r] = a as u32;
        }
        // A single bit-plane is enough for a binary input; temporarily a
        // full evaluate would multiply by 2^b planes of zeros, so evaluate
        // and take the plane-0 contribution = the full sum (planes 1.. see
        // zero input bits and contribute zero).
        let out = self.xbar.evaluate(&input)?;
        Ok(out[..cols].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::testing::{forall, Rng};

    fn core() -> AggregationCore {
        let cfg = presets::decentralized();
        AggregationCore::new(cfg.aggregation, cfg.device).unwrap()
    }

    #[test]
    fn taxi_passes_match_calibration() {
        // 4 column groups × 12 frames × 3 edge types = 144 passes.
        assert_eq!(core().passes_per_node(&GnnWorkload::taxi()), 144);
    }

    #[test]
    fn taxi_latency_is_table1_t2() {
        let t = core().per_node_latency(&GnnWorkload::taxi());
        crate::testing::assert_close(t.as_us(), 14.27, 0.001);
    }

    #[test]
    fn taxi_power_is_table1() {
        let c = core();
        let w = GnnWorkload::taxi();
        let p = c.per_node_energy(&w) / c.per_node_latency(&w);
        crate::testing::assert_close(p.as_mw(), 41.6, 0.001);
    }

    #[test]
    fn more_neighbors_than_rows_need_more_windows() {
        let c = core();
        let mut w = GnnWorkload::gcn("x", 16, 10);
        let base = c.passes_per_node(&w);
        w.neighbors = 1000; // > 512 rows → 2 windows
        assert_eq!(c.passes_per_node(&w), base * 2);
    }

    #[test]
    fn functional_aggregate_sums_active_rows() {
        let mut c = core();
        let features = vec![vec![1, 2, 3], vec![4, 5, 6], vec![7, 7, 7]];
        let out = c.aggregate(&features, &[true, false, true]).unwrap();
        assert_eq!(out, vec![8, 9, 10]);
        // nothing active → zeros
        let out = c.aggregate(&features, &[false, false, false]).unwrap();
        assert_eq!(out, vec![0, 0, 0]);
    }

    #[test]
    fn property_aggregate_equals_masked_sum() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(32) + 1;
            let f = rng.index(24) + 1;
            let features: Vec<Vec<i32>> =
                (0..n).map(|_| (0..f).map(|_| rng.i64_in(-8, 7) as i32).collect()).collect();
            let active: Vec<bool> = (0..n).map(|_| rng.bool()).collect();
            let mut c = core();
            let got = c.aggregate(&features, &active).unwrap();
            for col in 0..f {
                let want: i64 = features
                    .iter()
                    .zip(&active)
                    .filter(|(_, a)| **a)
                    .map(|(row, _)| row[col] as i64)
                    .sum();
                assert_eq!(got[col], want);
            }
        });
    }

    #[test]
    fn rejects_invalid_windows() {
        let mut c = core();
        let too_many = vec![vec![0i32]; 513];
        assert!(c.aggregate(&too_many, &vec![true; 513]).is_err());
        assert!(c.aggregate(&[vec![0; 3]], &[true, false]).is_err()); // arity
        assert!(c.aggregate(&[vec![0; 3], vec![0; 2]], &[true, false]).is_err());
        // ragged
    }
}
