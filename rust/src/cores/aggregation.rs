//! Aggregation core: resistive MVM crossbars accumulating neighbor
//! features (paper §2.3, step 3).
//!
//! Node-stationary dataflow: a window of node features is programmed into
//! the crossbar (features bit-sliced across columns, one node per row); the
//! vector generator & scheduler renders a binary row-activation vector from
//! the traversal core's output, and one evaluate pass accumulates all
//! active neighbors per column — the in-situ Σ of the Z matrix (Fig. 1).
//!
//! DESIGN.md: §3 (architecture level).

use crate::config::{CoreConfig, DeviceParams};
use crate::crossbar::MvmCrossbar;
use crate::error::{Error, Result};
use crate::obs::MetricsRegistry;
use crate::units::{Energy, Time};

use super::tile::Tile;
use super::workload::GnnWorkload;

/// The aggregation core: a bank of identical MVM crossbars.
#[derive(Debug)]
pub struct AggregationCore {
    config: CoreConfig,
    xbar: MvmCrossbar,
    /// Shape of the resident window (`program_window`), if any.  The
    /// window *contents* are not duplicated — residency is tested
    /// against the crossbar array itself (`MvmCrossbar::tile_resident`).
    window: Option<(usize, usize)>,
    /// Scratch: packed row-activation mask (one bit per crossbar row).
    mask: Vec<u64>,
    /// High-water mark of possibly-nonzero `mask` words: `accumulate_into`
    /// packs/clears only this prefix instead of refilling the whole
    /// array-sized mask for every (usually much smaller) window.
    mask_live: usize,
    /// Always-on counters (`aggregation.programs` counts the RRAM cache
    /// misses the `programs()` accessor reports).
    metrics: MetricsRegistry,
}

impl AggregationCore {
    pub fn new(config: CoreConfig, device: DeviceParams) -> Result<AggregationCore> {
        config.validate()?;
        let mask_words = config.geometry.rows.div_ceil(64);
        Ok(AggregationCore {
            xbar: MvmCrossbar::new(config.geometry, device)?,
            config,
            window: None,
            mask: vec![0u64; mask_words],
            mask_live: 0,
            metrics: MetricsRegistry::new(),
        })
    }

    pub fn config(&self) -> &CoreConfig {
        &self.config
    }

    /// Evaluate passes needed for one node's aggregation under `w`:
    /// column groups to cover the bit-sliced features × frames × edge
    /// types × row windows to cover the contributing neighbors.
    pub fn passes_per_node(&self, w: &GnnWorkload) -> usize {
        let g = &self.config.geometry;
        let col_groups = w.feature_cells(g.cell_bits).div_ceil(g.cols).max(1);
        let row_windows = w.neighbors.div_ceil(g.rows).max(1);
        col_groups * row_windows * w.frames * w.edge_types
    }

    /// Per-node aggregation latency (t₂ of Eq. 2).
    pub fn per_node_latency(&self, w: &GnnWorkload) -> Time {
        self.xbar.pass_latency() * self.passes_per_node(w) as f64
    }

    /// Per-node aggregation dynamic energy.
    pub fn per_node_energy(&self, w: &GnnWorkload) -> Energy {
        self.xbar.pass_energy() * self.passes_per_node(w) as f64
    }

    /// Program `features` (quantized levels, one row per node, flat
    /// row-major [`Tile`]) as the stationary node window.  When the same
    /// window — shape *and* contents — is already resident, the RRAM
    /// write is skipped entirely: the program-once / evaluate-many path
    /// that lets repeated activation sweeps over one window run at
    /// evaluate cost only.
    pub fn program_window(&mut self, features: &Tile) -> Result<()> {
        let g = self.config.geometry;
        if features.rows() > g.rows {
            return Err(Error::Hardware(format!(
                "{} nodes exceed {} crossbar rows",
                features.rows(),
                g.rows
            )));
        }
        if features.cols() > g.cols {
            return Err(Error::Hardware(format!(
                "{} feature cells exceed {} columns",
                features.cols(),
                g.cols
            )));
        }
        let shape = (features.rows(), features.cols());
        // No shape gate is needed here (unlike the FE core): every read
        // goes through `accumulate_into`, which masks rows to the window
        // and clips columns to the window width, so cells outside the
        // compared block — stale or not — are never observed.
        if self.xbar.tile_resident(features.as_slice(), shape.0, shape.1) {
            self.window = Some(shape);
            return Ok(());
        }
        // On failure the array is untouched (`program_tile` validates
        // before writing), so the previous window — if any — stays valid.
        self.xbar.program_tile(features.as_slice(), shape.0, shape.1)?;
        self.metrics.inc("aggregation.programs", 1);
        self.window = Some(shape);
        Ok(())
    }

    /// Shape of the resident window, if one is programmed.
    pub fn window(&self) -> Option<(usize, usize)> {
        self.window
    }

    /// How often the crossbar was actually (re)programmed — cache misses
    /// of the program-once path.  Thin read of the
    /// `aggregation.programs` counter in [`Self::metrics`].
    pub fn programs(&self) -> u64 {
        self.metrics.counter_value("aggregation.programs")
    }

    /// The core's always-on metrics registry.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Accumulate the resident window's rows selected by `active` into
    /// `out` (`active.len()` = window rows, `out.len()` = window columns).
    /// Zero-alloc: the activation vector is packed into a reusable u64
    /// mask and the crossbar sums the selected rows in one plane.
    pub fn accumulate_into(&mut self, active: &[bool], out: &mut [i64]) -> Result<()> {
        let (rows, cols) = self
            .window
            .ok_or_else(|| Error::Hardware("no window programmed".into()))?;
        if active.len() != rows {
            return Err(Error::Hardware("activation vector length mismatch".into()));
        }
        if out.len() != cols {
            return Err(Error::Hardware(format!(
                "output arity {} != window columns {cols}",
                out.len()
            )));
        }
        // Pack word-at-a-time over exactly the window's rows.  Every
        // packed word is fully assigned (never OR-ed), so only whole
        // words beyond this window's coverage can carry stale bits —
        // clear those up to the previous high-water mark and leave the
        // (array-sized) tail alone: all rows past the window are
        // always-false and their words were never touched.
        let words = rows.div_ceil(64);
        for w in self.mask[words..self.mask_live.max(words)].iter_mut() {
            *w = 0;
        }
        for (w, chunk) in active.chunks(64).enumerate() {
            let mut bits = 0u64;
            for (i, &a) in chunk.iter().enumerate() {
                bits |= (a as u64) << i;
            }
            self.mask[w] = bits;
        }
        self.mask_live = words;
        self.xbar.accumulate_rows(&self.mask, out)
    }

    /// Functional aggregation of one column group into the caller's
    /// buffer: program `features` (cache-aware) and accumulate the rows
    /// selected by `active` (the scheduler's row-activation vector).
    ///
    /// Produces per-column sums — exactly `Σ_{active r} features[r][c]`,
    /// which is what a 1-bit input pass of the crossbar computes.
    pub fn aggregate_into(
        &mut self,
        features: &Tile,
        active: &[bool],
        out: &mut [i64],
    ) -> Result<()> {
        // Validate the full call before touching the array: a rejected
        // activation vector must not replace the resident window.
        if active.len() != features.rows() {
            return Err(Error::Hardware("activation vector length mismatch".into()));
        }
        if out.len() != features.cols() {
            return Err(Error::Hardware(format!(
                "output arity {} != window columns {}",
                out.len(),
                features.cols()
            )));
        }
        self.program_window(features)?;
        self.accumulate_into(active, out)
    }

    /// Allocating convenience wrapper over [`Self::aggregate_into`].
    pub fn aggregate(&mut self, features: &Tile, active: &[bool]) -> Result<Vec<i64>> {
        let mut out = vec![0i64; features.cols()];
        self.aggregate_into(features, active, &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::testing::{forall, Rng};

    fn core() -> AggregationCore {
        let cfg = presets::decentralized();
        AggregationCore::new(cfg.aggregation, cfg.device).unwrap()
    }

    #[test]
    fn taxi_passes_match_calibration() {
        // 4 column groups × 12 frames × 3 edge types = 144 passes.
        assert_eq!(core().passes_per_node(&GnnWorkload::taxi()), 144);
    }

    #[test]
    fn taxi_latency_is_table1_t2() {
        let t = core().per_node_latency(&GnnWorkload::taxi());
        crate::testing::assert_close(t.as_us(), 14.27, 0.001);
    }

    #[test]
    fn taxi_power_is_table1() {
        let c = core();
        let w = GnnWorkload::taxi();
        let p = c.per_node_energy(&w) / c.per_node_latency(&w);
        crate::testing::assert_close(p.as_mw(), 41.6, 0.001);
    }

    #[test]
    fn more_neighbors_than_rows_need_more_windows() {
        let c = core();
        let mut w = GnnWorkload::gcn("x", 16, 10);
        let base = c.passes_per_node(&w);
        w.neighbors = 1000; // > 512 rows → 2 windows
        assert_eq!(c.passes_per_node(&w), base * 2);
    }

    #[test]
    fn functional_aggregate_sums_active_rows() {
        let mut c = core();
        let features =
            Tile::from_rows(&[vec![1, 2, 3], vec![4, 5, 6], vec![7, 7, 7]]).unwrap();
        let out = c.aggregate(&features, &[true, false, true]).unwrap();
        assert_eq!(out, vec![8, 9, 10]);
        // nothing active → zeros
        let out = c.aggregate(&features, &[false, false, false]).unwrap();
        assert_eq!(out, vec![0, 0, 0]);
    }

    #[test]
    fn property_aggregate_equals_masked_sum() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(32) + 1;
            let f = rng.index(24) + 1;
            let features = Tile::from_fn(n, f, |_, _| rng.i64_in(-8, 7) as i32);
            let active: Vec<bool> = (0..n).map(|_| rng.bool()).collect();
            let mut c = core();
            let got = c.aggregate(&features, &active).unwrap();
            for col in 0..f {
                let want: i64 = features
                    .iter_rows()
                    .zip(&active)
                    .filter(|(_, a)| **a)
                    .map(|(row, _)| row[col] as i64)
                    .sum();
                assert_eq!(got[col], want);
            }
        });
    }

    #[test]
    fn unchanged_windows_program_once() {
        let mut c = core();
        let features = Tile::from_rows(&[vec![1, 2], vec![3, 4]]).unwrap();
        let mut out = vec![0i64; 2];
        assert_eq!(c.programs(), 0);
        assert!(c.window().is_none());
        c.aggregate_into(&features, &[true, true], &mut out).unwrap();
        assert_eq!(out, vec![4, 6]);
        assert_eq!(c.programs(), 1);
        assert_eq!(c.window(), Some((2, 2)));
        // Same window, many activation sweeps: no reprogramming.
        for _ in 0..5 {
            c.aggregate_into(&features, &[true, false], &mut out).unwrap();
            assert_eq!(out, vec![1, 2]);
        }
        assert_eq!(c.programs(), 1);
        // A rejected activation vector must not disturb the residency...
        let different = Tile::from_rows(&[vec![9, 9], vec![9, 9]]).unwrap();
        assert!(c.aggregate_into(&different, &[true], &mut out).is_err()); // arity
        assert_eq!(c.programs(), 1, "failed call must not reprogram");
        c.aggregate_into(&features, &[false, true], &mut out).unwrap();
        assert_eq!(out, vec![3, 4], "original window still resident");
        assert_eq!(c.programs(), 1);
        // A changed cell forces a rewrite...
        let mut other = features.clone();
        other.set(0, 0, -5);
        c.aggregate_into(&other, &[true, false], &mut out).unwrap();
        assert_eq!(out, vec![-5, 2]);
        assert_eq!(c.programs(), 2);
        // ... as does a changed shape with identical contents.
        let wide = Tile::from_flat(1, 4, vec![-5, 2, 3, 4]).unwrap();
        let mut out4 = vec![0i64; 4];
        c.aggregate_into(&wide, &[true], &mut out4).unwrap();
        assert_eq!(out4, vec![-5, 2, 3, 4]);
        assert_eq!(c.programs(), 3);
    }

    /// The word-at-a-time repack covers the ragged tail word (window
    /// rows % 64 ≠ 0) exactly: activations in the partial last chunk
    /// land, bits beyond it stay clear.
    #[test]
    fn ragged_tail_word_packs_exactly() {
        let mut c = core();
        // 70 rows: word 0 full, word 1 a 6-bit tail.
        let features = Tile::from_fn(70, 3, |r, col| ((r + col) % 15) as i32 - 7);
        let mut active = vec![false; 70];
        for r in 60..70 {
            active[r] = true; // straddles the word boundary
        }
        let mut out = vec![0i64; 3];
        c.aggregate_into(&features, &active, &mut out).unwrap();
        for col in 0..3 {
            let want: i64 = (60..70).map(|r| ((r + col) % 15) as i64 - 7).sum();
            assert_eq!(out[col], want, "col {col}");
        }
        assert_eq!(c.mask[0], !0u64 << 60);
        assert_eq!(c.mask[1], 0b11_1111);
        assert!(c.mask[2..].iter().all(|&w| w == 0), "rows past the window stay clear");
    }

    /// Shrinking the window must clear the larger window's stale mask
    /// words beyond the new coverage (the high-water mark) — a stale set
    /// bit would select array rows outside the window on every later
    /// sweep.
    #[test]
    fn window_shrink_clears_stale_high_words() {
        let mut c = core();
        let big = Tile::from_fn(130, 2, |_, _| 1); // 3 mask words
        let mut out = vec![0i64; 2];
        c.aggregate_into(&big, &vec![true; 130], &mut out).unwrap();
        assert_eq!(out, vec![130, 130]);
        assert_eq!(c.mask_live, 3);
        assert_eq!(c.mask[2], 0b11); // rows 128..130
        let small = Tile::from_fn(2, 2, |_, _| 5); // 1 mask word
        c.aggregate_into(&small, &[true, true], &mut out).unwrap();
        assert_eq!(out, vec![10, 10]);
        assert_eq!(c.mask_live, 1);
        assert!(c.mask[1..].iter().all(|&w| w == 0), "stale high words must be cleared");
        // Growing again repacks cleanly on top of the shrunk state.
        c.aggregate_into(&big, &vec![true; 130], &mut out).unwrap();
        assert_eq!(out, vec![130, 130]);
    }

    #[test]
    fn rejects_invalid_windows() {
        let mut c = core();
        let too_many = Tile::zeros(513, 1);
        assert!(c.aggregate(&too_many, &vec![true; 513]).is_err());
        let one = Tile::zeros(1, 3);
        assert!(c.aggregate(&one, &[true, false]).is_err()); // arity
        assert!(Tile::from_rows(&[vec![0; 3], vec![0; 2]]).is_err()); // ragged
        // No window resident yet → accumulate has nothing to sweep.
        let mut fresh = core();
        assert!(fresh.accumulate_into(&[true], &mut [0i64; 1]).is_err());
        // Output arity must match the window's columns.
        c.program_window(&one).unwrap();
        assert!(c.accumulate_into(&[true], &mut [0i64; 2]).is_err());
    }
}
