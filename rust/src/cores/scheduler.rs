//! Vector generator & scheduler (paper Fig. 2(a), step 2).
//!
//! Sits between the traversal core and the aggregation core: receives the
//! scan-CAM result (source nodes with edges into the destination) and
//! renders the binary row-activation vectors for the aggregation crossbar,
//! window by window under the node-stationary placement.
//!
//! DESIGN.md: §3 (architecture level).

use crate::error::{Error, Result};

/// Maps graph nodes to aggregation-crossbar rows within windows of
/// `rows` nodes and renders activation vectors.
#[derive(Debug, Clone)]
pub struct VectorScheduler {
    /// Crossbar row count (window size).
    rows: usize,
}

impl VectorScheduler {
    pub fn new(rows: usize) -> Result<VectorScheduler> {
        if rows == 0 {
            return Err(Error::Hardware("scheduler window must be > 0".into()));
        }
        Ok(VectorScheduler { rows })
    }

    /// Window index holding `node` under node-stationary placement.
    pub fn window_of(&self, node: usize) -> usize {
        node / self.rows
    }

    /// Row within its window.
    pub fn row_of(&self, node: usize) -> usize {
        node % self.rows
    }

    /// Number of windows needed for `num_nodes` nodes.
    pub fn num_windows(&self, num_nodes: usize) -> usize {
        num_nodes.div_ceil(self.rows).max(1)
    }

    /// Render the per-window activation vectors for a set of source nodes
    /// (the traversal core's output).  Returns `(window, activation)`
    /// pairs for the windows that have at least one active row — the
    /// schedule skips all-zero windows.
    pub fn activation_vectors(&self, sources: &[usize]) -> Vec<(usize, Vec<bool>)> {
        if sources.is_empty() {
            return Vec::new();
        }
        let max_window = sources.iter().map(|&s| self.window_of(s)).max().unwrap();
        let mut vecs: Vec<Option<Vec<bool>>> = vec![None; max_window + 1];
        for &s in sources {
            let w = self.window_of(s);
            let v = vecs[w].get_or_insert_with(|| vec![false; self.rows]);
            v[self.row_of(s)] = true;
        }
        vecs.into_iter()
            .enumerate()
            .filter_map(|(w, v)| v.map(|v| (w, v)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn placement_is_contiguous() {
        let s = VectorScheduler::new(4).unwrap();
        assert_eq!(s.window_of(0), 0);
        assert_eq!(s.window_of(3), 0);
        assert_eq!(s.window_of(4), 1);
        assert_eq!(s.row_of(5), 1);
        assert_eq!(s.num_windows(9), 3);
        assert_eq!(s.num_windows(0), 1);
    }

    #[test]
    fn activation_vectors_mark_exactly_the_sources() {
        let s = VectorScheduler::new(4).unwrap();
        let av = s.activation_vectors(&[1, 6, 2, 6]);
        assert_eq!(av.len(), 2);
        assert_eq!(av[0], (0, vec![false, true, true, false]));
        assert_eq!(av[1], (1, vec![false, false, true, false]));
    }

    #[test]
    fn empty_sources_render_nothing() {
        let s = VectorScheduler::new(8).unwrap();
        assert!(s.activation_vectors(&[]).is_empty());
    }

    #[test]
    fn all_zero_windows_are_skipped() {
        let s = VectorScheduler::new(2).unwrap();
        let av = s.activation_vectors(&[0, 9]);
        let windows: Vec<usize> = av.iter().map(|(w, _)| *w).collect();
        assert_eq!(windows, vec![0, 4]);
    }

    #[test]
    fn property_roundtrip_recovers_sources() {
        forall(32, |rng: &mut Rng| {
            let rows = rng.index(16) + 1;
            let s = VectorScheduler::new(rows).unwrap();
            let n = rng.index(40);
            let mut sources: Vec<usize> = (0..n).map(|_| rng.index(200)).collect();
            let av = s.activation_vectors(&sources);
            // reconstruct
            let mut got: Vec<usize> = av
                .iter()
                .flat_map(|(w, v)| {
                    v.iter()
                        .enumerate()
                        .filter(|(_, a)| **a)
                        .map(move |(r, _)| w * rows + r)
                })
                .collect();
            got.sort_unstable();
            sources.sort_unstable();
            sources.dedup();
            assert_eq!(got, sources);
        });
    }

    #[test]
    fn zero_rows_rejected() {
        assert!(VectorScheduler::new(0).is_err());
    }
}
