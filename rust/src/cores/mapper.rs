//! Weight/feature mapper: places a logical matrix onto a bank of physical
//! crossbars and derives the execution schedule.
//!
//! The paper's cores are *banks* (2K / 1K / 256 crossbars); turning a GNN
//! layer into crossbar passes requires deciding which tile of the weight
//! (or feature) matrix lives in which crossbar and which tiles execute in
//! parallel.  This is the PUMA-style compilation step the latency model's
//! `passes_per_node` abstracts; the mapper makes it explicit, checkable
//! and reusable by the scaling study.
//!
//! DESIGN.md: §3 (architecture level).

use crate::config::CrossbarGeometry;
use crate::error::{Error, Result};
use crate::units::Time;

/// One tile of the logical matrix placed on a physical crossbar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileAssignment {
    /// Physical crossbar index within the bank.
    pub crossbar: usize,
    /// Execution round (tiles in the same round run in parallel).
    pub round: usize,
    /// Logical origin of the tile.
    pub row0: usize,
    pub col0: usize,
    /// Tile extent (≤ geometry).
    pub rows: usize,
    pub cols: usize,
}

/// A complete placement + schedule.
#[derive(Debug, Clone)]
pub struct MappingPlan {
    pub geometry: CrossbarGeometry,
    pub tiles: Vec<TileAssignment>,
    /// Crossbars actually used (≤ bank size).
    pub crossbars_used: usize,
    /// Sequential rounds needed (1 = fully parallel).
    pub rounds: usize,
    /// Logical matrix extent.
    pub rows: usize,
    pub cols: usize,
}

impl MappingPlan {
    /// Fraction of programmed cells that hold real data.
    pub fn utilization(&self) -> f64 {
        let used: usize = self.tiles.iter().map(|t| t.rows * t.cols).sum();
        let programmed = self.tiles.len() * self.geometry.cells();
        used as f64 / programmed as f64
    }

    /// Schedule latency: rounds × bit-serial pass stack on one crossbar.
    pub fn latency(&self, pass_latency: Time, input_bits: u32) -> Time {
        pass_latency * (self.rounds as f64) * input_bits as f64
    }

    /// Every logical cell is covered by exactly one tile.
    pub fn validate(&self) -> Result<()> {
        let mut covered = vec![false; self.rows * self.cols];
        for t in &self.tiles {
            if t.rows > self.geometry.rows || t.cols > self.geometry.cols {
                return Err(Error::Hardware("tile exceeds crossbar geometry".into()));
            }
            for r in t.row0..t.row0 + t.rows {
                for c in t.col0..t.col0 + t.cols {
                    if r >= self.rows || c >= self.cols {
                        return Err(Error::Hardware(format!(
                            "tile spills outside the matrix at ({r}, {c})"
                        )));
                    }
                    let idx = r * self.cols + c;
                    if covered[idx] {
                        return Err(Error::Hardware(format!("cell ({r}, {c}) covered twice")));
                    }
                    covered[idx] = true;
                }
            }
        }
        if !covered.iter().all(|&c| c) {
            return Err(Error::Hardware("uncovered cells in mapping".into()));
        }
        Ok(())
    }
}

/// Map a logical `rows × cols` matrix onto a bank of `bank` crossbars.
///
/// Tiles are cut geometry-sized, assigned round-robin across the bank;
/// tile `i` runs in round `i / bank` — the greedy schedule that both
/// maximizes parallelism and matches the scaling study's saturation point
/// (no gain once `bank >= tiles`).
pub fn map_matrix(
    rows: usize,
    cols: usize,
    geometry: CrossbarGeometry,
    bank: usize,
) -> Result<MappingPlan> {
    geometry.validate()?;
    if rows == 0 || cols == 0 {
        return Err(Error::Hardware("cannot map an empty matrix".into()));
    }
    if bank == 0 {
        return Err(Error::Hardware("bank needs at least one crossbar".into()));
    }
    let mut tiles = Vec::new();
    let mut i = 0usize;
    for row0 in (0..rows).step_by(geometry.rows) {
        for col0 in (0..cols).step_by(geometry.cols) {
            tiles.push(TileAssignment {
                crossbar: i % bank,
                round: i / bank,
                row0,
                col0,
                rows: geometry.rows.min(rows - row0),
                cols: geometry.cols.min(cols - col0),
            });
            i += 1;
        }
    }
    let crossbars_used = tiles.len().min(bank);
    let rounds = tiles.len().div_ceil(bank);
    let plan = MappingPlan { geometry, tiles, crossbars_used, rounds, rows, cols };
    plan.validate()?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;
    use crate::crossbar::MvmCrossbar;
    use crate::testing::{forall, Rng};

    fn geo(r: usize, c: usize) -> CrossbarGeometry {
        CrossbarGeometry::new(r, c)
    }

    #[test]
    fn exact_fit_uses_one_tile() {
        let p = map_matrix(512, 512, geo(512, 512), 4).unwrap();
        assert_eq!(p.tiles.len(), 1);
        assert_eq!(p.rounds, 1);
        assert_eq!(p.crossbars_used, 1);
        assert!((p.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn taxi_features_need_four_tiles() {
        // 1728 feature cells over 512-column crossbars (10 neighbor rows).
        let p = map_matrix(10, 1728, geo(512, 512), 1).unwrap();
        assert_eq!(p.tiles.len(), 4);
        assert_eq!(p.rounds, 4); // one crossbar → sequential
        let p4 = map_matrix(10, 1728, geo(512, 512), 4).unwrap();
        assert_eq!(p4.rounds, 1); // four crossbars → parallel
        let p8 = map_matrix(10, 1728, geo(512, 512), 8).unwrap();
        assert_eq!(p8.rounds, 1, "saturation: extra crossbars don't help");
        assert_eq!(p8.crossbars_used, 4);
    }

    #[test]
    fn schedule_latency_follows_rounds() {
        let xbar = MvmCrossbar::new(geo(512, 512), DeviceParams::default_45nm()).unwrap();
        let seq = map_matrix(10, 1728, geo(512, 512), 1).unwrap();
        let par = map_matrix(10, 1728, geo(512, 512), 4).unwrap();
        let t_seq = seq.latency(xbar.pass_latency(), 1);
        let t_par = par.latency(xbar.pass_latency(), 1);
        crate::testing::assert_close(t_seq / t_par, 4.0, 1e-12);
    }

    #[test]
    fn ragged_edges_lower_utilization() {
        let p = map_matrix(513, 513, geo(512, 512), 8).unwrap();
        assert_eq!(p.tiles.len(), 4);
        assert!(p.utilization() < 0.3, "corner tiles are nearly empty");
        p.validate().unwrap();
    }

    #[test]
    fn property_full_single_coverage() {
        forall(32, |rng: &mut Rng| {
            let rows = rng.index(300) + 1;
            let cols = rng.index(300) + 1;
            let g = geo(rng.index(96) + 8, rng.index(96) + 8);
            let bank = rng.index(8) + 1;
            let p = map_matrix(rows, cols, g, bank).unwrap();
            p.validate().unwrap(); // exact single coverage
            assert!(p.crossbars_used <= bank);
            assert_eq!(
                p.rounds,
                p.tiles.len().div_ceil(bank),
                "greedy round-robin schedule"
            );
            // every round except the last is full
            for round in 0..p.rounds.saturating_sub(1) {
                let in_round = p.tiles.iter().filter(|t| t.round == round).count();
                assert_eq!(in_round, bank.min(p.tiles.len()));
            }
        });
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(map_matrix(0, 5, geo(8, 8), 1).is_err());
        assert!(map_matrix(5, 5, geo(8, 8), 0).is_err());
    }
}
