//! The IMA-GNN accelerator: traversal + aggregation + feature-extraction
//! cores (paper Fig. 2(a)) and their per-node compute roll-up.
//!
//! `Accelerator::per_node(workload)` yields the t₁/t₂/t₃ latencies and
//! per-core energies that §3's network model composes into Eqs. (2)–(3);
//! with the paper presets and the taxi workload the values reproduce
//! Table 1 (see tests).
//!
//! DESIGN.md: §3 (architecture level).

mod aggregation;
mod feature;
mod mapper;
mod scheduler;
mod tile;
mod traversal;
mod workload;

pub use aggregation::AggregationCore;
pub use feature::FeatureExtractionCore;
pub use mapper::{map_matrix, MappingPlan, TileAssignment};
pub use scheduler::VectorScheduler;
pub use tile::{FeatureMatrix, Mat, Tile};
pub use traversal::TraversalCore;
pub use workload::GnnWorkload;

use crate::config::AcceleratorConfig;
use crate::error::Result;
use crate::units::{Energy, Power, Time};

/// Per-node compute figures for one workload on one accelerator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreBreakdown {
    /// Traversal latency t₁ / aggregation t₂ / feature extraction t₃.
    pub t1: Time,
    pub t2: Time,
    pub t3: Time,
    /// Per-core dynamic energies for one node.
    pub e1: Energy,
    pub e2: Energy,
    pub e3: Energy,
}

impl CoreBreakdown {
    /// Sequential per-node compute latency (Eq. 2, decentralized).
    pub fn total_latency(&self) -> Time {
        self.t1 + self.t2 + self.t3
    }

    /// Per-node compute latency with the paper's §2.3 overlap: the
    /// aggregation and feature-extraction cores work in parallel, so the
    /// slower of the two hides the faster (ablation knob, not Table 1).
    pub fn overlapped_latency(&self) -> Time {
        self.t1 + self.t2.max(self.t3)
    }

    pub fn total_energy(&self) -> Energy {
        self.e1 + self.e2 + self.e3
    }

    /// Average per-core powers while streaming nodes back to back.
    pub fn powers(&self) -> (Power, Power, Power) {
        (self.e1 / self.t1, self.e2 / self.t2, self.e3 / self.t3)
    }

    /// Net computation power — the sum of the three cores' average powers,
    /// which is how Table 1's "Computation (Net)" row composes
    /// (0.21 + 41.6 + 3.68 = 45.49 mW).
    pub fn net_power(&self) -> Power {
        let (p1, p2, p3) = self.powers();
        p1 + p2 + p3
    }
}

/// The assembled accelerator.
#[derive(Debug)]
pub struct Accelerator {
    config: AcceleratorConfig,
    pub traversal: TraversalCore,
    pub aggregation: AggregationCore,
    pub feature: FeatureExtractionCore,
}

impl Accelerator {
    pub fn new(config: AcceleratorConfig) -> Result<Accelerator> {
        config.validate()?;
        Ok(Accelerator {
            traversal: TraversalCore::new(config.traversal, config.device.clone())?,
            aggregation: AggregationCore::new(config.aggregation, config.device.clone())?,
            feature: FeatureExtractionCore::new(config.feature, config.device.clone())?,
            config,
        })
    }

    pub fn config(&self) -> &AcceleratorConfig {
        &self.config
    }

    /// Per-node compute breakdown for `workload`.
    pub fn per_node(&self, workload: &GnnWorkload) -> CoreBreakdown {
        CoreBreakdown {
            t1: self.traversal.per_node_latency(),
            t2: self.aggregation.per_node_latency(workload),
            t3: self.feature.per_node_latency(workload),
            e1: self.traversal.per_node_energy(),
            e2: self.aggregation.per_node_energy(workload),
            e3: self.feature.per_node_energy(workload),
        }
    }

    /// A scheduler matched to the aggregation crossbar's row count.
    pub fn scheduler(&self) -> VectorScheduler {
        VectorScheduler::new(self.config.aggregation.geometry.rows)
            .expect("validated geometry has rows > 0")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::testing::assert_close;

    /// E1 calibration: the decentralized column of Table 1.
    #[test]
    fn table1_decentralized_column() {
        let acc = Accelerator::new(presets::decentralized()).unwrap();
        let b = acc.per_node(&GnnWorkload::taxi());
        // Latencies: 7.68 ns / 14.27 µs / 0.37 µs, net 14.6 µs.
        assert_close(b.t1.as_ns(), 7.68, 0.005);
        assert_close(b.t2.as_us(), 14.27, 0.005);
        assert_close(b.t3.as_us(), 0.37, 0.005);
        assert_close(b.total_latency().as_us(), 14.65, 0.005);
        // Powers: 0.21 / 41.6 / 3.68 mW, net 45.49 mW.
        let (p1, p2, p3) = b.powers();
        assert_close(p1.as_mw(), 0.21, 0.005);
        assert_close(p2.as_mw(), 41.6, 0.005);
        assert_close(p3.as_mw(), 3.68, 0.005);
        assert_close(b.net_power().as_mw(), 45.49, 0.02);
    }

    #[test]
    fn per_node_figures_do_not_depend_on_bank_size() {
        // t₁/t₂/t₃ are single-crossbar figures; the centralized setting has
        // more crossbars but each works the same — Eq. 3 divides by Mᵢ at
        // the network level instead.
        let cent = Accelerator::new(presets::centralized()).unwrap();
        let dec = Accelerator::new(presets::decentralized()).unwrap();
        let w = GnnWorkload::taxi();
        assert_eq!(cent.per_node(&w).t2, dec.per_node(&w).t2);
        assert_eq!(cent.per_node(&w).t1, dec.per_node(&w).t1);
        assert_eq!(cent.per_node(&w).t3, dec.per_node(&w).t3);
    }

    #[test]
    fn overlap_hides_the_faster_core() {
        let acc = Accelerator::new(presets::decentralized()).unwrap();
        let b = acc.per_node(&GnnWorkload::taxi());
        assert!(b.overlapped_latency() < b.total_latency());
        assert_close(
            b.overlapped_latency().as_us(),
            (b.t1 + b.t2).as_us(), // t2 > t3 for taxi
            1e-9,
        );
    }

    #[test]
    fn aggregation_dominates_latency_and_power() {
        // Paper §4.2: "The aggregation core ... consumes most of the power
        // in both settings as well as the highest latency."
        let acc = Accelerator::new(presets::decentralized()).unwrap();
        let b = acc.per_node(&GnnWorkload::taxi());
        assert!(b.t2 > b.t1 && b.t2 > b.t3);
        let (p1, p2, p3) = b.powers();
        assert!(p2 > p1 && p2 > p3);
    }

    #[test]
    fn scheduler_window_matches_aggregation_rows() {
        let acc = Accelerator::new(presets::decentralized()).unwrap();
        assert_eq!(acc.scheduler().num_windows(513), 2);
    }
}
