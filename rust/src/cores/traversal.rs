//! Traversal core: resistive CAM crossbars walking the CSR graph
//! (paper §2.3 + Fig. 3).
//!
//! The *search CAM* stores the Column-Index (CI) array; querying it with a
//! destination node id fires the match-lines of the edge positions whose
//! edges point at that destination.  The *scan CAM* stores the Row-Pointer
//! (RP) array; comparing an edge position against it yields the source node
//! owning that edge.  Together: `incoming(dst) -> [src]`.
//!
//! DESIGN.md: §3 (architecture level).

use crate::config::{CoreConfig, DeviceParams};
use crate::crossbar::CamCrossbar;
use crate::error::{Error, Result};
use crate::graph::Csr;
use crate::units::{Energy, Time};

/// The traversal core: a bank of search + scan CAM pairs.
#[derive(Debug)]
pub struct TraversalCore {
    config: CoreConfig,
    search: CamCrossbar,
    scan: CamCrossbar,
    /// Row pointers mirrored digitally for result decoding.
    rp: Vec<u64>,
    loaded_edges: usize,
}

impl TraversalCore {
    pub fn new(config: CoreConfig, device: DeviceParams) -> Result<TraversalCore> {
        config.validate()?;
        Ok(TraversalCore {
            search: CamCrossbar::new(config.geometry, device.clone())?,
            scan: CamCrossbar::new(config.geometry, device)?,
            config,
            rp: Vec::new(),
            loaded_edges: 0,
        })
    }

    pub fn config(&self) -> &CoreConfig {
        &self.config
    }

    /// Load a CSR graph into the CAM pair (paper Fig. 3(b)->(c),(d)).
    ///
    /// The functional model holds one crossbar's worth of rows; graphs with
    /// more edges than CAM rows are processed in windows by the schedule —
    /// the timing model accounts for that via `lookups_per_node`.
    pub fn load_graph(&mut self, csr: &Csr) -> Result<()> {
        let rows = self.config.geometry.rows;
        if csr.num_edges() > rows {
            return Err(Error::Hardware(format!(
                "functional CAM holds {rows} edges, graph has {} (window the graph)",
                csr.num_edges()
            )));
        }
        if csr.num_nodes() > rows {
            return Err(Error::Hardware(format!(
                "functional scan CAM holds {rows} row pointers, graph has {} nodes",
                csr.num_nodes()
            )));
        }
        let ci: Vec<u64> = csr.column_indices().iter().map(|&c| c as u64).collect();
        self.search.load(&ci)?;
        self.rp = csr.row_pointers().iter().map(|&r| r as u64).collect();
        self.scan.load(&self.rp[..csr.num_nodes()])?;
        self.loaded_edges = csr.num_edges();
        Ok(())
    }

    /// Sources with an edge to `dst`: search CAM match + scan CAM compare.
    pub fn incoming(&self, dst: usize) -> Result<Vec<usize>> {
        if self.loaded_edges == 0 {
            return Err(Error::Hardware("traversal core: no graph loaded".into()));
        }
        let positions = self.search.search(dst as u64);
        let mut sources = Vec::with_capacity(positions.len());
        for pos in positions {
            let src = self
                .scan
                .scan_owner(pos as u64)
                .ok_or_else(|| Error::Hardware(format!("edge position {pos} has no owner")))?;
            sources.push(src);
        }
        Ok(sources)
    }

    /// Latency of one per-node traversal: one search + one scan op
    /// (the compare runs on all matched positions in parallel).
    pub fn per_node_latency(&self) -> Time {
        self.search.op_latency() + self.scan.op_latency()
    }

    /// Dynamic energy of one per-node traversal.
    pub fn per_node_energy(&self) -> Energy {
        self.search.op_energy() + self.scan.op_energy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::graph::Csr;
    use crate::testing::{forall, Rng};

    fn core() -> TraversalCore {
        let cfg = presets::decentralized();
        TraversalCore::new(cfg.traversal, cfg.device).unwrap()
    }

    /// The paper's Fig. 3 example adjacency (5 nodes).
    fn fig3_csr() -> Csr {
        // edges (src -> dst): 0->1, 0->3, 1->2, 2->0, 2->4, 3->2, 4->1
        Csr::from_edges(5, &[(0, 1), (0, 3), (1, 2), (2, 0), (2, 4), (3, 2), (4, 1)]).unwrap()
    }

    #[test]
    fn incoming_matches_adjacency() {
        let mut t = core();
        let g = fig3_csr();
        t.load_graph(&g).unwrap();
        let mut inc = t.incoming(2).unwrap();
        inc.sort_unstable();
        assert_eq!(inc, vec![1, 3]); // 1->2 and 3->2
        assert_eq!(t.incoming(0).unwrap(), vec![2]);
        assert!(t.incoming(9).unwrap().is_empty());
    }

    #[test]
    fn property_incoming_equals_reverse_adjacency() {
        forall(24, |rng: &mut Rng| {
            let n = rng.index(20) + 2;
            let mut edges = Vec::new();
            for src in 0..n {
                for _ in 0..rng.index(4) {
                    edges.push((src, rng.index(n)));
                }
            }
            edges.sort_unstable();
            edges.dedup();
            if edges.is_empty() || edges.len() > 512 {
                return;
            }
            let g = Csr::from_edges(n, &edges).unwrap();
            let mut t = core();
            t.load_graph(&g).unwrap();
            for dst in 0..n {
                let mut got = t.incoming(dst).unwrap();
                got.sort_unstable();
                let mut want: Vec<usize> =
                    edges.iter().filter(|(_, d)| *d == dst).map(|(s, _)| *s).collect();
                want.sort_unstable();
                assert_eq!(got, want, "dst={dst}");
            }
        });
    }

    #[test]
    fn latency_is_table1_t1() {
        // 2 CAM ops × 3.84 ns = 7.68 ns (Table 1, decentralized traversal).
        crate::testing::assert_close(core().per_node_latency().as_ns(), 7.68, 1e-9);
    }

    #[test]
    fn energy_gives_table1_power() {
        let t = core();
        let p = t.per_node_energy() / t.per_node_latency();
        crate::testing::assert_close(p.as_mw(), 0.21, 0.001);
    }

    #[test]
    fn rejects_oversized_graphs_and_unloaded_lookups() {
        let mut t = core();
        assert!(t.incoming(0).is_err(), "lookup before load must fail");
        let big: Vec<(usize, usize)> = (0..600).map(|i| (i % 300, (i + 1) % 300)).collect();
        let g = Csr::from_edges(300, &big).unwrap();
        assert!(t.load_graph(&g).is_err(), "600 edges exceed 512 CAM rows");
    }
}
