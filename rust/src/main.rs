//! `ima-gnn` — the IMA-GNN leader binary.
//!
//! Subcommands regenerate the paper's evaluation artifacts and drive the
//! serving stack:
//!
//! ```text
//! ima-gnn table1                  # E1: Table 1 (taxi case study)
//! ima-gnn table2                  # E2: dataset statistics
//! ima-gnn fig8                    # E3: Fig. 8 latency breakdown
//! ima-gnn scaling                 # E4: crossbar-count scaling study
//! ima-gnn simulate [options]      # DES over either deployment
//! ima-gnn traffic [options]       # E13: arrival-driven traffic engine
//! ima-gnn faults [options]        # E14: fault injection + recovery accounting
//! ima-gnn control [options]       # E15: closed-loop adaptive runtime control
//! ima-gnn tune [options]          # E11: hybrid operating-point autotuner
//! ima-gnn perf [options]          # E10: hot-kernel perf baseline
//! ima-gnn serve [options]         # serve a GCN layer over PJRT artifacts
//! ima-gnn resident [options]      # E16: million-node residency under a byte budget
//! ima-gnn trace [options]         # traced E13 round -> Perfetto timeline
//! ima-gnn info                    # artifact + platform info
//! ```
//!
//! DESIGN.md: §1 (layering); README.md maps subcommands to experiments.

use std::time::Duration;

use ima_gnn::autotune::{Autotuner, SettingKind, TunerConfig};
use ima_gnn::cli::Command;
use ima_gnn::coordinator::{
    CentralizedLeader, GcnLayerBinding, InferenceService, LatencyProvider, Request, RoundEngine,
};
use ima_gnn::cores::GnnWorkload;
use ima_gnn::error::{Error, Result};
use ima_gnn::experiments::{
    control_cell, control_setup, hybrid_target, scaling_sweep, table2, ControllerSweep,
    FaultSweep, Fig8, HybridSweep, NetsimSweep, ResidencySweep, ServingSweep, Table1,
    TrafficSweep, CTRL_SCENARIOS, FAULT_DEGRADED_FACTOR, RESIDENCY_BUDGET_SHARDS,
    TRAFFIC_MAX_BATCH, TRAFFIC_WAIT_MS,
};
use ima_gnn::graph::{generate, ShardPlan};
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::netsim::{simulate_fabric, simulate_fabric_observed, NetSimConfig, Scenario};
use ima_gnn::obs::{chrome_trace_json, MetricsRegistry, Obs, Tracer};
use ima_gnn::report::{speedup, Table};
use ima_gnn::runtime::{default_artifact_dir, Manifest};
use ima_gnn::sim::{simulate, CrashImpact, FaultConfig, FaultPlan, Outage, SimConfig};
use ima_gnn::testing::{gcn_layer_binding, Rng};
use ima_gnn::traffic::{
    closed_loop, deployment_shape, md1_mean_wait, open_loop, open_loop_controlled,
    open_loop_faulted, open_loop_observed, ArrivalProcess, BatchPolicy, ClosedLoopConfig,
    ThinkTime, TrafficReport,
};
use ima_gnn::units::Time;
use ima_gnn::workload::DiurnalCurve;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let sub = argv.first().map(String::as_str).unwrap_or("help");
    let rest = if argv.is_empty() { &[] } else { &argv[1..] };
    match sub {
        "table1" => cmd_table1(rest),
        "table2" => cmd_table2(rest),
        "fig8" => cmd_fig8(rest),
        "scaling" => cmd_scaling(rest),
        "simulate" => cmd_simulate(rest),
        "netsim" => cmd_netsim(rest),
        "traffic" => cmd_traffic(rest),
        "faults" => cmd_faults(rest),
        "control" => cmd_control(rest),
        "tune" => cmd_tune(rest),
        "perf" => cmd_perf(rest),
        "serve" => cmd_serve(rest),
        "resident" => cmd_resident(rest),
        "trace" => cmd_trace(rest),
        "area" => cmd_area(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown subcommand `{other}`; try `ima-gnn help`"))),
    }
}

/// `<path minus .json>.metrics.json` — the metrics-snapshot sidecar
/// written next to every `BENCH_*.json` artifact.
fn metrics_sidecar_path(path: &str) -> String {
    match path.strip_suffix(".json") {
        Some(stem) => format!("{stem}.metrics.json"),
        None => format!("{path}.metrics.json"),
    }
}

fn write_metrics_sidecar(path: &str, metrics: &MetricsRegistry) -> Result<String> {
    let sidecar = metrics_sidecar_path(path);
    std::fs::write(&sidecar, metrics.to_json())?;
    Ok(sidecar)
}

fn print_help() {
    println!(
        "ima-gnn — In-Memory Acceleration of Centralized and Decentralized GNNs at the Edge\n\n\
         subcommands:\n  \
         table1     reproduce Table 1 (taxi case study latency/power)\n  \
         table2     dataset statistics (Table 2) + materialized check\n  \
         fig8       latency breakdown per dataset and setting (Fig. 8)\n  \
         scaling    crossbar-count scaling study (§4.3)\n  \
         simulate   discrete-event simulation of either deployment\n  \
         netsim     packet-level contention-aware fabric simulation (E9)\n  \
         traffic    arrival-driven traffic engine: queueing + dynamic batching + SLO\n             \
         accounting per deployment shape; --sweep emits BENCH_traffic.json (E13)\n  \
         faults     fault injection: crash windows, downtime + MTTR accounting and\n             \
         span reconciliation; --sweep emits BENCH_faults.json (E14)\n  \
         control    closed-loop adaptive runtime control over the capacity ladder\n             \
         with priced switches; --sweep emits BENCH_controller.json (E15)\n  \
         tune       hybrid operating-point autotuner, emits BENCH_hybrid.json (E11)\n  \
         perf       hot-kernel perf baseline, emits BENCH_perf.fresh.json; --check\n             gates against the committed BENCH_perf.json floors (E10)\n  \
         serve      serve GCN-layer inference over the PJRT artifacts; --sweep runs\n             \
         the E12 sharded-serving sweep, emits BENCH_serving.json\n  \
         resident   million-node residency: compact CSR + byte-budgeted shard\n             \
         streaming; --sweep emits BENCH_residency.json (E16)\n  \
         trace      traced E13 round across the three deployment settings; exports a\n             \
         Perfetto-loadable Chrome trace-event timeline + a metrics snapshot\n  \
         area       silicon-area report for both accelerator presets\n  \
         info       artifact manifest + platform info\n  \
         help       this message"
    );
}

fn cmd_table1(argv: &[String]) -> Result<()> {
    let cmd = Command::new("table1", "reproduce Table 1")
        .opt("nodes", "edge devices N", Some("10000"))
        .opt("cluster", "cluster size cs", Some("10"))
        .opt("csv", "also write the table as CSV to this path", None);
    let args = cmd.parse(argv)?;
    let mut t1 = Table1::new()?;
    t1.topo = Topology {
        nodes: args.usize_or("nodes", 10_000)?,
        cluster_size: args.usize_or("cluster", 10)?,
    };
    let table = t1.render();
    table.print();
    if let Some(path) = args.get("csv") {
        table.write_csv(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    if t1.topo.nodes == 10_000 && t1.topo.cluster_size == 10 {
        println!("max relative error vs paper: {:.2}%", t1.max_relative_error() * 100.0);
    }
    Ok(())
}

fn cmd_table2(argv: &[String]) -> Result<()> {
    let cmd = Command::new("table2", "dataset statistics")
        .opt("cap", "max materialized nodes per dataset", Some("20000"))
        .opt("csv", "also write the table as CSV to this path", None);
    let args = cmd.parse(argv)?;
    let table = table2(args.usize_or("cap", 20_000)?)?;
    table.print();
    if let Some(path) = args.get("csv") {
        table.write_csv(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_fig8(argv: &[String]) -> Result<()> {
    Command::new("fig8", "Fig. 8 latency breakdown").parse(argv)?;
    let f = Fig8::new()?;
    f.render().print();
    println!("\n{}", f.summary());
    Ok(())
}

fn cmd_scaling(argv: &[String]) -> Result<()> {
    Command::new("scaling", "crossbar scaling study").parse(argv)?;
    let rows = scaling_sweep(&GnnWorkload::taxi())?;
    let mut t = Table::new(
        "§4.3 scaling — decentralized per-node figures vs crossbars per core",
        &["Crossbars/core", "Per-node latency", "Per-node power", "Speedup vs 1"],
    );
    let base = rows[0].1;
    for (k, lat, mw) in &rows {
        t.row(&[
            k.to_string(),
            lat.to_string(),
            format!("{mw:.2} mW"),
            speedup(base / *lat),
        ]);
    }
    t.print();
    println!(
        "performance increases ~linearly with crossbar count and saturates once the\n\
         node feature data fits onto the crossbars, at the cost of per-node power (§4.3)."
    );
    Ok(())
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("simulate", "discrete-event simulation")
        .opt("setting", "centralized | decentralized", Some("decentralized"))
        .opt("nodes", "edge devices", Some("1000"))
        .opt("cluster", "cluster size", Some("10"))
        .opt("jitter", "link jitter fraction", Some("0"))
        .opt("seed", "rng seed", Some("1"))
        .flag("shared-medium", "serialize intra-cluster radio (CSMA)")
        .flag("overlap", "overlap aggregation and feature extraction");
    let args = cmd.parse(argv)?;
    let setting = match args.get_or("setting", "decentralized") {
        "centralized" => Setting::Centralized,
        "decentralized" => Setting::Decentralized,
        other => return Err(Error::Usage(format!("unknown setting `{other}`"))),
    };
    let topo = Topology {
        nodes: args.usize_or("nodes", 1000)?,
        cluster_size: args.usize_or("cluster", 10)?,
    };
    let cfg = SimConfig {
        link_jitter: args.f64_or("jitter", 0.0)?,
        shared_medium: args.flag("shared-medium"),
        overlap_cores: args.flag("overlap"),
        seed: args.usize_or("seed", 1)? as u64,
    };
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let report = simulate(&model, setting, topo, &cfg)?;
    let analytic = model.latency(setting, topo);
    let mut t = Table::new(
        format!("DES — {setting:?}, N={}, cs={}", topo.nodes, topo.cluster_size),
        &["Metric", "Simulated", "Analytical (Eqs. 1-5)"],
    );
    t.row(&[
        "completion".into(),
        report.completion.to_string(),
        analytic.total().to_string(),
    ]);
    t.row(&[
        "communication done".into(),
        report.comm_done.to_string(),
        analytic.communicate.to_string(),
    ]);
    t.row(&["events".into(), report.events.to_string(), "-".into()]);
    t.row(&[
        "leader utilization".into(),
        format!("{:.1}%", report.leader_utilization * 100.0),
        "-".into(),
    ]);
    t.print();
    Ok(())
}

fn cmd_netsim(argv: &[String]) -> Result<()> {
    let cmd = Command::new("netsim", "packet-level fabric simulation")
        .opt("topology", "centralized | decentralized | semi", Some("centralized"))
        .opt("nodes", "edge devices", Some("1000"))
        .opt("cluster", "cluster size cs", Some("10"))
        .opt("head-capacity", "cluster-head capacity multiple (semi)", Some("10"))
        .opt("rx-ports", "receive ports at the leader/heads (0 = unlimited)", Some("0"))
        .opt("channels", "simultaneous intra-cluster transfers (0 = dedicated)", Some("0"))
        .opt("hops", "store-and-forward relay hops per cluster exchange", Some("1"))
        .opt("jitter", "per-packet link jitter fraction", Some("0"))
        .opt("seed", "rng seed", Some("1"))
        .opt("json", "sweep artifact path", Some("BENCH_netsim.json"))
        .flag("sweep", "run the cluster-count x graph-scale sweep (E9)")
        .flag("overlap", "overlap aggregation and feature extraction");
    let args = cmd.parse(argv)?;
    let opt = |v: usize| if v == 0 { None } else { Some(v) };
    let cfg = NetSimConfig {
        rx_ports: opt(args.usize_or("rx-ports", 0)?),
        cluster_channels: opt(args.usize_or("channels", 0)?),
        hops: args.usize_or("hops", 1)?.max(1),
        overlap_cores: args.flag("overlap"),
        link_jitter: args.f64_or("jitter", 0.0)?,
        seed: args.usize_or("seed", 1)? as u64,
    };

    if args.flag("sweep") {
        let sweep = NetsimSweep::paper_grid(&cfg)?;
        sweep.render().print();
        println!(
            "max simulated-vs-analytic gap: {:.3e} (0 under the paper's no-contention \
             assumptions)",
            sweep.max_rel_gap()
        );
        println!(
            "avg comm gap (dec/cent): {}; avg compute gap (cent/dec): {}",
            speedup(sweep.avg_comm_gap()),
            speedup(sweep.avg_compute_gap()),
        );
        match sweep.crossover() {
            Some(r) => println!(
                "semi-decentralized crossover: N={}, cs={} (hybrid beats both extremes)",
                r.nodes, r.cluster_size
            ),
            None => println!(
                "no semi-decentralized crossover on this grid (try --rx-ports to \
                 model a finite leader NIC)"
            ),
        }
        let path = args.get_or("json", "BENCH_netsim.json").to_string();
        std::fs::write(&path, sweep.to_json())?;
        let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
        println!("wrote {path} and {sidecar}");
        return Ok(());
    }

    let topo = Topology {
        nodes: args.usize_or("nodes", 1000)?,
        cluster_size: args.usize_or("cluster", 10)?,
    };
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let (scenario, analytic) = match args.get_or("topology", "centralized") {
        "centralized" => (
            Scenario::CentralizedStar,
            model.latency(Setting::Centralized, topo).total(),
        ),
        "decentralized" => (
            Scenario::DecentralizedMesh,
            model.latency(Setting::Decentralized, topo).total(),
        ),
        "semi" => {
            let head = args.f64_or("head-capacity", 10.0)?;
            (
                Scenario::SemiOverlay { head_capacity: head },
                model.semi_latency(topo, head).total(),
            )
        }
        other => return Err(Error::Usage(format!("unknown topology `{other}`"))),
    };
    let report = simulate_fabric(&model, scenario, topo, &cfg)?;
    let mut t = Table::new(
        format!("netsim — {scenario:?}, N={}, cs={}", topo.nodes, topo.cluster_size),
        &["Metric", "Simulated", "Analytical"],
    );
    t.row(&["completion".into(), report.completion.to_string(), analytic.to_string()]);
    t.row(&["communication done".into(), report.comm_done.to_string(), "-".into()]);
    t.row(&["messages".into(), report.messages.to_string(), "-".into()]);
    t.row(&["packets".into(), report.packets.to_string(), "-".into()]);
    t.row(&["events".into(), report.events.to_string(), "-".into()]);
    t.row(&[
        "contended packets".into(),
        format!("{} ({:.1}%)", report.contended_packets, report.contention_fraction() * 100.0),
        "-".into(),
    ]);
    t.row(&["total queue wait".into(), report.queue_wait.to_string(), "-".into()]);
    t.print();
    Ok(())
}

fn cmd_traffic(argv: &[String]) -> Result<()> {
    let cmd = Command::new("traffic", "arrival-driven traffic engine (E13)")
        .opt("dataset", "taxi | a Table 2 dataset (single-run mode)", Some("taxi"))
        .opt("setting", "centralized | semi | decentralized", Some("centralized"))
        .opt("rate", "offered system rate, requests/second", Some("5000"))
        .opt("requests", "target requests per run / sweep point", Some("2000"))
        .opt(
            "arrival",
            "poisson | diurnal | flash | closed (open-loop unless closed)",
            Some("poisson"),
        )
        .opt("policy", "immediate | size | deadline", Some("deadline"))
        .opt("batch", "max batch for size/deadline policies", Some("64"))
        .opt("wait-ms", "deadline policy coalescing wait (ms)", Some("2"))
        .opt("clients", "closed-loop fleet size", Some("64"))
        .opt("think-ms", "closed-loop mean think time (ms)", Some("50"))
        .opt("cap", "max materialized sample nodes (sweep)", Some("512"))
        .opt("seed", "rng seed", Some("1"))
        .opt("json", "sweep artifact path", Some("BENCH_traffic.json"))
        .flag("sweep", "run the E13 rate x setting x dataset sweep");
    let args = cmd.parse(argv)?;
    let requests = args.usize_or("requests", 2_000)?.max(1);

    if args.flag("sweep") {
        let sweep = TrafficSweep::run(args.usize_or("cap", 512)?, requests)?;
        sweep.render().print();
        println!("{}", sweep.summary());
        println!("max Little's-law gap: {:.3e} (round-off)", sweep.max_littles_gap());
        let path = args.get_or("json", "BENCH_traffic.json").to_string();
        std::fs::write(&path, sweep.to_json())?;
        let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
        println!("wrote {path} and {sidecar}");
        return Ok(());
    }

    // Single-run mode: one deployment shape under one arrival process.
    let dataset = args.get_or("dataset", "taxi").to_string();
    let (name, model, topo) = if dataset.eq_ignore_ascii_case("taxi") {
        (
            "Taxi".to_string(),
            NetModel::paper(&GnnWorkload::taxi())?,
            Topology::taxi(),
        )
    } else {
        let d = ima_gnn::graph::datasets::by_name(&dataset)?;
        (
            d.name.to_string(),
            NetModel::fig8(&d)?,
            Topology { nodes: d.nodes, cluster_size: d.avg_cs },
        )
    };
    let kind = match args.get_or("setting", "centralized") {
        "centralized" => SettingKind::Centralized,
        "semi" => SettingKind::Semi,
        "decentralized" => SettingKind::Decentralized,
        other => return Err(Error::Usage(format!("unknown setting `{other}`"))),
    };
    let setting = kind.name();
    let (queues, service) =
        deployment_shape(kind, LatencyProvider::Analytic, &model, topo)?;
    let policy = match args.get_or("policy", "deadline") {
        "immediate" => BatchPolicy::Immediate,
        "size" => BatchPolicy::Size { max: args.usize_or("batch", 64)?.max(1) },
        "deadline" => BatchPolicy::Deadline {
            max: args.usize_or("batch", 64)?.max(1),
            max_wait: Time::ms(args.f64_or("wait-ms", 2.0)?),
        },
        other => return Err(Error::Usage(format!("unknown policy `{other}`"))),
    };
    let seed = args.usize_or("seed", 1)? as u64;
    let rate = args.f64_or("rate", 5_000.0)?;
    let queue_rate = queues.per_queue_rate(rate);
    let arrival = args.get_or("arrival", "poisson").to_string();
    let report: TrafficReport = if arrival == "closed" {
        // A closed loop paces itself by fleet + think time; --rate
        // prices nothing here, so the horizon is sized for ~`requests`
        // client cycles instead of being derived from it.
        let fleet = args.usize_or("clients", 64)?.max(1);
        let think = Time::ms(args.f64_or("think-ms", 50.0)?);
        let cycle = think + service.service(1);
        let horizon = Time::s(requests as f64 * cycle.as_s() / fleet as f64);
        closed_loop(
            1,
            &service,
            policy,
            &ClosedLoopConfig {
                fleet,
                think: ThinkTime::Exponential { mean: think },
                horizon,
                nodes: topo.nodes,
                seed,
            },
        )?
    } else {
        if !(queue_rate > 0.0) {
            return Err(Error::Usage("--rate must be > 0 for open-loop arrivals".into()));
        }
        let horizon = Time::s(requests as f64 / queue_rate);
        let arrivals = match arrival.as_str() {
            "poisson" => ArrivalProcess::Poisson { rate: queue_rate }
                .generate(horizon, topo.nodes, seed)?,
            // One demand cycle over the run, ±80% swing.
            "diurnal" => ArrivalProcess::Diurnal(DiurnalCurve::new(queue_rate, 0.8, horizon)?)
                .generate(horizon, topo.nodes, seed)?,
            // 5x flash crowd over the middle fifth of the run.
            "flash" => ArrivalProcess::FlashCrowd {
                base: queue_rate,
                boost: 5.0,
                at: horizon * 0.4,
                width: horizon * 0.2,
            }
            .generate(horizon, topo.nodes, seed)?,
            other => {
                return Err(Error::Usage(format!("unknown arrival process `{other}`")))
            }
        };
        open_loop(1, &service, policy, &arrivals)?
    };

    let mut t = Table::new(
        format!(
            "traffic — {name} / {setting}: {} requests over 1 of {} queue(s) \
             (service {} + {}/req)",
            report.offered,
            queues.servers(),
            service.per_batch,
            service.per_request,
        ),
        &["Metric", "Value"],
    );
    t.row(&["offered rate (queue)".into(), format!("{queue_rate:.1} req/s")]);
    t.row(&["throughput".into(), format!("{:.1} req/s", report.throughput_per_s)]);
    t.row(&["utilization".into(), format!("{:.1}%", report.utilization * 100.0)]);
    t.row(&["mean wait".into(), report.mean_wait.to_string()]);
    t.row(&["mean response".into(), report.latency.mean().to_string()]);
    t.row(&["p50 / p95 / p99".into(), format!(
        "{} / {} / {}",
        report.latency.p50(),
        report.latency.p95(),
        report.latency.p99()
    )]);
    t.row(&["batches (mean size)".into(), format!(
        "{} ({:.1})",
        report.batches, report.mean_batch
    )]);
    t.row(&["max queue depth".into(), report.max_queue_depth.to_string()]);
    t.row(&["Little's-law gap".into(), format!("{:.3e}", report.littles_law_gap())]);
    t.print();
    if matches!(policy, BatchPolicy::Immediate) {
        if let Ok(w) = md1_mean_wait(queue_rate, service.service(1)) {
            println!(
                "M/D/1 Pollaczek-Khinchine mean wait at this point: {w} (simulated {})",
                report.mean_wait
            );
        }
    }
    Ok(())
}

fn cmd_faults(argv: &[String]) -> Result<()> {
    let cmd = Command::new("faults", "fault injection and recovery accounting (E14)")
        .opt("dataset", "taxi | a Table 2 dataset (single-run mode)", Some("taxi"))
        .opt("setting", "centralized | semi | decentralized", Some("semi"))
        .opt("rate", "offered system rate, requests/second", Some("5000"))
        .opt("requests", "target requests per run / sweep point", Some("2000"))
        .opt("crash-rate", "crash windows per second of queue time", Some("1"))
        .opt("outage-ms", "fixed outage per crash window (ms)", Some("10"))
        .opt("cap", "max materialized sample nodes (sweep)", Some("512"))
        .opt("seed", "rng seed", Some("1"))
        .opt("json", "sweep artifact path", Some("BENCH_faults.json"))
        .flag("degraded", "serve crash windows from halo replicas at degraded speed")
        .flag("sweep", "run the E14 scenario x rate x setting x dataset sweep");
    let args = cmd.parse(argv)?;
    let requests = args.usize_or("requests", 2_000)?.max(1);

    if args.flag("sweep") {
        let sweep = FaultSweep::run(args.usize_or("cap", 512)?, requests)?;
        sweep.render().print();
        println!("{}", sweep.summary());
        let path = args.get_or("json", "BENCH_faults.json").to_string();
        std::fs::write(&path, sweep.to_json())?;
        let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
        println!("wrote {path} and {sidecar}");
        return Ok(());
    }

    // Single-run mode: one representative queue under an injected crash
    // schedule, observability on, and the obs contract checked out loud
    // (`fault.crash` span durations must sum to the reported downtime).
    let dataset = args.get_or("dataset", "taxi").to_string();
    let (name, model, topo) = if dataset.eq_ignore_ascii_case("taxi") {
        ("Taxi".to_string(), NetModel::paper(&GnnWorkload::taxi())?, Topology::taxi())
    } else {
        let d = ima_gnn::graph::datasets::by_name(&dataset)?;
        (
            d.name.to_string(),
            NetModel::fig8(&d)?,
            Topology { nodes: d.nodes, cluster_size: d.avg_cs },
        )
    };
    let kind = match args.get_or("setting", "semi") {
        "centralized" => SettingKind::Centralized,
        "semi" => SettingKind::Semi,
        "decentralized" => SettingKind::Decentralized,
        other => return Err(Error::Usage(format!("unknown setting `{other}`"))),
    };
    let (queues, service) = deployment_shape(kind, LatencyProvider::Analytic, &model, topo)?;
    let policy = BatchPolicy::Deadline {
        max: TRAFFIC_MAX_BATCH,
        max_wait: Time::ms(TRAFFIC_WAIT_MS),
    };
    let seed = args.usize_or("seed", 1)? as u64;
    let queue_rate = queues.per_queue_rate(args.f64_or("rate", 5_000.0)?);
    if !(queue_rate > 0.0) {
        return Err(Error::Usage("--rate must be > 0".into()));
    }
    let horizon = Time::s(requests as f64 / queue_rate);
    let arrivals =
        ArrivalProcess::Poisson { rate: queue_rate }.generate(horizon, topo.nodes, seed)?;
    let impact = if args.flag("degraded") {
        CrashImpact::Degraded { factor: FAULT_DEGRADED_FACTOR }
    } else {
        CrashImpact::Outage
    };
    let cfg = FaultConfig::crashes(
        args.f64_or("crash-rate", 1.0)?,
        Outage::Fixed(Time::ms(args.f64_or("outage-ms", 10.0)?)),
        impact,
    );
    let plan = FaultPlan::generate(&cfg, 1, horizon, seed)?;
    let obs = Obs::new(16_384);
    let report = open_loop_faulted(1, &service, policy, &arrivals, &plan, &obs)?;

    let span_downtime: Time = obs
        .tracer
        .spans()
        .iter()
        .filter(|s| s.name == "fault.crash")
        .map(|s| s.end - s.start)
        .sum();
    let gap = (span_downtime - report.downtime).as_s().abs();

    let mut t = Table::new(
        format!(
            "faults — {name} / {}: {} requests, {} scheduled fault window(s)",
            kind.name(),
            report.offered,
            plan.events().len(),
        ),
        &["Metric", "Value"],
    );
    t.row(&["p50 / p95 / p99".into(), format!(
        "{} / {} / {}",
        report.latency.p50(),
        report.latency.p95(),
        report.latency.p99()
    )]);
    t.row(&["crash windows executed".into(), report.fault_windows.to_string()]);
    t.row(&["downtime".into(), report.downtime.to_string()]);
    t.row(&["availability".into(), format!("{:.4}%", report.availability * 100.0)]);
    t.row(&["MTTR".into(), report.mttr.to_string()]);
    t.row(&["planned outage total".into(), plan.total_outage().to_string()]);
    t.row(&["fault.crash span sum".into(), span_downtime.to_string()]);
    t.row(&["span/report gap".into(), format!("{gap:.3e} s")]);
    t.row(&["spans dropped (ring)".into(), report.dropped_spans.to_string()]);
    t.print();
    if gap > 1e-9 {
        return Err(Error::Sim(format!(
            "fault.crash spans do not reconcile with downtime (gap {gap:.3e} s)"
        )));
    }
    Ok(())
}

fn cmd_control(argv: &[String]) -> Result<()> {
    let cmd = Command::new("control", "closed-loop adaptive runtime control (E15)")
        .opt("dataset", "a Table 2 dataset (single-run mode)", Some("Cora"))
        .opt("scenario", "diurnal | flash | linkfault (single-run mode)", Some("diurnal"))
        .opt("requests", "target requests per run / sweep cell", Some("2000"))
        .opt("cap", "max materialized sample nodes", Some("512"))
        .opt("seed", "rng seed", Some("1"))
        .opt("json", "sweep artifact path", Some("BENCH_controller.json"))
        .flag("sweep", "run the E15 scenario x dataset sweep");
    let args = cmd.parse(argv)?;
    let requests = args.usize_or("requests", 2_000)?.max(1);
    let cap = args.usize_or("cap", 512)?;

    if args.flag("sweep") {
        let sweep = ControllerSweep::run(cap, requests)?;
        sweep.render().print();
        println!("{}", sweep.summary());
        let path = args.get_or("json", "BENCH_controller.json").to_string();
        std::fs::write(&path, sweep.to_json())?;
        let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
        println!("wrote {path} and {sidecar}");
        return Ok(());
    }

    // Single-run mode: one dataset's capacity ladder through one
    // scenario, observability on, the statics replayed on the same
    // arrivals for comparison, and the obs contract checked out loud
    // (`ctrl.switch` span durations must sum *bit-exactly* to the
    // controller's accrued switch downtime).
    let scenario = args.get_or("scenario", "diurnal").to_string();
    if !CTRL_SCENARIOS.contains(&scenario.as_str()) {
        return Err(Error::Usage(format!(
            "unknown scenario `{scenario}`; expected one of {CTRL_SCENARIOS:?}"
        )));
    }
    let d = ima_gnn::graph::datasets::by_name(args.get_or("dataset", "Cora"))?;
    let seed = args.usize_or("seed", 1)? as u64;
    let setup = control_setup(&d, cap)?;
    let cell = control_cell(&setup, &scenario, d.nodes, requests, seed)?;
    let obs = Obs::new(16_384);
    let cr = open_loop_controlled(&cell.controller, &cell.arrivals, &cell.plan, &obs)?;

    let span_downtime: Time = obs
        .tracer
        .spans()
        .iter()
        .filter(|s| s.name == "ctrl.switch")
        .map(|s| s.end - s.start)
        .sum();
    let gap = (span_downtime - cr.switch_downtime).as_s().abs();

    let slo = setup.slo;
    let mut t = Table::new(
        format!(
            "control — {} / {scenario}: {} requests over a {}-rung ladder (SLO {slo})",
            d.name,
            cr.report.offered,
            setup.ladder.len(),
        ),
        &["Config", "p95", "SLO attainment", "Switches", "Switch downtime"],
    );
    t.row(&[
        format!("adaptive (final: {})", setup.ladder[cr.final_config].label()),
        cr.report.latency.p95().to_string(),
        format!("{:.2}%", cr.report.slo_attainment(slo) * 100.0),
        cr.switches.len().to_string(),
        cr.switch_downtime.to_string(),
    ]);
    for cfg in &setup.ladder {
        let r = open_loop_faulted(
            cfg.queues.servers(),
            &cfg.service,
            cfg.policy,
            &cell.arrivals,
            &cell.plan,
            &Obs::disabled(),
        )?;
        t.row(&[
            format!("static {}", cfg.label()),
            r.latency.p95().to_string(),
            format!("{:.2}%", r.slo_attainment(slo) * 100.0),
            "-".into(),
            "-".into(),
        ]);
    }
    t.print();
    println!(
        "switch blast radius: {} request(s) re-routed or arrived mid-pause; \
         ctrl.switch span sum {span_downtime} (gap {gap:.3e} s, {} span(s) dropped)",
        cr.switch_affected, cr.report.dropped_spans
    );
    if cr.report.dropped_spans == 0 && gap != 0.0 {
        return Err(Error::Sim(format!(
            "ctrl.switch spans do not reconcile with switch downtime (gap {gap:.3e} s)"
        )));
    }
    Ok(())
}

fn cmd_tune(argv: &[String]) -> Result<()> {
    let cmd = Command::new("tune", "hybrid operating-point autotuner (E11)")
        .opt("dataset", "all | taxi | a Table 2 dataset (full grid detail)", Some("all"))
        .opt("cap", "max materialized sample nodes", Some("2000"))
        .opt("threads", "sweep workers (0 = all cores)", Some("0"))
        .opt("refine", "netsim cross-checks of the best points", Some("3"))
        .opt("json", "sweep artifact path (sweep mode only)", None);
    let args = cmd.parse(argv)?;
    let cap = args.usize_or("cap", 2_000)?;
    let refine = args.usize_or("refine", 3)?;
    let threads = match args.usize_or("threads", 0)? {
        0 => ima_gnn::par::available_threads(),
        n => n,
    };

    let dataset = args.get_or("dataset", "all").to_string();
    if dataset != "all" {
        if args.get("json").is_some() {
            return Err(Error::Usage(
                "--json writes the full-sweep artifact; drop --dataset to use it".into(),
            ));
        }
        // Single-target deep dive: print every grid point, mark the
        // frontier and the argmin.
        let (name, nodes, model, sample) = hybrid_target(&dataset, cap)?;
        let tuner = Autotuner::new(
            &model,
            &sample,
            nodes,
            HybridSweep::paper_grid(),
            TunerConfig {
                netsim_refine: refine,
                netsim_nodes_cap: cap,
                ..Default::default()
            },
        )?;
        let out = tuner.explore_with_threads(threads)?;
        let mut t = Table::new(
            format!("E11 — {name} (N={nodes}), full grid"),
            &["Operating point", "Latency", "Energy", "Device power", "Intra-edge", "Rank"],
        );
        for (i, e) in out.evaluated.iter().enumerate() {
            let rank = if i == out.best {
                "best"
            } else if out.pareto.contains(&i) {
                "pareto"
            } else {
                ""
            };
            t.row(&[
                e.point.label(),
                e.score.latency.to_string(),
                e.score.energy.to_string(),
                e.score.per_device_power.to_string(),
                ima_gnn::report::pct(e.facts.intra_fraction),
                rank.into(),
            ]);
        }
        t.print();
        let best = out.best_point();
        println!("argmin: {} at {}", best.point.label(), best.score.latency);
        if let Some(c) = &best.simulated {
            println!(
                "netsim cross-check @ N={}: simulated {} vs analytic {}",
                c.nodes, c.simulated, c.analytic
            );
        }
        return Ok(());
    }

    let sweep = HybridSweep::run_configured(cap, threads, refine)?;
    sweep.render().print();
    let wins = sweep.hybrid_wins();
    match wins.as_slice() {
        [] => println!("no dataset where the tuned hybrid beats both pure settings"),
        some => {
            let names: Vec<&str> = some.iter().map(|r| r.dataset.as_str()).collect();
            println!(
                "tuned semi-decentralized beats both pure settings on: {} \
                 (the conclusion's hybrid case, demonstrated)",
                names.join(", ")
            );
        }
    }
    let path = args.get_or("json", "BENCH_hybrid.json").to_string();
    std::fs::write(&path, sweep.to_json())?;
    let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
    println!("wrote {path} and {sidecar}");
    Ok(())
}

fn cmd_perf(argv: &[String]) -> Result<()> {
    let cmd = Command::new("perf", "hot-kernel perf baseline (E10)")
        // The default output deliberately differs from the committed
        // BENCH_perf.json regression-gate baseline so a bare `ima-gnn
        // perf` can never overwrite the floors in the working tree.
        .opt("json", "perf artifact path", Some("BENCH_perf.fresh.json"))
        .opt(
            "check",
            "committed baseline to gate against (fails on >25% speedup regression)",
            None,
        )
        .flag("quick", "reduced measurement budget (smoke runs)");
    let args = cmd.parse(argv)?;
    let report = ima_gnn::perfbench::run(args.flag("quick"))?;
    println!();
    for s in &report.speedups {
        println!("{:<24} {}  ({} vs {})", s.name, speedup(s.factor), s.fast, s.reference);
    }
    let path = args.get_or("json", "BENCH_perf.fresh.json").to_string();
    std::fs::write(&path, report.to_json())?;
    let sidecar = write_metrics_sidecar(&path, &report.metrics_snapshot())?;
    println!("wrote {path} and {sidecar}");

    if let Some(baseline_path) = args.get("check") {
        let baseline = std::fs::read_to_string(baseline_path)?;
        let rows = ima_gnn::perfbench::check_against(&report, &baseline)?;
        let mut t = Table::new(
            format!("perf regression gate vs {baseline_path} (floor: baseline x 0.75)"),
            &["Headline", "Baseline", "Fresh", "Floor", "Margin", "Ratio", "Gate"],
        );
        for r in &rows {
            t.row(&[
                r.name.clone(),
                format!("{:.3}x", r.baseline),
                format!("{:.3}x", r.fresh),
                format!("{:.3}x", r.floor),
                format!("{:+.3}", r.margin),
                format!("{:.2}", r.ratio),
                if r.pass { "pass".into() } else { "FAIL".into() },
            ]);
        }
        t.print();
        let failed: Vec<&str> =
            rows.iter().filter(|r| !r.pass).map(|r| r.name.as_str()).collect();
        if !failed.is_empty() {
            return Err(Error::Runtime(format!(
                "perf regression gate failed (>25% below baseline): {}",
                failed.join(", ")
            )));
        }
        println!("perf regression gate passed ({} headlines)", rows.len());
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "serve GCN inference over PJRT")
        .opt("requests", "requests to serve", Some("64"))
        .opt("nodes", "graph nodes (shards when > artifact table)", Some("48"))
        .opt("degree", "graph degree", Some("6"))
        .opt("artifacts", "artifact directory", None)
        .opt("cap", "max materialized nodes per dataset (sweep)", Some("512"))
        .opt("rounds", "serving rounds per dataset (sweep)", Some("3"))
        .opt("json", "sweep artifact path", Some("BENCH_serving.json"))
        .flag("sweep", "run the E12 sharded-serving sweep (no PJRT needed)");
    let args = cmd.parse(argv)?;

    if args.flag("sweep") {
        let sweep =
            ServingSweep::run(args.usize_or("cap", 512)?, args.usize_or("rounds", 3)?.max(1))?;
        sweep.render().print();
        let sharded = sweep.rows.iter().filter(|r| r.shards > 1).count();
        println!(
            "{sharded}/{} dataset samples exceed the artifact table and serve through shards",
            sweep.rows.len()
        );
        let path = args.get_or("json", "BENCH_serving.json").to_string();
        std::fs::write(&path, sweep.to_json())?;
        let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
        println!("wrote {path} and {sidecar}");
        return Ok(());
    }
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let n_req = args.usize_or("requests", 64)?;
    let nodes = args.usize_or("nodes", 48)?;
    let degree = args.usize_or("degree", 6)?;

    let svc = InferenceService::start(dir.clone())?;
    let manifest = Manifest::load(&dir)?;
    let binding = GcnLayerBinding::from_spec(manifest.get("gcn_layer_small")?)?;
    let feature = binding.feature;
    let graph = generate::regular(nodes, degree.min(nodes - 1), 3)?;
    let mut rng = Rng::new(7);
    let weights: Vec<f32> =
        (0..binding.feature * binding.hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();
    let mut leader = CentralizedLeader::new(
        binding,
        graph,
        weights,
        &GnnWorkload::gcn("serve", feature, degree),
        Duration::from_millis(5),
    )?;
    for node in 0..nodes {
        let f: Vec<f32> = (0..feature).map(|_| rng.f64_in(0.0, 1.0) as f32).collect();
        leader.upload(node, &f)?;
    }
    leader.end_round();
    // Compile outside the timed window: the paper's deployment compiles
    // once at provisioning time, not per request.
    svc.warm("gcn_layer_small")?;

    let t0 = std::time::Instant::now();
    let mut served = 0usize;
    let mut wall_total = Duration::ZERO;
    for id in 0..n_req as u64 {
        let node = rng.index(nodes);
        for r in leader.submit(&svc, Request { id, node })? {
            served += 1;
            wall_total += r.wall;
        }
    }
    for r in leader.drain(&svc)? {
        served += 1;
        wall_total += r.wall;
    }
    let elapsed = t0.elapsed();
    println!(
        "served {served} requests in {:.1} ms ({:.0} req/s, {} batches, mean PJRT wall/request {:.3} ms)",
        elapsed.as_secs_f64() * 1e3,
        served as f64 / elapsed.as_secs_f64(),
        leader.served_batches(),
        wall_total.as_secs_f64() * 1e3 / served.max(1) as f64,
    );
    Ok(())
}

fn cmd_resident(argv: &[String]) -> Result<()> {
    let cmd = Command::new("resident", "E16 million-node residency under a byte budget")
        .opt("nodes", "graph nodes for a single run", Some("100000"))
        .opt("max-nodes", "sweep scale ceiling (filters the E16 grid)", Some("1000000"))
        .opt("rounds", "serving rounds per scale", Some("2"))
        .opt("budget-shards", "resident-set byte budget, in decoded shards", Some("2"))
        .opt("json", "sweep artifact path", Some("BENCH_residency.json"))
        .flag("sweep", "run the E16 residency sweep over the scale grid");
    let args = cmd.parse(argv)?;
    let rounds = args.usize_or("rounds", 2)?.max(1);
    let budget_shards = args.usize_or("budget-shards", RESIDENCY_BUDGET_SHARDS)?.max(1);

    if args.flag("sweep") {
        let max_nodes = args.usize_or("max-nodes", 1_000_000)?.max(1);
        let sweep = ResidencySweep::run(max_nodes, rounds, budget_shards)?;
        sweep.render().print();
        let top = sweep.rows.iter().max_by_key(|r| r.nodes).expect("sweep has rows");
        println!(
            "largest scale: {} nodes served under a {} B ceiling (peak {} B; an \
             unbounded cache would hold {} B); outputs bit-identical to the seed path",
            top.nodes, top.budget_bytes, top.peak_bytes, top.unbounded_bytes
        );
        let path = args.get_or("json", "BENCH_residency.json").to_string();
        std::fs::write(&path, sweep.to_json())?;
        let sidecar = write_metrics_sidecar(&path, &sweep.metrics_snapshot())?;
        println!("wrote {path} and {sidecar}");
        return Ok(());
    }

    let nodes = args.usize_or("nodes", 100_000)?.max(1);
    // `single` errors on budget violation or resident/seed digest
    // divergence, so reaching the prints below IS the invariant check.
    let r = ResidencySweep::single(nodes, rounds, budget_shards)?;
    println!(
        "{} nodes / {} edges -> {} shards; compact CSR {:.2}x smaller ({} -> {} B)",
        r.nodes, r.edges, r.shards, r.compression_ratio, r.graph_seed_bytes, r.graph_encoded_bytes
    );
    println!(
        "peak resident {} B <= budget {} B (unbounded cache: {} B)",
        r.peak_bytes, r.budget_bytes, r.unbounded_bytes
    );
    println!(
        "cache: {} hits / {} misses / {} evictions, {:.1}% hit rate ({} prefetch hits)",
        r.hits,
        r.misses,
        r.evictions,
        r.hit_rate * 100.0,
        r.prefetch_hits
    );
    if let Some(o) = r.decode_overhead() {
        println!("decode-on-fetch overhead vs the seed path: {o:.2}x (bit-identical outputs)");
    }
    Ok(())
}

fn cmd_trace(argv: &[String]) -> Result<()> {
    let cmd = Command::new("trace", "traced E13 round -> Perfetto timeline")
        .opt("dataset", "taxi | a Table 2 dataset", Some("taxi"))
        .opt("requests", "target requests per deployment setting", Some("300"))
        .opt("rate", "offered system rate, requests/second", Some("5000"))
        .opt("spans", "span ring-buffer capacity per process", Some("65536"))
        .opt("seed", "rng seed", Some("1"))
        .opt("out", "Chrome trace-event output path", Some("round.trace.json"));
    let args = cmd.parse(argv)?;
    let requests = args.usize_or("requests", 300)?.max(1);
    let spans = args.usize_or("spans", 65_536)?.max(1);
    let seed = args.usize_or("seed", 1)? as u64;
    let rate = args.f64_or("rate", 5_000.0)?;
    if !(rate > 0.0) {
        return Err(Error::Usage("--rate must be > 0".into()));
    }

    let dataset = args.get_or("dataset", "taxi").to_string();
    let (name, model, topo) = if dataset.eq_ignore_ascii_case("taxi") {
        ("Taxi".to_string(), NetModel::paper(&GnnWorkload::taxi())?, Topology::taxi())
    } else {
        let d = ima_gnn::graph::datasets::by_name(&dataset)?;
        (
            d.name.to_string(),
            NetModel::fig8(&d)?,
            Topology { nodes: d.nodes, cluster_size: d.avg_cs },
        )
    };
    let policy = BatchPolicy::Deadline {
        max: TRAFFIC_MAX_BATCH,
        max_wait: Time::ms(TRAFFIC_WAIT_MS),
    };

    // One observed open-loop E13 run per deployment setting: each setting
    // becomes a Perfetto process, each server queue a timeline track.
    let mut traffic = Vec::with_capacity(3);
    for kind in [SettingKind::Centralized, SettingKind::Semi, SettingKind::Decentralized] {
        let (queues, service) = deployment_shape(kind, LatencyProvider::Analytic, &model, topo)?;
        let queue_rate = queues.per_queue_rate(rate);
        if !(queue_rate > 0.0) {
            return Err(Error::Usage("--rate splits to a non-positive queue rate".into()));
        }
        let horizon = Time::s(requests as f64 / queue_rate);
        let arrivals =
            ArrivalProcess::Poisson { rate: queue_rate }.generate(horizon, topo.nodes, seed)?;
        let obs = Obs::new(spans);
        let report = open_loop_observed(1, &service, policy, &arrivals, &obs)?;
        traffic.push((kind.name(), obs, report));
    }

    // A short sharded serving run for the engine/shard tracks: plan the
    // shards under a `shard.plan` span, then drive two full upload ->
    // barrier -> assemble rounds through a tracing round engine.
    let obs_shard = Obs::new(spans);
    let binding = gcn_layer_binding();
    let (feature, hidden, table) = (binding.feature, binding.hidden, binding.table);
    let graph = generate::regular(96, 6, 3)?;
    let plan = ShardPlan::build_observed(&graph, &binding.sampler(), table, &obs_shard)?;
    let mut engine = RoundEngine::new(binding, plan, vec![0.01; feature * hidden])?;
    engine.enable_tracing(spans);
    let n = graph.num_nodes();
    let all: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(11);
    for _round in 0..2 {
        for node in 0..n {
            let f: Vec<f32> = (0..feature).map(|_| rng.f64() as f32).collect();
            engine.upload(node, &f)?;
        }
        engine.end_round();
        engine.assemble(&all)?;
    }

    // One observed netsim round: `net.packet` spans per fabric resource.
    let obs_net = Obs::new(spans);
    let net_cfg = NetSimConfig { rx_ports: Some(8), ..Default::default() };
    let net_topo = Topology { nodes: 64, cluster_size: 8 };
    let net =
        simulate_fabric_observed(&model, Scenario::CentralizedStar, net_topo, &net_cfg, &obs_net)?;

    // Reconcile the traffic timelines against the engine's own
    // accounting: per setting, sum(wait spans) + sum(serve spans) must
    // equal the report's total response time.
    let mut worst_gap = 0.0f64;
    for (setting, obs, report) in &traffic {
        let recorded = obs.tracer.spans();
        let covered: f64 = recorded
            .iter()
            .filter(|s| s.name == "traffic.wait" || s.name == "traffic.serve")
            .map(|s| (s.end - s.start).as_s())
            .sum();
        let gap = (covered - report.sum_response.as_s()).abs()
            / report.sum_response.as_s().max(1e-30);
        worst_gap = worst_gap.max(gap);
        println!(
            "{setting}: {} spans over {} requests; span-covered {:.6} s vs \
             sum_response {:.6} s (rel gap {:.3e})",
            recorded.len(),
            report.offered,
            covered,
            report.sum_response.as_s(),
            gap
        );
        if obs.tracer.dropped() > 0 {
            println!(
                "  warning: ring buffer dropped {} spans; raise --spans to reconcile",
                obs.tracer.dropped()
            );
        }
    }
    println!(
        "netsim: {} packets ({} contended) over {} events",
        net.packets, net.contended_packets, net.events
    );

    let labels: Vec<String> =
        traffic.iter().map(|(setting, _, _)| format!("traffic:{setting}")).collect();
    let mut procs: Vec<(&str, &Tracer)> = Vec::with_capacity(labels.len() + 3);
    for (i, (_, obs, _)) in traffic.iter().enumerate() {
        procs.push((labels[i].as_str(), &obs.tracer));
    }
    procs.push(("engine", engine.tracer()));
    procs.push(("shard", &obs_shard.tracer));
    procs.push(("netsim", &obs_net.tracer));
    let out = args.get_or("out", "round.trace.json").to_string();
    std::fs::write(&out, chrome_trace_json(&procs))?;

    let merged = MetricsRegistry::new();
    for (setting, obs, _) in &traffic {
        merged.merge_from(&obs.metrics, &format!("{setting}."));
    }
    merged.merge_from(&obs_shard.metrics, "");
    merged.merge_from(engine.metrics(), "");
    merged.merge_from(&obs_net.metrics, "");
    let sidecar = write_metrics_sidecar(&out, &merged)?;
    println!(
        "traced {name} round across {} settings; worst reconciliation gap {worst_gap:.3e}",
        traffic.len()
    );
    println!("wrote {out} and {sidecar} (load {out} at ui.perfetto.dev)");
    Ok(())
}

fn cmd_area(argv: &[String]) -> Result<()> {
    Command::new("area", "silicon-area report").parse(argv)?;
    use ima_gnn::config::presets;
    use ima_gnn::device::area;
    let mut t = Table::new(
        "silicon area (45 nm behavioral roll-up)",
        &["Preset", "Traversal", "Aggregation", "Feature extraction", "Total"],
    );
    for (name, cfg) in
        [("centralized", presets::centralized()), ("decentralized node", presets::decentralized())]
    {
        let (tr, ag, fe, total) = area::accelerator(&cfg);
        t.row(&[
            name.into(),
            tr.to_string(),
            ag.to_string(),
            fe.to_string(),
            total.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact + platform info")
        .opt("artifacts", "artifact directory", None);
    let args = cmd.parse(argv)?;
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    println!("ima-gnn {} — artifact dir: {}", env!("CARGO_PKG_VERSION"), dir.display());
    match Manifest::load(&dir) {
        Ok(m) => {
            let mut t = Table::new("artifacts", &["Name", "Inputs", "Outputs", "File"]);
            for a in m.artifacts() {
                t.row(&[
                    a.name.clone(),
                    a.inputs.len().to_string(),
                    a.outputs.len().to_string(),
                    a.file.clone(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    Ok(())
}
