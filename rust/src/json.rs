//! Minimal JSON parser + serializer for artifacts.
//!
//! The offline crate set has no `serde_json`; this module implements the
//! subset of JSON the manifest uses (objects, arrays, strings, numbers,
//! booleans, null) with precise error offsets.  It is strict: trailing
//! commas, comments and unquoted keys are rejected.
//!
//! [`Json::dump`] is the single serialization path for every metric and
//! trace artifact the crate emits.  Objects are [`BTreeMap`]s, so keys
//! serialize in sorted order for free, and number formatting is a pure
//! function of the value — the output is byte-deterministic regardless
//! of thread count, matching the E9/E11/E13 byte-identity contract.
//!
//! DESIGN.md: §4 (experiment artifacts are emitted and checked through this).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with the missing key name.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json { offset: 0, message: format!("missing key `{key}`") })
    }

    /// Serialize to a compact JSON string.
    ///
    /// Object keys come out sorted (the map is a `BTreeMap`) and numbers
    /// format deterministically: integral values within `i64`'s exact
    /// range print without a fraction, everything else uses Rust's
    /// shortest round-trip float form, and non-finite values become
    /// `null` (JSON has no NaN/Inf).  `parse(dump(x)) == x` for every
    /// finite document.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_number(*n, out),
            Json::Str(s) => write_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_number(v: f64, out: &mut String) {
    if !v.is_finite() {
        out.push_str("null");
        return;
    }
    // 2^53: below this every integral f64 is exact, so the integer form
    // round-trips; above it the float form is the honest one.
    if v == v.trunc() && v.abs() < 9_007_199_254_740_992.0 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, message: impl Into<String>) -> Result<T> {
        Err(Error::Json { offset: self.pos, message: message.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected `{}`", b as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte `{}`", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal, expected `{word}`"))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected `,` or `}` in object"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return self.err("expected `,` or `]` in array"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return self.err("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| Error::Json {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| Error::Json {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                        self.pos += 4;
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    if start + len > self.bytes.len() {
                        return self.err("truncated utf-8");
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| Error::Json { offset: start, message: "bad utf-8".into() })?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number `{text}`")),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage after document");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}, "n": -0.25}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(-0.25));
    }

    #[test]
    fn parses_manifest_shaped_doc() {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "m", "file": "m.hlo.txt",
             "inputs": [{"shape": [16, 64], "dtype": "float32"}],
             "outputs": [{"shape": [16, 32], "dtype": "float32"}]}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(16));
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"q\" \\ A""#).unwrap(),
            Json::Str("a\nb\t\"q\" \\ A".into())
        );
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse("\"µs → ok\"").unwrap(), Json::Str("µs → ok".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "{a: 1}", "\"unterminated"] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn error_carries_offset() {
        match parse("[1, x]") {
            Err(Error::Json { offset, .. }) => assert_eq!(offset, 4),
            other => panic!("expected json error, got {other:?}"),
        }
    }

    #[test]
    fn require_reports_key() {
        let v = parse("{\"a\": 1}").unwrap();
        assert!(v.require("a").is_ok());
        let e = v.require("missing").unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn dump_sorts_keys_and_roundtrips() {
        let doc = r#"{"z": 1, "a": [true, null, "x\ny"], "m": {"q": -0.25}}"#;
        let v = parse(doc).unwrap();
        let s = v.dump();
        // BTreeMap ordering: keys come out sorted regardless of input order.
        assert_eq!(s, r#"{"a":[true,null,"x\ny"],"m":{"q":-0.25},"z":1}"#);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn dump_number_forms() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(-3.0).dump(), "-3");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        // Past 2^53 the integral check is off — float form round-trips.
        let big = Json::Num(1e300);
        assert_eq!(parse(&big.dump()).unwrap(), big);
    }

    #[test]
    fn dump_escapes_control_characters() {
        let v = Json::Str("tab\t quote\" back\\ bell\u{0007}".into());
        let s = v.dump();
        assert!(s.contains("\\t") && s.contains("\\\"") && s.contains("\\\\"));
        assert!(s.contains("\\u0007"));
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn dump_roundtrips_random_floats() {
        let mut rng = crate::testing::Rng::new(9);
        for _ in 0..200 {
            let v = Json::Num((rng.f64() - 0.5) * 1e9);
            assert_eq!(parse(&v.dump()).unwrap(), v);
        }
    }
}
