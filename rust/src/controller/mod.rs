//! # Closed-loop adaptive runtime controller (E15)
//!
//! Watches the live serving window — windowed p95 response, mean queue
//! depth, utilization and arrival rate, all on the traffic engine's
//! sim-time axis — and switches the deployment between a validated
//! *capacity ladder* of operating points ([`CtrlConfig`]) mid-run.
//!
//! The controller is a pure decision function: [`Controller::decide`]
//! maps an observation snapshot ([`CtrlView`]) to `Some(target)` or
//! `None`.  The traffic engine owns the windows and executes switches
//! (`traffic::open_loop_controlled`); this module owns the policy, so
//! the hysteresis contract is testable without running a simulation.
//!
//! ## Hysteresis contract
//!
//! * **Warm-up** — no decision before one full window of samples.
//! * **Min-dwell** — after a switch completes (measured from the *end*
//!   of the paused rebuild, not its start), no further decision for
//!   `dwell`; after a *de-escalation*, escalation is blocked for
//!   `2·dwell`.  Together these make up/down flapping impossible.
//! * **Dual thresholds** — escalation needs the windowed p95 *and* the
//!   mean queue depth over threshold simultaneously (plus a busy
//!   fleet); de-escalation needs the arrival rate comfortably under
//!   the cheaper rung's aggregate saturation *and* a backlog that the
//!   spare capacity can absorb within one dwell.  The up and down
//!   conditions cannot both hold, so there is no chatter band.
//!
//! Every switch is honestly priced: the engine bills the target rung's
//! ShardPlan-rebuild + FeatureStore re-upload cost (a
//! [`crate::sim::faults::RecoveryCost`] total) as a dispatch pause
//! through the double-buffer barrier, and emits a `ctrl.switch` span
//! whose duration reconciles bit-exactly with the report's accrued
//! switch downtime.
//!
//! DESIGN.md: §14 (closed-loop adaptive runtime control).

use crate::autotune::OperatingPoint;
use crate::error::{Error, Result};
use crate::traffic::{BatchPolicy, DeploymentQueues, ServiceModel};
use crate::units::Time;

/// One rung of the controller's capacity ladder: a deployment shape,
/// its calibrated service model, the batching policy it serves with,
/// and the priced cost of switching *into* it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CtrlConfig {
    /// The autotuner operating point this rung realizes (labels only;
    /// the queueing behavior is fully captured by the fields below).
    pub point: OperatingPoint,
    pub queues: DeploymentQueues,
    pub service: ServiceModel,
    pub policy: BatchPolicy,
    /// Priced switch-into cost: ShardPlan rebuild + FeatureStore
    /// re-upload through the double-buffer barrier
    /// ([`crate::sim::faults::RecoveryCost::total`]).
    pub switch_cost: Time,
}

impl CtrlConfig {
    /// Human-readable rung label for tables and JSON.
    pub fn label(&self) -> String {
        self.point.label()
    }

    /// Aggregate saturation throughput (req/s) of this rung: servers ×
    /// per-queue saturation rate at the policy's maximum batch.
    pub fn saturation_aggregate(&self) -> f64 {
        self.queues.servers() as f64 * self.service.saturation_rate(self.policy.max_batch())
    }
}

/// Dual-threshold hysteresis parameters.  See the module docs for the
/// no-flap argument.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hysteresis {
    /// Observation window width; also the warm-up horizon before the
    /// first decision.
    pub window: Time,
    /// Minimum dwell after a switch completes before the next decision.
    pub dwell: Time,
    /// Escalate only while the windowed p95 response exceeds this.
    pub p95_hi: Time,
    /// Escalate only while the windowed mean total queue depth is at
    /// least this many requests.
    pub depth_hi: f64,
    /// Escalate only with at least this many response samples in the
    /// window (a thin window is noise, not load).
    pub min_samples: usize,
    /// De-escalate to rung `j` only while the windowed arrival rate is
    /// below `down_fraction × saturation_aggregate(j)`.
    pub down_fraction: f64,
    /// Escalate only while windowed utilization is at least this (an
    /// idle fleet with a stale p95 tail is not overload).
    pub util_hi: f64,
}

impl Hysteresis {
    pub fn validate(&self) -> Result<()> {
        if !(self.window.as_s() > 0.0) || !self.window.as_s().is_finite() {
            return Err(Error::Sim("hysteresis window must be finite and > 0".into()));
        }
        if !(self.dwell.as_s() > 0.0) || !self.dwell.as_s().is_finite() {
            return Err(Error::Sim("hysteresis dwell must be finite and > 0".into()));
        }
        if !(self.p95_hi.as_s() > 0.0) {
            return Err(Error::Sim("hysteresis p95 threshold must be > 0".into()));
        }
        if !(self.depth_hi > 0.0) {
            return Err(Error::Sim("hysteresis depth threshold must be > 0".into()));
        }
        if self.min_samples == 0 {
            return Err(Error::Sim("hysteresis needs min_samples >= 1".into()));
        }
        if !(0.0..=1.0).contains(&self.down_fraction) {
            return Err(Error::Sim("hysteresis down_fraction must be in [0, 1]".into()));
        }
        if !(0.0..=1.0).contains(&self.util_hi) {
            return Err(Error::Sim("hysteresis util_hi must be in [0, 1]".into()));
        }
        Ok(())
    }

    /// A hysteresis that can never fire: infinite escalation
    /// thresholds and a zero de-escalation fraction.  A controller
    /// built with this must be bit-identical to the static run of its
    /// initial rung (property-tested in `tests/controller.rs`).
    pub fn never(window: Time, dwell: Time) -> Hysteresis {
        Hysteresis {
            window,
            dwell,
            p95_hi: Time::s(f64::INFINITY),
            depth_hi: f64::INFINITY,
            min_samples: 8,
            down_fraction: 0.0,
            util_hi: 0.5,
        }
    }
}

/// Observation snapshot handed to [`Controller::decide`] by the
/// traffic engine after each completed batch.  All times are sim time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CtrlView {
    pub now: Time,
    /// Index of the currently active rung.
    pub current: usize,
    /// Windowed p95 of response times (arrival → batch completion).
    pub windowed_p95: Time,
    /// Response samples currently in the window.
    pub resp_samples: usize,
    /// Windowed mean of total pending depth sampled at completions.
    pub mean_depth: f64,
    /// Windowed mean busy fraction of the active fleet.
    pub utilization: f64,
    /// Windowed arrival rate (arrivals in window / window width).
    pub arrival_rate_per_s: f64,
    /// Total requests pending across all active queues right now.
    pub total_pending: usize,
    /// End of the most recent switch pause, if any switch happened.
    pub last_switch_resume: Option<Time>,
    /// End of the most recent *de-escalation* pause, if any.
    pub last_down_resume: Option<Time>,
}

/// A deterministic closed-loop controller over a capacity ladder.
#[derive(Debug, Clone)]
pub struct Controller {
    configs: Vec<CtrlConfig>,
    initial: usize,
    hysteresis: Hysteresis,
}

impl Controller {
    /// Build a controller over `configs` ordered cheapest-first (the
    /// capacity ladder).  Escalation moves one rung up; de-escalation
    /// may drop several rungs at once to the cheapest rung that can
    /// absorb the observed rate plus backlog.
    pub fn new(
        configs: Vec<CtrlConfig>,
        initial: usize,
        hysteresis: Hysteresis,
    ) -> Result<Controller> {
        if configs.is_empty() {
            return Err(Error::Sim("controller needs at least one config".into()));
        }
        if initial >= configs.len() {
            return Err(Error::Sim(format!(
                "controller initial rung {initial} out of range (ladder has {})",
                configs.len()
            )));
        }
        hysteresis.validate()?;
        for (i, c) in configs.iter().enumerate() {
            if c.queues.servers() == 0 {
                return Err(Error::Sim(format!("controller rung {i} has no servers")));
            }
            if !(c.switch_cost.as_s() >= 0.0) || !c.switch_cost.as_s().is_finite() {
                return Err(Error::Sim(format!(
                    "controller rung {i} switch cost must be finite and >= 0"
                )));
            }
            if !(c.saturation_aggregate() > 0.0) {
                return Err(Error::Sim(format!(
                    "controller rung {i} has non-positive saturation throughput"
                )));
            }
        }
        for w in configs.windows(2) {
            if w[1].saturation_aggregate() <= w[0].saturation_aggregate() {
                return Err(Error::Sim(
                    "controller ladder must be ordered by strictly increasing \
                     aggregate saturation throughput"
                        .into(),
                ));
            }
        }
        Ok(Controller { configs, initial, hysteresis })
    }

    pub fn configs(&self) -> &[CtrlConfig] {
        &self.configs
    }

    pub fn initial(&self) -> usize {
        self.initial
    }

    pub fn hysteresis(&self) -> &Hysteresis {
        &self.hysteresis
    }

    /// The pure decision function: `Some(target)` to switch, `None` to
    /// stay.  Deterministic in the view; holds the hysteresis contract
    /// documented on the module.
    pub fn decide(&self, v: &CtrlView) -> Option<usize> {
        let h = &self.hysteresis;
        // Warm-up: never act on a partial first window.
        if v.now < h.window {
            return None;
        }
        // Min-dwell, measured from the end of the switch pause.
        if let Some(resume) = v.last_switch_resume {
            if v.now < resume + h.dwell {
                return None;
            }
        }
        // Escalate one rung when the window shows sustained overload.
        let up_blocked = match v.last_down_resume {
            Some(resume) => v.now < resume + h.dwell * 2.0,
            None => false,
        };
        if v.current + 1 < self.configs.len()
            && !up_blocked
            && v.resp_samples >= h.min_samples
            && v.windowed_p95 > h.p95_hi
            && v.mean_depth >= h.depth_hi
            && v.utilization >= h.util_hi
        {
            return Some(v.current + 1);
        }
        // De-escalate to the cheapest rung whose spare capacity covers
        // the observed rate and can absorb the backlog within a dwell.
        for j in 0..v.current {
            let sat_j = self.configs[j].saturation_aggregate();
            let headroom = sat_j - v.arrival_rate_per_s;
            if v.arrival_rate_per_s < h.down_fraction * sat_j
                && v.total_pending as f64 <= headroom * h.dwell.as_s()
            {
                return Some(j);
            }
        }
        None
    }
}

/// One executed switch, as recorded by the traffic engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchRecord {
    /// Sim time the switch started (dispatch pause begins).
    pub at: Time,
    pub from: usize,
    pub to: usize,
    /// Priced pause: the target rung's `switch_cost`.
    pub cost: Time,
    /// Pending requests migrated across the double-buffer barrier.
    pub moved: usize,
}

/// A [`crate::traffic::TrafficReport`] plus the controller's ledger.
#[derive(Debug, Clone)]
pub struct ControlledReport {
    pub report: crate::traffic::TrafficReport,
    pub switches: Vec<SwitchRecord>,
    /// Total paused time across all switches.  Accumulated as
    /// `resume − start` — the identical f64 expression as the
    /// `ctrl.switch` span durations, so the two reconcile bit-exactly.
    pub switch_downtime: Time,
    /// Requests touched by switches: migrated pending requests plus
    /// arrivals landing during a pause.
    pub switch_affected: usize,
    /// Rung active when the run drained.
    pub final_config: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::Partitioner;

    fn rung(servers: usize, per_batch: f64, per_req: f64, cost: f64) -> CtrlConfig {
        let queues = if servers == 1 {
            DeploymentQueues::Leader
        } else {
            DeploymentQueues::ClusterHeads { clusters: servers }
        };
        CtrlConfig {
            point: if servers == 1 {
                OperatingPoint::centralized()
            } else {
                OperatingPoint::semi(10, 2.0, Partitioner::FixedSize)
            },
            queues,
            service: ServiceModel::new(Time::s(per_batch), Time::s(per_req)).unwrap(),
            policy: BatchPolicy::Deadline {
                max: 16,
                max_wait: Time::s(0.25 * (per_batch + per_req)),
            },
            switch_cost: Time::s(cost),
        }
    }

    fn ladder() -> Vec<CtrlConfig> {
        vec![rung(1, 1.0, 1e-4, 0.5), rung(15, 4.0, 1e-4, 2.0)]
    }

    fn hyst() -> Hysteresis {
        Hysteresis {
            window: Time::s(10.0),
            dwell: Time::s(30.0),
            p95_hi: Time::s(3.0),
            depth_hi: 32.0,
            min_samples: 8,
            down_fraction: 0.7,
            util_hi: 0.5,
        }
    }

    fn overloaded(now: f64) -> CtrlView {
        CtrlView {
            now: Time::s(now),
            current: 0,
            windowed_p95: Time::s(9.0),
            resp_samples: 40,
            mean_depth: 80.0,
            utilization: 1.0,
            arrival_rate_per_s: 14.0,
            total_pending: 90,
            last_switch_resume: None,
            last_down_resume: None,
        }
    }

    #[test]
    fn escalates_only_when_all_thresholds_hold() {
        let c = Controller::new(ladder(), 0, hyst()).unwrap();
        assert_eq!(c.decide(&overloaded(50.0)), Some(1));
        // Each threshold individually gates the decision.
        let mut v = overloaded(50.0);
        v.windowed_p95 = Time::s(2.0);
        assert_eq!(c.decide(&v), None);
        let mut v = overloaded(50.0);
        v.mean_depth = 10.0;
        assert_eq!(c.decide(&v), None);
        let mut v = overloaded(50.0);
        v.resp_samples = 7;
        assert_eq!(c.decide(&v), None);
        let mut v = overloaded(50.0);
        v.utilization = 0.2;
        assert_eq!(c.decide(&v), None);
        // Top of the ladder never escalates.
        let mut v = overloaded(50.0);
        v.current = 1;
        assert_eq!(c.decide(&v), None);
    }

    #[test]
    fn warmup_and_dwell_block_decisions() {
        let c = Controller::new(ladder(), 0, hyst()).unwrap();
        // Inside the first window: no decision regardless of load.
        assert_eq!(c.decide(&overloaded(5.0)), None);
        // Dwell counts from the pause *end*.
        let mut v = overloaded(100.0);
        v.last_switch_resume = Some(Time::s(80.0));
        assert_eq!(c.decide(&v), None, "80 + 30 dwell > 100");
        v.now = Time::s(111.0);
        assert_eq!(c.decide(&v), Some(1));
        // A recent de-escalation blocks re-escalation for 2*dwell.
        let mut v = overloaded(150.0);
        v.last_down_resume = Some(Time::s(100.0));
        assert_eq!(c.decide(&v), None, "100 + 60 > 150");
        v.now = Time::s(161.0);
        assert_eq!(c.decide(&v), Some(1));
    }

    #[test]
    fn deescalates_to_cheapest_feasible_rung() {
        let three = vec![
            rung(1, 1.0, 1e-4, 0.5),
            rung(15, 4.0, 1e-4, 2.0),
            rung(150, 8.0, 1.0, 1.0),
        ];
        let c = Controller::new(three, 0, hyst()).unwrap();
        let sat0 = c.configs()[0].saturation_aggregate();
        let quiet = CtrlView {
            now: Time::s(200.0),
            current: 2,
            windowed_p95: Time::s(0.5),
            resp_samples: 20,
            mean_depth: 1.0,
            utilization: 0.1,
            arrival_rate_per_s: 0.1 * sat0,
            total_pending: 3,
            last_switch_resume: None,
            last_down_resume: None,
        };
        // Rate fits rung 0 with room to drain the backlog: multi-hop
        // drop straight to the cheapest rung.
        assert_eq!(c.decide(&quiet), Some(0));
        // A backlog too deep for rung 0's headroom falls through to
        // rung 1.
        let mut v = quiet;
        let headroom0 = sat0 - v.arrival_rate_per_s;
        v.total_pending = (headroom0 * 30.0) as usize + 10;
        assert_eq!(c.decide(&v), Some(1));
        // Rate above the down fraction of every cheaper rung: stay.
        let mut v = quiet;
        v.arrival_rate_per_s = 0.95 * c.configs()[1].saturation_aggregate();
        assert_eq!(c.decide(&v), None);
    }

    #[test]
    fn never_hysteresis_never_fires() {
        let c = Controller::new(ladder(), 0, Hysteresis::never(Time::s(10.0), Time::s(30.0)))
            .unwrap();
        assert_eq!(c.decide(&overloaded(1e6)), None);
        let mut v = overloaded(1e6);
        v.current = 1;
        v.arrival_rate_per_s = 0.0;
        v.total_pending = 0;
        assert_eq!(c.decide(&v), None, "down_fraction 0 blocks de-escalation");
    }

    #[test]
    fn constructor_rejects_malformed_ladders() {
        assert!(Controller::new(vec![], 0, hyst()).is_err());
        assert!(Controller::new(ladder(), 2, hyst()).is_err());
        // Not strictly increasing in aggregate saturation.
        let mut cfgs = ladder();
        cfgs.reverse();
        assert!(Controller::new(cfgs, 0, hyst()).is_err());
        // Bad hysteresis.
        let mut h = hyst();
        h.dwell = Time::ZERO;
        assert!(Controller::new(ladder(), 0, h).is_err());
        let mut h = hyst();
        h.down_fraction = 1.5;
        assert!(Controller::new(ladder(), 0, h).is_err());
        // Negative switch cost.
        let mut cfgs = ladder();
        cfgs[1].switch_cost = Time::s(-1.0);
        assert!(Controller::new(cfgs, 0, hyst()).is_err());
    }
}
