//! E10 — `ima-gnn perf`: the hot-kernel performance baseline.
//!
//! Times the simulator's compute hot spots — crossbar evaluate (seed
//! bit-serial reference vs the dispatched fast paths), the 512×512
//! binary-activation aggregate kernel (seed re-program-every-call path vs
//! the flat program-once/packed path), the dense-mask `accumulate_rows`
//! dispatch (seed sparse bit-walk vs the SWAR word-dense lanes), CSR
//! construction, the netsim star/mesh scenarios, the E9 sweep grid
//! sequential vs parallel, multi-shard batch assembly sequential vs
//! parallel, and the end-to-end offline round (upload → barrier →
//! assemble) — and emits `BENCH_perf.json`, the perf-trajectory artifact
//! CI uploads next to `BENCH_netsim.json`.  Headline `speedups` compare
//! each fast path against its seed-equivalent baseline on the same
//! inputs, with bit-/byte-identity asserted before anything is timed.
//!
//! DESIGN.md: §8 (fast paths and the perf trajectory).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::bench::{black_box, Bench, Stats};
use crate::config::{presets, CrossbarGeometry, DeviceParams};
use crate::coordinator::{FeatureStore, GcnLayerBinding, RoundEngine, ShardBatch};
use crate::cores::{AggregationCore, GnnWorkload, Tile};
use crate::crossbar::MvmCrossbar;
use crate::error::Result;
use crate::experiments::NetsimSweep;
use crate::graph::{
    generate, Csr, FeatureQuant, NeighborSampler, QuantizedFeatures, ResidentSet, ShardPlan,
};
use crate::netmodel::{NetModel, Topology};
use crate::netsim::{simulate_fabric, NetSimConfig, Scenario};
use crate::obs::MetricsRegistry;
use crate::par;
use crate::runtime::Tensor;
use crate::testing::Rng;

/// Frozen replica of the seed's `AggregationCore::aggregate` hot path —
/// flatten the ragged rows, zero + validate + write the full array
/// (`program_tile`), materialize 1-bit DAC codes, run the bit-serial
/// plane loop, copy the column group out.  Replicated verbatim (rather
/// than calling the live crossbar) so the baseline stays exactly the
/// seed's cost and cannot drift as the live implementation evolves
/// (e.g. `program_tile` now also maintains clip-free plane bounds,
/// which the seed never paid for).
#[allow(clippy::needless_range_loop)]
fn seed_aggregate(
    array: &mut [i32],
    geo_rows: usize,
    geo_cols: usize,
    features: &[Vec<i32>],
    active: &[bool],
    input_bits: u32,
    adc_bits: u32,
) -> Vec<i64> {
    let cols = features.first().map(Vec::len).unwrap_or(0);
    // aggregate(): flatten the ragged rows into a tile.
    let mut tile = vec![0i32; features.len() * cols];
    for (r, f) in features.iter().enumerate() {
        tile[r * cols..(r + 1) * cols].copy_from_slice(f);
    }
    // program_tile(): zero the array, per-cell range check, write.
    array.fill(0);
    for r in 0..features.len() {
        for c in 0..cols {
            let w = tile[r * cols + c];
            assert!((-8..=7).contains(&w), "weight outside conductance range");
            array[r * geo_cols + c] = w;
        }
    }
    // 1-bit activation input as DAC codes.
    let mut input = vec![0u32; geo_rows];
    for (r, &a) in active.iter().enumerate() {
        input[r] = a as u32;
    }
    // evaluate(): the bit-serial plane loop.
    let lo = -(1i64 << (adc_bits - 1));
    let hi = (1i64 << (adc_bits - 1)) - 1;
    let mut out = vec![0i64; geo_cols];
    let mut plane_sum = vec![0i64; geo_cols];
    for b in 0..input_bits {
        plane_sum.fill(0);
        for (r, &x) in input.iter().enumerate() {
            if (x >> b) & 1 == 1 {
                for (c, &w) in array[r * geo_cols..(r + 1) * geo_cols].iter().enumerate() {
                    plane_sum[c] += w as i64;
                }
            }
        }
        for c in 0..geo_cols {
            out[c] += plane_sum[c].clamp(lo, hi) << b;
        }
    }
    out[..cols].to_vec()
}

/// Frozen replica of the pre-lane `MvmCrossbar::accumulate_rows` body —
/// the sparse `bits &= bits - 1` walk that adds each selected row one
/// column at a time, then clamps.  On a dense mask this touches every
/// row anyway but pays the per-bit dispatch and scalar column loop the
/// word-dense SWAR path removes.  Replicated (not called through the
/// live crossbar) so the baseline stays exactly the seed's cost.
fn seed_accumulate_rows(
    weights: &[i32],
    cols: usize,
    adc_bits: u32,
    mask: &[u64],
    out: &mut [i64],
) {
    let k = out.len();
    out.fill(0);
    for (w, &word) in mask.iter().enumerate() {
        let mut bits = word;
        while bits != 0 {
            let r = w * 64 + bits.trailing_zeros() as usize;
            bits &= bits - 1;
            let row = &weights[r * cols..r * cols + k];
            for (o, &wt) in out.iter_mut().zip(row.iter()) {
                *o += wt as i64;
            }
        }
    }
    let lo = -(1i64 << (adc_bits - 1));
    let hi = (1i64 << (adc_bits - 1)) - 1;
    for o in out.iter_mut() {
        *o = (*o).clamp(lo, hi);
    }
}

/// Frozen replica of the seed offline round: staged per-node uploads
/// (home + every halo site), then a per-shard barrier doing the
/// buffer flip and a row-at-a-time table gather, then a BTreeMap-grouped
/// assemble that allocates fresh slot / `x_self` / `nbr_idx` vectors per
/// chunk and gathers `x_self` one row at a time.  Built only on the
/// public `FeatureStore` / `ShardPlan` APIs so it cannot inherit the
/// engine's improvements (run-coalesced gather, reused group index,
/// parallel per-shard construction, tensor handle reuse).  Returns the
/// per-shard tables and the assembled batches so `run` can assert
/// equality with the live engine before timing either side.
fn seed_offline_round(
    binding: &GcnLayerBinding,
    plan: &ShardPlan,
    stores: &mut [FeatureStore],
    row: &[f32],
    nodes: &[usize],
) -> (Vec<Vec<f32>>, Vec<ShardBatch>) {
    // upload(): home member slot plus every halo replica.
    for &node in nodes {
        let (s, slot) = plan.home(node);
        stores[s].write(slot, row).unwrap();
        for &(hs, hslot) in plan.halo_sites(node) {
            stores[hs].write(hslot, row).unwrap();
        }
    }
    // end_round(): flip, then gather the full table one row at a time.
    let mut tables = Vec::with_capacity(stores.len());
    for store in stores.iter_mut() {
        store.swap();
        let mut x_table = Vec::with_capacity(binding.table * binding.feature);
        for n in 0..binding.table {
            x_table.extend_from_slice(store.read(n).unwrap());
        }
        tables.push(x_table);
    }
    // assemble(): BTreeMap grouping, fresh vectors per chunk.
    let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, &v) in nodes.iter().enumerate() {
        groups.entry(plan.home(v).0).or_default().push(i);
    }
    let mut out = Vec::new();
    for (s, positions) in groups {
        let shard = &plan.shards()[s];
        for chunk in positions.chunks(binding.batch) {
            let mut slots: Vec<usize> = chunk.iter().map(|&i| plan.home(nodes[i]).1).collect();
            let pad = *slots.last().expect("chunks are non-empty");
            slots.resize(binding.batch, pad);
            let mut x_self = Vec::with_capacity(binding.batch * binding.feature);
            for &slot in &slots {
                x_self.extend_from_slice(stores[s].read(slot).unwrap());
            }
            let mut nbr_idx = Vec::with_capacity(binding.batch * binding.sample);
            for &slot in &slots {
                nbr_idx.extend_from_slice(shard.member_nbr_row(slot, binding.sample));
            }
            out.push(ShardBatch {
                shard: s,
                nodes: chunk.iter().map(|&i| nodes[i]).collect(),
                positions: chunk.to_vec(),
                x_self,
                nbr_idx,
            });
        }
    }
    (tables, out)
}

/// One headline comparison: `reference` / `fast` median, by case name.
#[derive(Debug, Clone)]
pub struct Speedup {
    pub name: String,
    pub reference: String,
    pub fast: String,
    pub factor: f64,
}

/// The full perf-baseline report.
#[derive(Debug)]
pub struct PerfReport {
    pub quick: bool,
    pub threads: usize,
    pub cases: Vec<Stats>,
    pub speedups: Vec<Speedup>,
}

impl PerfReport {
    fn case(&self, name: &str) -> &Stats {
        self.cases.iter().find(|c| c.name == name).expect("case recorded")
    }

    fn push_speedup(&mut self, name: &str, reference: &str, fast: &str) {
        let factor = self.case(reference).median_ns / self.case(fast).median_ns.max(1e-9);
        self.speedups.push(Speedup {
            name: name.to_string(),
            reference: reference.to_string(),
            fast: fast.to_string(),
            factor,
        });
    }

    /// The `BENCH_perf.json` artifact.
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.3}");
        let mut cases = Vec::with_capacity(self.cases.len());
        for c in &self.cases {
            cases.push(format!(
                "    {{\"name\": \"{}\", \"median_ns\": {}, \"mean_ns\": {}, \
                 \"min_ns\": {}, \"mad_ns\": {}, \"iterations\": {}}}",
                c.name,
                num(c.median_ns),
                num(c.mean_ns),
                num(c.min_ns),
                num(c.mad_ns),
                c.iterations
            ));
        }
        let mut speedups = Vec::with_capacity(self.speedups.len());
        for s in &self.speedups {
            speedups.push(format!(
                "    {{\"name\": \"{}\", \"reference\": \"{}\", \"fast\": \"{}\", \
                 \"factor\": {}}}",
                s.name,
                s.reference,
                s.fast,
                num(s.factor)
            ));
        }
        format!(
            "{{\n  \"experiment\": \"perfbench\",\n  \"quick\": {},\n  \"threads\": {},\n  \
             \"cases\": [\n{}\n  ],\n  \"speedups\": [\n{}\n  ]\n}}\n",
            self.quick,
            self.threads,
            cases.join(",\n"),
            speedups.join(",\n"),
        )
    }

    /// Headline factor by speedup name (for reporting and tests).
    pub fn speedup(&self, name: &str) -> Option<f64> {
        self.speedups.iter().find(|s| s.name == name).map(|s| s.factor)
    }

    /// Post-hoc metrics view of the report — the `.metrics.json` sidecar
    /// the CLI writes next to `BENCH_perf.json`.  Timing-derived values
    /// land in gauges/histograms keyed by stable case names.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        m.inc("perf.cases", self.cases.len() as u64);
        m.set_gauge("perf.quick", if self.quick { 1.0 } else { 0.0 });
        m.set_gauge("perf.threads", self.threads as f64);
        for c in &self.cases {
            m.observe("perf.median_ns", c.median_ns);
        }
        for s in &self.speedups {
            m.set_gauge(&format!("perf.speedup.{}", s.name), s.factor);
        }
        m
    }
}

/// Fraction of a baseline speedup factor a fresh run may lose before
/// the CI gate fails: a >25% regression on any headline fails the job.
pub const CHECK_MAX_REGRESSION: f64 = 0.25;

/// One headline comparison of the `--check` regression gate.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckRow {
    pub name: String,
    /// Committed baseline factor (a conservative floor — see
    /// EXPERIMENTS.md E10).
    pub baseline: f64,
    /// Freshly measured factor.
    pub fresh: f64,
    /// Rounded `fresh / baseline` at artifact precision; the gate
    /// passes at `rounded(fresh) >= rounded(baseline × (1 −
    /// CHECK_MAX_REGRESSION))`, boundary-inclusive.
    pub ratio: f64,
    /// The effective pass floor `rounded(baseline × (1 −
    /// CHECK_MAX_REGRESSION))` — what the fresh factor is gated against.
    pub floor: f64,
    /// `rounded(fresh) − floor`: how much headroom the headline has
    /// above its gate (negative exactly when `pass` is false).
    pub margin: f64,
    pub pass: bool,
}

/// Round to the 3-decimal precision `BENCH_perf.json` stores factors
/// at (`to_json` writes `{v:.3}`), so the gate compares exactly what
/// the artifact records (ISSUE 8 bugfix): raw float math used to make
/// a headline sitting exactly on the floor pass or fail depending on
/// rounding direction across the serialize/reparse trip.
fn round_to_artifact(v: f64) -> f64 {
    format!("{v:.3}").parse().expect("rounded factor reparses")
}

/// Compare a fresh report's `speedups[]` against the committed
/// `BENCH_perf.json` baseline (the `ima-gnn perf --check` gate).
///
/// Every headline named in the baseline must exist in the fresh run and
/// keep at least `1 − CHECK_MAX_REGRESSION` of its committed factor;
/// a missing headline is itself a failure (a silently dropped benchmark
/// must not pass the gate).  Returns one row per baseline headline;
/// callers fail on any `!pass`.
///
/// The comparison is deterministic at artifact precision: both the
/// fresh factor and the regression floor are rounded to the 3 decimals
/// the artifact stores before the boundary-inclusive `>=` — a factor
/// that prints equal to the floor passes regardless of sub-thousandth
/// noise.
pub fn check_against(report: &PerfReport, baseline_json: &str) -> Result<Vec<CheckRow>> {
    use crate::error::Error;
    let doc = crate::json::parse(baseline_json)?;
    let speedups = doc
        .require("speedups")?
        .as_arr()
        .ok_or_else(|| Error::Runtime("baseline `speedups` must be an array".into()))?;
    if speedups.is_empty() {
        return Err(Error::Runtime("baseline has no speedup headlines to gate on".into()));
    }
    let mut rows = Vec::with_capacity(speedups.len());
    for s in speedups {
        let name = s
            .require("name")?
            .as_str()
            .ok_or_else(|| Error::Runtime("baseline speedup `name` must be a string".into()))?
            .to_string();
        let baseline = s
            .require("factor")?
            .as_f64()
            .ok_or_else(|| Error::Runtime(format!("baseline `{name}` factor must be a number")))?;
        if !(baseline > 0.0) {
            return Err(Error::Runtime(format!("baseline `{name}` factor must be > 0")));
        }
        let fresh = report.speedup(&name).ok_or_else(|| {
            Error::Runtime(format!("baseline headline `{name}` missing from the fresh run"))
        })?;
        let fresh_r = round_to_artifact(fresh);
        let base_r = round_to_artifact(baseline);
        let floor = round_to_artifact(baseline * (1.0 - CHECK_MAX_REGRESSION));
        let ratio = if base_r > 0.0 { fresh_r / base_r } else { f64::INFINITY };
        let margin = fresh_r - floor;
        rows.push(CheckRow {
            name,
            baseline,
            fresh,
            ratio,
            floor,
            margin,
            pass: fresh_r >= floor,
        });
    }
    Ok(rows)
}

fn budgets(quick: bool) -> (Duration, Duration) {
    if quick {
        (Duration::from_millis(10), Duration::from_millis(40))
    } else {
        (Duration::from_millis(150), Duration::from_millis(750))
    }
}

/// Run the full baseline.  `quick` shrinks every measurement budget (CI
/// smoke / unit tests); the artifact CI uploads uses the full budget.
pub fn run(quick: bool) -> Result<PerfReport> {
    let (warmup, measure) = budgets(quick);
    let mut b = Bench::new().with_budget(warmup, measure);
    let mut rng = Rng::new(5);

    // --- 512×512 binary-activation aggregate: the paper's aggregation
    // core inner loop, and the acceptance kernel of this baseline. ------
    b.section("aggregate kernel (512x512 window, binary activations)");
    let cfg = presets::decentralized();
    let rows = cfg.aggregation.geometry.rows;
    let cols = cfg.aggregation.geometry.cols;
    let feats: Vec<Vec<i32>> = (0..rows)
        .map(|_| (0..cols).map(|_| rng.i64_in(-8, 7) as i32).collect())
        .collect();
    let window = Tile::from_rows(&feats)?;
    let active: Vec<bool> = (0..rows).map(|_| rng.bool()).collect();

    // Seed path (frozen replica — see `seed_aggregate`): flatten the
    // ragged rows, reprogram the full array, run the bit-serial plane
    // loop, copy the column group out — every call.
    let g = cfg.aggregation.geometry;
    let mut seed_array = vec![0i32; g.rows * g.cols];
    b.case("aggregate/seed: flatten + program + bit-serial", || {
        black_box(seed_aggregate(
            &mut seed_array,
            g.rows,
            g.cols,
            &feats,
            &active,
            g.input_bits,
            g.adc_bits,
        ))
    });

    // Flat path: the window is programmed once and stays resident
    // (program-once / evaluate-many); each call packs the activation
    // vector and runs the single-plane accumulate into a reused buffer —
    // zero allocations, no reprogramming.
    let mut agg = AggregationCore::new(cfg.aggregation, cfg.device.clone())?;
    agg.program_window(&window)?;
    let mut agg_out = vec![0i64; cols];
    agg.accumulate_into(&active, &mut agg_out)?;
    // Both paths must agree bit-for-bit before either is timed.
    let seed_out =
        seed_aggregate(&mut vec![0i32; g.rows * g.cols], g.rows, g.cols, &feats, &active, g.input_bits, g.adc_bits);
    assert_eq!(agg_out, seed_out, "fast aggregate diverged from the seed replica");
    b.case("aggregate/fast: resident window + packed accumulate", || {
        agg.accumulate_into(&active, &mut agg_out).unwrap();
        black_box(agg_out[0])
    });

    // --- full 8-bit MVM evaluate: bit-serial vs fused clip-free. --------
    b.section("mvm evaluate (512x512, 8-bit inputs)");
    let mut mvm = MvmCrossbar::new(
        CrossbarGeometry::new(512, 512),
        DeviceParams::default_45nm(),
    )?;
    let weights: Vec<i32> =
        (0..512 * 512).map(|_| rng.i64_in(-8, 7) as i32).collect();
    mvm.program(&weights)?;
    let input: Vec<u32> = (0..512).map(|_| rng.u64_in(0, 255) as u32).collect();
    b.case("mvm/seed: bit-serial reference", || {
        black_box(mvm.evaluate_reference(&input).unwrap())
    });
    let mut mvm_out = vec![0i64; 512];
    b.case("mvm/fast: fused clip-free evaluate_into", || {
        mvm.evaluate_into(&input, &mut mvm_out).unwrap();
        black_box(mvm_out[0])
    });

    // --- dense-mask accumulate_rows: seed bit-walk vs SWAR lanes. -------
    // A ~7/8-dense activation mask over the 512×512 array programmed
    // above: every word clears DENSE_WORD_THRESHOLD, so the live call
    // takes the word-dense column-block path while the seed replica pays
    // the per-bit walk with a scalar column loop.
    b.section("accumulate_rows (512x512, ~7/8-dense mask)");
    let mut dense_mask = vec![0u64; 512 / 64];
    for r in 0..512 {
        if rng.index(8) != 0 {
            dense_mask[r / 64] |= 1u64 << (r % 64);
        }
    }
    let adc_bits = mvm.geometry().adc_bits;
    let mut accum_out = vec![0i64; 512];
    let mut accum_seed_out = vec![0i64; 512];
    mvm.accumulate_rows(&dense_mask, &mut accum_out)?;
    seed_accumulate_rows(&weights, 512, adc_bits, &dense_mask, &mut accum_seed_out);
    assert_eq!(accum_out, accum_seed_out, "dense accumulate diverged from the seed replica");
    b.case("accum/seed: sparse bit-walk", || {
        seed_accumulate_rows(&weights, 512, adc_bits, &dense_mask, &mut accum_seed_out);
        black_box(accum_seed_out[0])
    });
    b.case("accum/fast: dense word lanes", || {
        mvm.accumulate_rows(&dense_mask, &mut accum_out).unwrap();
        black_box(accum_out[0])
    });

    // --- CSR construction (the graph ingestion hot path). ---------------
    b.section("csr build");
    let n_nodes = if quick { 2_000 } else { 10_000 };
    let n_edges = if quick { 20_000 } else { 100_000 };
    let edges: Vec<(usize, usize)> = (0..n_edges)
        .map(|_| (rng.index(n_nodes), rng.index(n_nodes)))
        .collect();
    b.case("csr: from_edges (direct build)", || {
        black_box(Csr::from_edges(n_nodes, &edges).unwrap())
    });

    // --- resident-set fetch: warm LRU hit vs decode-every-call. ---------
    // One 4096×64 shard (a 1 MiB decoded table, the E16 residency tier's
    // unit of caching).  The seed side pays what every fetch would cost
    // without the LRU — decode the quantized blob and materialize a
    // fresh tensor per call; the fast side is a warm `ResidentSet::fetch`
    // (an Arc clone plus LRU bookkeeping).  Both must return the same
    // tensor before either is timed.
    b.section("resident fetch (4096x64 shard, warm cache vs decode)");
    let res_rows = 4_096usize;
    let res_feature = 64usize;
    let res_vals: Vec<f32> =
        (0..res_rows * res_feature).map(|_| rng.index(512) as f32).collect();
    let res_bytes = res_vals.len() * std::mem::size_of::<f32>();
    let mut res_set = ResidentSet::new(1, res_feature, FeatureQuant::ExactI32, res_bytes)?;
    res_set.store(0, &res_vals)?;
    let warm = res_set.fetch(0)?; // prime the cache
    let res_blob = QuantizedFeatures::encode(FeatureQuant::ExactI32, &res_vals)?;
    let seed_fetch =
        || Tensor::f32(&[res_rows, res_feature], res_blob.decode()).unwrap();
    assert_eq!(seed_fetch(), warm, "decode replica diverged from the resident fetch");
    b.case("resident/seed: decode every fetch", || black_box(seed_fetch()));
    b.case("resident/fast: warm LRU fetch", || black_box(res_set.fetch(0).unwrap()));

    // --- netsim scenarios (the event-loop hot path). --------------------
    b.section("netsim scenarios");
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let net_cfg = NetSimConfig { rx_ports: Some(64), ..Default::default() };
    let star_n = if quick { 500 } else { 2_000 };
    let star = Topology { nodes: star_n, cluster_size: 10 };
    b.case("netsim: centralized star (contended)", || {
        black_box(simulate_fabric(&model, Scenario::CentralizedStar, star, &net_cfg).unwrap())
    });
    let mesh_n = if quick { 200 } else { 500 };
    let mesh = Topology { nodes: mesh_n, cluster_size: 10 };
    b.case("netsim: decentralized mesh", || {
        black_box(
            simulate_fabric(&model, Scenario::DecentralizedMesh, mesh, &net_cfg).unwrap(),
        )
    });

    let threads = par::available_threads();

    // --- multi-shard batch assembly: sequential vs parallel. ------------
    // A LiveJournal-shaped serving plan: a regular graph sharded into
    // 64 (8 in quick mode) 128-row tables, with every node requested —
    // hundreds of per-shard chunk builds, each gathering a 32×256 f32
    // batch, so the work items are large enough to amortize the
    // scoped-thread fan-out `assemble_with_threads` uses.
    b.section("batch assembly (multi-shard plan, sequential vs parallel)");
    let asm_n = if quick { 1_024 } else { 8_192 };
    let asm_binding = GcnLayerBinding {
        artifact: "gcn_layer_perf".to_string(),
        batch: 32,
        sample: 8,
        feature: 256,
        hidden: 16,
        table: 128,
    };
    let asm_graph = generate::regular(asm_n, 6, 3)?;
    let asm_sampler = NeighborSampler::new(asm_binding.sample, 7);
    let asm_plan = ShardPlan::build(&asm_graph, &asm_sampler, asm_binding.table)?;
    let asm_weights = vec![0.01f32; asm_binding.feature * asm_binding.hidden];
    let mut engine = RoundEngine::new(asm_binding.clone(), asm_plan, asm_weights)?;
    let req: Vec<usize> = (0..asm_n).collect();
    // Sequential and parallel assembly must be byte-identical before
    // either is timed.
    let asm_seq = engine.assemble_with_threads(&req, 1)?;
    assert_eq!(
        asm_seq,
        engine.assemble_with_threads(&req, threads)?,
        "parallel assembly diverged from sequential"
    );
    b.case("assemble/seed: sequential per-shard batches", || {
        black_box(engine.assemble_with_threads(&req, 1).unwrap().len())
    });
    b.case("assemble/fast: parallel per-shard batches", || {
        black_box(engine.assemble_with_threads(&req, threads).unwrap().len())
    });

    // --- end-to-end offline round: seed replica vs live engine. ---------
    // One full round — upload every node's features (home + halo), run
    // the barrier (flip + table build), assemble every batch.  The seed
    // side replays the pre-engine composition (per-row gathers, fresh
    // allocations, BTreeMap grouping); the live side is `upload` /
    // `end_round` / `assemble` with parallel assembly enabled.
    b.section("offline round (upload + barrier + assemble)");
    engine.set_assembly_threads(threads);
    let feat_row = vec![0.3f32; asm_binding.feature];
    for node in 0..asm_n {
        engine.upload(node, &feat_row)?;
    }
    engine.end_round();
    let live_batches = engine.assemble(&req)?;
    let mut seed_stores: Vec<FeatureStore> = (0..engine.plan().num_shards())
        .map(|_| FeatureStore::new(asm_binding.table, asm_binding.feature))
        .collect();
    let (seed_tables, seed_batches) =
        seed_offline_round(&asm_binding, engine.plan(), &mut seed_stores, &feat_row, &req);
    assert_eq!(live_batches, seed_batches, "engine round diverged from the seed replica");
    for (s, table) in seed_tables.iter().enumerate() {
        assert_eq!(
            engine.table_tensor(s).expect("barrier ran").as_f32()?,
            &table[..],
            "table tensor {s} diverged from the seed replica"
        );
    }
    b.case("round/seed: per-row gather + fresh-alloc assemble", || {
        black_box(
            seed_offline_round(&asm_binding, engine.plan(), &mut seed_stores, &feat_row, &req)
                .1
                .len(),
        )
    });
    b.case("round/fast: engine barrier + assemble", || {
        for node in 0..asm_n {
            engine.upload(node, &feat_row).unwrap();
        }
        engine.end_round();
        black_box(engine.assemble(&req).unwrap().len())
    });

    // --- E9 sweep grid: sequential vs parallel driver. ------------------
    b.section("E9 sweep grid (sequential vs parallel)");
    let (grid_nodes, grid_cs): (&[usize], &[usize]) = if quick {
        (&[200, 500], &[5, 10])
    } else {
        (&[500, 1_000, 2_000], &[5, 10, 25])
    };
    let reps = if quick { 1 } else { 3 };
    let workload = GnnWorkload::taxi();
    let grid_case = |name: &str, t: usize| -> Result<Stats> {
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            black_box(NetsimSweep::run_with_threads(
                &workload, grid_nodes, grid_cs, &net_cfg, t,
            )?);
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Stats::from_samples(name, &mut samples);
        println!("{stats}");
        Ok(stats)
    };
    // Stable case name — the worker count is the top-level `threads`
    // field, so trajectory comparisons can key on the name across
    // machines with different core counts.
    let seq_stats = grid_case("e9/seed: sequential sweep", 1)?;
    let par_stats = grid_case("e9/fast: parallel sweep", threads)?;

    let mut report = PerfReport {
        quick,
        threads,
        cases: b.results().to_vec(),
        speedups: Vec::new(),
    };
    report.cases.push(seq_stats);
    report.cases.push(par_stats);

    report.push_speedup(
        "aggregate_512_binary",
        "aggregate/seed: flatten + program + bit-serial",
        "aggregate/fast: resident window + packed accumulate",
    );
    report.push_speedup(
        "mvm_512_8bit",
        "mvm/seed: bit-serial reference",
        "mvm/fast: fused clip-free evaluate_into",
    );
    report.push_speedup(
        "accumulate_dense_mask",
        "accum/seed: sparse bit-walk",
        "accum/fast: dense word lanes",
    );
    report.push_speedup(
        "assemble_par",
        "assemble/seed: sequential per-shard batches",
        "assemble/fast: parallel per-shard batches",
    );
    report.push_speedup(
        "round_offline",
        "round/seed: per-row gather + fresh-alloc assemble",
        "round/fast: engine barrier + assemble",
    );
    report.push_speedup(
        "resident_warm_fetch",
        "resident/seed: decode every fetch",
        "resident/fast: warm LRU fetch",
    );
    report.push_speedup("e9_sweep_parallel", "e9/seed: sequential sweep", "e9/fast: parallel sweep");
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Structural check on a quick run: every headline case present and
    /// the JSON artifact parses with the crate's own parser.  No
    /// wall-clock threshold is asserted here — timing bounds flake on
    /// contended CI runners; the ≥5× headline lives in the release
    /// `BENCH_perf.json` artifact, and correctness of the fast path is
    /// asserted unconditionally inside `run` (seed-replica equality) and
    /// in `crossbar::mvm`'s property tests.
    #[test]
    fn quick_run_produces_a_wellformed_artifact() {
        let report = run(true).unwrap();
        assert!(report.cases.len() >= 16);
        for name in [
            "aggregate_512_binary",
            "mvm_512_8bit",
            "accumulate_dense_mask",
            "assemble_par",
            "round_offline",
            "resident_warm_fetch",
            "e9_sweep_parallel",
        ] {
            let f = report.speedup(name).unwrap();
            assert!(f.is_finite() && f > 0.0, "{name}: {f}");
        }
        let json = report.to_json();
        let doc = crate::json::parse(&json).unwrap();
        assert_eq!(doc.get("experiment").unwrap().as_str(), Some("perfbench"));
        assert_eq!(doc.get("quick").unwrap(), &crate::json::Json::Bool(true));
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), report.cases.len());
        assert!(cases[0].get("median_ns").unwrap().as_f64().unwrap() > 0.0);
        let speedups = doc.get("speedups").unwrap().as_arr().unwrap();
        assert_eq!(speedups.len(), 7);

        // The regression gate round-trips through the artifact: a fresh
        // run checked against its own JSON passes every headline with
        // ratio ~1 (the artifact rounds factors to 3 decimals).
        let rows = check_against(&report, &json).unwrap();
        assert_eq!(rows.len(), 7);
        for r in &rows {
            assert!(r.pass, "{}: self-check must pass", r.name);
            assert!((r.ratio - 1.0).abs() < 1e-2, "{}: ratio {}", r.name, r.ratio);
            assert!(r.margin >= 0.0, "{}: margin {}", r.name, r.margin);
            assert!((r.floor + r.margin - round_to_artifact(r.fresh)).abs() < 1e-9, "{}", r.name);
        }
    }

    /// Regression (ISSUE 8): the gate used raw float math
    /// (`fresh/baseline >= 0.75`) while the artifact rounds factors to
    /// 3 decimals — a fresh factor printing exactly at the floor could
    /// fail by a sub-thousandth.  Pin the exact edge: baseline 4.000,
    /// floor 3.000; a fresh 2.9996 *prints* as 3.000 and must pass,
    /// 2.9994 prints as 2.999 and must fail.
    #[test]
    fn check_gate_boundary_is_inclusive_at_artifact_precision() {
        let at = |fresh: f64| PerfReport {
            quick: true,
            threads: 1,
            cases: Vec::new(),
            speedups: vec![Speedup {
                name: "edge".into(),
                reference: "ref".into(),
                fast: "fast".into(),
                factor: fresh,
            }],
        };
        let baseline = r#"{"speedups": [{"name": "edge", "factor": 4.0}]}"#;
        // Exactly on the floor: inclusive pass, zero margin.
        let rows = check_against(&at(3.0), baseline).unwrap();
        assert!(rows[0].pass, "boundary must be inclusive");
        assert_eq!(rows[0].floor, 3.0);
        assert_eq!(rows[0].margin, 0.0);
        // Rounds up to the floor: pass (pre-fix: 2.9996/4 = 0.7499 < 0.75).
        assert!(check_against(&at(2.9996), baseline).unwrap()[0].pass);
        // Rounds below the floor: fail, with a negative margin.
        let below = check_against(&at(2.9994), baseline).unwrap();
        assert!(!below[0].pass);
        assert!(below[0].margin < 0.0);
        // The artifact round-trip is the identity for the gate: a
        // factor and its 3-decimal print compare identically.
        assert_eq!(
            check_against(&at(3.000_4), baseline).unwrap()[0].pass,
            check_against(&at(3.0), baseline).unwrap()[0].pass
        );
    }

    #[test]
    fn check_gate_fails_on_regressions_and_malformed_baselines() {
        let report = run(true).unwrap();
        // An absurdly high committed factor → >25% regression → fail.
        let demanding = r#"{"speedups": [
            {"name": "aggregate_512_binary", "factor": 1.0e9}]}"#;
        let rows = check_against(&report, demanding).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(!rows[0].pass);
        assert!(rows[0].ratio < 0.75);
        // A factor floor of ~0 always passes.
        let floor = r#"{"speedups": [
            {"name": "aggregate_512_binary", "factor": 1.0e-6},
            {"name": "mvm_512_8bit", "factor": 1.0e-6}]}"#;
        assert!(check_against(&report, floor).unwrap().iter().all(|r| r.pass));
        // A headline the fresh run no longer produces must fail loudly,
        // as must malformed or empty baselines.
        let missing = r#"{"speedups": [{"name": "gone_headline", "factor": 2.0}]}"#;
        assert!(check_against(&report, missing).is_err());
        assert!(check_against(&report, "{not json").is_err());
        assert!(check_against(&report, r#"{"speedups": []}"#).is_err());
        assert!(check_against(&report, r#"{"speedups": 7}"#).is_err());
        let bad_factor = r#"{"speedups": [{"name": "aggregate_512_binary", "factor": 0}]}"#;
        assert!(check_against(&report, bad_factor).is_err());
    }
}
