//! Packet-level, contention-aware network fabric simulator.
//!
//! The closed-form model (`netmodel`, Eqs. 1–7) assumes away everything
//! that dominates real GNN-accelerator communication: Eq. (5) takes the
//! centralized uplinks as perfectly concurrent, Eq. (4) gives every
//! cluster device a dedicated channel.  This subsystem models the three
//! deployment topologies as message-passing fabrics over the
//! deterministic event queue (`sim::EventQueue`):
//!
//! * **Centralized star** — every device uplinks over the V2X link L_n
//!   into the leader's receive-port pool ([`NetSimConfig::rx_ports`]);
//!   messages packetize exactly as [`crate::comm::InterNetworkLink`] does.
//! * **Decentralized mesh** — per-device half-duplex radios, tₑ session
//!   setup, cₛ store-and-forward transfers per direction over L_c, an
//!   optional shared CSMA medium per cluster
//!   ([`NetSimConfig::cluster_channels`]) and multi-hop relaying
//!   ([`NetSimConfig::hops`]).
//! * **Semi-decentralized overlay** — V2X star per cluster into each
//!   head, head-side batching, head↔head boundary exchange, downlink.
//!
//! **Cross-validation invariant:** with every capacity knob unlimited
//! (the defaults) the simulated communication latencies coincide with
//! Eqs. (4)/(5) and the E8 hybrid model to within float round-off — the
//! analytic equations are the uncongested fixed point of this simulator
//! (asserted in `rust/tests/netsim_cross_validation.rs` and the tests
//! below).  The knobs then expose what the equations cannot: queueing
//! under finite ports, CSMA serialization, relay chains.
//!
//! Entry points: [`simulate_fabric`] for one round of one scenario, and
//! [`NetSim`] as a [`CommFabric`] implementation that `netmodel`
//! consumes via [`NetModel::latency_via`].
//!
//! DESIGN.md: §6 (simulation).

mod fabric;
mod scenario;

use crate::error::Result;
use crate::netmodel::{CommFabric, NetModel, Setting, Topology};
use crate::obs::Obs;
use crate::units::Time;

/// Capacity and behavior knobs of the fabric.
///
/// The defaults reproduce the paper's assumptions (no contention), so a
/// default-configured run must agree with the analytic model.
#[derive(Debug, Clone)]
pub struct NetSimConfig {
    /// Concurrent receive ports at the central leader / each cluster head.
    /// `None` = unlimited (Eq. 5's "concurrent transfers" assumption).
    pub rx_ports: Option<usize>,
    /// Simultaneous transfers the intra-cluster radio medium admits.
    /// `None` = dedicated channels (Eq. 4's assumption); `Some(1)` = CSMA.
    pub cluster_channels: Option<usize>,
    /// Store-and-forward relay hops per cluster exchange (§4.2's relaying
    /// configuration; 1 = adjacent nodes).
    pub hops: usize,
    /// Overlap the aggregation and feature-extraction cores in the compute
    /// composition (paper §2.3), like `sim::SimConfig::overlap_cores`.
    pub overlap_cores: bool,
    /// Multiplicative per-packet jitter, uniform in `[1, 1 + link_jitter]`.
    /// 0 = deterministic (the cross-validation setting).
    pub link_jitter: f64,
    /// Seed for the jitter stream; runs are bit-identical per seed.
    pub seed: u64,
}

impl Default for NetSimConfig {
    fn default() -> Self {
        NetSimConfig {
            rx_ports: None,
            cluster_channels: None,
            hops: 1,
            overlap_cores: false,
            link_jitter: 0.0,
            seed: 1,
        }
    }
}

/// Which fabric to simulate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// Star over L_n into the central leader (paper Fig. 4(a)).
    CentralizedStar,
    /// Multi-hop cluster mesh over L_c (paper Fig. 4(b)).
    DecentralizedMesh,
    /// Cluster-head overlay (conclusion / E8) with heads `head_capacity`×
    /// as strong as a member device.
    SemiOverlay { head_capacity: f64 },
}

/// Outcome of one simulated round.
#[derive(Debug, Clone, PartialEq)]
pub struct NetSimReport {
    /// Time the round finished (last communication or compute event).
    pub completion: Time,
    /// Time the last message was delivered.
    pub comm_done: Time,
    /// Events processed.
    pub events: usize,
    /// Messages injected (sessions, boundary exchanges, downlinks).
    pub messages: usize,
    /// Packets put on the air.
    pub packets: usize,
    /// Devices simulated.
    pub devices: usize,
    /// Packets that had to wait for a busy resource.
    pub contended_packets: usize,
    /// Total time packets spent queued on busy resources.
    pub queue_wait: Time,
    /// Aggregate reserved (on-air) time across every fabric resource.
    pub busy_total: Time,
}

impl NetSimReport {
    /// Fraction of packets that experienced queueing.
    pub fn contention_fraction(&self) -> f64 {
        if self.packets == 0 {
            0.0
        } else {
            self.contended_packets as f64 / self.packets as f64
        }
    }
}

/// Simulate one full communication (+ compute) round of `scenario`.
pub fn simulate_fabric(
    model: &NetModel,
    scenario: Scenario,
    topo: Topology,
    cfg: &NetSimConfig,
) -> Result<NetSimReport> {
    simulate_fabric_observed(model, scenario, topo, cfg, &Obs::disabled())
}

/// [`simulate_fabric`] with an observability handle: every on-air packet
/// becomes a `net.packet` span on the *simulated* time axis (track = the
/// first claimed resource id, `wait_us` = time queued on busy resources)
/// and the fabric counters (`net.packets`, `net.contended`,
/// `net.messages`, the `net.queue_wait_us` histogram and the
/// `sim.event_queue.*` depth gauges) land in `obs.metrics`.  The
/// simulated schedule — and therefore the report — is bit-identical to
/// [`simulate_fabric`].
pub fn simulate_fabric_observed(
    model: &NetModel,
    scenario: Scenario,
    topo: Topology,
    cfg: &NetSimConfig,
    obs: &Obs,
) -> Result<NetSimReport> {
    match scenario {
        Scenario::CentralizedStar => scenario::centralized(model, topo, cfg, obs),
        Scenario::DecentralizedMesh => scenario::decentralized(model, topo, cfg, obs),
        Scenario::SemiOverlay { head_capacity } => {
            scenario::semi(model, topo, head_capacity, cfg, obs)
        }
    }
}

/// [`CommFabric`] adapter: lets `netmodel` swap Eqs. (4)/(5) for the
/// packet-level fabric (`model.latency_via(&NetSim::new(cfg), ...)`).
#[derive(Debug, Clone, Default)]
pub struct NetSim {
    pub cfg: NetSimConfig,
}

impl NetSim {
    pub fn new(cfg: NetSimConfig) -> NetSim {
        NetSim { cfg }
    }
}

impl CommFabric for NetSim {
    fn round_comm_latency(
        &self,
        model: &NetModel,
        setting: Setting,
        topo: Topology,
    ) -> Result<Time> {
        let scenario = match setting {
            Setting::Centralized => Scenario::CentralizedStar,
            Setting::Decentralized => Scenario::DecentralizedMesh,
        };
        Ok(simulate_fabric(model, scenario, topo, &self.cfg)?.comm_done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::GnnWorkload;
    use crate::testing::assert_close;

    fn model() -> NetModel {
        NetModel::paper(&GnnWorkload::taxi()).unwrap()
    }

    fn topo() -> Topology {
        Topology { nodes: 200, cluster_size: 10 }
    }

    /// The acceptance invariant: uncongested single-message latencies
    /// match Eq. (5) / Eq. (4) / the E8 hybrid within 1% (they agree to
    /// round-off; 1% is the criterion's bound).
    #[test]
    fn uncongested_fabric_matches_the_analytic_equations() {
        let m = model();
        let t = topo();
        let cfg = NetSimConfig::default();

        let cent = simulate_fabric(&m, Scenario::CentralizedStar, t, &cfg).unwrap();
        let c_analytic = m.latency(Setting::Centralized, t);
        assert_close(cent.comm_done.as_s(), c_analytic.communicate.as_s(), 0.01);
        assert_close(cent.comm_done.as_s(), c_analytic.communicate.as_s(), 1e-9);
        assert_close(cent.completion.as_s(), c_analytic.total().as_s(), 1e-6);

        let dec = simulate_fabric(&m, Scenario::DecentralizedMesh, t, &cfg).unwrap();
        let d_analytic = m.latency(Setting::Decentralized, t);
        assert_close(dec.comm_done.as_s(), d_analytic.communicate.as_s(), 0.01);
        assert_close(dec.comm_done.as_s(), d_analytic.communicate.as_s(), 1e-9);
        assert_close(dec.completion.as_s(), d_analytic.total().as_s(), 1e-6);

        let semi =
            simulate_fabric(&m, Scenario::SemiOverlay { head_capacity: 10.0 }, t, &cfg)
                .unwrap();
        let s_analytic = m.semi_latency(t, 10.0);
        assert_close(semi.completion.as_s(), s_analytic.total().as_s(), 0.01);
        assert_close(semi.completion.as_s(), s_analytic.total().as_s(), 1e-6);

        // Nothing queued anywhere.
        for r in [&cent, &dec, &semi] {
            assert_eq!(r.contended_packets, 0, "{r:?}");
            assert_eq!(r.queue_wait, Time::ZERO);
        }
    }

    #[test]
    fn finite_rx_ports_make_uplinks_contend() {
        let m = model();
        let t = topo();
        let free = simulate_fabric(&m, Scenario::CentralizedStar, t, &NetSimConfig::default())
            .unwrap();
        let mut cfg = NetSimConfig { rx_ports: Some(4), ..Default::default() };
        let ported = simulate_fabric(&m, Scenario::CentralizedStar, t, &cfg).unwrap();
        assert!(ported.comm_done > free.comm_done);
        assert!(ported.contended_packets > 0);
        assert!(ported.queue_wait > Time::ZERO);
        // Tighter pools queue longer.
        cfg.rx_ports = Some(1);
        let serial = simulate_fabric(&m, Scenario::CentralizedStar, t, &cfg).unwrap();
        assert!(serial.comm_done > ported.comm_done);
        // One port = fully serialized uplink: N · transfer.
        let transfer = m.inter_link().transfer(m.message_bytes());
        assert_close(
            serial.comm_done.as_s(),
            (transfer * t.nodes as f64).as_s(),
            1e-9,
        );
    }

    #[test]
    fn csma_medium_serializes_cluster_exchanges() {
        let m = model();
        let t = Topology { nodes: 60, cluster_size: 6 };
        let dedicated =
            simulate_fabric(&m, Scenario::DecentralizedMesh, t, &NetSimConfig::default())
                .unwrap();
        let csma = simulate_fabric(
            &m,
            Scenario::DecentralizedMesh,
            t,
            &NetSimConfig { cluster_channels: Some(1), ..Default::default() },
        )
        .unwrap();
        assert!(
            csma.comm_done > dedicated.comm_done * 2.0,
            "CSMA {} vs dedicated {}",
            csma.comm_done,
            dedicated.comm_done
        );
        assert!(csma.contended_packets > 0);
        // A wider medium sits between the two.
        let two = simulate_fabric(
            &m,
            Scenario::DecentralizedMesh,
            t,
            &NetSimConfig { cluster_channels: Some(2), ..Default::default() },
        )
        .unwrap();
        assert!(two.comm_done < csma.comm_done);
        assert!(two.comm_done >= dedicated.comm_done);
    }

    #[test]
    fn relay_hops_stretch_the_mesh() {
        let m = model();
        let t = Topology { nodes: 40, cluster_size: 4 };
        let one = simulate_fabric(&m, Scenario::DecentralizedMesh, t, &NetSimConfig::default())
            .unwrap();
        let three = simulate_fabric(
            &m,
            Scenario::DecentralizedMesh,
            t,
            &NetSimConfig { hops: 3, ..Default::default() },
        )
        .unwrap();
        assert!(three.comm_done > one.comm_done);
        // Hop time triples; setup does not: 2(tₑ + cs·3·hop) vs 2(tₑ + cs·hop).
        let link = m.intra_link();
        let want = (link.setup() + link.hop(m.message_bytes()) * 3.0 * 4.0) * 2.0;
        assert_close(three.comm_done.as_s(), want.as_s(), 1e-9);
    }

    #[test]
    fn runs_are_bit_identical_per_seed() {
        let m = model();
        let t = Topology { nodes: 120, cluster_size: 8 };
        let cfg = NetSimConfig {
            rx_ports: Some(6),
            cluster_channels: Some(1),
            link_jitter: 0.3,
            seed: 42,
            ..Default::default()
        };
        for sc in [
            Scenario::CentralizedStar,
            Scenario::DecentralizedMesh,
            Scenario::SemiOverlay { head_capacity: 8.0 },
        ] {
            let a = simulate_fabric(&m, sc, t, &cfg).unwrap();
            let b = simulate_fabric(&m, sc, t, &cfg).unwrap();
            assert_eq!(a, b, "{sc:?} must be bit-identical per seed");
        }
        // A different seed perturbs the jittered schedule.
        let other = NetSimConfig { seed: 43, ..cfg.clone() };
        let a = simulate_fabric(&m, Scenario::DecentralizedMesh, t, &cfg).unwrap();
        let c = simulate_fabric(&m, Scenario::DecentralizedMesh, t, &other).unwrap();
        assert_ne!(a.completion, c.completion);
    }

    #[test]
    fn jitter_only_delays() {
        let m = model();
        let t = Topology { nodes: 80, cluster_size: 8 };
        for sc in [
            Scenario::CentralizedStar,
            Scenario::DecentralizedMesh,
            Scenario::SemiOverlay { head_capacity: 4.0 },
        ] {
            let base = simulate_fabric(&m, sc, t, &NetSimConfig::default()).unwrap();
            let jit = simulate_fabric(
                &m,
                sc,
                t,
                &NetSimConfig { link_jitter: 0.25, ..Default::default() },
            )
            .unwrap();
            assert!(jit.completion >= base.completion, "{sc:?}");
        }
    }

    #[test]
    fn netmodel_consumes_the_fabric_through_the_trait() {
        let m = model();
        let t = topo();
        let sim = NetSim::default();
        for s in [Setting::Centralized, Setting::Decentralized] {
            let via = m.latency_via(&sim, s, t).unwrap();
            let analytic = m.latency(s, t);
            assert_close(via.communicate.as_s(), analytic.communicate.as_s(), 1e-9);
            assert_eq!(via.compute, analytic.compute);
        }
    }

    #[test]
    fn rejects_degenerate_topologies() {
        let m = model();
        let cfg = NetSimConfig::default();
        let empty = Topology { nodes: 0, cluster_size: 1 };
        assert!(simulate_fabric(&m, Scenario::CentralizedStar, empty, &cfg).is_err());
        let no_cluster = Topology { nodes: 5, cluster_size: 0 };
        assert!(simulate_fabric(&m, Scenario::DecentralizedMesh, no_cluster, &cfg).is_err());
        assert!(simulate_fabric(
            &m,
            Scenario::SemiOverlay { head_capacity: 0.5 },
            topo(),
            &cfg
        )
        .is_err());
    }

    #[test]
    fn event_and_packet_counts_are_structural() {
        let m = model();
        let t = Topology { nodes: 30, cluster_size: 5 };
        let p = m.inter_link().packets(m.message_bytes());
        let cent =
            simulate_fabric(&m, Scenario::CentralizedStar, t, &NetSimConfig::default()).unwrap();
        assert_eq!(cent.messages, 30);
        assert_eq!(cent.packets, 30 * p);
        assert_eq!(cent.devices, 30);
        let dec = simulate_fabric(&m, Scenario::DecentralizedMesh, t, &NetSimConfig::default())
            .unwrap();
        // two sessions per device, cₛ transfers each
        assert_eq!(dec.messages, 60);
        assert_eq!(dec.packets, 60 * 5);
    }
}
