//! Scenario drivers: the three deployment fabrics as message-passing
//! protocols over the packet engine.
//!
//! Each driver lays devices, radios, receive-port pools and (optionally)
//! shared cluster media out as [`Resource`]s, injects the round's messages
//! and then runs the deterministic event loop: `Start` → per-packet
//! `Packet` completions (reserving the claimed resources for each on-air
//! interval) → protocol continuations (follow-up sessions, compute
//! events).  With every capacity knob left unlimited the schedules
//! collapse to the closed-form Eqs. (4)/(5) — the cross-validation
//! invariant `netsim_cross_validation.rs` asserts.
//!
//! DESIGN.md: §6 (simulation).

use crate::error::{Error, Result};
use crate::netmodel::{NetModel, Topology};
use crate::obs::Obs;
use crate::sim::EventQueue;
use crate::testing::Rng;
use crate::units::Time;

use super::fabric::{reserve, Resource};
use super::{NetSimConfig, NetSimReport};

/// One directed message: `packets` store-and-forward units, each holding
/// every claimed resource for `per_packet` (± jitter) on air.
struct Msg {
    claims: Vec<usize>,
    packets: usize,
    sent: usize,
    per_packet: Time,
    /// Connection-establishment time charged before the first packet
    /// (off-medium, like the analytic tₑ).
    setup: Time,
    done: Done,
}

/// Protocol continuation fired when a message's last packet lands.
#[derive(Debug, Clone, Copy)]
enum Done {
    /// Centralized: one device's uplink reached the leader.
    CentUplink,
    /// Decentralized: a device finished its outbound exchange session.
    DecOutbound { device: usize },
    /// Decentralized: a device finished its inbound exchange session.
    DecInbound,
    /// Semi: one member's V2X upload reached its cluster head.
    SemiUplink { cluster: usize },
    /// Semi: a head finished the two-way boundary exchange.
    SemiBoundary { cluster: usize },
    /// Semi: a head's downlink broadcast landed (terminal).
    SemiDownlink,
}

/// What follows a compute completion.
#[derive(Debug, Clone, Copy)]
enum After {
    /// Terminal compute (leader slot, device inference).
    End,
    /// Semi head finished its member batch: start the boundary exchange.
    Boundary { cluster: usize },
}

enum Ev {
    /// A message becomes eligible to transmit.
    Start(usize),
    /// One packet of a message finished its on-air interval.
    Packet(usize),
    /// A compute phase finished.
    Compute(After),
}

/// Shared engine state: resources, messages, the deterministic event
/// queue and the statistics every scenario reports.
struct Sim<'a> {
    queue: EventQueue<Ev>,
    msgs: Vec<Msg>,
    res: Vec<Resource>,
    rng: Rng,
    jitter: f64,
    events: usize,
    packets_sent: usize,
    contended: usize,
    queue_wait: Time,
    comm_done: Time,
    completion: Time,
    /// Observability handle: `net.packet` spans on the sim-time axis plus
    /// the fabric counters.  Disabled by default through
    /// [`super::simulate_fabric`]; the simulated schedule is identical
    /// either way.
    obs: &'a Obs,
}

impl<'a> Sim<'a> {
    /// `msgs_hint` / `events_hint`: expected message and event counts —
    /// the scenarios know both up front, so the queue and the message
    /// table never regrow mid-run.
    fn new(cfg: &NetSimConfig, msgs_hint: usize, events_hint: usize, obs: &'a Obs) -> Sim<'a> {
        Sim {
            queue: EventQueue::with_capacity(events_hint),
            msgs: Vec::with_capacity(msgs_hint),
            res: Vec::new(),
            rng: Rng::new(cfg.seed),
            jitter: cfg.link_jitter.max(0.0),
            events: 0,
            packets_sent: 0,
            contended: 0,
            queue_wait: Time::ZERO,
            comm_done: Time::ZERO,
            completion: Time::ZERO,
            obs,
        }
    }

    fn add_resource(&mut self, r: Resource) -> usize {
        self.res.push(r);
        self.res.len() - 1
    }

    /// Register `msg` and schedule its `Start` at `at`.
    fn send(&mut self, msg: Msg, at: Time) {
        debug_assert!(msg.packets > 0, "messages carry at least one packet");
        let id = self.msgs.len();
        self.msgs.push(msg);
        self.queue.push(at, Ev::Start(id));
    }

    /// `Start` handler: pay the session setup, then launch packet 0.
    fn start(&mut self, id: usize, now: Time) {
        let ready = now + self.msgs[id].setup;
        self.launch_packet(id, ready);
    }

    /// Reserve the message's claims for its next packet (ready at
    /// `ready`) and schedule the on-air completion.
    fn launch_packet(&mut self, id: usize, ready: Time) {
        // Claims are at most [radio, medium]; copy them to the stack so
        // the hot loop never allocates.
        debug_assert!(self.msgs[id].claims.len() <= 2, "at most radio + medium");
        let mut buf = [0usize; 2];
        let n = self.msgs[id].claims.len().min(2);
        buf[..n].copy_from_slice(&self.msgs[id].claims[..n]);
        let base = self.msgs[id].per_packet;
        let hold = if self.jitter > 0.0 {
            base * self.rng.f64_in(1.0, 1.0 + self.jitter)
        } else {
            base
        };
        let start = reserve(&mut self.res, &buf[..n], ready, hold);
        if start > ready {
            self.contended += 1;
            self.queue_wait += start - ready;
        }
        self.packets_sent += 1;
        if self.obs.is_enabled() {
            let track = buf[..n].first().copied().unwrap_or(0) as u64;
            let wait = start - ready;
            self.obs.tracer.record_at(
                "net.packet",
                track,
                start,
                start + hold,
                vec![("wait_us", wait.as_us().into())],
            );
            self.obs.metrics.inc("net.packets", 1);
            if start > ready {
                self.obs.metrics.inc("net.contended", 1);
            }
            self.obs.metrics.observe("net.queue_wait_us", wait.as_us());
        }
        self.queue.push(start + hold, Ev::Packet(id));
    }

    /// `Packet` handler: advance the message; `Some(done)` on delivery.
    fn packet_done(&mut self, id: usize, now: Time) -> Option<Done> {
        self.msgs[id].sent += 1;
        if self.msgs[id].sent < self.msgs[id].packets {
            self.launch_packet(id, now);
            return None;
        }
        self.comm_done = self.comm_done.max(now);
        Some(self.msgs[id].done)
    }

    /// Pop the next event, tracking the makespan.
    fn next(&mut self) -> Option<(Time, Ev)> {
        let (t, ev) = self.queue.pop()?;
        self.events += 1;
        self.completion = self.completion.max(t);
        Some((t, ev))
    }

    fn report(self, devices: usize) -> NetSimReport {
        if self.obs.is_enabled() {
            self.obs.metrics.inc("net.messages", self.msgs.len() as u64);
            self.obs.metrics.set_gauge("sim.event_queue.depth", self.queue.len() as f64);
            self.obs
                .metrics
                .raise_gauge("sim.event_queue.max_depth", self.queue.max_depth() as f64);
        }
        NetSimReport {
            completion: self.completion,
            comm_done: self.comm_done,
            events: self.events,
            messages: self.msgs.len(),
            packets: self.packets_sent,
            devices,
            contended_packets: self.contended,
            queue_wait: self.queue_wait,
            busy_total: self.res.iter().map(|r| r.busy).sum(),
        }
    }
}

/// Centralized star (paper Fig. 4(a)): every device uplinks its message
/// over L_n into the leader's receive-port pool; the leader pipelines one
/// Eq. (3) slot per arrived peer.
pub(super) fn centralized(
    model: &NetModel,
    topo: Topology,
    cfg: &NetSimConfig,
    obs: &Obs,
) -> Result<NetSimReport> {
    if topo.nodes == 0 {
        return Err(Error::Sim("topology needs at least one node".into()));
    }
    let packets = model.inter_link().packets(model.message_bytes());
    // Per uplink: 1 Start + `packets` Packet events; plus ≤1 Compute each.
    let mut sim = Sim::new(cfg, topo.nodes, topo.nodes * (packets + 2), obs);
    let rx = sim.add_resource(Resource::with_capacity(cfg.rx_ports));
    let lat = model.inter_link().packet_latency();
    for _device in 0..topo.nodes {
        sim.send(
            Msg {
                claims: vec![rx],
                packets,
                sent: 0,
                per_packet: lat,
                setup: Time::ZERO,
                done: Done::CentUplink,
            },
            Time::ZERO,
        );
    }

    // The leader pipelines nodes at the banked-core issue rate (Eq. 3's
    // per-node slot); the other N−1 devices' data each takes one slot.
    let (m1, m2, m3) = model.capacity_ratios();
    let b = model.breakdown();
    let slot = b.t1 * (1.0 / m1) + b.t2 * (1.0 / m2) + b.t3 * (1.0 / m3);
    let mut remaining = topo.nodes.saturating_sub(1);
    let mut leader_free = Time::ZERO;

    while let Some((now, ev)) = sim.next() {
        match ev {
            Ev::Start(id) => sim.start(id, now),
            Ev::Packet(id) => {
                if let Some(done) = sim.packet_done(id, now) {
                    match done {
                        Done::CentUplink => {
                            if remaining > 0 {
                                remaining -= 1;
                                let start = leader_free.max(now);
                                leader_free = start + slot;
                                sim.queue.push(start + slot, Ev::Compute(After::End));
                            }
                        }
                        other => unreachable!("centralized sim saw {other:?}"),
                    }
                }
            }
            Ev::Compute(After::End) => {}
            Ev::Compute(After::Boundary { .. }) => {
                unreachable!("semi continuation in centralized sim")
            }
        }
    }
    Ok(sim.report(topo.nodes))
}

/// Decentralized multi-hop cluster mesh (paper Fig. 4(b)): each device
/// runs an outbound then an inbound exchange session — tₑ setup plus cₛ
/// store-and-forward transfers over L_c — then computes locally.
pub(super) fn decentralized(
    model: &NetModel,
    topo: Topology,
    cfg: &NetSimConfig,
    obs: &Obs,
) -> Result<NetSimReport> {
    if topo.nodes == 0 || topo.cluster_size == 0 {
        return Err(Error::Sim("need nodes and a positive cluster size".into()));
    }
    let cs = topo.cluster_size;
    let n_clusters = topo.nodes.div_ceil(cs);
    // Two sessions per device (1 Start + cs Packet events each) + 1 Compute.
    let mut sim = Sim::new(cfg, 2 * topo.nodes, topo.nodes * (2 * (cs + 1) + 1), obs);

    // Resources: one half-duplex radio per device, then (under the
    // shared-medium knob) one CSMA medium per cluster.
    sim.res.reserve(topo.nodes + n_clusters);
    for _ in 0..topo.nodes {
        sim.add_resource(Resource::single());
    }
    let medium_base = topo.nodes;
    if cfg.cluster_channels.is_some() {
        for _ in 0..n_clusters {
            sim.add_resource(Resource::with_capacity(cfg.cluster_channels));
        }
    }
    let shared = cfg.cluster_channels.is_some();
    let claims_of = |device: usize| -> Vec<usize> {
        if shared {
            vec![device, medium_base + device / cs]
        } else {
            vec![device]
        }
    };

    let link = model.intra_link();
    let hold = link.relay_chain(model.message_bytes(), cfg.hops);
    let setup = link.setup();
    for device in 0..topo.nodes {
        sim.send(
            Msg {
                claims: claims_of(device),
                packets: cs,
                sent: 0,
                per_packet: hold,
                setup,
                done: Done::DecOutbound { device },
            },
            Time::ZERO,
        );
    }

    let b = model.breakdown();
    let compute =
        if cfg.overlap_cores { b.overlapped_latency() } else { b.total_latency() };

    while let Some((now, ev)) = sim.next() {
        match ev {
            Ev::Start(id) => sim.start(id, now),
            Ev::Packet(id) => {
                if let Some(done) = sim.packet_done(id, now) {
                    match done {
                        Done::DecOutbound { device } => {
                            // Mirror session: gather from the cₛ neighbors.
                            sim.send(
                                Msg {
                                    claims: claims_of(device),
                                    packets: cs,
                                    sent: 0,
                                    per_packet: hold,
                                    setup,
                                    done: Done::DecInbound,
                                },
                                now,
                            );
                        }
                        Done::DecInbound => {
                            sim.queue.push(now + compute, Ev::Compute(After::End));
                        }
                        other => unreachable!("decentralized sim saw {other:?}"),
                    }
                }
            }
            Ev::Compute(After::End) => {}
            Ev::Compute(After::Boundary { .. }) => {
                unreachable!("semi continuation in decentralized sim")
            }
        }
    }
    Ok(sim.report(topo.nodes))
}

/// Semi-decentralized cluster-head overlay (conclusion / E8): members
/// upload over V2X into their head's port pool, the head batches its
/// members' nodes at `head_capacity`× a member's rate, exchanges boundary
/// data with adjacent heads (two-way) and downlinks the results.
pub(super) fn semi(
    model: &NetModel,
    topo: Topology,
    head_capacity: f64,
    cfg: &NetSimConfig,
    obs: &Obs,
) -> Result<NetSimReport> {
    if topo.nodes == 0 || topo.cluster_size == 0 {
        return Err(Error::Sim("need nodes and a positive cluster size".into()));
    }
    if head_capacity.is_nan() || head_capacity < 1.0 {
        return Err(Error::Sim("head capacity must be >= 1".into()));
    }
    let cs = topo.cluster_size;
    let n_clusters = topo.nodes.div_ceil(cs);
    let packets = model.inter_link().packets(model.message_bytes());
    // Member uplinks + per-cluster (boundary exchange, downlink); events:
    // every message is 1 Start + its packets, plus 1 Compute per cluster.
    let mut sim = Sim::new(
        cfg,
        topo.nodes + 2 * n_clusters,
        topo.nodes * (packets + 1) + n_clusters * (3 * packets + 3),
        obs,
    );

    // Per-cluster: a V2X receive-port pool at the head plus the head's own
    // radio for the boundary exchange and the downlink.
    let mut head_rx = Vec::with_capacity(n_clusters);
    let mut head_radio = Vec::with_capacity(n_clusters);
    for _ in 0..n_clusters {
        head_rx.push(sim.add_resource(Resource::with_capacity(cfg.rx_ports)));
    }
    for _ in 0..n_clusters {
        head_radio.push(sim.add_resource(Resource::single()));
    }

    let lat = model.inter_link().packet_latency();
    let b = model.breakdown();
    let per_node =
        if cfg.overlap_cores { b.overlapped_latency() } else { b.total_latency() };
    let per_member = per_node * (1.0 / head_capacity);

    let mut members = vec![0usize; n_clusters];
    let mut pending = vec![0usize; n_clusters];
    for cluster in 0..n_clusters {
        let m = cs.min(topo.nodes - cluster * cs);
        members[cluster] = m;
        pending[cluster] = m;
        for _ in 0..m {
            sim.send(
                Msg {
                    claims: vec![head_rx[cluster]],
                    packets,
                    sent: 0,
                    per_packet: lat,
                    setup: Time::ZERO,
                    done: Done::SemiUplink { cluster },
                },
                Time::ZERO,
            );
        }
    }

    while let Some((now, ev)) = sim.next() {
        match ev {
            Ev::Start(id) => sim.start(id, now),
            Ev::Packet(id) => {
                if let Some(done) = sim.packet_done(id, now) {
                    match done {
                        Done::SemiUplink { cluster } => {
                            pending[cluster] -= 1;
                            if pending[cluster] == 0 {
                                let batch = per_member
                                    * members[cluster].saturating_sub(1).max(1) as f64;
                                sim.queue
                                    .push(now + batch, Ev::Compute(After::Boundary { cluster }));
                            }
                        }
                        Done::SemiBoundary { cluster } => {
                            sim.send(
                                Msg {
                                    claims: vec![head_radio[cluster]],
                                    packets,
                                    sent: 0,
                                    per_packet: lat,
                                    setup: Time::ZERO,
                                    done: Done::SemiDownlink,
                                },
                                now,
                            );
                        }
                        Done::SemiDownlink => {}
                        other => unreachable!("semi sim saw {other:?}"),
                    }
                }
            }
            Ev::Compute(After::Boundary { cluster }) => {
                // Head↔head boundary exchange: two transfers back to back
                // on the head's radio (the E8 model's `transfer × 2`).
                sim.send(
                    Msg {
                        claims: vec![head_radio[cluster]],
                        packets: packets * 2,
                        sent: 0,
                        per_packet: lat,
                        setup: Time::ZERO,
                        done: Done::SemiBoundary { cluster },
                    },
                    now,
                );
            }
            Ev::Compute(After::End) => {}
        }
    }
    Ok(sim.report(topo.nodes))
}
