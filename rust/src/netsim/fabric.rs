//! Contention primitives of the packet fabric.
//!
//! A [`Resource`] is anything a packet must hold for the duration of its
//! transmission: a link, a device radio, a receive-port pool, a shared
//! cluster medium.  Reservations are committed in event order against the
//! earliest-free server of each claimed resource, so a run is a pure
//! function of the scenario + seed (the determinism the event queue's
//! FIFO tie-break guarantees at the event level extends to the resource
//! level).
//!
//! DESIGN.md: §6 (simulation).

use crate::units::Time;

/// A transmission resource with `k` FIFO servers.
///
/// `None` capacity models the analytic equations' infinite concurrency
/// (Eq. 5's "concurrent transfers" assumption); `Some(k)` gives `k`
/// servers and makes excess packets queue.
#[derive(Debug, Clone)]
pub struct Resource {
    /// `free_at[i]` = when server `i` finishes its last reservation;
    /// `None` = unlimited servers (reservations never wait).
    servers: Option<Vec<Time>>,
    /// Total reserved (busy) time across all servers.
    pub busy: Time,
}

impl Resource {
    pub fn with_capacity(capacity: Option<usize>) -> Resource {
        Resource {
            servers: capacity.map(|k| vec![Time::ZERO; k.max(1)]),
            busy: Time::ZERO,
        }
    }

    /// One server — a half-duplex radio, a point-to-point link.
    pub fn single() -> Resource {
        Resource::with_capacity(Some(1))
    }

    /// Earliest time any server is free (`ZERO` when unlimited).
    fn earliest(&self) -> Time {
        match &self.servers {
            None => Time::ZERO,
            Some(s) => s.iter().copied().reduce(Time::min).unwrap_or(Time::ZERO),
        }
    }

    /// Book the earliest-free server for `[start, start + hold]`.
    fn commit(&mut self, start: Time, hold: Time) {
        self.busy += hold;
        if let Some(s) = &mut self.servers {
            let mut best = 0;
            for (i, free) in s.iter().enumerate().skip(1) {
                if *free < s[best] {
                    best = i;
                }
            }
            s[best] = start + hold;
        }
    }
}

/// Reserve every claimed resource *simultaneously* for `[start, start +
/// hold]` with `start >= ready` (a packet occupies its sender's radio, the
/// link and the receiver's port for the same on-air interval).  Returns
/// the start time; `start > ready` means the packet queued.
pub fn reserve(resources: &mut [Resource], claims: &[usize], ready: Time, hold: Time) -> Time {
    let mut start = ready;
    for &rid in claims {
        start = start.max(resources[rid].earliest());
    }
    for &rid in claims {
        resources[rid].commit(start, hold);
    }
    start
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_resources_never_queue() {
        let mut res = vec![Resource::with_capacity(None)];
        for i in 0..10 {
            let start = reserve(&mut res, &[0], Time::ns(i as f64), Time::ns(100.0));
            assert_eq!(start, Time::ns(i as f64));
        }
    }

    #[test]
    fn single_server_serializes() {
        let mut res = vec![Resource::single()];
        let a = reserve(&mut res, &[0], Time::ZERO, Time::ns(10.0));
        let b = reserve(&mut res, &[0], Time::ZERO, Time::ns(10.0));
        let c = reserve(&mut res, &[0], Time::ns(25.0), Time::ns(10.0));
        assert_eq!(a, Time::ZERO);
        assert_eq!(b, Time::ns(10.0));
        assert_eq!(c, Time::ns(25.0)); // idle gap: arrives after the queue drained
        assert_eq!(res[0].busy, Time::ns(30.0));
    }

    #[test]
    fn k_servers_admit_k_concurrent_holds() {
        let mut res = vec![Resource::with_capacity(Some(2))];
        let a = reserve(&mut res, &[0], Time::ZERO, Time::ns(10.0));
        let b = reserve(&mut res, &[0], Time::ZERO, Time::ns(10.0));
        let c = reserve(&mut res, &[0], Time::ZERO, Time::ns(10.0));
        assert_eq!(a, Time::ZERO);
        assert_eq!(b, Time::ZERO);
        assert_eq!(c, Time::ns(10.0));
    }

    #[test]
    fn multi_claim_holds_all_resources_for_one_interval() {
        let mut res = vec![Resource::single(), Resource::single()];
        // Occupy resource 1 until t=50.
        reserve(&mut res, &[1], Time::ZERO, Time::ns(50.0));
        // A packet claiming both must wait for the later one.
        let start = reserve(&mut res, &[0, 1], Time::ZERO, Time::ns(10.0));
        assert_eq!(start, Time::ns(50.0));
        // ... and resource 0 is now blocked until t=60 too.
        let after = reserve(&mut res, &[0], Time::ZERO, Time::ns(5.0));
        assert_eq!(after, Time::ns(60.0));
    }
}
