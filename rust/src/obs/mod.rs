//! Deterministic observability: metrics, spans, and Perfetto export.
//!
//! The serving stack's phase decomposition (the quantity the paper's
//! ~790×/~1400× headline speed-ups are computed from) is recorded, not
//! just summarized: a [`MetricsRegistry`] of counters / gauges /
//! mergeable log-bucketed [`Histogram`]s, a [`WindowedStats`] rolling
//! view keyed on sim time (the runtime-controller substrate), a
//! span-based [`Tracer`] threaded through the hot path, and a Chrome
//! trace-event exporter ([`chrome_trace_json`]) that renders an E13 run
//! as per-device / per-shard timeline tracks.
//!
//! **Determinism contract.**  Nothing in this module reads wall clock,
//! thread ids, or iteration order of unordered containers.  Spans carry
//! sim time or logical ticks; registries are `BTreeMap`-backed and
//! serialize through the one sorted-key path in [`crate::json`]; merges
//! of parallel sections happen in deterministic input order.  Every
//! emitted artifact is therefore byte-identical seq-vs-par and across
//! repeated runs of the same seed.
//!
//! **Disabled-mode cost.**  [`Obs::disabled`] / [`Tracer::disabled`]
//! reduce every instrumentation point to one predictable branch with no
//! allocation, and disabled runs produce bit-identical outputs to
//! uninstrumented builds — tracing can stay compiled in everywhere.
//!
//! DESIGN.md: §12 (observability).

pub mod chrome;
pub mod metrics;
pub mod tracer;

pub use chrome::{chrome_trace, chrome_trace_json};
pub use metrics::{Histogram, MetricsRegistry, WindowedStats, MAX_REL_ERROR};
pub use tracer::{Attr, Span, SpanGuard, Tracer};

/// One handle bundling a [`Tracer`] and a [`MetricsRegistry`], threaded
/// through subsystems as `&Obs`.
///
/// Hot paths guard non-trivial instrumentation with
/// [`Obs::is_enabled`]; a disabled handle makes every observation a
/// cheap no-op and never perturbs outputs.
#[derive(Debug, Clone, Default)]
pub struct Obs {
    enabled: bool,
    pub tracer: Tracer,
    pub metrics: MetricsRegistry,
}

impl Obs {
    /// An enabled handle whose tracer retains up to `span_capacity`
    /// spans.
    pub fn new(span_capacity: usize) -> Obs {
        Obs { enabled: true, tracer: Tracer::new(span_capacity), metrics: MetricsRegistry::new() }
    }

    /// The inert handle (also [`Default`]): one branch per observation,
    /// no allocation, bit-identical outputs.
    pub fn disabled() -> Obs {
        Obs { enabled: false, tracer: Tracer::disabled(), metrics: MetricsRegistry::new() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_handle_modes() {
        let off = Obs::disabled();
        assert!(!off.is_enabled());
        assert!(!off.tracer.is_enabled());
        let on = Obs::new(128);
        assert!(on.is_enabled());
        on.metrics.inc("x", 1);
        {
            let _s = crate::span!(on.tracer, "s", k = 1i64);
        }
        assert_eq!(on.metrics.counter_value("x"), 1);
        assert_eq!(on.tracer.len(), 1);
        assert!(matches!(Obs::default(), Obs { enabled: false, .. }));
    }
}
