//! Named counters, gauges, and log-bucketed mergeable histograms.
//!
//! The registry is interior-mutable (`&self` recording) so it can be
//! threaded through call stacks that only hold shared borrows — span
//! guards and metric increments never fight the borrow checker on the
//! hot path.  `RefCell`/`Cell` keep the types `Send` (engines move into
//! worker threads whole); they are deliberately not `Sync` — parallel
//! sections each own a registry and [`MetricsRegistry::merge_from`]
//! combines them deterministically afterwards.
//!
//! Histograms bucket on a base-2 log scale with 8 sub-buckets per
//! octave (bucket growth `2^(1/8)`), so any quantile estimate `e` of an
//! exact nearest-rank percentile `x` satisfies `x ≤ e ≤ x·2^(1/8)` —
//! at most [`MAX_REL_ERROR`] ≈ 9.05 % relative error — while merges are
//! exact bucket-count additions (associative and commutative).
//!
//! DESIGN.md: §12 (observability).

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

use crate::json::Json;
use crate::units::Time;

/// Sub-buckets per octave: bucket `i` covers `[2^(i/8), 2^((i+1)/8))`.
const SUB_BUCKETS: f64 = 8.0;

/// Worst-case relative error of a histogram quantile vs the exact
/// nearest-rank percentile: `2^(1/8) − 1`.
pub const MAX_REL_ERROR: f64 = 0.090_507_733_f64;

fn bucket_lower(idx: i64) -> f64 {
    (idx as f64 / SUB_BUCKETS).exp2()
}

fn bucket_upper(idx: i64) -> f64 {
    ((idx + 1) as f64 / SUB_BUCKETS).exp2()
}

/// `floor(log2(v) · 8)` with an exact boundary correction, so the
/// invariant `lower(idx) ≤ v < upper(idx)` holds even when the float
/// log rounds across a bucket edge.
fn bucket_index(v: f64) -> i64 {
    debug_assert!(v > 0.0 && v.is_finite());
    let mut idx = (v.log2() * SUB_BUCKETS).floor() as i64;
    if v < bucket_lower(idx) {
        idx -= 1;
    }
    if v >= bucket_upper(idx) {
        idx += 1;
    }
    idx
}

/// Log-bucketed histogram of non-negative samples.
///
/// `count`/`sum`/`min`/`max` are exact; quantiles are exact to within
/// one bucket (≤ [`MAX_REL_ERROR`] relative).  Samples `≤ 0` land in a
/// dedicated zero bucket.  Non-finite samples are ignored.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Histogram {
    buckets: BTreeMap<i64, u64>,
    zeros: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        if v <= 0.0 {
            self.zeros += 1;
        } else {
            *self.buckets.entry(bucket_index(v)).or_insert(0) += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest recorded sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest recorded sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile estimate, `q ∈ [0, 1]`.
    ///
    /// Returns the upper edge of the bucket holding the rank-`⌈qN⌉`
    /// sample, clamped to `[min, max]` — so `quantile(1.0) == max`
    /// exactly, and every estimate over-approximates the exact
    /// percentile by at most [`MAX_REL_ERROR`].
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0, 1]");
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count as f64 * q).ceil() as u64).max(1);
        let mut cum = self.zeros;
        let mut est = 0.0;
        if cum < rank {
            for (&idx, &n) in &self.buckets {
                cum += n;
                if cum >= rank {
                    est = bucket_upper(idx);
                    break;
                }
            }
        }
        est.clamp(self.min, self.max)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold `other` in.  Bucket counts, `count`, `min` and `max` merge
    /// exactly (associative); `sum` is a float addition, associative to
    /// round-off only.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        for (&idx, &n) in &other.buckets {
            *self.buckets.entry(idx).or_insert(0) += n;
        }
        self.zeros += other.zeros;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".into(), Json::Num(self.count as f64));
        m.insert("sum".into(), Json::Num(self.sum));
        m.insert("mean".into(), Json::Num(self.mean()));
        m.insert("min".into(), Json::Num(self.min()));
        m.insert("max".into(), Json::Num(self.max()));
        m.insert("p50".into(), Json::Num(self.p50()));
        m.insert("p95".into(), Json::Num(self.p95()));
        m.insert("p99".into(), Json::Num(self.p99()));
        Json::Obj(m)
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist(Histogram),
}

/// A registry of named metrics with `&self` recording.
///
/// Names are `dotted.paths`; a name is bound to one metric kind on
/// first use and recording it as a different kind panics (catching
/// taxonomy typos early).  Snapshots serialize through the one
/// sorted-key path in [`crate::json`], so emitted artifacts are
/// byte-deterministic.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsRegistry {
    inner: RefCell<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to the named counter (created at 0).
    pub fn inc(&self, name: &str, by: u64) {
        let mut inner = self.inner.borrow_mut();
        match inner.entry(name.to_string()).or_insert(Metric::Counter(0)) {
            Metric::Counter(c) => *c += by,
            _ => panic!("metric `{name}` is not a counter"),
        }
    }

    /// Current counter value; 0 when the counter was never touched.
    pub fn counter_value(&self, name: &str) -> u64 {
        match self.inner.borrow().get(name) {
            None => 0,
            Some(Metric::Counter(c)) => *c,
            Some(_) => panic!("metric `{name}` is not a counter"),
        }
    }

    /// Set the named gauge to `v` (last write wins).
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut inner = self.inner.borrow_mut();
        match inner.entry(name.to_string()).or_insert(Metric::Gauge(v)) {
            Metric::Gauge(g) => *g = v,
            _ => panic!("metric `{name}` is not a gauge"),
        }
    }

    /// High-water gauge: keep the max of the current value and `v`.
    pub fn raise_gauge(&self, name: &str, v: f64) {
        let mut inner = self.inner.borrow_mut();
        match inner.entry(name.to_string()).or_insert(Metric::Gauge(v)) {
            Metric::Gauge(g) => *g = g.max(v),
            _ => panic!("metric `{name}` is not a gauge"),
        }
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        match self.inner.borrow().get(name) {
            None => None,
            Some(Metric::Gauge(g)) => Some(*g),
            Some(_) => panic!("metric `{name}` is not a gauge"),
        }
    }

    /// Record one sample into the named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        let mut inner = self.inner.borrow_mut();
        match inner.entry(name.to_string()).or_insert_with(|| Metric::Hist(Histogram::new())) {
            Metric::Hist(h) => h.record(v),
            _ => panic!("metric `{name}` is not a histogram"),
        }
    }

    /// Snapshot of the named histogram, if any samples were recorded.
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        match self.inner.borrow().get(name) {
            None => None,
            Some(Metric::Hist(h)) => Some(h.clone()),
            Some(_) => panic!("metric `{name}` is not a histogram"),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.borrow().len() == 0
    }

    /// Fold `other` in under a name prefix: counters add, gauges keep
    /// the max, histograms merge.  With distinct prefixes per source
    /// the merge is lossless; with a shared prefix it aggregates.
    pub fn merge_from(&self, other: &MetricsRegistry, prefix: &str) {
        for (name, metric) in other.inner.borrow().iter() {
            let full = format!("{prefix}{name}");
            match metric {
                Metric::Counter(c) => self.inc(&full, *c),
                Metric::Gauge(g) => self.raise_gauge(&full, *g),
                Metric::Hist(h) => {
                    let mut inner = self.inner.borrow_mut();
                    match inner.entry(full.clone()).or_insert_with(|| Metric::Hist(Histogram::new()))
                    {
                        Metric::Hist(mine) => mine.merge(h),
                        _ => panic!("metric `{full}` is not a histogram"),
                    }
                }
            }
        }
    }

    /// Snapshot as a JSON document: `{"counters": {..}, "gauges": {..},
    /// "histograms": {..}}`, keys sorted, byte-deterministic.
    pub fn snapshot(&self) -> Json {
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        for (name, metric) in self.inner.borrow().iter() {
            match metric {
                Metric::Counter(c) => {
                    counters.insert(name.clone(), Json::Num(*c as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(name.clone(), Json::Num(*g));
                }
                Metric::Hist(h) => {
                    hists.insert(name.clone(), h.to_json());
                }
            }
        }
        let mut doc = BTreeMap::new();
        doc.insert("counters".into(), Json::Obj(counters));
        doc.insert("gauges".into(), Json::Obj(gauges));
        doc.insert("histograms".into(), Json::Obj(hists));
        Json::Obj(doc)
    }

    /// [`MetricsRegistry::snapshot`] rendered to a string.
    pub fn to_json(&self) -> String {
        self.snapshot().dump()
    }
}

/// Rolling statistics over a sim-time window — **never wall clock** —
/// so a windowed p95 at sim time `t` is a pure function of the sample
/// stream and bit-reproducible per seed.  This is the live view the
/// runtime controller (ROADMAP item 1) keys decisions on.
///
/// Samples must arrive in non-decreasing sim-time order; each push
/// evicts samples older than `at − window`.  Quantiles are exact
/// (sorted nearest-rank) — windows are small by construction.
///
/// Out-of-order pushes are **rejected in all builds** (not just
/// `debug_assert!`ed): an out-of-order sample would corrupt the
/// front-eviction loop and strand stale samples in the decision
/// window of whoever thresholds on it.  Rejections are counted in
/// [`WindowedStats::dropped_out_of_order`] so a misbehaving feed is
/// visible rather than silent.
#[derive(Debug, Clone)]
pub struct WindowedStats {
    window: Time,
    samples: VecDeque<(Time, f64)>,
    dropped_out_of_order: u64,
}

impl WindowedStats {
    /// `window` must be finite and positive.
    pub fn new(window: Time) -> WindowedStats {
        assert!(window.is_finite() && window > Time::ZERO, "window must be finite and positive");
        WindowedStats { window, samples: VecDeque::new(), dropped_out_of_order: 0 }
    }

    pub fn window(&self) -> Time {
        self.window
    }

    /// Record `v` at sim time `at`, evicting samples older than the
    /// window.  Non-finite samples are ignored.  A sample older than
    /// the newest one already recorded is dropped (counted in
    /// [`WindowedStats::dropped_out_of_order`]) — identically in debug
    /// and release builds.
    pub fn push(&mut self, at: Time, v: f64) {
        if !v.is_finite() {
            return;
        }
        if let Some(&(t, _)) = self.samples.back() {
            if at < t {
                self.dropped_out_of_order += 1;
                return;
            }
        }
        while let Some(&(t, _)) = self.samples.front() {
            if t + self.window < at {
                self.samples.pop_front();
            } else {
                break;
            }
        }
        self.samples.push_back((at, v));
    }

    /// How many out-of-order samples have been rejected since
    /// construction.  Survives [`WindowedStats::clear`] — it diagnoses
    /// the *feed*, not the current window.
    pub fn dropped_out_of_order(&self) -> u64 {
        self.dropped_out_of_order
    }

    /// Drop every buffered sample (the rejection counter is kept).
    /// The runtime controller clears its decision windows at a
    /// configuration switch so post-switch decisions only see the new
    /// shape's samples.
    pub fn clear(&mut self) {
        self.samples.clear();
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|&(_, v)| v).sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact nearest-rank quantile over the current window (0.0 when
    /// empty).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0, 1]");
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut vals: Vec<f64> = self.samples.iter().map(|&(_, v)| v).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((vals.len() as f64 * q).ceil() as usize).max(1);
        vals[rank - 1]
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::testing::{assert_close, forall};

    #[test]
    fn bucket_invariant_holds_at_boundaries() {
        for k in -64i64..64 {
            let v = (k as f64 / SUB_BUCKETS).exp2();
            let idx = bucket_index(v);
            assert!(bucket_lower(idx) <= v && v < bucket_upper(idx), "v {v} idx {idx}");
        }
    }

    #[test]
    fn histogram_exact_fields_and_zero_bucket() {
        let mut h = Histogram::new();
        for v in [0.0, 3.0, 1.5, 0.0, 12.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_close(h.sum(), 16.5, 1e-12);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 12.0);
        // q low enough to land in the zero bucket → exactly 0.
        assert_eq!(h.quantile(0.2), 0.0);
        assert_eq!(h.quantile(1.0), 12.0);
        h.record(f64::NAN); // ignored
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn histogram_quantiles_within_error_bound_vs_exact() {
        forall(30, |rng| {
            let n = rng.u64_in(1, 400) as usize;
            let mut h = Histogram::new();
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                // Span several orders of magnitude.
                let v = rng.f64_in(1e-4, 1.0) * 10f64.powi(rng.i64_in(0, 6) as i32);
                h.record(v);
                vals.push(v);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let rank = ((n as f64 * q).ceil() as usize).max(1);
                let exact = vals[rank - 1];
                let est = h.quantile(q);
                assert!(
                    exact <= est && est <= exact * (1.0 + MAX_REL_ERROR) * (1.0 + 1e-12),
                    "q {q}: exact {exact} est {est}"
                );
            }
        });
    }

    #[test]
    fn histogram_merge_is_associative_and_matches_pooled() {
        forall(20, |rng| {
            let mut parts = Vec::new();
            let mut pooled = Histogram::new();
            for _ in 0..3 {
                let mut h = Histogram::new();
                for _ in 0..rng.u64_in(0, 100) {
                    let v = rng.f64_in(0.0, 1e3);
                    h.record(v);
                    pooled.record(v);
                }
                parts.push(h);
            }
            // (a ⊕ b) ⊕ c
            let mut left = parts[0].clone();
            left.merge(&parts[1]);
            left.merge(&parts[2]);
            // a ⊕ (b ⊕ c)
            let mut bc = parts[1].clone();
            bc.merge(&parts[2]);
            let mut right = parts[0].clone();
            right.merge(&bc);
            // Bucket state is exactly associative → identical quantiles.
            assert_eq!(left.count(), right.count());
            assert_eq!(left.min(), right.min());
            assert_eq!(left.max(), right.max());
            for q in [0.25, 0.5, 0.95, 1.0] {
                assert_eq!(left.quantile(q), right.quantile(q), "q {q}");
                assert_eq!(left.quantile(q), pooled.quantile(q), "pooled q {q}");
            }
            // Sums are float additions: associative to round-off.
            if left.count() > 0 {
                assert_close(left.sum(), right.sum(), 1e-12);
                assert_close(left.sum(), pooled.sum(), 1e-12);
            }
        });
    }

    #[test]
    fn registry_kinds_and_values() {
        let reg = MetricsRegistry::new();
        reg.inc("a.count", 2);
        reg.inc("a.count", 3);
        assert_eq!(reg.counter_value("a.count"), 5);
        assert_eq!(reg.counter_value("never.touched"), 0);
        reg.set_gauge("g", 1.5);
        reg.set_gauge("g", 0.5);
        assert_eq!(reg.gauge_value("g"), Some(0.5));
        reg.raise_gauge("hw", 2.0);
        reg.raise_gauge("hw", 1.0);
        assert_eq!(reg.gauge_value("hw"), Some(2.0));
        reg.observe("h", 10.0);
        reg.observe("h", 20.0);
        assert_eq!(reg.histogram("h").unwrap().count(), 2);
        assert_eq!(reg.len(), 4);
        assert!(!reg.is_empty());
    }

    #[test]
    #[should_panic(expected = "is not a counter")]
    fn registry_rejects_kind_confusion() {
        let reg = MetricsRegistry::new();
        reg.set_gauge("x", 1.0);
        reg.inc("x", 1);
    }

    #[test]
    fn registry_merge_prefixed() {
        let a = MetricsRegistry::new();
        a.inc("req", 10);
        a.observe("lat", 5.0);
        a.raise_gauge("depth", 3.0);
        let b = MetricsRegistry::new();
        b.inc("req", 7);
        b.observe("lat", 15.0);
        b.raise_gauge("depth", 9.0);
        let merged = MetricsRegistry::new();
        merged.merge_from(&a, "");
        merged.merge_from(&b, "");
        assert_eq!(merged.counter_value("req"), 17);
        assert_eq!(merged.histogram("lat").unwrap().count(), 2);
        assert_eq!(merged.gauge_value("depth"), Some(9.0));
        let split = MetricsRegistry::new();
        split.merge_from(&a, "a.");
        split.merge_from(&b, "b.");
        assert_eq!(split.counter_value("a.req"), 10);
        assert_eq!(split.counter_value("b.req"), 7);
    }

    #[test]
    fn registry_snapshot_parses_and_sorts() {
        let reg = MetricsRegistry::new();
        reg.inc("z.count", 1);
        reg.inc("a.count", 2);
        reg.set_gauge("g", 0.25);
        reg.observe("h", 2.0);
        let text = reg.to_json();
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("counters").unwrap().get("a.count").unwrap().as_usize(), Some(2));
        assert_eq!(doc.get("gauges").unwrap().get("g").unwrap().as_f64(), Some(0.25));
        let h = doc.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(1));
        // Sorted keys: "a.count" serializes before "z.count".
        let a_pos = text.find("a.count").unwrap();
        let z_pos = text.find("z.count").unwrap();
        assert!(a_pos < z_pos);
        // Identical content → identical bytes, regardless of insert order.
        let reg2 = MetricsRegistry::new();
        reg2.observe("h", 2.0);
        reg2.set_gauge("g", 0.25);
        reg2.inc("a.count", 2);
        reg2.inc("z.count", 1);
        assert_eq!(reg2.to_json(), text);
    }

    #[test]
    fn windowed_stats_evicts_by_sim_time() {
        let mut w = WindowedStats::new(Time::s(1.0));
        w.push(Time::s(0.0), 10.0);
        w.push(Time::s(0.5), 20.0);
        w.push(Time::s(0.9), 30.0);
        assert_eq!(w.len(), 3);
        assert_close(w.mean(), 20.0, 1e-12);
        // 2.1 s: everything before 1.1 s ages out.
        w.push(Time::s(2.1), 40.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w.p50(), 40.0);
        assert_eq!(w.max(), 40.0);
    }

    /// Regression (ISSUE 8): out-of-order pushes used to be only
    /// `debug_assert!`ed — a release build silently walked the
    /// eviction loop with a stale `at`, stranding old samples in the
    /// window.  Now the sample is rejected identically in every build
    /// and the rejection is counted.
    #[test]
    fn windowed_stats_rejects_out_of_order_in_all_builds() {
        let mut w = WindowedStats::new(Time::s(1.0));
        w.push(Time::s(5.0), 10.0);
        // Out of order: must be dropped, not evict-corrupt the queue.
        w.push(Time::s(1.0), 99.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w.dropped_out_of_order(), 1);
        assert_eq!(w.max(), 10.0);
        // Equal timestamps are in order (FIFO ties are fine).
        w.push(Time::s(5.0), 20.0);
        assert_eq!(w.len(), 2);
        assert_eq!(w.dropped_out_of_order(), 1);
        // clear() empties the window but keeps the feed diagnostic.
        w.clear();
        assert!(w.is_empty());
        assert_eq!(w.dropped_out_of_order(), 1);
        w.push(Time::s(6.0), 1.0);
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn windowed_quantiles_are_exact() {
        let mut w = WindowedStats::new(Time::s(10.0));
        for (i, v) in [5.0, 1.0, 4.0, 2.0, 3.0].into_iter().enumerate() {
            w.push(Time::ms(i as f64), v);
        }
        assert_eq!(w.quantile(0.0), 1.0);
        assert_eq!(w.p50(), 3.0);
        assert_eq!(w.quantile(1.0), 5.0);
        assert!(WindowedStats::new(Time::s(1.0)).is_empty());
        assert_eq!(WindowedStats::new(Time::s(1.0)).p95(), 0.0);
    }
}
