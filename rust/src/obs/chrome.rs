//! Chrome trace-event (Perfetto-loadable) export.
//!
//! Renders tracer spans as complete events (`ph: "X"`) in the [Trace
//! Event Format] consumed by `chrome://tracing` and
//! [ui.perfetto.dev](https://ui.perfetto.dev): each labeled tracer
//! becomes one process track (`pid`), each span's [`Span::track`]
//! becomes a thread lane (`tid`), and span attributes become `args`.
//! Timestamps are microseconds — [`crate::units::Time::as_us`] of the
//! span's (sim or logical) clock, never wall clock — so the exported
//! document is byte-deterministic per seed via [`crate::json::Json::dump`].
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! DESIGN.md: §12 (observability).

use std::collections::BTreeMap;

use crate::json::Json;

use super::tracer::{Attr, Span, Tracer};

fn attr_json(attr: &Attr) -> Json {
    match attr {
        Attr::Int(v) => Json::Num(*v as f64),
        Attr::Float(v) => Json::Num(*v),
        Attr::Str(s) => Json::Str(s.clone()),
    }
}

fn span_event(span: &Span, pid: u64) -> Json {
    let mut ev = BTreeMap::new();
    ev.insert("name".to_string(), Json::Str(span.name.to_string()));
    ev.insert("cat".to_string(), Json::Str("obs".to_string()));
    ev.insert("ph".to_string(), Json::Str("X".to_string()));
    ev.insert("ts".to_string(), Json::Num(span.start.as_us()));
    ev.insert("dur".to_string(), Json::Num((span.end - span.start).as_us().max(0.0)));
    ev.insert("pid".to_string(), Json::Num(pid as f64));
    ev.insert("tid".to_string(), Json::Num(span.track as f64));
    if !span.attrs.is_empty() {
        let mut args = BTreeMap::new();
        for (k, v) in &span.attrs {
            args.insert(k.to_string(), attr_json(v));
        }
        ev.insert("args".to_string(), Json::Obj(args));
    }
    Json::Obj(ev)
}

fn process_name_event(pid: u64, name: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(name.to_string()));
    let mut ev = BTreeMap::new();
    ev.insert("name".to_string(), Json::Str("process_name".to_string()));
    ev.insert("ph".to_string(), Json::Str("M".to_string()));
    ev.insert("pid".to_string(), Json::Num(pid as f64));
    ev.insert("args".to_string(), Json::Obj(args));
    Json::Obj(ev)
}

/// Assemble a Chrome trace document from labeled tracers.
///
/// Each `(label, tracer)` pair becomes one process track (pids are
/// assigned 1, 2, … in input order, announced via `"M"` metadata
/// events); every retained span becomes an `"X"` complete event on
/// thread lane [`Span::track`].
pub fn chrome_trace(processes: &[(&str, &Tracer)]) -> Json {
    let mut events = Vec::new();
    for (i, (label, tracer)) in processes.iter().enumerate() {
        let pid = i as u64 + 1;
        events.push(process_name_event(pid, label));
        for span in tracer.spans() {
            events.push(span_event(&span, pid));
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    doc.insert("traceEvents".to_string(), Json::Arr(events));
    Json::Obj(doc)
}

/// [`chrome_trace`] rendered through the sorted-key serializer.
pub fn chrome_trace_json(processes: &[(&str, &Tracer)]) -> String {
    chrome_trace(processes).dump()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::units::Time;

    #[test]
    fn export_is_wellformed_and_deterministic() {
        let t = Tracer::new(8);
        t.record_at("round", 3, Time::us(10.0), Time::us(25.0), vec![("shard", Attr::Int(3))]);
        t.record_at("flip", 0, Time::us(25.0), Time::us(25.0), Vec::new());
        let text = chrome_trace_json(&[("engine", &t)]);
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3); // metadata + 2 spans
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        let round = &events[1];
        assert_eq!(round.get("name").unwrap().as_str(), Some("round"));
        assert_eq!(round.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(round.get("ts").unwrap().as_f64(), Some(10.0));
        assert_eq!(round.get("dur").unwrap().as_f64(), Some(15.0));
        assert_eq!(round.get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(round.get("tid").unwrap().as_usize(), Some(3));
        assert_eq!(round.get("args").unwrap().get("shard").unwrap().as_usize(), Some(3));
        // Zero-duration spans are legal and stay non-negative.
        assert_eq!(events[2].get("dur").unwrap().as_f64(), Some(0.0));
        // Byte determinism: same spans → same bytes.
        assert_eq!(text, chrome_trace_json(&[("engine", &t)]));
    }

    #[test]
    fn multiple_processes_get_distinct_pids() {
        let a = Tracer::new(4);
        a.record_at("x", 0, Time::ZERO, Time::us(1.0), Vec::new());
        let b = Tracer::new(4);
        b.record_at("y", 1, Time::ZERO, Time::us(2.0), Vec::new());
        let doc = chrome_trace(&[("alpha", &a), ("beta", &b)]);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(events[1].get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(events[3].get("pid").unwrap().as_usize(), Some(2));
        assert_eq!(events[2].get("args").unwrap().get("name").unwrap().as_str(), Some("beta"));
    }
}
