//! Span-based tracing with a bounded ring buffer and two clock domains.
//!
//! Spans record `(name, track, start, end, attrs)` where `start`/`end`
//! are [`Time`] values in one of two deterministic clock domains:
//!
//! * **Sim time** — event-driven code (traffic, netsim) records spans
//!   at explicit simulated timestamps via [`Tracer::record_at`].  These
//!   spans line up with `TrafficReport`/`NetSimReport` totals exactly.
//! * **Logical ticks** — code with no simulated clock (engine assembly,
//!   shard planning) uses [`Tracer::scope`] guards stamped from a
//!   monotone tick counter (rendered as 1 µs per tick).  Tick spans
//!   order and nest correctly and are a pure function of the call
//!   sequence — never of wall clock.
//!
//! A disabled tracer ([`Tracer::disabled`]) costs one branch per call
//! and performs no allocation or clock movement, so instrumented and
//! uninstrumented runs are bit-identical in every output.
//!
//! DESIGN.md: §12 (observability).

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::mem;

use crate::units::Time;

/// A span attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum Attr {
    Int(i64),
    Float(f64),
    Str(String),
}

impl From<i64> for Attr {
    fn from(v: i64) -> Attr {
        Attr::Int(v)
    }
}

impl From<usize> for Attr {
    fn from(v: usize) -> Attr {
        Attr::Int(v as i64)
    }
}

impl From<u64> for Attr {
    fn from(v: u64) -> Attr {
        Attr::Int(v as i64)
    }
}

impl From<f64> for Attr {
    fn from(v: f64) -> Attr {
        Attr::Float(v)
    }
}

impl From<&str> for Attr {
    fn from(v: &str) -> Attr {
        Attr::Str(v.to_string())
    }
}

impl From<String> for Attr {
    fn from(v: String) -> Attr {
        Attr::Str(v)
    }
}

/// One completed span.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: &'static str,
    /// Timeline track (Chrome trace `tid`): server index, shard index,
    /// device id — whatever "lane" the span belongs to.
    pub track: u64,
    pub start: Time,
    pub end: Time,
    pub attrs: Vec<(&'static str, Attr)>,
}

/// Interior-mutable span recorder with a bounded ring buffer.
///
/// All recording goes through `&self`, so guards nest freely and the
/// tracer can be threaded through call stacks holding only shared
/// borrows.  When the ring is full the oldest span is dropped and
/// [`Tracer::dropped`] counts it — memory stays bounded on arbitrarily
/// long runs.
#[derive(Debug, Clone)]
pub struct Tracer {
    enabled: bool,
    capacity: usize,
    spans: RefCell<VecDeque<Span>>,
    dropped: Cell<u64>,
    clock: Cell<u64>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::disabled()
    }
}

impl Tracer {
    /// An enabled tracer retaining at most `capacity` spans (≥ 1).
    pub fn new(capacity: usize) -> Tracer {
        Tracer {
            enabled: true,
            capacity: capacity.max(1),
            spans: RefCell::new(VecDeque::new()),
            dropped: Cell::new(0),
            clock: Cell::new(0),
        }
    }

    /// A no-op tracer: every call is one branch, no allocation.
    pub fn disabled() -> Tracer {
        Tracer {
            enabled: false,
            capacity: 0,
            spans: RefCell::new(VecDeque::new()),
            dropped: Cell::new(0),
            clock: Cell::new(0),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Advance the logical clock and return the new tick as a time
    /// (1 µs per tick).  Disabled tracers return zero without moving.
    pub fn tick(&self) -> Time {
        if !self.enabled {
            return Time::ZERO;
        }
        let t = self.clock.get() + 1;
        self.clock.set(t);
        Time::us(t as f64)
    }

    /// Record a completed span (no-op when disabled).
    pub fn record(&self, span: Span) {
        if !self.enabled {
            return;
        }
        let mut spans = self.spans.borrow_mut();
        if spans.len() == self.capacity {
            spans.pop_front();
            self.dropped.set(self.dropped.get() + 1);
        }
        spans.push_back(span);
    }

    /// Record a span at explicit sim times.
    pub fn record_at(
        &self,
        name: &'static str,
        track: u64,
        start: Time,
        end: Time,
        attrs: Vec<(&'static str, Attr)>,
    ) {
        if !self.enabled {
            return;
        }
        self.record(Span { name, track, start, end, attrs });
    }

    /// Open a logical-clock span; it records on drop.  Prefer the
    /// [`crate::span!`] macro, which attaches attributes inline.
    pub fn scope(&self, name: &'static str) -> SpanGuard<'_> {
        SpanGuard {
            tracer: if self.enabled { Some(self) } else { None },
            name,
            track: 0,
            start: self.tick(),
            attrs: Vec::new(),
        }
    }

    /// Snapshot of the retained spans, oldest first.
    pub fn spans(&self) -> Vec<Span> {
        self.spans.borrow().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.spans.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.borrow().is_empty()
    }

    /// Spans evicted from the ring because it was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    /// Forget retained spans and reset the drop count and clock.
    pub fn clear(&self) {
        self.spans.borrow_mut().clear();
        self.dropped.set(0);
        self.clock.set(0);
    }
}

/// RAII guard for a logical-clock span; records on drop.
pub struct SpanGuard<'a> {
    tracer: Option<&'a Tracer>,
    name: &'static str,
    track: u64,
    start: Time,
    attrs: Vec<(&'static str, Attr)>,
}

impl SpanGuard<'_> {
    /// Attach an attribute (no-op and no allocation when disabled).
    pub fn attr(mut self, key: &'static str, v: impl Into<Attr>) -> Self {
        if self.tracer.is_some() {
            self.attrs.push((key, v.into()));
        }
        self
    }

    /// Assign the span to a timeline track.
    pub fn track(mut self, track: u64) -> Self {
        self.track = track;
        self
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(t) = self.tracer {
            t.record(Span {
                name: self.name,
                track: self.track,
                start: self.start,
                end: t.tick(),
                attrs: mem::take(&mut self.attrs),
            });
        }
    }
}

/// Open a span guard on `$tracer` with inline attributes:
///
/// ```
/// use ima_gnn::obs::Tracer;
/// let tracer = Tracer::new(64);
/// {
///     let _s = ima_gnn::span!(tracer, "round", shard = 3usize);
/// }
/// assert_eq!(tracer.spans()[0].name, "round");
/// ```
///
/// Attribute values are anything `Into<Attr>` (integers, floats,
/// strings).  The span closes when the guard drops.
#[macro_export]
macro_rules! span {
    ($tracer:expr, $name:expr $(, $key:ident = $val:expr)* $(,)?) => {{
        #[allow(unused_mut)]
        let mut guard = $tracer.scope($name);
        $(
            guard = guard.attr(stringify!($key), $val);
        )*
        guard
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guards_nest_and_stamp_logical_ticks() {
        let t = Tracer::new(16);
        {
            let _outer = span!(t, "outer", kind = "test");
            let _inner = span!(t, "inner", n = 7usize).track(2);
        }
        let spans = t.spans();
        // Inner drops first.
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "inner");
        assert_eq!(spans[0].track, 2);
        assert_eq!(spans[0].attrs, vec![("n", Attr::Int(7))]);
        assert_eq!(spans[1].name, "outer");
        // Ticks: outer opens at 1, inner spans [2, 3], outer closes at 4.
        assert_eq!(spans[0].start, Time::us(2.0));
        assert_eq!(spans[0].end, Time::us(3.0));
        assert_eq!(spans[1].start, Time::us(1.0));
        assert_eq!(spans[1].end, Time::us(4.0));
        // Nesting: inner strictly inside outer.
        assert!(spans[1].start < spans[0].start && spans[0].end < spans[1].end);
    }

    #[test]
    fn ring_buffer_bounds_memory_and_counts_drops() {
        let t = Tracer::new(3);
        for i in 0..5u64 {
            t.record_at("s", i, Time::us(i as f64), Time::us(i as f64 + 1.0), Vec::new());
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        // Oldest two were evicted.
        assert_eq!(t.spans()[0].track, 2);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        {
            let _s = span!(t, "never", x = 1i64);
        }
        t.record_at("also_never", 0, Time::ZERO, Time::us(1.0), Vec::new());
        assert!(t.is_empty());
        assert_eq!(t.tick(), Time::ZERO);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn record_at_keeps_sim_times_verbatim() {
        let t = Tracer::new(8);
        t.record_at("pkt", 4, Time::ms(1.5), Time::ms(2.25), vec![("bytes", Attr::Int(512))]);
        let s = &t.spans()[0];
        assert_eq!(s.start, Time::ms(1.5));
        assert_eq!(s.end, Time::ms(2.25));
        assert_eq!(s.track, 4);
    }
}
