//! # IMA-GNN — In-Memory Acceleration of Centralized and Decentralized GNNs at the Edge
//!
//! Reproduction of the IMA-GNN paper (Morsali et al., 2023) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels emulating the
//!   resistive MVM / CAM crossbars (bit-serial quantized MVM, search, scan).
//! * **Layer 2** (`python/compile/`) — JAX GNN models (GCN, hetGNN-LSTM)
//!   lowered once to HLO-text artifacts.
//! * **Layer 3** (this crate) — the edge coordinator, the bottom-up
//!   hardware model (device → crossbar → core), the centralized /
//!   decentralized network model (paper Eqs. 1–7), a discrete-event
//!   simulator, the packet-level contention-aware network fabric
//!   simulator (`netsim`), and the PJRT runtime that executes the AOT
//!   artifacts (optional `pjrt` feature; stubbed offline).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! models once; the `ima-gnn` binary and the examples are self-contained.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod autotune;
pub mod bench;
pub mod cli;
pub mod comm;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod cores;
pub mod crossbar;
pub mod device;
pub mod error;
pub mod experiments;
pub mod graph;
pub mod json;
pub mod netmodel;
pub mod netsim;
pub mod obs;
pub mod par;
pub mod perfbench;
pub mod pjrt;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod traffic;
pub mod units;
pub mod workload;

pub use error::{Error, Result};
pub use units::{Area, Energy, Power, Time};
