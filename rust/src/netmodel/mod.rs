//! Network modeling (paper §3): composes the per-node core figures and the
//! link models into the centralized / decentralized latency & power
//! equations (1)–(7), plus the semi-decentralized extension the paper's
//! conclusion calls for (E8).
//!
//! Equation map:
//! * Eq. (1)  `T_Net = T_compute + T_communicate`            → [`NetModel::latency`]
//! * Eq. (2)  `T_compute-dec = t₁ + t₂ + t₃`                 → [`NetModel::compute_latency`]
//! * Eq. (3)  `T_compute-cent = (t₁/M₁ + t₂/M₂ + t₃/M₃)(N−1)`
//! * Eq. (4)  `T_comm-dec = (tₑ + cₛ·t(L_c))·2`  (the paper's (4)/(5)
//!   labels are swapped: (4) describes the decentralized cluster exchange)
//! * Eq. (5)  `T_comm-cent = t(L_n)` (concurrent transfers)
//! * Eq. (6)  `P_Net = P_compute + P_communicate`            → [`NetModel::power`]
//! * Eq. (7)  `P_comm-dec = (1/t(L_c)) Σ_{x=1}^{X−1} α(x+1)·E_perBit`
//!
//! DESIGN.md: §4 (network model and the experiment code path).

use crate::comm::{InterClusterLink, InterNetworkLink};
use crate::config::{AcceleratorConfig, CommConfig};
use crate::cores::{Accelerator, CoreBreakdown, GnnWorkload};
use crate::error::Result;
use crate::units::{Power, Time};

/// Deployment setting (paper Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Setting {
    Centralized,
    Decentralized,
}

/// A communication fabric the network model can consult in place of the
/// closed-form Eqs. (4)/(5): the equations themselves ([`AnalyticFabric`])
/// or the packet-level simulator (`netsim::NetSim`), which must coincide
/// with them in the uncongested single-message case (cross-validated in
/// `rust/tests/netsim_cross_validation.rs`).
pub trait CommFabric {
    /// Latency of one full communication round of `setting` over `topo`.
    fn round_comm_latency(
        &self,
        model: &NetModel,
        setting: Setting,
        topo: Topology,
    ) -> Result<Time>;
}

/// The closed-form fabric: defers back to [`NetModel::communicate_latency`].
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticFabric;

impl CommFabric for AnalyticFabric {
    fn round_comm_latency(
        &self,
        model: &NetModel,
        setting: Setting,
        topo: Topology,
    ) -> Result<Time> {
        Ok(model.communicate_latency(setting, topo))
    }
}

/// Edge-graph topology parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of edge devices N.
    pub nodes: usize,
    /// Cluster size cₛ (adjacent nodes exchanged with, decentralized).
    pub cluster_size: usize,
}

impl Topology {
    /// The paper's taxi study: N = 10 000, cₛ = 10.
    pub fn taxi() -> Topology {
        Topology { nodes: 10_000, cluster_size: 10 }
    }
}

/// Concurrently-active crossbar banks in the centralized cores.
///
/// The centralized accelerator has Mᵢ× the crossbars but the shared vector
/// generator & scheduler and the core bus bound how many banks stream
/// simultaneously; average power scales with this activity, not with Mᵢ.
/// Values fitted to Table 1's centralized power column (DESIGN.md §4):
/// 10.8/0.21, 780.1/41.6, 32.21/3.68.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActivityFactors {
    pub traversal: f64,
    pub aggregation: f64,
    pub feature: f64,
}

impl Default for ActivityFactors {
    fn default() -> Self {
        ActivityFactors { traversal: 51.4286, aggregation: 18.7524, feature: 8.7527 }
    }
}

/// Latency decomposition (Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetLatency {
    pub compute: Time,
    pub communicate: Time,
}

impl NetLatency {
    pub fn total(&self) -> Time {
        self.compute + self.communicate
    }
}

/// Power decomposition (Eq. 6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetPower {
    pub compute: Power,
    pub communicate: Power,
}

impl NetPower {
    pub fn total(&self) -> Power {
        self.compute + self.communicate
    }
}

/// Per-core latency triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreLatencies {
    pub traversal: Time,
    pub aggregation: Time,
    pub feature: Time,
}

impl CoreLatencies {
    pub fn total(&self) -> Time {
        self.traversal + self.aggregation + self.feature
    }
}

/// The assembled network model for one workload.
#[derive(Debug)]
pub struct NetModel {
    breakdown: CoreBreakdown,
    /// The paper's M₁/M₂/M₃ capacity ratios.
    m: (f64, f64, f64),
    activity: ActivityFactors,
    inter: InterNetworkLink,
    intra: InterClusterLink,
    /// Per-node message payload on the links.
    message_bytes: usize,
    /// Neuron activations per GNN layer (α(x) of Eq. 7), outermost first.
    alpha: Vec<usize>,
    /// Bits per activation on the wire.
    activation_bits: u32,
}

impl NetModel {
    /// Build from explicit accelerator configs + comm parameters.
    pub fn new(
        centralized: &AcceleratorConfig,
        decentralized: &AcceleratorConfig,
        comm: CommConfig,
        workload: &GnnWorkload,
    ) -> Result<NetModel> {
        comm.validate()?;
        let acc = Accelerator::new(decentralized.clone())?;
        let breakdown = acc.per_node(workload);
        let m = centralized.capacity_ratios(decentralized);
        Ok(NetModel {
            breakdown,
            m,
            activity: ActivityFactors::default(),
            inter: InterNetworkLink::new(comm.clone()),
            intra: InterClusterLink::new(comm),
            message_bytes: workload.message_bytes(),
            alpha: vec![workload.feature_len, workload.fe_out],
            activation_bits: workload.feature_bits,
        })
    }

    /// The paper's evaluation setup (§4.1 presets + §4.2 comm calibration).
    pub fn paper(workload: &GnnWorkload) -> Result<NetModel> {
        use crate::config::presets;
        NetModel::new(
            &presets::centralized(),
            &presets::decentralized(),
            CommConfig::paper(),
            workload,
        )
    }

    /// Override the on-wire message size (bytes per node exchange).
    ///
    /// Fig. 8 evaluates the four datasets with the *standard* per-node
    /// compute workload (the Table 1 t₁/t₂/t₃) while the communication
    /// payload follows each dataset's feature length at 8-bit wire
    /// encoding (the DAC input quantization); this override decouples the
    /// two, matching how the paper's averages compose (EXPERIMENTS.md E3).
    pub fn with_message_bytes(mut self, bytes: usize) -> NetModel {
        self.message_bytes = bytes;
        self
    }

    /// Fig. 8 model for one dataset: standard compute workload, dataset
    /// feature length on the wire (1 byte per feature).
    pub fn fig8(stats: &crate::graph::DatasetStats) -> Result<NetModel> {
        Ok(NetModel::paper(&GnnWorkload::taxi())?.with_message_bytes(stats.feature_len))
    }

    pub fn breakdown(&self) -> &CoreBreakdown {
        &self.breakdown
    }

    pub fn capacity_ratios(&self) -> (f64, f64, f64) {
        self.m
    }

    pub fn message_bytes(&self) -> usize {
        self.message_bytes
    }

    /// The centralized inter-network link L_n.
    pub fn inter_link(&self) -> &InterNetworkLink {
        &self.inter
    }

    /// The decentralized inter-cluster link L_c.
    pub fn intra_link(&self) -> &InterClusterLink {
        &self.intra
    }

    /// Per-core computation latencies in `setting` (the Table 1 rows).
    pub fn per_core_latency(&self, setting: Setting, topo: Topology) -> CoreLatencies {
        let b = &self.breakdown;
        match setting {
            Setting::Decentralized => {
                CoreLatencies { traversal: b.t1, aggregation: b.t2, feature: b.t3 }
            }
            Setting::Centralized => {
                let n1 = (topo.nodes.saturating_sub(1)) as f64;
                CoreLatencies {
                    traversal: b.t1 * (n1 / self.m.0),
                    aggregation: b.t2 * (n1 / self.m.1),
                    feature: b.t3 * (n1 / self.m.2),
                }
            }
        }
    }

    /// Eq. (2) / Eq. (3).
    pub fn compute_latency(&self, setting: Setting, topo: Topology) -> Time {
        self.per_core_latency(setting, topo).total()
    }

    /// Eq. (4) / Eq. (5).
    pub fn communicate_latency(&self, setting: Setting, topo: Topology) -> Time {
        match setting {
            // Concurrent transfers over the fast inter-network link.
            Setting::Centralized => self.inter.transfer(self.message_bytes),
            // Sequential exchange with all cₛ adjacent nodes, two-way.
            Setting::Decentralized => {
                (self.intra.setup()
                    + self.intra.hop(self.message_bytes) * topo.cluster_size as f64)
                    * 2.0
            }
        }
    }

    /// Eq. (1).
    pub fn latency(&self, setting: Setting, topo: Topology) -> NetLatency {
        NetLatency {
            compute: self.compute_latency(setting, topo),
            communicate: self.communicate_latency(setting, topo),
        }
    }

    /// Eq. (1) with the communication term delegated to `fabric` — the
    /// entry point the packet-level `netsim` simulator plugs into.
    pub fn latency_via(
        &self,
        fabric: &dyn CommFabric,
        setting: Setting,
        topo: Topology,
    ) -> Result<NetLatency> {
        Ok(NetLatency {
            compute: self.compute_latency(setting, topo),
            communicate: fabric.round_comm_latency(self, setting, topo)?,
        })
    }

    /// Per-core computation powers (the Table 1 power column).
    pub fn per_core_power(&self, setting: Setting) -> (Power, Power, Power) {
        let (p1, p2, p3) = self.breakdown.powers();
        match setting {
            Setting::Decentralized => (p1, p2, p3),
            Setting::Centralized => (
                p1 * self.activity.traversal,
                p2 * self.activity.aggregation,
                p3 * self.activity.feature,
            ),
        }
    }

    /// P_compute of Eq. (6).
    pub fn compute_power(&self, setting: Setting) -> Power {
        let (p1, p2, p3) = self.per_core_power(setting);
        p1 + p2 + p3
    }

    /// P_communicate of Eq. (6): `p(L_n)·2` centralized, Eq. (7)
    /// decentralized.
    pub fn communicate_power(&self, setting: Setting) -> Power {
        match setting {
            Setting::Centralized => self.inter.power() * 2.0,
            Setting::Decentralized => {
                // (1 / t(L_c)) · Σ_{x=1}^{X-1} α(x+1) · E_perBit
                let t_lc = self.intra.hop(self.message_bytes);
                let mut energy = crate::units::Energy::ZERO;
                for x in 1..self.alpha.len() {
                    let bits = self.alpha[x] * self.activation_bits as usize;
                    energy += self.intra.hop_energy(bits.div_ceil(8));
                }
                energy / t_lc
            }
        }
    }

    /// Eq. (6).
    pub fn power(&self, setting: Setting, topo: Topology) -> NetPower {
        let _ = topo;
        NetPower {
            compute: self.compute_power(setting),
            communicate: self.communicate_power(setting),
        }
    }

    /// X-layer GNN latency: the decentralized setting pays one cluster
    /// exchange per layer boundary (each layer's aggregation needs the
    /// neighbors' previous-layer embeddings — the sum structure of Eq. 7);
    /// the centralized leader holds all state, so only the initial gather
    /// is paid.  `X = 1` degenerates to [`NetModel::latency`].
    pub fn latency_layers(&self, setting: Setting, topo: Topology, layers: usize) -> NetLatency {
        let x = layers.max(1);
        let one = self.latency(setting, topo);
        match setting {
            Setting::Centralized => NetLatency {
                compute: one.compute * x as f64,
                communicate: one.communicate,
            },
            Setting::Decentralized => NetLatency {
                compute: one.compute * x as f64,
                communicate: one.communicate * x as f64,
            },
        }
    }

    /// Energy of one full-graph inference (P·t over the Eq. 1/6 terms):
    /// returns (compute, communication) energy.
    pub fn inference_energy(
        &self,
        setting: Setting,
        topo: Topology,
    ) -> (crate::units::Energy, crate::units::Energy) {
        let b = &self.breakdown;
        let n = topo.nodes as f64;
        // Per-node compute energy is setting-independent (same work); the
        // centralized leader simply does N-1 nodes' worth of it.
        let compute = match setting {
            Setting::Decentralized => b.total_energy() * n,
            Setting::Centralized => b.total_energy() * (n - 1.0).max(0.0),
        };
        let comm_power = self.communicate_power(setting);
        let comm = match setting {
            Setting::Centralized => comm_power * self.communicate_latency(setting, topo),
            // every device pays its cluster exchange
            Setting::Decentralized => {
                comm_power * self.communicate_latency(setting, topo) * n
            }
        };
        (compute, comm)
    }

    /// Semi-decentralized hybrid (conclusion / paper ref [26], E8):
    /// cluster heads with `head_capacity`× a member's cores serve their
    /// region in a centralized fashion over fast V2X links, while the graph
    /// level stays decentralized (heads exchange boundary data with
    /// adjacent heads over L_n).
    pub fn semi_latency(&self, topo: Topology, head_capacity: f64) -> NetLatency {
        self.semi_latency_clustered(topo, head_capacity, 1.0)
    }

    /// Boundary-aware Eq. (4) (E11): a real clustering keeps only a
    /// fraction `intra_fraction` of each device's cₛ exchanges inside the
    /// cluster; the remaining boundary neighbors are reached through a
    /// border relay (two L_c hops instead of one), so the per-exchange hop
    /// cost scales by `2 − f`.  `f = 1` recovers the paper's Eq. (4).
    pub fn communicate_latency_clustered(
        &self,
        topo: Topology,
        intra_fraction: f64,
    ) -> Time {
        let beta = 2.0 - intra_fraction.clamp(0.0, 1.0);
        (self.intra.setup()
            + self.intra.hop(self.message_bytes) * (topo.cluster_size as f64 * beta))
            * 2.0
    }

    /// Boundary-aware E8 hybrid (E11): heads exchange boundary embeddings
    /// with adjacent heads, and the volume of that exchange grows with the
    /// cut — member↔head up/down stays 2 transfers, the head↔head phase
    /// costs `2·(2 − f)` transfers.  `f = 1` recovers [`Self::semi_latency`]
    /// (4 transfers total).
    pub fn semi_latency_clustered(
        &self,
        topo: Topology,
        head_capacity: f64,
        intra_fraction: f64,
    ) -> NetLatency {
        let b = &self.breakdown;
        let cs = topo.cluster_size.max(1) as f64;
        let h = head_capacity.max(1.0);
        let f = intra_fraction.clamp(0.0, 1.0);
        let compute = (b.t1 + b.t2 + b.t3) * ((cs - 1.0).max(1.0) / h);
        // members↔head (concurrent, V2X) + head↔head boundary exchange.
        let communicate = self.inter.transfer(self.message_bytes) * (2.0 + 2.0 * (2.0 - f));
        NetLatency { compute, communicate }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::testing::assert_close;

    fn model() -> NetModel {
        NetModel::paper(&GnnWorkload::taxi()).unwrap()
    }

    /// E1: the full Table 1, both settings, all rows, within 1%.
    #[test]
    fn table1_reproduction() {
        let m = model();
        let topo = Topology::taxi();

        // Decentralized latency column.
        let dec = m.per_core_latency(Setting::Decentralized, topo);
        assert_close(dec.traversal.as_ns(), 7.68, 0.01);
        assert_close(dec.aggregation.as_us(), 14.27, 0.01);
        assert_close(dec.feature.as_us(), 0.37, 0.01);
        assert_close(dec.total().as_us(), 14.6, 0.01);

        // Centralized latency column.
        let cent = m.per_core_latency(Setting::Centralized, topo);
        assert_close(cent.traversal.as_ns(), 38.43, 0.01);
        assert_close(cent.aggregation.as_us(), 142.77, 0.01);
        assert_close(cent.feature.as_us(), 14.53, 0.01);
        assert_close(cent.total().as_us(), 157.34, 0.01);

        // Power columns.
        let (p1, p2, p3) = m.per_core_power(Setting::Decentralized);
        assert_close(p1.as_mw(), 0.21, 0.01);
        assert_close(p2.as_mw(), 41.6, 0.01);
        assert_close(p3.as_mw(), 3.68, 0.01);
        assert_close(m.compute_power(Setting::Decentralized).as_mw(), 45.49, 0.01);

        let (q1, q2, q3) = m.per_core_power(Setting::Centralized);
        assert_close(q1.as_mw(), 10.8, 0.01);
        assert_close(q2.as_mw(), 780.1, 0.01);
        assert_close(q3.as_mw(), 32.21, 0.01);
        assert_close(m.compute_power(Setting::Centralized).as_mw(), 823.11, 0.01);

        // Communication row: ~3.3 ms vs ~406 ms.
        assert_close(m.communicate_latency(Setting::Centralized, topo).as_ms(), 3.3, 0.01);
        assert_close(m.communicate_latency(Setting::Decentralized, topo).as_ms(), 406.0, 0.01);
    }

    /// §4.2's derived ratios: 5× / 10× / ~39× per core, ~10× net compute,
    /// ~120× communication, 18× power-per-node.
    #[test]
    fn table1_derived_ratios() {
        let m = model();
        let topo = Topology::taxi();
        let c = m.per_core_latency(Setting::Centralized, topo);
        let d = m.per_core_latency(Setting::Decentralized, topo);
        assert_close(c.traversal / d.traversal, 5.0, 0.01);
        assert_close(c.aggregation / d.aggregation, 10.0, 0.01);
        assert_close(c.feature / d.feature, 39.0, 0.02);
        assert_close(c.total() / d.total(), 10.7, 0.02);
        let comm_ratio = m.communicate_latency(Setting::Decentralized, topo)
            / m.communicate_latency(Setting::Centralized, topo);
        assert_close(comm_ratio, 123.0, 0.02);
        let p_ratio = m.compute_power(Setting::Centralized)
            / m.compute_power(Setting::Decentralized);
        assert_close(p_ratio, 18.0, 0.02);
    }

    /// E3: Fig. 8's headline averages over the four datasets:
    /// decentralized computes ~1400× faster, centralized communicates
    /// ~790× faster.
    #[test]
    fn fig8_headline_averages() {
        let mut comp_ratio_sum = 0.0;
        let mut comm_ratio_sum = 0.0;
        for d in datasets::all() {
            let m = NetModel::fig8(&d).unwrap();
            let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
            comp_ratio_sum += m.compute_latency(Setting::Centralized, topo)
                / m.compute_latency(Setting::Decentralized, topo);
            comm_ratio_sum += m.communicate_latency(Setting::Decentralized, topo)
                / m.communicate_latency(Setting::Centralized, topo);
        }
        assert_close(comp_ratio_sum / 4.0, 1400.0, 0.05);
        assert_close(comm_ratio_sum / 4.0, 790.0, 0.05);
    }

    /// Fig. 8 orderings the paper calls out explicitly.
    #[test]
    fn fig8_dataset_orderings() {
        let lat = |d: &crate::graph::DatasetStats| {
            let m = NetModel::fig8(d).unwrap();
            let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
            (m.latency(Setting::Centralized, topo), m.latency(Setting::Decentralized, topo))
        };
        let (lj_c, _) = lat(&datasets::livejournal());
        let (co_c, co_d) = lat(&datasets::collab());
        let (cr_c, cr_d) = lat(&datasets::cora());
        let (ci_c, ci_d) = lat(&datasets::citeseer());
        // "LiveJournal has the largest computation latency in the
        // centralized settings because it owns the largest number of nodes."
        assert!(lj_c.compute > co_c.compute);
        assert!(lj_c.compute > cr_c.compute && lj_c.compute > ci_c.compute);
        // "Collab has the largest communication latency ... in the
        // decentralized settings due to its large Average Cs."
        assert!(co_d.communicate > cr_d.communicate);
        assert!(co_d.communicate > ci_d.communicate);
        // Decentralized compute beats centralized on every dataset.
        for d in datasets::all() {
            let (c, dd) = lat(&d);
            assert!(dd.compute < c.compute, "{}", d.name);
        }
    }

    #[test]
    fn analytic_fabric_round_trips_through_latency_via() {
        let m = model();
        let topo = Topology::taxi();
        for s in [Setting::Centralized, Setting::Decentralized] {
            let direct = m.latency(s, topo);
            let via = m.latency_via(&AnalyticFabric, s, topo).unwrap();
            assert_eq!(via.compute, direct.compute);
            assert_eq!(via.communicate, direct.communicate);
        }
    }

    #[test]
    fn decentralized_compute_is_independent_of_n() {
        let m = model();
        let a = m.compute_latency(Setting::Decentralized, Topology { nodes: 10, cluster_size: 5 });
        let b = m.compute_latency(
            Setting::Decentralized,
            Topology { nodes: 1_000_000, cluster_size: 5 },
        );
        assert_eq!(a, b);
    }

    #[test]
    fn centralized_compute_scales_linearly_with_n() {
        let m = model();
        let t1 = m.compute_latency(Setting::Centralized, Topology { nodes: 1001, cluster_size: 5 });
        let t2 =
            m.compute_latency(Setting::Centralized, Topology { nodes: 2001, cluster_size: 5 });
        assert_close(t2 / t1, 2.0, 1e-9);
    }

    #[test]
    fn decentralized_comm_scales_with_cluster_size() {
        let m = model();
        let t5 = m.communicate_latency(Setting::Decentralized, Topology { nodes: 10, cluster_size: 5 });
        let t10 =
            m.communicate_latency(Setting::Decentralized, Topology { nodes: 10, cluster_size: 10 });
        assert!(t10 > t5);
        // centralized comm is cluster-free
        let c5 = m.communicate_latency(Setting::Centralized, Topology { nodes: 10, cluster_size: 5 });
        let c10 =
            m.communicate_latency(Setting::Centralized, Topology { nodes: 10, cluster_size: 10 });
        assert_eq!(c5, c10);
    }

    #[test]
    fn eq1_and_eq6_compose() {
        let m = model();
        let topo = Topology::taxi();
        for s in [Setting::Centralized, Setting::Decentralized] {
            let l = m.latency(s, topo);
            assert_close(l.total().as_s(), (l.compute + l.communicate).as_s(), 1e-12);
            let p = m.power(s, topo);
            assert!(p.total().as_w() >= p.compute.as_w());
        }
    }

    #[test]
    fn eq7_decentralized_comm_power_is_positive_and_layer_driven() {
        let m = model();
        let p = m.communicate_power(Setting::Decentralized);
        assert!(p.as_w() > 0.0);
        // Centralized comm power is the two-way radio power.
        let c = m.communicate_power(Setting::Centralized);
        assert_close(c.as_w(), (m.inter.power() * 2.0).as_w(), 1e-12);
    }

    #[test]
    fn layerwise_latency_composes() {
        let m = model();
        let topo = Topology::taxi();
        let one = m.latency(Setting::Decentralized, topo);
        let three = m.latency_layers(Setting::Decentralized, topo, 3);
        assert_close(three.compute.as_s(), (one.compute * 3.0).as_s(), 1e-12);
        assert_close(three.communicate.as_s(), (one.communicate * 3.0).as_s(), 1e-12);
        // centralized pays the gather once
        let c1 = m.latency(Setting::Centralized, topo);
        let c3 = m.latency_layers(Setting::Centralized, topo, 3);
        assert_eq!(c3.communicate, c1.communicate);
        assert!(c3.compute > c1.compute);
        // X=1 degenerates
        assert_eq!(m.latency_layers(Setting::Centralized, topo, 1).total(), c1.total());
        // deeper GNNs widen the decentralized communication gap
        let ratio1 = one.communicate / c1.communicate;
        let ratio3 = three.communicate / c3.communicate;
        assert!(ratio3 > ratio1 * 2.9);
    }

    #[test]
    fn inference_energy_structure() {
        let m = model();
        let topo = Topology::taxi();
        let (dc, dm) = m.inference_energy(Setting::Decentralized, topo);
        let (cc, cm) = m.inference_energy(Setting::Centralized, topo);
        // same total compute work ⇒ nearly equal compute energy (N vs N-1)
        assert_close(dc.as_j(), cc.as_j() * 10_000.0 / 9_999.0, 1e-6);
        // per-graph communication energy is far higher decentralized
        assert!(dm > cm, "dec comm {dm} must exceed cent comm {cm}");
        assert!(dc.as_j() > 0.0 && cm.as_j() > 0.0);
    }

    /// E11: the boundary-aware variants degenerate to Eqs. (4)/E8 at
    /// `f = 1` and degrade monotonically as the clustering's cut grows.
    #[test]
    fn clustered_variants_degenerate_and_are_monotone_in_f() {
        let m = model();
        let topo = Topology::taxi();
        // f = 1 recovers the closed forms exactly.
        assert_eq!(
            m.communicate_latency_clustered(topo, 1.0),
            m.communicate_latency(Setting::Decentralized, topo)
        );
        let semi = m.semi_latency(topo, 10.0);
        let semi_f1 = m.semi_latency_clustered(topo, 10.0, 1.0);
        assert_eq!(semi_f1.compute, semi.compute);
        assert_eq!(semi_f1.communicate, semi.communicate);
        // f = 0: every exchange relays (2 hops) — dec comm doubles minus
        // the setup term; semi boundary phase doubles (4 → 6 transfers).
        let f0 = m.communicate_latency_clustered(topo, 0.0);
        let f1 = m.communicate_latency_clustered(topo, 1.0);
        assert!(f0 > f1);
        assert_close(
            (f0 - f1).as_s(),
            (m.intra.hop(m.message_bytes) * topo.cluster_size as f64 * 2.0).as_s(),
            1e-12,
        );
        let s0 = m.semi_latency_clustered(topo, 10.0, 0.0);
        assert_close(
            s0.communicate.as_s(),
            (m.inter.transfer(m.message_bytes) * 6.0).as_s(),
            1e-12,
        );
        // Monotone: a better clustering never costs latency.
        let mut prev_dec = f0;
        let mut prev_semi = s0.communicate;
        for f in [0.25, 0.5, 0.75, 1.0] {
            let d = m.communicate_latency_clustered(topo, f);
            let s = m.semi_latency_clustered(topo, 10.0, f).communicate;
            assert!(d <= prev_dec && s <= prev_semi, "f={f}");
            prev_dec = d;
            prev_semi = s;
        }
        // Out-of-range fractions clamp instead of corrupting the model.
        assert_eq!(m.communicate_latency_clustered(topo, 7.0), f1);
        assert_eq!(m.communicate_latency_clustered(topo, -3.0), f0);
    }

    /// E8: the semi-decentralized hybrid beats decentralized communication
    /// by orders of magnitude and centralized computation at scale.
    #[test]
    fn semi_decentralized_balances_the_tradeoff() {
        let m = model();
        let big = Topology { nodes: 1_000_000, cluster_size: 10 };
        let semi = m.semi_latency(big, 10.0);
        let cent = m.latency(Setting::Centralized, big);
        let dec = m.latency(Setting::Decentralized, big);
        assert!(semi.communicate < dec.communicate / 10.0);
        assert!(semi.compute < cent.compute / 100.0);
        // and total wins against both at this scale
        assert!(semi.total() < cent.total());
        assert!(semi.total() < dec.total());
    }
}
