//! Table and figure-series rendering for benches and the CLI.
//!
//! `Table` renders aligned ASCII tables shaped like the paper's Table 1/2;
//! `BarSeries` renders log-scale horizontal bars shaped like Fig. 8.
//!
//! DESIGN.md: §4 (experiment tables and figure series render through this).

use std::fmt::Write as _;

/// Simple aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Table {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "{}", self.title);
        }
        let line = |w: &[usize]| {
            let mut s = String::from("+");
            for width in w {
                s.push_str(&"-".repeat(width + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = w[i] - cell.chars().count();
                let _ = write!(s, " {}{} |", cell, " ".repeat(pad));
            }
            s
        };
        let _ = writeln!(out, "{}", line(&widths));
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let _ = writeln!(out, "{}", line(&widths));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        let _ = writeln!(out, "{}", line(&widths));
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV rendering (RFC-4180 quoting) for downstream plotting.
    pub fn to_csv(&self) -> String {
        let quote = |cell: &str| -> String {
            if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| quote(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV next to the logs (used by benches with `--csv`).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// One bar of a (stacked) bar chart: label + named segments.
#[derive(Debug, Clone)]
pub struct Bar {
    pub label: String,
    pub segments: Vec<(String, f64)>,
}

impl Bar {
    pub fn total(&self) -> f64 {
        self.segments.iter().map(|(_, v)| v).sum()
    }
}

/// Log-scale horizontal stacked bar chart (the shape of paper Fig. 8).
#[derive(Debug, Clone)]
pub struct BarSeries {
    title: String,
    unit: String,
    bars: Vec<Bar>,
    width: usize,
}

impl BarSeries {
    pub fn new(title: impl Into<String>, unit: impl Into<String>) -> BarSeries {
        BarSeries { title: title.into(), unit: unit.into(), bars: Vec::new(), width: 50 }
    }

    pub fn bar(&mut self, label: impl Into<String>, segments: &[(&str, f64)]) -> &mut BarSeries {
        self.bars.push(Bar {
            label: label.into(),
            segments: segments.iter().map(|(n, v)| (n.to_string(), *v)).collect(),
        });
        self
    }

    pub fn bars(&self) -> &[Bar] {
        &self.bars
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} (log scale, {})", self.title, self.unit);
        let max = self.bars.iter().map(Bar::total).fold(f64::MIN_POSITIVE, f64::max);
        let min = self
            .bars
            .iter()
            .flat_map(|b| b.segments.iter().map(|s| s.1))
            .filter(|v| *v > 0.0)
            .fold(f64::MAX, f64::min)
            .min(max);
        let span = (max / min).ln().max(1e-9);
        let label_w = self.bars.iter().map(|b| b.label.chars().count()).max().unwrap_or(0);
        let glyphs = ['#', '=', '.', '~'];
        for bar in &self.bars {
            let mut line = String::new();
            for (i, (_, v)) in bar.segments.iter().enumerate() {
                if *v <= 0.0 {
                    continue;
                }
                // Each segment's length reflects its own log magnitude.
                let frac = ((*v / min).ln() / span).clamp(0.0, 1.0);
                let n = (frac * self.width as f64).round().max(1.0) as usize;
                line.push_str(&glyphs[i % glyphs.len()].to_string().repeat(n));
            }
            let seg_desc = bar
                .segments
                .iter()
                .map(|(n, v)| format!("{n}={v:.3e}"))
                .collect::<Vec<_>>()
                .join(" ");
            let _ = writeln!(
                out,
                "{:<label_w$} |{:<width$}| total {:.3e} {} ({seg_desc})",
                bar.label,
                line,
                bar.total(),
                self.unit,
                label_w = label_w,
                width = self.width + 2,
            );
        }
        let mut legend = String::from("legend:");
        if let Some(first) = self.bars.first() {
            for (i, (name, _)) in first.segments.iter().enumerate() {
                let _ = write!(legend, "  {} {}", glyphs[i % glyphs.len()], name);
            }
        }
        let _ = writeln!(out, "{legend}");
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a 0–1 fraction as a percentage for table cells (`93.8%`).
pub fn pct(fraction: f64) -> String {
    format!("{:.1}%", fraction * 100.0)
}

/// Format a speedup factor the way the paper quotes them (`~790×`).
pub fn speedup(factor: f64) -> String {
    if factor >= 100.0 {
        format!("~{:.0}×", factor)
    } else if factor >= 10.0 {
        format!("~{:.1}×", factor)
    } else {
        format!("~{:.2}×", factor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Table 1", &["Setting", "Latency", "Power"]);
        t.row_str(&["Centralized", "157.34 µs", "823.11 mW"]);
        t.row_str(&["Decentralized", "14.6 µs", "45.49 mW"]);
        let s = t.render();
        assert!(s.contains("Table 1"));
        assert!(s.contains("| Centralized "));
        // All body lines equal width.
        let widths: Vec<usize> =
            s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn bars_render_all_labels_and_legend() {
        let mut b = BarSeries::new("Fig 8", "s");
        b.bar("Cora cent", &[("comm", 3.3e-3), ("comp", 1.57e-4)]);
        b.bar("Cora dec", &[("comm", 0.406), ("comp", 1.46e-5)]);
        let s = b.render();
        assert!(s.contains("Cora cent"));
        assert!(s.contains("Cora dec"));
        assert!(s.contains("legend:"));
        assert!(s.contains("comm"));
    }

    #[test]
    fn bars_handle_zero_segments() {
        let mut b = BarSeries::new("x", "s");
        b.bar("only", &[("a", 0.0), ("b", 1.0)]);
        let s = b.render();
        assert!(s.contains("only"));
    }

    #[test]
    fn csv_escapes_and_round_trips_cells() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_str(&["plain", "with,comma"]);
        t.row_str(&["quote\"inside", "multi\nline"]);
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "plain,\"with,comma\"");
        assert!(lines[2].starts_with("\"quote\"\"inside\""));
    }

    #[test]
    fn csv_writes_to_disk() {
        let mut t = Table::new("x", &["col"]);
        t.row_str(&["v"]);
        let path = std::env::temp_dir().join("ima_gnn_csv_test.csv");
        t.write_csv(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "col\nv\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn speedup_formats() {
        assert_eq!(speedup(789.6), "~790×");
        assert_eq!(speedup(18.04), "~18.0×");
        assert_eq!(speedup(5.0), "~5.00×");
    }

    #[test]
    fn pct_formats_fractions() {
        assert_eq!(pct(0.9375), "93.8%");
        assert_eq!(pct(1.0), "100.0%");
        assert_eq!(pct(0.0), "0.0%");
    }
}
