//! Layer-3 serving coordinator: deployment shapes over one shared engine.
//!
//! The round pipeline — gather → deterministic neighbor sampling → batch
//! padding → tensor assembly → PJRT → output slicing → modeled-latency
//! attachment — is implemented exactly once, in [`RoundEngine`]
//! (`engine.rs`), which executes a table-sharded [`ShardPlan`]: graphs
//! larger than the artifact's static `table` dimension split into
//! table-sized shards with halo-replicated boundary rows, per-shard
//! double-buffered [`FeatureStore`]s and round-constant tensor caches.
//! The paper's deployment settings are thin shapes over it:
//!
//! * [`CentralizedLeader`] — router → dynamic batcher → engine; one
//!   leader serves every request (Fig. 4(a)).
//! * [`SemiCoordinator`] — cluster heads batch their members through the
//!   engine (clusters map onto shards, never split); heads exchange
//!   boundary embeddings (the conclusion's hybrid, E8).
//! * [`run_decentralized`] — per-device worker threads exchanging
//!   features over channels and computing on the functional crossbar
//!   cores (Fig. 4(b)); no serving state, so no engine — but the same
//!   [`LatencyProvider`] prices its rounds (`run_decentralized_via`).
//!
//! [`Deployment::build`] resolves a tuned E11 `OperatingPoint` into any
//! of the three shapes through one funnel, and [`LatencyProvider`]
//! replaces the per-deployment `simulated_latency` fields: Analytic
//! (Eqs. 1/E8), Clustered (boundary-aware E11 variants) or Netsim (a
//! packet-level round completion).  All PJRT execution funnels through
//! the [`InferenceService`] thread; Python is never on this path.
//!
//! [`ShardPlan`]: crate::graph::ShardPlan
//!
//! DESIGN.md: §7 (serving coordinator); §10 (the shared engine).

mod batcher;
mod engine;
mod leader;
mod router;
mod semi;
mod service;
mod state;
mod trace;
mod worker;

pub use batcher::{Batch, Batcher, Request};
pub use engine::{
    DecentralizedPlan, Deployment, EngineOutput, GcnLayerBinding, LatencyProvider, RoundEngine,
    ShardBatch,
};
pub use leader::{CentralizedLeader, Response};
pub use router::Router;
pub use semi::{SemiCoordinator, SemiResult};
pub use service::InferenceService;
pub use state::FeatureStore;
pub use trace::{generate_trace, replay_trace, Arrival, LatencyStats, TraceConfig};
pub use worker::{
    run_decentralized, run_decentralized_oracle, run_decentralized_via, DeviceResult,
};
