//! Layer-3 serving coordinator.
//!
//! The request path (router → batcher → PJRT executor) plus the three
//! deployment shapes the paper analyzes: a centralized leader
//! ([`CentralizedLeader`]), decentralized per-device workers
//! ([`run_decentralized`]) and the semi-decentralized cluster-head hybrid
//! ([`SemiCoordinator`], the conclusion's proposal).  All PJRT execution
//! funnels through the [`InferenceService`] thread; Python is never on
//! this path.

mod batcher;
mod leader;
mod router;
mod semi;
mod service;
mod state;
mod trace;
mod worker;

pub use batcher::{Batch, Batcher, Request};
pub use leader::{CentralizedLeader, GcnLayerBinding, Response};
pub use router::Router;
pub use semi::{SemiCoordinator, SemiResult};
pub use service::InferenceService;
pub use state::FeatureStore;
pub use trace::{generate_trace, replay_trace, Arrival, LatencyStats, TraceConfig};
pub use worker::{run_decentralized, run_decentralized_oracle, DeviceResult};
