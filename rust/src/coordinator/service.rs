//! Inference service: a dedicated thread owning the PJRT client.
//!
//! PJRT handles are not `Send`, so the service thread *constructs* the
//! [`ArtifactStore`] itself and everything XLA lives and dies on that
//! thread; callers talk tensors over channels.  This mirrors the
//! single-accelerator reality of an edge device: one compute engine,
//! many requesters.
//!
//! DESIGN.md: §5 (runtime).

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::{ArtifactStore, Tensor};

enum Msg {
    Infer { artifact: String, inputs: Vec<Tensor>, reply: Sender<Result<Vec<Tensor>>> },
    /// Pre-compile an artifact (warm the executable cache).
    Warm { artifact: String, reply: Sender<Result<()>> },
    Shutdown,
}

/// Handle to the inference service thread.
pub struct InferenceService {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for InferenceService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("InferenceService").finish()
    }
}

impl InferenceService {
    /// Start the service over an artifact directory.  Fails fast when the
    /// manifest cannot be opened or the PJRT client cannot start.
    pub fn start(artifact_dir: PathBuf) -> Result<InferenceService> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("ima-gnn-inference".into())
            .spawn(move || {
                let store = match ArtifactStore::open(&artifact_dir) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Infer { artifact, inputs, reply } => {
                            let _ = reply.send(store.run(&artifact, &inputs));
                        }
                        Msg::Warm { artifact, reply } => {
                            let _ = reply.send(store.load(&artifact).map(|_| ()));
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Coordinator(format!("cannot spawn service thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Coordinator("service thread died during startup".into()))??;
        Ok(InferenceService { tx, handle: Some(handle) })
    }

    /// Compile `artifact` now so later `infer` calls hit the cache.
    pub fn warm(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| Error::Coordinator("service thread gone".into()))?;
        rx.recv().map_err(|_| Error::Coordinator("service thread gone".into()))?
    }

    /// Execute an artifact synchronously.
    pub fn infer(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Infer { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| Error::Coordinator("service thread gone".into()))?;
        rx.recv().map_err(|_| Error::Coordinator("service thread gone".into()))?
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
