//! Node-feature state store with double buffering (paper §2.3: IMA-GNN
//! "is equipped with double buffering for feature data and graph data",
//! overlapping programming with traversal).
//!
//! The *front* buffer serves reads (the crossbars' programmed contents);
//! writes land in the *back* buffer; `swap()` flips them atomically at a
//! round boundary — exactly the semantics the accelerator's buffer array
//! provides, and what keeps a serving round consistent while the next
//! round's features stream in.
//!
//! DESIGN.md: §7 (serving coordinator).

use crate::error::{Error, Result};

/// Double-buffered per-node feature storage.
#[derive(Debug, Clone)]
pub struct FeatureStore {
    num_nodes: usize,
    feature_len: usize,
    front: Vec<f32>,
    back: Vec<f32>,
    /// Which nodes have been written since the last swap.
    dirty: Vec<bool>,
    /// Round counter, bumped on swap.
    version: u64,
}

impl FeatureStore {
    pub fn new(num_nodes: usize, feature_len: usize) -> FeatureStore {
        FeatureStore {
            num_nodes,
            feature_len,
            front: vec![0.0; num_nodes * feature_len],
            back: vec![0.0; num_nodes * feature_len],
            dirty: vec![false; num_nodes],
            version: 0,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn feature_len(&self) -> usize {
        self.feature_len
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    fn check(&self, node: usize, len: usize) -> Result<()> {
        if node >= self.num_nodes {
            return Err(Error::Coordinator(format!(
                "node {node} out of range ({} nodes)",
                self.num_nodes
            )));
        }
        if len != self.feature_len {
            return Err(Error::Coordinator(format!(
                "feature length {len} != store width {}",
                self.feature_len
            )));
        }
        Ok(())
    }

    /// Read a node's current (front) features.
    pub fn read(&self, node: usize) -> Result<&[f32]> {
        self.check(node, self.feature_len)?;
        let at = node * self.feature_len;
        Ok(&self.front[at..at + self.feature_len])
    }

    /// Stage a node's next-round features into the back buffer.
    pub fn write(&mut self, node: usize, features: &[f32]) -> Result<()> {
        self.check(node, features.len())?;
        let at = node * self.feature_len;
        self.back[at..at + self.feature_len].copy_from_slice(features);
        self.dirty[node] = true;
        Ok(())
    }

    /// Nodes staged since the last swap.
    pub fn pending(&self) -> usize {
        self.dirty.iter().filter(|d| **d).count()
    }

    /// Flip buffers: staged writes become visible, untouched nodes keep
    /// their previous values (carried forward).
    pub fn swap(&mut self) {
        for node in 0..self.num_nodes {
            let at = node * self.feature_len;
            if self.dirty[node] {
                // staged value becomes current
                let (f, b) = (&mut self.front, &self.back);
                f[at..at + self.feature_len].copy_from_slice(&b[at..at + self.feature_len]);
                self.dirty[node] = false;
            }
        }
        self.version += 1;
    }

    /// Gather a batch of rows (front buffer) into a flat matrix.
    pub fn gather(&self, nodes: &[usize]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.gather_into(nodes, &mut out)?;
        Ok(out)
    }

    /// [`Self::gather`] into a reused buffer (cleared on entry; contents
    /// unspecified after an error).  Runs of consecutive node ids
    /// coalesce into one contiguous copy over the feature dimension —
    /// the cache-blocked path the engine's full-table build (one memcpy
    /// of the whole front buffer) and batch assembly ride.
    pub fn gather_into(&self, nodes: &[usize], out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        out.reserve(nodes.len() * self.feature_len);
        let f = self.feature_len;
        let mut i = 0;
        while i < nodes.len() {
            self.check(nodes[i], f)?;
            // Extend the run while ids stay consecutive and in range.
            let mut j = i + 1;
            while j < nodes.len() && nodes[j] < self.num_nodes && nodes[j] == nodes[j - 1] + 1
            {
                j += 1;
            }
            let at = nodes[i] * f;
            out.extend_from_slice(&self.front[at..at + (j - i) * f]);
            i = j;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    #[test]
    fn writes_are_invisible_until_swap() {
        let mut s = FeatureStore::new(4, 3);
        s.write(1, &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(s.read(1).unwrap(), &[0.0, 0.0, 0.0]);
        assert_eq!(s.pending(), 1);
        s.swap();
        assert_eq!(s.read(1).unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(s.pending(), 0);
        assert_eq!(s.version(), 1);
    }

    #[test]
    fn unwritten_nodes_carry_forward() {
        let mut s = FeatureStore::new(2, 1);
        s.write(0, &[5.0]).unwrap();
        s.swap();
        s.write(1, &[7.0]).unwrap();
        s.swap();
        assert_eq!(s.read(0).unwrap(), &[5.0]); // survived round 2
        assert_eq!(s.read(1).unwrap(), &[7.0]);
    }

    #[test]
    fn double_write_keeps_last() {
        let mut s = FeatureStore::new(1, 1);
        s.write(0, &[1.0]).unwrap();
        s.write(0, &[2.0]).unwrap();
        s.swap();
        assert_eq!(s.read(0).unwrap(), &[2.0]);
    }

    #[test]
    fn gather_concatenates_rows() {
        let mut s = FeatureStore::new(3, 2);
        s.write(0, &[1.0, 2.0]).unwrap();
        s.write(2, &[5.0, 6.0]).unwrap();
        s.swap();
        assert_eq!(s.gather(&[2, 0]).unwrap(), vec![5.0, 6.0, 1.0, 2.0]);
    }

    /// The run-coalesced gather is the per-row gather, bit for bit:
    /// identity ranges (one memcpy), scattered ids, duplicates, and
    /// descending ids all agree with the row-at-a-time reference.
    #[test]
    fn gather_coalescing_matches_per_row_reference() {
        forall(16, |rng: &mut Rng| {
            let n = rng.index(12) + 1;
            let f = rng.index(5) + 1;
            let mut s = FeatureStore::new(n, f);
            for node in 0..n {
                let vals: Vec<f32> = (0..f).map(|_| rng.f64() as f32).collect();
                s.write(node, &vals).unwrap();
            }
            s.swap();
            // Full-range identity: exactly the front buffer.
            let all: Vec<usize> = (0..n).collect();
            assert_eq!(s.gather(&all).unwrap(), s.front);
            // Random id lists (runs, repeats, reversals all arise).
            for _ in 0..4 {
                let ids: Vec<usize> = (0..rng.index(3 * n)).map(|_| rng.index(n)).collect();
                let want: Vec<f32> =
                    ids.iter().flat_map(|&v| s.read(v).unwrap().iter().copied()).collect();
                let mut out = vec![7.0f32; 3]; // stale contents must not survive
                s.gather_into(&ids, &mut out).unwrap();
                assert_eq!(out, want);
            }
        });
    }

    #[test]
    fn gather_rejects_out_of_range_ids_anywhere_in_a_run() {
        let s = FeatureStore::new(3, 2);
        let mut out = Vec::new();
        assert!(s.gather_into(&[0, 1, 2, 3], &mut out).is_err()); // run exits the store
        assert!(s.gather_into(&[5], &mut out).is_err());
        assert!(s.gather(&[1, 9, 0]).is_err());
    }

    #[test]
    fn bounds_and_arity_checked() {
        let mut s = FeatureStore::new(2, 2);
        assert!(s.write(2, &[0.0, 0.0]).is_err());
        assert!(s.write(0, &[0.0]).is_err());
        assert!(s.read(5).is_err());
        assert!(s.gather(&[0, 9]).is_err());
    }

    #[test]
    fn property_swap_is_a_barrier() {
        forall(16, |rng: &mut Rng| {
            let n = rng.index(10) + 1;
            let f = rng.index(5) + 1;
            let mut s = FeatureStore::new(n, f);
            let mut expected: Vec<Vec<f32>> = vec![vec![0.0; f]; n];
            for _round in 0..3 {
                let mut staged: Vec<Option<Vec<f32>>> = vec![None; n];
                for _w in 0..rng.index(2 * n + 1) {
                    let node = rng.index(n);
                    let vals: Vec<f32> = (0..f).map(|_| rng.f64() as f32).collect();
                    s.write(node, &vals).unwrap();
                    staged[node] = Some(vals);
                }
                // reads during the round still see the old state
                for node in 0..n {
                    assert_eq!(s.read(node).unwrap(), &expected[node][..]);
                }
                s.swap();
                for node in 0..n {
                    if let Some(v) = staged[node].take() {
                        expected[node] = v;
                    }
                    assert_eq!(s.read(node).unwrap(), &expected[node][..]);
                }
            }
        });
    }
}
