//! The shared serving engine (DESIGN.md §10).
//!
//! One implementation of the round pipeline every deployment shape runs
//! on: per-shard [`FeatureStore`] double-buffering, round-constant tensor
//! caches (the weight tensor is built once at construction, each shard's
//! feature-table tensor once per `end_round` barrier), batch padding to
//! the artifact's static shapes, the single PJRT funnel through
//! [`InferenceService`], and a [`LatencyProvider`] that replaces the
//! per-deployment `simulated_latency` fields.  The leader and the semi
//! coordinator are thin shapes over this engine; the decentralized
//! worker pool consumes the same [`LatencyProvider`]
//! (`run_decentralized_via`).
//!
//! Sharding: the engine executes a [`ShardPlan`], so graphs larger than
//! the artifact's `table` dimension serve through multiple table-sized
//! shards with halo-replicated boundary rows.  On a single-shard plan the
//! pipeline is bit-identical to the unsharded seed path (asserted in
//! `rust/tests/sharded_serving.rs`).

use std::cell::RefCell;
use std::time::{Duration, Instant};

use crate::cores::{FeatureMatrix, GnnWorkload};
use crate::error::{Error, Result};
use crate::graph::{Csr, FeatureQuant, NeighborSampler, ResidentSet, ShardPlan};
use crate::netmodel::{NetModel, Setting, Topology};
use crate::obs::{MetricsRegistry, Tracer};
use crate::par;
use crate::runtime::{ArtifactSpec, Tensor};
use crate::span;
use crate::units::Time;

use super::leader::CentralizedLeader;
use super::semi::SemiCoordinator;
use super::service::InferenceService;
use super::state::FeatureStore;

/// Shape binding of a `gcn_layer_*` artifact (from its manifest config).
#[derive(Debug, Clone)]
pub struct GcnLayerBinding {
    pub artifact: String,
    pub batch: usize,
    pub sample: usize,
    pub feature: usize,
    pub hidden: usize,
    pub table: usize,
}

impl GcnLayerBinding {
    pub fn from_spec(spec: &ArtifactSpec) -> Result<GcnLayerBinding> {
        let cfg = |k: &str| -> Result<usize> {
            spec.config
                .get(k)
                .map(|v| *v as usize)
                .ok_or_else(|| Error::Coordinator(format!("{}: missing config `{k}`", spec.name)))
        };
        Ok(GcnLayerBinding {
            artifact: spec.name.clone(),
            batch: cfg("batch")?,
            sample: cfg("sample")?,
            feature: cfg("feature")?,
            hidden: cfg("hidden")?,
            table: cfg("table")?,
        })
    }

    /// The deterministic neighbor sampler every deployment shares (seed 7
    /// — part of the serving determinism contract, DESIGN.md §10).
    pub fn sampler(&self) -> NeighborSampler {
        NeighborSampler::new(self.sample, 7)
    }
}

/// Where the modeled per-round edge latency attached to responses comes
/// from — one enum replacing the three per-deployment `simulated_latency`
/// fields the seed coordinators carried.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyProvider {
    /// Closed-form paper equations (Eq. 1 / E8).
    Analytic,
    /// Boundary-aware clustered variants (E11): the hop terms scale with
    /// the clustering's intra-edge fraction.  `intra_fraction = 1`
    /// coincides with [`LatencyProvider::Analytic`].
    Clustered { intra_fraction: f64 },
    /// A packet-level `netsim` round completion, computed once when the
    /// fabric is configured.
    Netsim(Time),
}

impl LatencyProvider {
    /// Centralized round latency (Eq. 1; the gather has no cluster
    /// structure, so `Clustered` coincides with `Analytic`).
    pub fn centralized(&self, model: &NetModel, topo: Topology) -> Time {
        match *self {
            LatencyProvider::Netsim(t) => t,
            LatencyProvider::Analytic | LatencyProvider::Clustered { .. } => {
                model.latency(Setting::Centralized, topo).total()
            }
        }
    }

    /// Decentralized per-device round latency (Eq. 1 with the Eq. 4
    /// exchange; `Clustered` applies the boundary-relay term).
    pub fn decentralized(&self, model: &NetModel, topo: Topology) -> Time {
        match *self {
            LatencyProvider::Netsim(t) => t,
            LatencyProvider::Analytic => model.latency(Setting::Decentralized, topo).total(),
            LatencyProvider::Clustered { intra_fraction } => {
                model.compute_latency(Setting::Decentralized, topo)
                    + model.communicate_latency_clustered(topo, intra_fraction)
            }
        }
    }

    /// Semi-decentralized round latency (E8 / its clustered E11 variant).
    pub fn semi(&self, model: &NetModel, topo: Topology, head_capacity: f64) -> Time {
        match *self {
            LatencyProvider::Netsim(t) => t,
            LatencyProvider::Analytic => model.semi_latency(topo, head_capacity).total(),
            LatencyProvider::Clustered { intra_fraction } => model
                .semi_latency_clustered(topo, head_capacity, intra_fraction)
                .total(),
        }
    }

    // Communication-only counterparts — the per-batch barrier cost the
    // E13 traffic engine prices (`traffic::ServiceModel`).  The variant
    // dispatch lives here, next to the total-latency forms, so adding a
    // provider variant stays a one-file change; `Netsim` carries one
    // pinned figure and prices the whole barrier with it.

    /// Centralized uplink-gather cost of one batch (Eq. 5; `Clustered`
    /// coincides with `Analytic` — the gather has no cluster structure).
    pub fn centralized_comm(&self, model: &NetModel, topo: Topology) -> Time {
        match *self {
            LatencyProvider::Netsim(t) => t,
            LatencyProvider::Analytic | LatencyProvider::Clustered { .. } => {
                model.communicate_latency(Setting::Centralized, topo)
            }
        }
    }

    /// Decentralized cluster-exchange cost of one batch (Eq. 4 / its
    /// boundary-aware E11 variant).
    pub fn decentralized_comm(&self, model: &NetModel, topo: Topology) -> Time {
        match *self {
            LatencyProvider::Netsim(t) => t,
            LatencyProvider::Analytic => {
                model.communicate_latency(Setting::Decentralized, topo)
            }
            LatencyProvider::Clustered { intra_fraction } => {
                model.communicate_latency_clustered(topo, intra_fraction)
            }
        }
    }

    /// Semi overlay-exchange cost of one batch (E8 / its clustered E11
    /// variant).
    pub fn semi_comm(&self, model: &NetModel, topo: Topology, head_capacity: f64) -> Time {
        match *self {
            LatencyProvider::Netsim(t) => t,
            LatencyProvider::Analytic => model.semi_latency(topo, head_capacity).communicate,
            LatencyProvider::Clustered { intra_fraction } => {
                model.semi_latency_clustered(topo, head_capacity, intra_fraction).communicate
            }
        }
    }
}

/// One assembled per-shard execution: the artifact's `x_self` / `nbr_idx`
/// inputs, padded to the static batch, plus which requested nodes the
/// batch answers.  Pure data — tests compare assembled inputs bit-for-bit
/// without a PJRT backend.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardBatch {
    pub shard: usize,
    /// The requested nodes this batch answers (unpadded, serve order).
    pub nodes: Vec<usize>,
    /// Positions into the original request slice, parallel to `nodes`.
    pub positions: Vec<usize>,
    /// `[batch × feature]` gathered self-features (padded).
    pub x_self: Vec<f32>,
    /// `[batch × sample]` local-slot neighbor indices (padded, -1 = none).
    pub nbr_idx: Vec<i32>,
}

/// Reused allocations of the `assemble` hot path: the per-shard group
/// index (a dense `Vec` keyed by shard id plus the touched-shard list
/// for cheap clearing — replacing the per-call `BTreeMap` and its fresh
/// position vectors) and the sequential path's slot buffer.  Lives
/// behind a `RefCell` because `assemble` is `&self` (shared-ref callers
/// in the serving tests); the engine is `!Sync` anyway (its `Tracer`
/// uses interior mutability), so no cross-thread aliasing can exist.
#[derive(Debug, Default)]
struct AssembleScratch {
    /// `groups[s]` — positions (indices into the request slice) homed on
    /// shard `s`.  Only the entries named in `touched` are live.
    groups: Vec<Vec<usize>>,
    /// Shards with a non-empty group this call, ascending.
    touched: Vec<usize>,
    /// Per-chunk slot buffer of the sequential path.
    slots: Vec<usize>,
}

/// Build one padded [`ShardBatch`]: slot lookup, last-slot padding,
/// run-coalesced feature gather, neighbor-row concatenation.  A free
/// function over the engine's fields (not a method) so the parallel
/// `assemble` path can call it without capturing `&RoundEngine` — the
/// `RefCell` scratch makes the engine `!Sync`.
fn build_shard_batch(
    binding: &GcnLayerBinding,
    plan: &ShardPlan,
    stores: &[FeatureStore],
    nodes: &[usize],
    s: usize,
    chunk: &[usize],
    slots: &mut Vec<usize>,
) -> Result<ShardBatch> {
    let shard = &plan.shards()[s];
    slots.clear();
    slots.extend(chunk.iter().map(|&i| plan.home(nodes[i]).1));
    let pad = *slots.last().expect("chunks are non-empty");
    slots.resize(binding.batch, pad);
    let x_self = stores[s].gather(slots)?;
    let mut nbr_idx = Vec::with_capacity(binding.batch * binding.sample);
    for &slot in slots.iter() {
        nbr_idx.extend_from_slice(shard.member_nbr_row(slot, binding.sample));
    }
    Ok(ShardBatch {
        shard: s,
        nodes: chunk.iter().map(|&i| nodes[i]).collect(),
        positions: chunk.to_vec(),
        x_self,
        nbr_idx,
    })
}

/// Outputs of one engine execution over a request list.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    /// Per requested node, in request order: the layer output.
    pub outputs: Vec<Vec<f32>>,
    /// Total wall-clock of the PJRT executions that served the request.
    pub wall: Duration,
    /// PJRT batches executed (≥ 1; grows with shard spread).
    pub batches: u64,
}

/// The shared round engine (module docs).
pub struct RoundEngine {
    binding: GcnLayerBinding,
    plan: ShardPlan,
    /// One double-buffered store per shard, `table` rows each.
    stores: Vec<FeatureStore>,
    /// Round-invariant weight tensor, built once.
    w_tensor: Tensor,
    /// Per-shard feature-table tensors, rebuilt only at the `end_round`
    /// barrier (`None` until the first barrier).
    table_tensors: Vec<Option<Tensor>>,
    /// Always-on counters: `engine.table_builds` (tensor-cache misses,
    /// the analogue of `AggregationCore::programs()` — serving batches
    /// must not bump it) and `engine.served_batches`.
    metrics: MetricsRegistry,
    /// Span recorder for the serve / assemble / round-barrier hot path;
    /// disabled by default ([`RoundEngine::enable_tracing`] opts in),
    /// so untraced runs stay bit-identical.
    tracer: Tracer,
    /// Reused `assemble` allocations (see [`AssembleScratch`]).
    scratch: RefCell<AssembleScratch>,
    /// Worker threads `assemble` fans per-shard batch construction over
    /// (1 = sequential, the default; output is identical at any count).
    assembly_threads: usize,
    /// Out-of-core residency tier (DESIGN.md §16).  `None` (default)
    /// keeps the seed behavior: every shard's table tensor cached
    /// unbounded in `table_tensors`.  When enabled, `end_round` encodes
    /// tables into the tier instead and serve-path fetches decode them
    /// through its byte-budgeted LRU.
    resident: Option<ResidentSet>,
}

impl RoundEngine {
    pub fn new(
        binding: GcnLayerBinding,
        plan: ShardPlan,
        weights: Vec<f32>,
    ) -> Result<RoundEngine> {
        if plan.table() != binding.table || plan.sample() != binding.sample {
            return Err(Error::Coordinator(format!(
                "shard plan ({} rows, sample {}) does not match artifact binding \
                 ({} rows, sample {})",
                plan.table(),
                plan.sample(),
                binding.table,
                binding.sample
            )));
        }
        if weights.len() != binding.feature * binding.hidden {
            return Err(Error::Coordinator(format!(
                "weights must be {}x{}",
                binding.feature, binding.hidden
            )));
        }
        let stores = (0..plan.num_shards())
            .map(|_| FeatureStore::new(binding.table, binding.feature))
            .collect();
        let table_tensors = vec![None; plan.num_shards()];
        let w_tensor = Tensor::f32(&[binding.feature, binding.hidden], weights)?;
        Ok(RoundEngine {
            binding,
            plan,
            stores,
            w_tensor,
            table_tensors,
            metrics: MetricsRegistry::new(),
            tracer: Tracer::disabled(),
            scratch: RefCell::new(AssembleScratch::default()),
            assembly_threads: 1,
            resident: None,
        })
    }

    /// Switch table storage to the out-of-core residency tier: from the
    /// next [`RoundEngine::end_round`] barrier on, shard tables are
    /// encoded at `quant` precision and decoded on demand through an
    /// LRU holding at most `budget_bytes` of decoded payload
    /// (DESIGN.md §16).  With [`FeatureQuant::ExactI32`] and integral
    /// features the served tensors are bit-identical to the seed path
    /// (asserted in `rust/tests/residency.rs`); U8/U16 trade precision
    /// for footprint.  The budget must fit at least one decoded shard.
    pub fn enable_residency(&mut self, quant: FeatureQuant, budget_bytes: usize) -> Result<()> {
        let shard_bytes = self.binding.table * self.binding.feature * std::mem::size_of::<f32>();
        if shard_bytes > budget_bytes {
            return Err(Error::Coordinator(format!(
                "residency budget {budget_bytes} B cannot hold one decoded shard \
                 ({shard_bytes} B)"
            )));
        }
        self.resident = Some(ResidentSet::new(
            self.plan.num_shards(),
            self.binding.feature,
            quant,
            budget_bytes,
        )?);
        // Drop the unbounded cache — the tier owns table state now.
        self.table_tensors = vec![None; self.plan.num_shards()];
        Ok(())
    }

    /// The residency tier, when [`RoundEngine::enable_residency`] was
    /// called (its metrics carry the hit/miss/prefetch counters and the
    /// `resident.bytes` / `resident.peak_bytes` gauges).
    pub fn resident(&self) -> Option<&ResidentSet> {
        self.resident.as_ref()
    }

    /// Configure how many worker threads [`RoundEngine::assemble`] fans
    /// per-shard batch construction over (capped by the number of work
    /// items; 1 = sequential).  Assembly output is byte-identical at
    /// every setting — results land slot-indexed, like the sweep
    /// drivers (asserted in tests and in perfbench before timing).
    pub fn set_assembly_threads(&mut self, threads: usize) {
        self.assembly_threads = threads.max(1);
    }

    /// Opt in to span recording on the serve / assemble / round-barrier
    /// path, keeping at most `span_capacity` spans.
    pub fn enable_tracing(&mut self, span_capacity: usize) {
        self.tracer = Tracer::new(span_capacity);
    }

    /// The engine's span recorder (disabled unless
    /// [`RoundEngine::enable_tracing`] was called).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The engine's always-on metrics registry.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn binding(&self) -> &GcnLayerBinding {
        &self.binding
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn num_nodes(&self) -> usize {
        self.plan.num_nodes()
    }

    /// Stage one node's next-round features: its home member slot plus
    /// every halo replica (visible after [`RoundEngine::end_round`]).
    pub fn upload(&mut self, node: usize, features: &[f32]) -> Result<()> {
        if node >= self.plan.num_nodes() {
            return Err(Error::Coordinator(format!("node {node} not in graph")));
        }
        let (s, slot) = self.plan.home(node);
        self.stores[s].write(slot, features)?;
        for &(hs, hslot) in self.plan.halo_sites(node) {
            self.stores[hs].write(hslot, features)?;
        }
        Ok(())
    }

    /// A node's current (front, home-slot) features.
    pub fn read(&self, node: usize) -> Result<&[f32]> {
        if node >= self.plan.num_nodes() {
            return Err(Error::Coordinator(format!("node {node} not in graph")));
        }
        let (s, slot) = self.plan.home(node);
        self.stores[s].read(slot)
    }

    /// Round barrier: every shard's staged uploads become the serving
    /// state and its round-constant table tensor is rebuilt here (once per
    /// shard per round, never per served batch).  Infallible on the seed
    /// path; panics if the residency tier rejects a table (see
    /// [`RoundEngine::try_end_round`] for the fallible form).
    pub fn end_round(&mut self) {
        self.try_end_round().expect("round barrier failed");
    }

    /// [`RoundEngine::end_round`], surfacing residency-tier errors (the
    /// only fallible step: [`FeatureQuant::ExactI32`] rejects
    /// non-integral features).  Without residency this cannot fail.
    pub fn try_end_round(&mut self) -> Result<()> {
        let b = &self.binding;
        let all: Vec<usize> = (0..b.table).collect();
        for (s, store) in self.stores.iter_mut().enumerate() {
            let _barrier = span!(self.tracer, "engine.round_barrier", shard = s).track(s as u64);
            {
                // The double-buffer flip: staged uploads become the
                // serving state.
                let _flip = span!(self.tracer, "store.swap", shard = s).track(s as u64);
                store.swap();
            }
            let x_table = store.gather(&all).expect("table rows are in range");
            match self.resident.as_mut() {
                Some(tier) => {
                    // Residency: encode into the out-of-core tier; the
                    // decoded tensor materializes lazily at fetch time,
                    // under the tier's byte budget.
                    tier.store(s, &x_table)?;
                    self.metrics.inc("engine.shard_encodes", 1);
                }
                None => {
                    self.table_tensors[s] =
                        Some(Tensor::f32(&[b.table, b.feature], x_table).expect("shape is static"));
                    self.metrics.inc("engine.table_builds", 1);
                }
            }
        }
        Ok(())
    }

    /// Load a full feature matrix and run the round barrier — the semi
    /// round's per-call state load.
    pub fn set_features(&mut self, features: &FeatureMatrix) -> Result<()> {
        if features.rows() != self.plan.num_nodes() {
            return Err(Error::Coordinator("feature rows != nodes".into()));
        }
        if features.cols() != self.binding.feature {
            return Err(Error::Coordinator("feature width mismatch".into()));
        }
        for node in 0..features.rows() {
            self.upload(node, features.row(node))?;
        }
        self.end_round();
        Ok(())
    }

    /// Current round number (bumped by every barrier).
    pub fn version(&self) -> u64 {
        self.stores.first().map(FeatureStore::version).unwrap_or(0)
    }

    /// Tensor-cache misses: table tensors built so far.  One increment
    /// per shard per `end_round`; serving any number of batches in
    /// between leaves it untouched (asserted in tests).  Thin read of
    /// the `engine.table_builds` counter in [`Self::metrics`].
    pub fn table_builds(&self) -> u64 {
        self.metrics.counter_value("engine.table_builds")
    }

    /// Thin read of the `engine.served_batches` counter.
    pub fn served_batches(&self) -> u64 {
        self.metrics.counter_value("engine.served_batches")
    }

    /// Thin read of the `engine.shard_encodes` counter — the residency
    /// analogue of [`RoundEngine::table_builds`]: one increment per
    /// shard per barrier, never per served batch.
    pub fn shard_encodes(&self) -> u64 {
        self.metrics.counter_value("engine.shard_encodes")
    }

    /// The cached table tensor of one shard (`None` before the first
    /// round barrier, and always `None` in residency mode — use
    /// [`RoundEngine::fetch_table`] there).
    pub fn table_tensor(&self, shard: usize) -> Option<&Tensor> {
        self.table_tensors.get(shard).and_then(Option::as_ref)
    }

    /// The serve path's table source: a clone of the round-constant
    /// cache on the seed path (a refcount bump), or a fetch through the
    /// residency tier's byte-budgeted LRU when
    /// [`RoundEngine::enable_residency`] is on.  Either way the tensor
    /// reflects the last [`RoundEngine::end_round`] barrier.
    pub fn fetch_table(&self, shard: usize) -> Result<Tensor> {
        match self.resident.as_ref() {
            Some(tier) => tier.fetch(shard),
            None => self
                .table_tensors
                .get(shard)
                .and_then(Option::as_ref)
                .cloned()
                .ok_or_else(|| Error::Coordinator("serve before end_round barrier".into())),
        }
    }

    /// Split a request list into padded per-shard artifact batches:
    /// requests group by home shard (ascending shard id, request order
    /// within a shard), chunk to the static batch size and pad by
    /// repeating the last entry — exactly the seed pipeline, per shard.
    pub fn assemble(&self, nodes: &[usize]) -> Result<Vec<ShardBatch>> {
        self.assemble_with_threads(nodes, self.assembly_threads)
    }

    /// [`RoundEngine::assemble`] with an explicit worker count.  The
    /// grouping pass runs once on the caller (reused scratch); per-shard
    /// batch construction then fans over [`par::par_try_map`] with
    /// slot-indexed results, so the output is byte-identical to the
    /// sequential path at every thread count.
    pub fn assemble_with_threads(
        &self,
        nodes: &[usize],
        threads: usize,
    ) -> Result<Vec<ShardBatch>> {
        let _span = span!(self.tracer, "engine.assemble", nodes = nodes.len());
        let b = &self.binding;
        if nodes.is_empty() {
            return Err(Error::Coordinator("empty batch".into()));
        }
        for &v in nodes {
            if v >= self.plan.num_nodes() {
                return Err(Error::Coordinator(format!("node {v} not in graph")));
            }
        }
        let mut scratch = self.scratch.borrow_mut();
        let AssembleScratch { groups, touched, slots } = &mut *scratch;
        groups.resize_with(self.plan.num_shards(), Vec::new);
        for &s in touched.iter() {
            groups[s].clear();
        }
        touched.clear();
        for (i, &v) in nodes.iter().enumerate() {
            let s = self.plan.home(v).0;
            if groups[s].is_empty() {
                touched.push(s);
            }
            groups[s].push(i);
        }
        // Ascending shard order — the output contract the BTreeMap
        // grouping used to provide.
        touched.sort_unstable();

        if threads <= 1 {
            let mut out = Vec::new();
            for &s in touched.iter() {
                for chunk in groups[s].chunks(b.batch) {
                    out.push(build_shard_batch(
                        b,
                        &self.plan,
                        &self.stores,
                        nodes,
                        s,
                        chunk,
                        slots,
                    )?);
                }
            }
            return Ok(out);
        }
        // One work item per (shard, chunk); the closure captures
        // individual engine fields, never `&self` (the scratch
        // `RefCell` makes the engine `!Sync`).
        let mut items: Vec<(usize, &[usize])> = Vec::new();
        for &s in touched.iter() {
            for chunk in groups[s].chunks(b.batch) {
                items.push((s, chunk));
            }
        }
        let (plan, stores) = (&self.plan, &self.stores);
        par::par_try_map(&items, threads, |&(s, chunk)| {
            let mut slots = Vec::with_capacity(b.batch);
            build_shard_batch(b, plan, stores, nodes, s, chunk, &mut slots)
        })
    }

    /// Execute one request list through the PJRT funnel: assemble,
    /// run every shard batch against its cached round-constant tensors,
    /// and scatter the layer outputs back into request order.
    pub fn serve(&mut self, svc: &InferenceService, nodes: &[usize]) -> Result<EngineOutput> {
        let _span = span!(self.tracer, "engine.serve", nodes = nodes.len());
        let batches = self.assemble(nodes)?;
        let b = &self.binding;
        let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); nodes.len()];
        let mut wall = Duration::ZERO;
        let mut served = 0u64;
        for sb in batches {
            // Round-constant tensors come from the end_round cache (a
            // refcount bump over the shared Arc-backed buffer) or, in
            // residency mode, from the tier's byte-budgeted LRU.
            let table_tensor = self.fetch_table(sb.shard)?;
            let inputs = vec![
                Tensor::f32(&[b.batch, b.feature], sb.x_self)?,
                Tensor::i32(&[b.batch, b.sample], sb.nbr_idx)?,
                table_tensor,
                self.w_tensor.clone(),
            ];
            let t0 = Instant::now();
            let outs = svc.infer(&b.artifact, inputs)?;
            wall += t0.elapsed();
            served += 1;
            let flat = outs
                .first()
                .ok_or_else(|| Error::Coordinator("artifact returned no outputs".into()))?
                .as_f32()?;
            for (k, &pos) in sb.positions.iter().enumerate() {
                outputs[pos] = flat[k * b.hidden..(k + 1) * b.hidden].to_vec();
            }
        }
        self.metrics.inc("engine.served_batches", served);
        Ok(EngineOutput { outputs, wall, batches: served })
    }
}

/// A decentralized deployment resolved from an operating point: the
/// clustering plus the latency provider `run_decentralized_via` consumes
/// (the workers hold no serving state, so there is no engine to build).
#[derive(Debug, Clone)]
pub struct DecentralizedPlan {
    pub clustering: crate::graph::Clustering,
    pub latency: LatencyProvider,
}

/// The three deployment shapes, built from one entry point so every
/// setting's `from_operating_point` funnels through the same path.
pub enum Deployment {
    Centralized(CentralizedLeader),
    Semi(SemiCoordinator),
    Decentralized(DecentralizedPlan),
}

impl Deployment {
    /// Build the deployment a tuned [`OperatingPoint`] describes.
    /// `max_wait` configures the centralized batcher (ignored by the
    /// other settings); the decentralized arm returns the clustering and
    /// a boundary-aware [`LatencyProvider`] for `run_decentralized_via`.
    ///
    /// [`OperatingPoint`]: crate::autotune::OperatingPoint
    pub fn build(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        max_wait: Duration,
        point: &crate::autotune::OperatingPoint,
    ) -> Result<Deployment> {
        use crate::autotune::SettingKind;
        match point.setting {
            SettingKind::Centralized => Ok(Deployment::Centralized(CentralizedLeader::new(
                binding, graph, weights, workload, max_wait,
            )?)),
            SettingKind::Semi => {
                let clustering = point.partitioner.partition(&graph, point.cluster_size)?;
                Ok(Deployment::Semi(
                    SemiCoordinator::new(binding, graph, clustering, weights, workload)?
                        .with_head_capacity(point.head_capacity)?,
                ))
            }
            SettingKind::Decentralized => {
                let clustering = point.partitioner.partition(&graph, point.cluster_size)?;
                let intra_fraction = clustering.intra_edge_fraction(&graph);
                Ok(Deployment::Decentralized(DecentralizedPlan {
                    clustering,
                    latency: LatencyProvider::Clustered { intra_fraction },
                }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::testing::{gcn_layer_binding, Rng};

    fn engine(n: usize) -> RoundEngine {
        let b = gcn_layer_binding();
        let g = generate::regular(n, 6, 3).unwrap();
        let plan = ShardPlan::build(&g, &b.sampler(), b.table).unwrap();
        let w = vec![0.01f32; b.feature * b.hidden];
        RoundEngine::new(b, plan, w).unwrap()
    }

    #[test]
    fn construction_validates_weights_and_plan_agreement() {
        let b = gcn_layer_binding();
        let g = generate::regular(16, 4, 1).unwrap();
        let plan = ShardPlan::build(&g, &b.sampler(), b.table).unwrap();
        assert!(RoundEngine::new(b.clone(), plan.clone(), vec![0.0; 7]).is_err());
        // A plan built for a different table/sample is rejected.
        let other = ShardPlan::build(&g, &NeighborSampler::new(2, 7), b.table).unwrap();
        assert!(RoundEngine::new(b.clone(), other, vec![0.0; b.feature * b.hidden]).is_err());
        assert!(RoundEngine::new(b, plan, vec![0.0; 64 * 32]).is_ok());
    }

    #[test]
    fn double_buffering_survives_the_per_shard_split() {
        // 256 nodes over 64-row tables: multiple shards, several with
        // halo rows.  Staged uploads must stay invisible until the
        // barrier — in the home shard AND in every halo replica.
        let mut e = engine(256);
        assert!(e.plan().num_shards() > 1);
        assert!(e.plan().max_halo() > 0, "a 6-regular 256-node graph must need halos");
        e.upload(3, &vec![1.0; 64]).unwrap();
        assert_eq!(e.read(3).unwrap()[0], 0.0);
        for &(hs, hslot) in e.plan().halo_sites(3) {
            assert_eq!(e.stores[hs].read(hslot).unwrap()[0], 0.0);
        }
        assert_eq!(e.version(), 0);
        e.end_round();
        assert_eq!(e.read(3).unwrap()[0], 1.0);
        let sites: Vec<(usize, usize)> = e.plan().halo_sites(3).to_vec();
        for (hs, hslot) in sites {
            assert_eq!(e.stores[hs].read(hslot).unwrap()[0], 1.0, "halo replica stale");
        }
        // Every shard advanced its round together.
        assert_eq!(e.version(), 1);
        assert!(e.stores.iter().all(|s| s.version() == 1));
    }

    #[test]
    fn table_tensor_cache_misses_only_at_the_barrier() {
        let mut e = engine(256);
        let shards = e.plan().num_shards() as u64;
        assert_eq!(e.table_builds(), 0);
        assert!(e.table_tensor(0).is_none());
        e.end_round();
        assert_eq!(e.table_builds(), shards);
        // Assembling many serving batches is a pure cache hit.
        let nodes: Vec<usize> = (0..256).collect();
        for _ in 0..5 {
            let batches = e.assemble(&nodes).unwrap();
            assert!(!batches.is_empty());
        }
        assert_eq!(e.table_builds(), shards, "serving must not rebuild round tensors");
        e.end_round();
        assert_eq!(e.table_builds(), 2 * shards);
    }

    #[test]
    fn single_shard_assembly_matches_the_seed_pipeline() {
        // On a graph that fits one shard the assembled inputs must be
        // bit-identical to the unsharded seed path: global-id gather +
        // global-id neighbor sampling + last-node padding.
        let b = gcn_layer_binding();
        let g = generate::regular(48, 6, 3).unwrap();
        let plan = ShardPlan::build(&g, &b.sampler(), b.table).unwrap();
        assert!(plan.is_single_shard());
        let mut e = RoundEngine::new(b.clone(), plan, vec![0.01; 64 * 32]).unwrap();
        let mut rng = Rng::new(2);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for node in 0..48 {
            let f: Vec<f32> = (0..64).map(|_| rng.f64_in(0.0, 1.0) as f32).collect();
            e.upload(node, &f).unwrap();
            rows.push(f);
        }
        e.end_round();

        let nodes: Vec<usize> = vec![5, 1, 40, 7, 7];
        let got = e.assemble(&nodes).unwrap();
        assert_eq!(got.len(), 1);
        let sb = &got[0];
        assert_eq!(sb.nodes, nodes);
        assert_eq!(sb.positions, vec![0, 1, 2, 3, 4]);

        // Seed path: pad with the last node, gather rows, sample globally.
        let mut padded = nodes.clone();
        padded.resize(b.batch, *nodes.last().unwrap());
        let want_x: Vec<f32> =
            padded.iter().flat_map(|&v| rows[v].iter().copied()).collect();
        assert_eq!(sb.x_self, want_x);
        assert_eq!(sb.nbr_idx, b.sampler().sample_batch(&g, &padded));

        // And the cached table tensor is the seed's full-table gather.
        let table = e.table_tensor(0).unwrap().as_f32().unwrap().to_vec();
        let mut want_table = vec![0.0f32; b.table * b.feature];
        for (v, r) in rows.iter().enumerate() {
            want_table[v * b.feature..(v + 1) * b.feature].copy_from_slice(r);
        }
        assert_eq!(table, want_table);
    }

    #[test]
    fn assembly_splits_requests_across_shards_and_remembers_positions() {
        let mut e = engine(256);
        e.end_round();
        // Mix nodes from the first and last shard.
        let last = e.plan().num_shards() - 1;
        let a = e.plan().shards()[0].members[0];
        let b_node = e.plan().shards()[last].members[0];
        let c = e.plan().shards()[0].members[1];
        let got = e.assemble(&[a, b_node, c]).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].shard, 0);
        assert_eq!(got[0].nodes, vec![a, c]);
        assert_eq!(got[0].positions, vec![0, 2]);
        assert_eq!(got[1].shard, last);
        assert_eq!(got[1].positions, vec![1]);
        // Out-of-range and empty requests fail loudly.
        assert!(e.assemble(&[]).is_err());
        assert!(e.assemble(&[999]).is_err());
    }

    /// Tentpole invariant: parallel per-shard batch construction is
    /// byte-identical to the sequential path on a multi-shard plan, at
    /// every thread count, through both the explicit and the
    /// engine-configured entry points — and the reused scratch leaks no
    /// state between calls.
    #[test]
    fn parallel_assembly_is_byte_identical_to_sequential() {
        let mut e = engine(256);
        assert!(e.plan().num_shards() > 1);
        let mut rng = Rng::new(9);
        for node in 0..256 {
            let f: Vec<f32> = (0..64).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
            e.upload(node, &f).unwrap();
        }
        e.end_round();
        // Interleaved shards, duplicates, and a shard-crossing tail.
        let mut nodes: Vec<usize> = (0..256).rev().collect();
        nodes.extend([3, 3, 17, 250]);
        let seq = e.assemble_with_threads(&nodes, 1).unwrap();
        assert!(seq.len() > 2);
        for threads in [2, 3, 8, 64] {
            let par = e.assemble_with_threads(&nodes, threads).unwrap();
            assert_eq!(par, seq, "threads={threads}");
        }
        e.set_assembly_threads(4);
        assert_eq!(e.assemble(&nodes).unwrap(), seq);
        // A small follow-up request reuses the scratch cleanly, and the
        // parallel path reports errors like the sequential one.
        let small = e.assemble(&[1, 2]).unwrap();
        assert_eq!(small, e.assemble_with_threads(&[1, 2], 1).unwrap());
        assert!(e.assemble_with_threads(&[999], 4).is_err());
        assert!(e.assemble_with_threads(&[], 4).is_err());
    }

    /// Satellite regression: handing the round-constant caches to a
    /// batch is a refcount bump over the shared buffer, never a table
    /// copy — and reading/cloning the cache is not a rebuild
    /// (`table_builds` stays pinned).
    #[test]
    fn round_constant_tensor_clones_share_their_buffers() {
        let mut e = engine(256);
        e.end_round();
        let builds = e.table_builds();
        let t = e.table_tensor(0).unwrap();
        let c = t.clone();
        assert_eq!(c, *t);
        assert_eq!(
            t.as_f32().unwrap().as_ptr(),
            c.as_f32().unwrap().as_ptr(),
            "table clone must alias the cached buffer"
        );
        let w0 = e.w_tensor.clone();
        assert_eq!(
            w0.as_f32().unwrap().as_ptr(),
            e.w_tensor.as_f32().unwrap().as_ptr(),
            "weight clone must alias the cached buffer"
        );
        assert_eq!(e.table_builds(), builds, "cache reads must not rebuild tensors");
    }

    /// Residency mode must be invisible to the serve inputs: every
    /// shard's fetched table is bit-identical to the seed cache's (the
    /// ExactI32 contract), the unbounded cache stays empty, and the
    /// encode counter replaces `table_builds` one-for-one.
    #[test]
    fn residency_mode_serves_the_same_tables_as_the_seed_cache() {
        use crate::graph::FeatureQuant;
        let mut seed = engine(256);
        let mut res = engine(256);
        let shard_bytes = 64 * 64 * 4; // table rows × feature width × f32
        assert!(res.enable_residency(FeatureQuant::ExactI32, shard_bytes - 1).is_err());
        res.enable_residency(FeatureQuant::ExactI32, 2 * shard_bytes).unwrap();
        let mut rng = Rng::new(4);
        for node in 0..256 {
            let f: Vec<f32> = (0..64).map(|_| rng.index(100) as f32).collect();
            seed.upload(node, &f).unwrap();
            res.upload(node, &f).unwrap();
        }
        seed.end_round();
        res.try_end_round().unwrap();
        assert_eq!(res.table_builds(), 0, "residency must not build unbounded tensors");
        assert_eq!(res.shard_encodes(), res.plan().num_shards() as u64);
        for s in 0..seed.plan().num_shards() {
            assert!(res.table_tensor(s).is_none());
            assert_eq!(
                res.fetch_table(s).unwrap().as_f32().unwrap(),
                seed.fetch_table(s).unwrap().as_f32().unwrap(),
                "shard {s}"
            );
        }
        let tier = res.resident().unwrap();
        assert!(tier.peak_bytes() <= 2 * shard_bytes);
        assert!(tier.peak_bytes() > 0);
        // Assembly is untouched by residency — identical on both engines.
        let nodes: Vec<usize> = (0..256).rev().collect();
        assert_eq!(res.assemble(&nodes).unwrap(), seed.assemble(&nodes).unwrap());
    }

    #[test]
    fn latency_provider_matches_the_closed_forms() {
        let model = NetModel::paper(&GnnWorkload::taxi()).unwrap();
        let topo = Topology { nodes: 10_000, cluster_size: 10 };
        let a = LatencyProvider::Analytic;
        assert_eq!(
            a.centralized(&model, topo),
            model.latency(Setting::Centralized, topo).total()
        );
        assert_eq!(
            a.decentralized(&model, topo),
            model.latency(Setting::Decentralized, topo).total()
        );
        assert_eq!(a.semi(&model, topo, 10.0), model.semi_latency(topo, 10.0).total());
        // Clustered at f = 1 coincides with the closed forms everywhere.
        let c1 = LatencyProvider::Clustered { intra_fraction: 1.0 };
        assert_eq!(c1.centralized(&model, topo), a.centralized(&model, topo));
        assert_eq!(c1.decentralized(&model, topo), a.decentralized(&model, topo));
        assert_eq!(c1.semi(&model, topo, 10.0), a.semi(&model, topo, 10.0));
        // A worse clustering never speeds a round up.
        let c0 = LatencyProvider::Clustered { intra_fraction: 0.25 };
        assert!(c0.decentralized(&model, topo) > c1.decentralized(&model, topo));
        assert!(c0.semi(&model, topo, 10.0) > c1.semi(&model, topo, 10.0));
        // Netsim pins the figure verbatim.
        let pin = LatencyProvider::Netsim(Time::ms(5.0));
        assert_eq!(pin.centralized(&model, topo), Time::ms(5.0));
        assert_eq!(pin.decentralized(&model, topo), Time::ms(5.0));
        assert_eq!(pin.semi(&model, topo, 10.0), Time::ms(5.0));
    }

    #[test]
    fn deployment_build_funnels_every_setting() {
        use crate::autotune::{OperatingPoint, Partitioner};
        let b = gcn_layer_binding();
        let g = generate::regular(48, 6, 3).unwrap();
        let w = vec![0.0f32; 64 * 32];
        let wl = GnnWorkload::gcn("t", 64, 8);
        let cent = Deployment::build(
            b.clone(),
            g.clone(),
            w.clone(),
            &wl,
            Duration::ZERO,
            &OperatingPoint::centralized(),
        )
        .unwrap();
        assert!(matches!(cent, Deployment::Centralized(_)));
        let semi = Deployment::build(
            b.clone(),
            g.clone(),
            w.clone(),
            &wl,
            Duration::ZERO,
            &OperatingPoint::semi(8, 10.0, Partitioner::FixedSize),
        )
        .unwrap();
        match semi {
            Deployment::Semi(s) => assert_eq!(s.head_capacity(), 10.0),
            _ => panic!("semi point must build a semi deployment"),
        }
        let dec = Deployment::build(
            b,
            g.clone(),
            w,
            &wl,
            Duration::ZERO,
            &OperatingPoint::decentralized(8, Partitioner::FixedSize),
        )
        .unwrap();
        match dec {
            Deployment::Decentralized(p) => {
                assert_eq!(p.clustering, crate::graph::fixed_size(48, 8).unwrap());
                let f = p.clustering.intra_edge_fraction(&g);
                assert_eq!(p.latency, LatencyProvider::Clustered { intra_fraction: f });
            }
            _ => panic!("decentralized point must build a worker plan"),
        }
    }
}
