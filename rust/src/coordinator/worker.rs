//! Decentralized workers (paper Fig. 4(b)): one thread per edge device,
//! exchanging feature messages with the adjacent nodes of its cluster over
//! channels, then computing locally on the functional crossbar cores.
//!
//! The threads do *real* message passing (so the dataflow and results are
//! genuine); the edge-network latencies are accounted with the calibrated
//! model (Eq. 4) since wall-clock channel hops are not radio hops.
//!
//! DESIGN.md: §7 (serving coordinator).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use crate::config::presets;
use crate::cores::{AggregationCore, FeatureExtractionCore, FeatureMatrix, Tile};
use crate::error::{Error, Result};
use crate::graph::Clustering;
use crate::netmodel::{NetModel, Topology};
use crate::units::Time;

use super::engine::LatencyProvider;

/// Result of one device's round.
#[derive(Debug, Clone)]
pub struct DeviceResult {
    pub device: usize,
    /// Hidden embedding computed from the cluster's features.
    pub output: Vec<i64>,
    /// Peers whose messages were aggregated (cluster size - 1).
    pub peers: usize,
    /// Modeled edge latency (Eq. 1 decentralized, per device).
    pub modeled: Time,
    /// Wall-clock the device thread actually spent.
    pub wall: Duration,
}

/// Quantize float features to unsigned 8-bit DAC codes with a shared scale.
fn quantize_codes(features: &[f32], scale: f32) -> Vec<u32> {
    features.iter().map(|&f| ((f / scale).clamp(0.0, 255.0)) as u32).collect()
}

/// One edge device's crossbar cores (paper Fig. 2(a)).  Constructed once
/// per device — or reused across sequential devices by the oracle — so
/// the ~1 MiB array allocations are not paid per aggregate call.
struct DeviceCores {
    agg: AggregationCore,
    fe: FeatureExtractionCore,
}

impl DeviceCores {
    fn new() -> Result<DeviceCores> {
        let cfg = presets::decentralized();
        Ok(DeviceCores {
            agg: AggregationCore::new(cfg.aggregation, cfg.device.clone())?,
            fe: FeatureExtractionCore::new(cfg.feature, cfg.device)?,
        })
    }
}

/// Per-device compute: mean-aggregate own + peer features on the
/// aggregation crossbar, transform through the feature-extraction
/// crossbar.  Returns the quantized embedding.
fn device_compute(
    cores: &mut DeviceCores,
    own: &[f32],
    peers: &[Vec<f32>],
    weights: &[i32],
    fe_out: usize,
    scale: f32,
) -> Result<Vec<i64>> {
    let DeviceCores { agg, fe } = cores;

    let feature_len = own.len();
    // Quantize each contributor to 4-bit signed levels for the crossbar
    // rows (the node-stationary feature window) — one flat tile, no
    // per-row allocations.
    let level = |f: f32| ((f / scale * 7.0).clamp(-8.0, 7.0)) as i32;
    let mut window = Tile::zeros(peers.len() + 1, feature_len);
    for (dst, &f) in window.row_mut(0).iter_mut().zip(own.iter()) {
        *dst = level(f);
    }
    for (r, p) in peers.iter().enumerate() {
        if p.len() != feature_len {
            return Err(Error::Coordinator("peer feature length mismatch".into()));
        }
        for (dst, &f) in window.row_mut(r + 1).iter_mut().zip(p.iter()) {
            *dst = level(f);
        }
    }
    let active = vec![true; window.rows()];
    let sums = agg.aggregate(&window, &active)?;

    // Mean → 8-bit DAC codes for the transform.
    let n = window.rows() as f32;
    let mean: Vec<f32> = sums.iter().map(|&s| s as f32 / n).collect();
    let codes = quantize_codes(&mean, 7.0 / 255.0 * 8.0);

    // The transform consumes at most one row window of the
    // feature-extraction crossbar — the bound is the programmed geometry
    // (`presets::decentralized().feature.geometry.rows`), not a magic
    // constant.
    let fe_in = codes.len().min(fe.config().geometry.rows);
    fe.program_weights(weights, fe_in, fe_out)?;
    fe.transform(&codes[..fe_in], fe_out)
}

/// Run one decentralized round: every device broadcasts its features to
/// its cluster peers, aggregates what it receives, and computes locally.
///
/// `features.row(d)` are device d's local features (shape validated by
/// the flat [`FeatureMatrix`] — no ragged rows by construction); clusters
/// come from `clustering`; `weights` is the shared `fe_in × fe_out`
/// quantized layer.
pub fn run_decentralized(
    features: &FeatureMatrix,
    clustering: &Clustering,
    weights: Vec<i32>,
    fe_out: usize,
    model: &NetModel,
) -> Result<Vec<DeviceResult>> {
    run_decentralized_via(features, clustering, weights, fe_out, model, LatencyProvider::Analytic)
}

/// [`run_decentralized`] with an explicit [`LatencyProvider`] — the same
/// enum the leader and the semi coordinator attach modeled latencies
/// with, so a tuned decentralized deployment (boundary-aware clustered
/// Eq. 4) or a packet-level `netsim` figure prices every device's round
/// identically across the three settings.
pub fn run_decentralized_via(
    features: &FeatureMatrix,
    clustering: &Clustering,
    weights: Vec<i32>,
    fe_out: usize,
    model: &NetModel,
    latency: LatencyProvider,
) -> Result<Vec<DeviceResult>> {
    let n = features.rows();
    if clustering.assignment.len() != n {
        return Err(Error::Coordinator("clustering does not cover all devices".into()));
    }
    let scale = features
        .as_slice()
        .iter()
        .fold(1e-6f32, |m, &v| m.max(v.abs()));

    // Channel fabric: one receiver per device, senders cloned to peers.
    let mut senders: Vec<Sender<(usize, Vec<f32>)>> = Vec::with_capacity(n);
    let mut receivers: Vec<Option<Receiver<(usize, Vec<f32>)>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    let mut handles = Vec::with_capacity(n);
    for device in 0..n {
        let cluster_id = clustering.assignment[device];
        let peers: Vec<usize> = clustering.clusters[cluster_id]
            .iter()
            .copied()
            .filter(|&p| p != device)
            .collect();
        let peer_txs: HashMap<usize, Sender<(usize, Vec<f32>)>> =
            peers.iter().map(|&p| (p, senders[p].clone())).collect();
        let rx = receivers[device].take().expect("receiver taken once");
        let own = features.row(device).to_vec();
        let weights = weights.clone();
        let cs = peers.len();
        let modeled =
            latency.decentralized(model, Topology { nodes: n, cluster_size: cs.max(1) });

        handles.push(std::thread::spawn(move || -> Result<DeviceResult> {
            let t0 = Instant::now();
            // Phase 1: broadcast to cluster peers.
            for (&p, tx) in &peer_txs {
                tx.send((device, own.clone()))
                    .map_err(|_| Error::Coordinator(format!("peer {p} hung up")))?;
            }
            drop(peer_txs);
            // Phase 2: collect exactly one message from every peer.
            let mut inbox: Vec<(usize, Vec<f32>)> = Vec::with_capacity(cs);
            for _ in 0..cs {
                let msg = rx
                    .recv_timeout(Duration::from_secs(30))
                    .map_err(|e| Error::Coordinator(format!("device {device} recv: {e}")))?;
                inbox.push(msg);
            }
            // Deterministic aggregation order regardless of arrival.
            inbox.sort_by_key(|(from, _)| *from);
            let peer_feats: Vec<Vec<f32>> = inbox.into_iter().map(|(_, f)| f).collect();
            // Phase 3: local crossbar compute (this device's own cores).
            let mut cores = DeviceCores::new()?;
            let output = device_compute(&mut cores, &own, &peer_feats, &weights, fe_out, scale)?;
            Ok(DeviceResult { device, output, peers: cs, modeled, wall: t0.elapsed() })
        }));
    }
    drop(senders);

    let mut results = Vec::with_capacity(n);
    for h in handles {
        results.push(h.join().map_err(|_| Error::Coordinator("worker panicked".into()))??);
    }
    results.sort_by_key(|r| r.device);
    Ok(results)
}

/// Single-threaded oracle of `run_decentralized` (same math, no threads) —
/// used by tests to pin the concurrent implementation.
pub fn run_decentralized_oracle(
    features: &FeatureMatrix,
    clustering: &Clustering,
    weights: &[i32],
    fe_out: usize,
) -> Result<Vec<Vec<i64>>> {
    let scale = features
        .as_slice()
        .iter()
        .fold(1e-6f32, |m, &v| m.max(v.abs()));
    // Sequential oracle: one pair of cores reused across every device —
    // the array allocations are paid once, not per device.
    let mut cores = DeviceCores::new()?;
    let mut out = Vec::with_capacity(features.rows());
    for device in 0..features.rows() {
        let cid = clustering.assignment[device];
        let peer_feats: Vec<Vec<f32>> = clustering.clusters[cid]
            .iter()
            .copied()
            .filter(|&p| p != device)
            .map(|p| features.row(p).to_vec())
            .collect();
        out.push(device_compute(&mut cores, features.row(device), &peer_feats, weights, fe_out, scale)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::GnnWorkload;
    use crate::graph::fixed_size;
    use crate::testing::Rng;

    fn setup(
        n: usize,
        cs: usize,
        feat: usize,
        fe_out: usize,
    ) -> (FeatureMatrix, Clustering, Vec<i32>, NetModel) {
        let mut rng = Rng::new(11);
        let features =
            FeatureMatrix::from_fn(n, feat, |_, _| rng.f64_in(0.0, 1.0) as f32);
        let clustering = fixed_size(n, cs).unwrap();
        let weights: Vec<i32> = (0..feat * fe_out).map(|_| rng.i64_in(-8, 7) as i32).collect();
        let model = NetModel::paper(&GnnWorkload::gcn("t", feat, cs)).unwrap();
        (features, clustering, weights, model)
    }

    #[test]
    fn workers_match_single_threaded_oracle() {
        let (features, clustering, weights, model) = setup(12, 4, 16, 8);
        let got = run_decentralized(&features, &clustering, weights.clone(), 8, &model).unwrap();
        let want = run_decentralized_oracle(&features, &clustering, &weights, 8).unwrap();
        assert_eq!(got.len(), 12);
        for r in &got {
            assert_eq!(r.output, want[r.device], "device {}", r.device);
            assert_eq!(r.peers, 3);
            assert!(r.modeled > crate::units::Time::ZERO);
        }
    }

    #[test]
    fn results_are_deterministic_across_runs() {
        let (features, clustering, weights, model) = setup(9, 3, 8, 4);
        let a = run_decentralized(&features, &clustering, weights.clone(), 4, &model).unwrap();
        let b = run_decentralized(&features, &clustering, weights, 4, &model).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.output, y.output);
        }
    }

    #[test]
    fn isolated_devices_compute_from_self_only() {
        let (features, clustering, weights, model) = setup(3, 1, 8, 4);
        let got = run_decentralized(&features, &clustering, weights, 4, &model).unwrap();
        for r in &got {
            assert_eq!(r.peers, 0);
        }
    }

    #[test]
    fn ragged_features_cannot_even_be_constructed() {
        // The flat FeatureMatrix rejects ragged inputs once, at the API
        // boundary, instead of every consumer re-checking.
        let rows = vec![vec![0.0f32; 8], vec![0.0f32; 5]];
        assert!(FeatureMatrix::from_rows(&rows).is_err());
    }

    #[test]
    fn rejects_mismatched_clustering() {
        let (features, _, weights, model) = setup(6, 2, 8, 4);
        let wrong = fixed_size(5, 2).unwrap();
        assert!(run_decentralized(&features, &wrong, weights, 4, &model).is_err());
    }

    /// The feature-extraction input bound is derived from the programmed
    /// crossbar geometry, not a magic constant: one row window of the
    /// decentralized preset's 128×128 feature crossbar.
    #[test]
    fn fe_input_bound_derives_from_the_crossbar_geometry() {
        let preset = presets::decentralized();
        let cores = DeviceCores::new().unwrap();
        assert_eq!(cores.fe.config().geometry.rows, preset.feature.geometry.rows);
        assert_eq!(preset.feature.geometry.rows, 128, "paper §4.1 feature core sizing");
        // Features wider than one row window truncate at the geometry
        // bound instead of overflowing the crossbar.
        let wide = preset.feature.geometry.rows + 22;
        let (features, clustering, _, model) = setup(4, 2, wide, 4);
        let weights: Vec<i32> =
            (0..preset.feature.geometry.rows * 4).map(|i| (i % 15) as i32 - 8).collect();
        let got = run_decentralized(&features, &clustering, weights.clone(), 4, &model).unwrap();
        let want = run_decentralized_oracle(&features, &clustering, &weights, 4).unwrap();
        for r in &got {
            assert_eq!(r.output, want[r.device]);
            assert_eq!(r.output.len(), 4);
        }
    }

    /// The worker pool consumes the same [`LatencyProvider`] as the other
    /// deployments: Analytic equals the Eq. 1 default, Clustered prices
    /// the boundary relay, Netsim pins the simulated figure — with the
    /// computed embeddings untouched in every mode.
    #[test]
    fn latency_provider_drives_the_modeled_figure_only() {
        let (features, clustering, weights, model) = setup(12, 4, 16, 8);
        let topo = Topology { nodes: 12, cluster_size: 3 };
        let base = run_decentralized(&features, &clustering, weights.clone(), 8, &model).unwrap();
        let analytic = run_decentralized_via(
            &features,
            &clustering,
            weights.clone(),
            8,
            &model,
            LatencyProvider::Analytic,
        )
        .unwrap();
        let clustered = run_decentralized_via(
            &features,
            &clustering,
            weights.clone(),
            8,
            &model,
            LatencyProvider::Clustered { intra_fraction: 0.5 },
        )
        .unwrap();
        let pinned = run_decentralized_via(
            &features,
            &clustering,
            weights,
            8,
            &model,
            LatencyProvider::Netsim(crate::units::Time::ms(3.0)),
        )
        .unwrap();
        for (((b, a), c), p) in base.iter().zip(&analytic).zip(&clustered).zip(&pinned) {
            assert_eq!(b.output, a.output);
            assert_eq!(b.output, c.output);
            assert_eq!(b.output, p.output);
            assert_eq!(b.modeled, a.modeled, "Analytic is the default");
            assert_eq!(
                c.modeled,
                LatencyProvider::Clustered { intra_fraction: 0.5 }
                    .decentralized(&model, topo),
                "clustered boundary pricing"
            );
            assert!(c.modeled > a.modeled, "a cut clustering never serves faster");
            assert_eq!(p.modeled, crate::units::Time::ms(3.0));
        }
    }
}
