//! Decentralized workers (paper Fig. 4(b)): one thread per edge device,
//! exchanging feature messages with the adjacent nodes of its cluster over
//! channels, then computing locally on the functional crossbar cores.
//!
//! The threads do *real* message passing (so the dataflow and results are
//! genuine); the edge-network latencies are accounted with the calibrated
//! model (Eq. 4) since wall-clock channel hops are not radio hops.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use crate::config::presets;
use crate::cores::{AggregationCore, FeatureExtractionCore};
use crate::error::{Error, Result};
use crate::graph::Clustering;
use crate::netmodel::{NetModel, Setting, Topology};
use crate::units::Time;

/// Result of one device's round.
#[derive(Debug, Clone)]
pub struct DeviceResult {
    pub device: usize,
    /// Hidden embedding computed from the cluster's features.
    pub output: Vec<i64>,
    /// Peers whose messages were aggregated (cluster size - 1).
    pub peers: usize,
    /// Modeled edge latency (Eq. 1 decentralized, per device).
    pub modeled: Time,
    /// Wall-clock the device thread actually spent.
    pub wall: Duration,
}

/// Quantize float features to unsigned 8-bit DAC codes with a shared scale.
fn quantize_codes(features: &[f32], scale: f32) -> Vec<u32> {
    features.iter().map(|&f| ((f / scale).clamp(0.0, 255.0)) as u32).collect()
}

/// Per-device compute: mean-aggregate own + peer features on the
/// aggregation crossbar, transform through the feature-extraction
/// crossbar.  Returns the quantized embedding.
fn device_compute(
    own: &[f32],
    peers: &[Vec<f32>],
    weights: &[i32],
    fe_out: usize,
    scale: f32,
) -> Result<Vec<i64>> {
    let cfg = presets::decentralized();
    let mut agg = AggregationCore::new(cfg.aggregation, cfg.device.clone())?;
    let mut fe = FeatureExtractionCore::new(cfg.feature, cfg.device)?;

    let feature_len = own.len();
    // Quantize each contributor to 4-bit signed levels for the crossbar
    // rows (the node-stationary feature window).
    let level = |f: f32| ((f / scale * 7.0).clamp(-8.0, 7.0)) as i32;
    let mut rows: Vec<Vec<i32>> = Vec::with_capacity(peers.len() + 1);
    rows.push(own.iter().map(|&f| level(f)).collect());
    for p in peers {
        if p.len() != feature_len {
            return Err(Error::Coordinator("peer feature length mismatch".into()));
        }
        rows.push(p.iter().map(|&f| level(f)).collect());
    }
    let active = vec![true; rows.len()];
    let sums = agg.aggregate(&rows, &active)?;

    // Mean → 8-bit DAC codes for the transform.
    let n = rows.len() as f32;
    let mean: Vec<f32> = sums.iter().map(|&s| s as f32 / n).collect();
    let codes = quantize_codes(&mean, 7.0 / 255.0 * 8.0);

    let fe_in = codes.len().min(128);
    fe.program_weights(weights, fe_in, fe_out)?;
    fe.transform(&codes[..fe_in], fe_out)
}

/// Run one decentralized round: every device broadcasts its features to
/// its cluster peers, aggregates what it receives, and computes locally.
///
/// `features[d]` are device d's local features; clusters come from
/// `clustering`; `weights` is the shared `fe_in × fe_out` quantized layer.
pub fn run_decentralized(
    features: &[Vec<f32>],
    clustering: &Clustering,
    weights: Vec<i32>,
    fe_out: usize,
    model: &NetModel,
) -> Result<Vec<DeviceResult>> {
    let n = features.len();
    if clustering.assignment.len() != n {
        return Err(Error::Coordinator("clustering does not cover all devices".into()));
    }
    let feature_len = features.first().map(Vec::len).unwrap_or(0);
    if features.iter().any(|f| f.len() != feature_len) {
        return Err(Error::Coordinator("ragged device features".into()));
    }
    let scale = features
        .iter()
        .flat_map(|f| f.iter())
        .fold(1e-6f32, |m, &v| m.max(v.abs()));

    // Channel fabric: one receiver per device, senders cloned to peers.
    let mut senders: Vec<Sender<(usize, Vec<f32>)>> = Vec::with_capacity(n);
    let mut receivers: Vec<Option<Receiver<(usize, Vec<f32>)>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(Some(rx));
    }

    let mut handles = Vec::with_capacity(n);
    for device in 0..n {
        let cluster_id = clustering.assignment[device];
        let peers: Vec<usize> = clustering.clusters[cluster_id]
            .iter()
            .copied()
            .filter(|&p| p != device)
            .collect();
        let peer_txs: HashMap<usize, Sender<(usize, Vec<f32>)>> =
            peers.iter().map(|&p| (p, senders[p].clone())).collect();
        let rx = receivers[device].take().expect("receiver taken once");
        let own = features[device].clone();
        let weights = weights.clone();
        let cs = peers.len();
        let modeled = model
            .latency(Setting::Decentralized, Topology { nodes: n, cluster_size: cs.max(1) })
            .total();

        handles.push(std::thread::spawn(move || -> Result<DeviceResult> {
            let t0 = Instant::now();
            // Phase 1: broadcast to cluster peers.
            for (&p, tx) in &peer_txs {
                tx.send((device, own.clone()))
                    .map_err(|_| Error::Coordinator(format!("peer {p} hung up")))?;
            }
            drop(peer_txs);
            // Phase 2: collect exactly one message from every peer.
            let mut inbox: Vec<(usize, Vec<f32>)> = Vec::with_capacity(cs);
            for _ in 0..cs {
                let msg = rx
                    .recv_timeout(Duration::from_secs(30))
                    .map_err(|e| Error::Coordinator(format!("device {device} recv: {e}")))?;
                inbox.push(msg);
            }
            // Deterministic aggregation order regardless of arrival.
            inbox.sort_by_key(|(from, _)| *from);
            let peer_feats: Vec<Vec<f32>> = inbox.into_iter().map(|(_, f)| f).collect();
            // Phase 3: local crossbar compute.
            let output = device_compute(&own, &peer_feats, &weights, fe_out, scale)?;
            Ok(DeviceResult { device, output, peers: cs, modeled, wall: t0.elapsed() })
        }));
    }
    drop(senders);

    let mut results = Vec::with_capacity(n);
    for h in handles {
        results.push(h.join().map_err(|_| Error::Coordinator("worker panicked".into()))??);
    }
    results.sort_by_key(|r| r.device);
    Ok(results)
}

/// Single-threaded oracle of `run_decentralized` (same math, no threads) —
/// used by tests to pin the concurrent implementation.
pub fn run_decentralized_oracle(
    features: &[Vec<f32>],
    clustering: &Clustering,
    weights: &[i32],
    fe_out: usize,
) -> Result<Vec<Vec<i64>>> {
    let scale = features
        .iter()
        .flat_map(|f| f.iter())
        .fold(1e-6f32, |m, &v| m.max(v.abs()));
    let mut out = Vec::with_capacity(features.len());
    for device in 0..features.len() {
        let cid = clustering.assignment[device];
        let peer_feats: Vec<Vec<f32>> = clustering.clusters[cid]
            .iter()
            .copied()
            .filter(|&p| p != device)
            .map(|p| features[p].clone())
            .collect();
        out.push(device_compute(&features[device], &peer_feats, weights, fe_out, scale)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::GnnWorkload;
    use crate::graph::fixed_size;
    use crate::testing::Rng;

    fn setup(
        n: usize,
        cs: usize,
        feat: usize,
        fe_out: usize,
    ) -> (Vec<Vec<f32>>, Clustering, Vec<i32>, NetModel) {
        let mut rng = Rng::new(11);
        let features: Vec<Vec<f32>> =
            (0..n).map(|_| (0..feat).map(|_| rng.f64_in(0.0, 1.0) as f32).collect()).collect();
        let clustering = fixed_size(n, cs).unwrap();
        let weights: Vec<i32> = (0..feat * fe_out).map(|_| rng.i64_in(-8, 7) as i32).collect();
        let model = NetModel::paper(&GnnWorkload::gcn("t", feat, cs)).unwrap();
        (features, clustering, weights, model)
    }

    #[test]
    fn workers_match_single_threaded_oracle() {
        let (features, clustering, weights, model) = setup(12, 4, 16, 8);
        let got = run_decentralized(&features, &clustering, weights.clone(), 8, &model).unwrap();
        let want = run_decentralized_oracle(&features, &clustering, &weights, 8).unwrap();
        assert_eq!(got.len(), 12);
        for r in &got {
            assert_eq!(r.output, want[r.device], "device {}", r.device);
            assert_eq!(r.peers, 3);
            assert!(r.modeled > crate::units::Time::ZERO);
        }
    }

    #[test]
    fn results_are_deterministic_across_runs() {
        let (features, clustering, weights, model) = setup(9, 3, 8, 4);
        let a = run_decentralized(&features, &clustering, weights.clone(), 4, &model).unwrap();
        let b = run_decentralized(&features, &clustering, weights, 4, &model).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.output, y.output);
        }
    }

    #[test]
    fn isolated_devices_compute_from_self_only() {
        let (features, clustering, weights, model) = setup(3, 1, 8, 4);
        let got = run_decentralized(&features, &clustering, weights, 4, &model).unwrap();
        for r in &got {
            assert_eq!(r.peers, 0);
        }
    }

    #[test]
    fn rejects_ragged_inputs() {
        let (mut features, clustering, weights, model) = setup(6, 2, 8, 4);
        features[3] = vec![0.0; 5];
        assert!(run_decentralized(&features, &clustering, weights, 4, &model).is_err());
    }

    #[test]
    fn rejects_mismatched_clustering() {
        let (features, _, weights, model) = setup(6, 2, 8, 4);
        let wrong = fixed_size(5, 2).unwrap();
        assert!(run_decentralized(&features, &wrong, weights, 4, &model).is_err());
    }
}
