//! Centralized leader (paper Fig. 4(a)): one powerful edge device gathers
//! every node's features over the inter-network link, runs the GNN on its
//! banked accelerator and serves inference requests.
//!
//! The request path is: router → dynamic batcher → [`RoundEngine`], with
//! the modeled edge latencies (Eqs. 3/5) accounted per response next to
//! the measured wall-clock of the actual execution.  Graphs larger than
//! the artifact's `table` dimension shard transparently through the
//! engine's [`ShardPlan`] (id-order shards, halo-replicated boundaries) —
//! the seed's "shard the graph" rejection is gone.
//!
//! DESIGN.md: §7 (serving coordinator).

use std::time::Duration;

use crate::cores::GnnWorkload;
use crate::error::{Error, Result};
use crate::graph::{Csr, ShardPlan};
use crate::netmodel::{NetModel, Setting, Topology};
use crate::units::Time;

use super::batcher::{Batch, Batcher, Request};
use super::engine::{Deployment, GcnLayerBinding, LatencyProvider, RoundEngine};
use super::service::InferenceService;

/// One served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub node: usize,
    /// The node's layer output (hidden embedding).
    pub output: Vec<f32>,
    /// Modeled edge latency for this round (Eq. 1, centralized).
    pub modeled: Time,
    /// Measured wall-clock of the PJRT execution(s) serving this batch.
    pub wall: Duration,
}

/// The centralized serving coordinator: a dynamic batcher over the shared
/// round engine.
pub struct CentralizedLeader {
    batcher: Batcher,
    engine: RoundEngine,
    model: NetModel,
    topo: Topology,
    latency: LatencyProvider,
}

impl CentralizedLeader {
    pub fn new(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        max_wait: Duration,
    ) -> Result<CentralizedLeader> {
        let topo = Topology { nodes: graph.num_nodes(), cluster_size: workload.neighbors.max(1) };
        let model = NetModel::paper(workload)?;
        let plan = ShardPlan::build(&graph, &binding.sampler(), binding.table)?;
        let batcher = Batcher::new(binding.batch, max_wait)?;
        let engine = RoundEngine::new(binding, plan, weights)?;
        Ok(CentralizedLeader { batcher, engine, model, topo, latency: LatencyProvider::Analytic })
    }

    /// Build the leader a tuned [`OperatingPoint`] describes, through the
    /// same [`Deployment::build`] funnel every setting configures with —
    /// so the serving path is driven by the same E11 artifact everywhere.
    /// Rejects non-centralized points.
    ///
    /// [`OperatingPoint`]: crate::autotune::OperatingPoint
    pub fn from_operating_point(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        max_wait: Duration,
        point: &crate::autotune::OperatingPoint,
    ) -> Result<CentralizedLeader> {
        if point.setting != crate::autotune::SettingKind::Centralized {
            return Err(Error::Coordinator(format!(
                "operating point `{}` is not centralized",
                point.label()
            )));
        }
        match Deployment::build(binding, graph, weights, workload, max_wait, point)? {
            Deployment::Centralized(leader) => Ok(leader),
            _ => unreachable!("a centralized point builds a centralized deployment"),
        }
    }

    /// The engine this leader serves through (shard plan, tensor-cache
    /// counters, per-shard state).
    pub fn engine(&self) -> &RoundEngine {
        &self.engine
    }

    /// Ingest one node's uploaded features (staged; visible after
    /// `end_round`, the double-buffer barrier — home slot and every halo
    /// replica together).
    pub fn upload(&mut self, node: usize, features: &[f32]) -> Result<()> {
        self.engine.upload(node, features)
    }

    /// Round barrier: staged uploads become the serving state; every
    /// shard's round-constant feature-table tensor is rebuilt here (once)
    /// rather than per batch (§Perf).
    pub fn end_round(&mut self) {
        self.engine.end_round();
    }

    /// Enqueue a request; serve a batch if one closes.
    pub fn submit(&mut self, svc: &InferenceService, req: Request) -> Result<Vec<Response>> {
        if req.node >= self.engine.num_nodes() {
            return Err(Error::Coordinator(format!("node {} not in graph", req.node)));
        }
        match self.batcher.push(req) {
            Some(batch) => self.serve(svc, batch),
            None => Ok(Vec::new()),
        }
    }

    /// Deadline poll: serve a partial batch whose oldest member expired.
    pub fn poll(&mut self, svc: &InferenceService) -> Result<Vec<Response>> {
        match self.batcher.poll() {
            Some(batch) => self.serve(svc, batch),
            None => Ok(Vec::new()),
        }
    }

    /// Drain all pending requests (shutdown path).
    pub fn drain(&mut self, svc: &InferenceService) -> Result<Vec<Response>> {
        match self.batcher.flush() {
            Some(batch) => self.serve(svc, batch),
            None => Ok(Vec::new()),
        }
    }

    /// PJRT batches executed so far (a request batch spanning several
    /// shards costs one execution per shard touched).
    pub fn served_batches(&self) -> u64 {
        self.engine.served_batches()
    }

    /// Switch the per-response `modeled` latency from the closed-form
    /// Eq. (1) to a packet-level `netsim` round over this leader's
    /// topology — uplink contention included, composed through the
    /// `CommFabric` entry point (`NetModel::latency_via`).  `None`
    /// returns to the analytic model.
    pub fn use_simulated_latency(
        &mut self,
        cfg: Option<&crate::netsim::NetSimConfig>,
    ) -> Result<()> {
        self.latency = match cfg {
            None => LatencyProvider::Analytic,
            Some(c) => {
                let fabric = crate::netsim::NetSim::new(c.clone());
                LatencyProvider::Netsim(
                    self.model
                        .latency_via(&fabric, Setting::Centralized, self.topo)?
                        .total(),
                )
            }
        };
        Ok(())
    }

    /// The round latency currently attached to responses: the simulated
    /// figure when [`CentralizedLeader::use_simulated_latency`] is active,
    /// the Eq. (1) closed form otherwise.
    pub fn modeled_round_latency(&self) -> Time {
        self.latency.centralized(&self.model, self.topo)
    }

    fn serve(&mut self, svc: &InferenceService, batch: Batch) -> Result<Vec<Response>> {
        let nodes = batch.nodes();
        let out = self.engine.serve(svc, &nodes)?;
        let modeled = self.modeled_round_latency();
        Ok(batch
            .requests
            .iter()
            .zip(out.outputs)
            .map(|(r, output)| Response { id: r.id, node: r.node, output, modeled, wall: out.wall })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gcn_layer_binding;

    fn binding() -> GcnLayerBinding {
        gcn_layer_binding()
    }

    fn leader() -> CentralizedLeader {
        let g = crate::graph::generate::regular(48, 6, 3).unwrap();
        let w = vec![0.01f32; 64 * 32];
        CentralizedLeader::new(
            binding(),
            g,
            w,
            &GnnWorkload::gcn("test", 64, 6),
            Duration::from_millis(10),
        )
        .unwrap()
    }

    #[test]
    fn binding_reads_manifest_config() {
        let b = binding();
        assert_eq!((b.batch, b.sample, b.feature, b.hidden, b.table), (16, 4, 64, 32, 64));
    }

    #[test]
    fn binding_requires_all_keys() {
        use crate::runtime::Manifest;
        use std::path::Path;
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "m", "file": "f", "inputs": [], "outputs": [],
             "config": {"batch": 16}}]}"#;
        let m = Manifest::parse(Path::new("/x"), doc).unwrap();
        assert!(GcnLayerBinding::from_spec(m.get("m").unwrap()).is_err());
    }

    #[test]
    fn oversized_graphs_shard_instead_of_erroring() {
        // The seed rejected any graph wider than the table ("shard the
        // graph"); the engine now does the sharding.
        let g = crate::graph::generate::regular(100, 4, 1).unwrap(); // > table 64
        let l = CentralizedLeader::new(
            binding(),
            g,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 4),
            Duration::ZERO,
        )
        .unwrap();
        assert!(l.engine().plan().num_shards() > 1);
        assert!(l.engine().plan().max_slots() <= 64);

        // Bad weight arity still fails loudly.
        let g = crate::graph::generate::regular(10, 2, 1).unwrap();
        let r = CentralizedLeader::new(
            binding(),
            g,
            vec![0.0; 7], // wrong arity
            &GnnWorkload::gcn("t", 64, 2),
            Duration::ZERO,
        );
        assert!(r.is_err());
    }

    #[test]
    fn from_operating_point_validates_the_setting() {
        use crate::autotune::{OperatingPoint, Partitioner};
        let g = crate::graph::generate::regular(48, 6, 3).unwrap();
        let ok = CentralizedLeader::from_operating_point(
            binding(),
            g.clone(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 6),
            Duration::ZERO,
            &OperatingPoint::centralized(),
        );
        assert!(ok.is_ok());
        let bad = CentralizedLeader::from_operating_point(
            binding(),
            g,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 6),
            Duration::ZERO,
            &OperatingPoint::semi(8, 10.0, Partitioner::FixedSize),
        );
        assert!(bad.is_err());
    }

    #[test]
    fn simulated_latency_mode_tracks_the_fabric() {
        use crate::netsim::NetSimConfig;
        let mut l = leader();
        let analytic = l.modeled_round_latency();
        // Uncongested fabric coincides with Eq. (1).
        l.use_simulated_latency(Some(&NetSimConfig::default())).unwrap();
        let sim = l.modeled_round_latency();
        assert!(
            (sim.as_s() - analytic.as_s()).abs() / analytic.as_s() < 1e-6,
            "uncongested sim {sim} vs analytic {analytic}"
        );
        // A single receive port serializes the gather — rounds get slower.
        l.use_simulated_latency(Some(&NetSimConfig {
            rx_ports: Some(1),
            ..Default::default()
        }))
        .unwrap();
        assert!(l.modeled_round_latency() > sim);
        // And None returns to the closed form.
        l.use_simulated_latency(None).unwrap();
        assert_eq!(l.modeled_round_latency(), analytic);
    }

    #[test]
    fn upload_respects_double_buffering() {
        let mut l = leader();
        l.upload(3, &vec![1.0; 64]).unwrap();
        assert_eq!(l.engine.read(3).unwrap()[0], 0.0);
        l.end_round();
        assert_eq!(l.engine.read(3).unwrap()[0], 1.0);
    }

    // The submit/poll/drain request paths require a live PJRT service and
    // built artifacts; they are covered by the integration tests in
    // `rust/tests/serving.rs` / `rust/tests/sharded_serving.rs` and the
    // `e2e_inference` example.
}
