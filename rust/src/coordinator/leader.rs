//! Centralized leader (paper Fig. 4(a)): one powerful edge device gathers
//! every node's features over the inter-network link, runs the GNN on its
//! banked accelerator and serves inference requests.
//!
//! The request path is: router → dynamic batcher → PJRT artifact, with the
//! modeled edge latencies (Eqs. 3/5) accounted per response next to the
//! measured wall-clock of the actual execution.

use std::time::{Duration, Instant};

use crate::cores::GnnWorkload;
use crate::error::{Error, Result};
use crate::graph::{Csr, NeighborSampler};
use crate::netmodel::{NetModel, Setting, Topology};
use crate::runtime::{ArtifactSpec, Tensor};
use crate::units::Time;

use super::batcher::{Batch, Batcher, Request};
use super::service::InferenceService;
use super::state::FeatureStore;

/// Shape binding of a `gcn_layer_*` artifact (from its manifest config).
#[derive(Debug, Clone)]
pub struct GcnLayerBinding {
    pub artifact: String,
    pub batch: usize,
    pub sample: usize,
    pub feature: usize,
    pub hidden: usize,
    pub table: usize,
}

impl GcnLayerBinding {
    pub fn from_spec(spec: &ArtifactSpec) -> Result<GcnLayerBinding> {
        let cfg = |k: &str| -> Result<usize> {
            spec.config
                .get(k)
                .map(|v| *v as usize)
                .ok_or_else(|| Error::Coordinator(format!("{}: missing config `{k}`", spec.name)))
        };
        Ok(GcnLayerBinding {
            artifact: spec.name.clone(),
            batch: cfg("batch")?,
            sample: cfg("sample")?,
            feature: cfg("feature")?,
            hidden: cfg("hidden")?,
            table: cfg("table")?,
        })
    }
}

/// One served response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub node: usize,
    /// The node's layer output (hidden embedding).
    pub output: Vec<f32>,
    /// Modeled edge latency for this round (Eq. 1, centralized).
    pub modeled: Time,
    /// Measured wall-clock of the PJRT execution serving this batch.
    pub wall: Duration,
}

/// The centralized serving coordinator.
pub struct CentralizedLeader {
    binding: GcnLayerBinding,
    batcher: Batcher,
    graph: Csr,
    sampler: NeighborSampler,
    store: FeatureStore,
    model: NetModel,
    topo: Topology,
    /// When set, the per-response `modeled` latency comes from a
    /// packet-level `netsim` round instead of the closed-form Eq. (1).
    simulated_latency: Option<Time>,
    served_batches: u64,
    /// §Perf: tensors that are constant within a round, rebuilt only at
    /// the `end_round` barrier instead of per served batch.
    w_tensor: Tensor,
    table_tensor: Option<Tensor>,
}

impl CentralizedLeader {
    pub fn new(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        max_wait: Duration,
    ) -> Result<CentralizedLeader> {
        if graph.num_nodes() > binding.table {
            return Err(Error::Coordinator(format!(
                "graph has {} nodes but artifact table holds {} (shard the graph)",
                graph.num_nodes(),
                binding.table
            )));
        }
        if weights.len() != binding.feature * binding.hidden {
            return Err(Error::Coordinator(format!(
                "weights must be {}x{}",
                binding.feature, binding.hidden
            )));
        }
        let store = FeatureStore::new(binding.table, binding.feature);
        let topo = Topology { nodes: graph.num_nodes(), cluster_size: workload.neighbors.max(1) };
        let model = NetModel::paper(workload)?;
        let w_tensor = Tensor::f32(&[binding.feature, binding.hidden], weights)?;
        Ok(CentralizedLeader {
            batcher: Batcher::new(binding.batch, max_wait)?,
            sampler: NeighborSampler::new(binding.sample, 7),
            binding,
            graph,
            store,
            model,
            topo,
            simulated_latency: None,
            served_batches: 0,
            w_tensor,
            table_tensor: None,
        })
    }

    /// Build the leader a tuned [`OperatingPoint`] describes.  The
    /// centralized setting has no cluster structure, so this validates the
    /// point's setting and otherwise defers to [`CentralizedLeader::new`]
    /// — the constructor exists so the serving path is configured through
    /// the same E11 artifact for every setting.
    ///
    /// [`OperatingPoint`]: crate::autotune::OperatingPoint
    pub fn from_operating_point(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        max_wait: Duration,
        point: &crate::autotune::OperatingPoint,
    ) -> Result<CentralizedLeader> {
        if point.setting != crate::autotune::SettingKind::Centralized {
            return Err(Error::Coordinator(format!(
                "operating point `{}` is not centralized",
                point.label()
            )));
        }
        CentralizedLeader::new(binding, graph, weights, workload, max_wait)
    }

    /// Ingest one node's uploaded features (staged; visible after
    /// `end_round`, the double-buffer barrier).
    pub fn upload(&mut self, node: usize, features: &[f32]) -> Result<()> {
        self.store.write(node, features)
    }

    /// Round barrier: staged uploads become the serving state; the
    /// round-constant feature-table tensor is rebuilt here (once) rather
    /// than per batch (§Perf).
    pub fn end_round(&mut self) {
        self.store.swap();
        let b = &self.binding;
        let all: Vec<usize> = (0..b.table).collect();
        let x_table = self.store.gather(&all).expect("table rows are in range");
        self.table_tensor =
            Some(Tensor::f32(&[b.table, b.feature], x_table).expect("shape is static"));
    }

    /// Enqueue a request; serve a batch if one closes.
    pub fn submit(&mut self, svc: &InferenceService, req: Request) -> Result<Vec<Response>> {
        if req.node >= self.graph.num_nodes() {
            return Err(Error::Coordinator(format!("node {} not in graph", req.node)));
        }
        match self.batcher.push(req) {
            Some(batch) => self.serve(svc, batch),
            None => Ok(Vec::new()),
        }
    }

    /// Deadline poll: serve a partial batch whose oldest member expired.
    pub fn poll(&mut self, svc: &InferenceService) -> Result<Vec<Response>> {
        match self.batcher.poll() {
            Some(batch) => self.serve(svc, batch),
            None => Ok(Vec::new()),
        }
    }

    /// Drain all pending requests (shutdown path).
    pub fn drain(&mut self, svc: &InferenceService) -> Result<Vec<Response>> {
        match self.batcher.flush() {
            Some(batch) => self.serve(svc, batch),
            None => Ok(Vec::new()),
        }
    }

    pub fn served_batches(&self) -> u64 {
        self.served_batches
    }

    /// Switch the per-response `modeled` latency from the closed-form
    /// Eq. (1) to a packet-level `netsim` round over this leader's
    /// topology — uplink contention included, composed through the
    /// `CommFabric` entry point (`NetModel::latency_via`).  `None`
    /// returns to the analytic model.
    pub fn use_simulated_latency(
        &mut self,
        cfg: Option<&crate::netsim::NetSimConfig>,
    ) -> Result<()> {
        self.simulated_latency = match cfg {
            None => None,
            Some(c) => {
                let fabric = crate::netsim::NetSim::new(c.clone());
                Some(
                    self.model
                        .latency_via(&fabric, Setting::Centralized, self.topo)?
                        .total(),
                )
            }
        };
        Ok(())
    }

    /// The round latency currently attached to responses: the simulated
    /// figure when [`CentralizedLeader::use_simulated_latency`] is active,
    /// the Eq. (1) closed form otherwise.
    pub fn modeled_round_latency(&self) -> Time {
        self.simulated_latency
            .unwrap_or_else(|| self.model.latency(Setting::Centralized, self.topo).total())
    }

    fn serve(&mut self, svc: &InferenceService, batch: Batch) -> Result<Vec<Response>> {
        let b = &self.binding;
        let real = batch.requests.len();
        // Pad short batches to the artifact's static batch dimension by
        // repeating the last node.
        let mut nodes = batch.nodes();
        let pad_node = *nodes.last().ok_or_else(|| Error::Coordinator("empty batch".into()))?;
        nodes.resize(b.batch, pad_node);

        let x_self = self.store.gather(&nodes)?;
        let nbr_idx = self.sampler.sample_batch(&self.graph, &nodes);
        // Round-constant tensors come from the end_round cache (§Perf).
        let table_tensor = self
            .table_tensor
            .clone()
            .ok_or_else(|| Error::Coordinator("serve before end_round barrier".into()))?;

        let inputs = vec![
            Tensor::f32(&[b.batch, b.feature], x_self)?,
            Tensor::i32(&[b.batch, b.sample], nbr_idx)?,
            table_tensor,
            self.w_tensor.clone(),
        ];

        let t0 = Instant::now();
        let outputs = svc.infer(&b.artifact, inputs)?;
        let wall = t0.elapsed();
        self.served_batches += 1;

        let out = outputs
            .first()
            .ok_or_else(|| Error::Coordinator("artifact returned no outputs".into()))?;
        let flat = out.as_f32()?;
        let modeled = self.modeled_round_latency();

        Ok(batch
            .requests
            .iter()
            .take(real)
            .enumerate()
            .map(|(i, r)| Response {
                id: r.id,
                node: r.node,
                output: flat[i * b.hidden..(i + 1) * b.hidden].to_vec(),
                modeled,
                wall,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::Path;

    fn binding() -> GcnLayerBinding {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "gcn_layer_small", "file": "f",
             "inputs": [], "outputs": [],
             "config": {"batch": 16, "sample": 4, "feature": 64,
                        "hidden": 32, "table": 64}}]}"#;
        let m = Manifest::parse(Path::new("/x"), doc).unwrap();
        GcnLayerBinding::from_spec(m.get("gcn_layer_small").unwrap()).unwrap()
    }

    fn leader() -> CentralizedLeader {
        let g = crate::graph::generate::regular(48, 6, 3).unwrap();
        let w = vec![0.01f32; 64 * 32];
        CentralizedLeader::new(
            binding(),
            g,
            w,
            &GnnWorkload::gcn("test", 64, 6),
            Duration::from_millis(10),
        )
        .unwrap()
    }

    #[test]
    fn binding_reads_manifest_config() {
        let b = binding();
        assert_eq!((b.batch, b.sample, b.feature, b.hidden, b.table), (16, 4, 64, 32, 64));
    }

    #[test]
    fn binding_requires_all_keys() {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "m", "file": "f", "inputs": [], "outputs": [],
             "config": {"batch": 16}}]}"#;
        let m = Manifest::parse(Path::new("/x"), doc).unwrap();
        assert!(GcnLayerBinding::from_spec(m.get("m").unwrap()).is_err());
    }

    #[test]
    fn rejects_oversized_graphs_and_bad_weights() {
        let g = crate::graph::generate::regular(100, 4, 1).unwrap(); // > table 64
        let r = CentralizedLeader::new(
            binding(),
            g,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 4),
            Duration::ZERO,
        );
        assert!(r.is_err());

        let g = crate::graph::generate::regular(10, 2, 1).unwrap();
        let r = CentralizedLeader::new(
            binding(),
            g,
            vec![0.0; 7], // wrong arity
            &GnnWorkload::gcn("t", 64, 2),
            Duration::ZERO,
        );
        assert!(r.is_err());
    }

    #[test]
    fn from_operating_point_validates_the_setting() {
        use crate::autotune::{OperatingPoint, Partitioner};
        let g = crate::graph::generate::regular(48, 6, 3).unwrap();
        let ok = CentralizedLeader::from_operating_point(
            binding(),
            g.clone(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 6),
            Duration::ZERO,
            &OperatingPoint::centralized(),
        );
        assert!(ok.is_ok());
        let bad = CentralizedLeader::from_operating_point(
            binding(),
            g,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 6),
            Duration::ZERO,
            &OperatingPoint::semi(8, 10.0, Partitioner::FixedSize),
        );
        assert!(bad.is_err());
    }

    #[test]
    fn simulated_latency_mode_tracks_the_fabric() {
        use crate::netsim::NetSimConfig;
        let mut l = leader();
        let analytic = l.modeled_round_latency();
        // Uncongested fabric coincides with Eq. (1).
        l.use_simulated_latency(Some(&NetSimConfig::default())).unwrap();
        let sim = l.modeled_round_latency();
        assert!(
            (sim.as_s() - analytic.as_s()).abs() / analytic.as_s() < 1e-6,
            "uncongested sim {sim} vs analytic {analytic}"
        );
        // A single receive port serializes the gather — rounds get slower.
        l.use_simulated_latency(Some(&NetSimConfig {
            rx_ports: Some(1),
            ..Default::default()
        }))
        .unwrap();
        assert!(l.modeled_round_latency() > sim);
        // And None returns to the closed form.
        l.use_simulated_latency(None).unwrap();
        assert_eq!(l.modeled_round_latency(), analytic);
    }

    #[test]
    fn upload_respects_double_buffering() {
        let mut l = leader();
        l.upload(3, &vec![1.0; 64]).unwrap();
        assert_eq!(l.store.read(3).unwrap()[0], 0.0);
        l.end_round();
        assert_eq!(l.store.read(3).unwrap()[0], 1.0);
    }

    // The submit/poll/drain request paths require a live PJRT service and
    // built artifacts; they are covered by the integration tests in
    // `rust/tests/serving.rs` and the `e2e_inference` example.
}
