//! Request router: maps inference requests for graph nodes to the edge
//! device that owns them (decentralized / semi-decentralized) or to a
//! leader replica (centralized).
//!
//! DESIGN.md: §7 (serving coordinator).

use crate::error::{Error, Result};
use crate::graph::Clustering;

/// Routing table over node ownership.
#[derive(Debug, Clone)]
pub struct Router {
    /// `owner[node] = device id`.
    owner: Vec<usize>,
    devices: usize,
    /// Round-robin cursor for stateless (replica) routing.
    cursor: usize,
    /// Outstanding requests per device (load view).
    load: Vec<usize>,
}

impl Router {
    /// Ownership routing from a cluster partition: cluster id = device id.
    pub fn from_clustering(c: &Clustering) -> Router {
        let devices = c.num_clusters().max(1);
        Router {
            owner: c.assignment.clone(),
            devices,
            cursor: 0,
            load: vec![0; devices],
        }
    }

    /// Centralized: every node owned by one of `replicas` leader replicas,
    /// assigned round-robin per request.
    pub fn centralized(num_nodes: usize, replicas: usize) -> Result<Router> {
        if replicas == 0 {
            return Err(Error::Coordinator("need at least one replica".into()));
        }
        Ok(Router {
            owner: vec![usize::MAX; num_nodes],
            devices: replicas,
            cursor: 0,
            load: vec![0; replicas],
        })
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Route a request for `node`: owner if pinned, else the least-loaded
    /// replica (round-robin on ties).
    pub fn route(&mut self, node: usize) -> Result<usize> {
        if node >= self.owner.len() {
            return Err(Error::Coordinator(format!(
                "node {node} out of range ({} nodes)",
                self.owner.len()
            )));
        }
        let dev = match self.owner[node] {
            usize::MAX => {
                // least-loaded, scanning from the round-robin cursor
                let mut best = self.cursor % self.devices;
                for k in 0..self.devices {
                    let cand = (self.cursor + k) % self.devices;
                    if self.load[cand] < self.load[best] {
                        best = cand;
                    }
                }
                self.cursor = (best + 1) % self.devices;
                best
            }
            owner => owner,
        };
        self.load[dev] += 1;
        Ok(dev)
    }

    /// Mark a request complete (load bookkeeping).
    pub fn complete(&mut self, device: usize) {
        if device < self.load.len() && self.load[device] > 0 {
            self.load[device] -= 1;
        }
    }

    pub fn load_of(&self, device: usize) -> usize {
        self.load.get(device).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::fixed_size;
    use crate::testing::{forall, Rng};

    #[test]
    fn ownership_routing_follows_clusters() {
        let c = fixed_size(25, 10).unwrap();
        let mut r = Router::from_clustering(&c);
        assert_eq!(r.devices(), 3);
        assert_eq!(r.route(0).unwrap(), 0);
        assert_eq!(r.route(9).unwrap(), 0);
        assert_eq!(r.route(10).unwrap(), 1);
        assert_eq!(r.route(24).unwrap(), 2);
        assert!(r.route(25).is_err());
    }

    #[test]
    fn replica_routing_balances() {
        let mut r = Router::centralized(100, 4).unwrap();
        for node in 0..40 {
            r.route(node).unwrap();
        }
        for dev in 0..4 {
            assert_eq!(r.load_of(dev), 10, "device {dev}");
        }
    }

    #[test]
    fn completion_frees_load_and_steers_routing() {
        let mut r = Router::centralized(10, 2).unwrap();
        let a = r.route(0).unwrap();
        let _b = r.route(1).unwrap();
        r.complete(a);
        // device `a` is now strictly less loaded → next request goes there
        assert_eq!(r.route(2).unwrap(), a);
    }

    #[test]
    fn property_ownership_is_stable_and_load_is_conserved() {
        forall(16, |rng: &mut Rng| {
            let n = rng.index(50) + 10;
            let k = rng.index(9) + 1;
            let c = fixed_size(n, k).unwrap();
            let mut r = Router::from_clustering(&c);
            let mut outstanding = vec![0usize; r.devices()];
            for _ in 0..100 {
                let node = rng.index(n);
                let dev = r.route(node).unwrap();
                assert_eq!(dev, c.assignment[node], "owner routing must be stable");
                outstanding[dev] += 1;
                if rng.bool() {
                    r.complete(dev);
                    outstanding[dev] -= 1;
                }
            }
            for d in 0..r.devices() {
                assert_eq!(r.load_of(d), outstanding[d]);
            }
        });
    }

    #[test]
    fn zero_replicas_rejected() {
        assert!(Router::centralized(5, 0).is_err());
    }
}
