//! Request-trace generation and replay with latency percentiles.
//!
//! Serving quality at the edge is a tail-latency question, not a mean:
//! this module generates Poisson (optionally diurnal) request traces,
//! replays them through the size-or-deadline batching policy in virtual
//! time (execution cost supplied by the caller — measured PJRT wall on the
//! real path, a model in tests), and reports p50/p90/p99/max.
//!
//! DESIGN.md: §7 (serving coordinator).

use crate::error::{Error, Result};
use crate::units::Time;

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Mean request rate (requests/second).
    pub rate_per_s: f64,
    /// Trace length in seconds.
    pub duration_s: f64,
    /// Modulate the rate with a diurnal (sinusoidal) profile.
    pub diurnal: bool,
    /// Nodes requests target (uniform).
    pub nodes: usize,
    pub seed: u64,
}

/// One request arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub at: Time,
    pub node: usize,
}

/// Generate a Poisson arrival trace (thinned when diurnal).
///
/// A thin wrapper over the E13 arrival generators
/// ([`crate::traffic::ArrivalProcess`]) so one code path owns arrival
/// sampling: the legacy diurnal profile `0.5·(1 + sin(t/T·2π))·rate`
/// is exactly the [`DiurnalCurve`] with mean `rate/2`, full swing and
/// one period per trace, thinned at its peak rate — same draw sequence,
/// same streams per seed.
///
/// [`DiurnalCurve`]: crate::workload::DiurnalCurve
pub fn generate_trace(cfg: &TraceConfig) -> Result<Vec<Arrival>> {
    use crate::traffic::ArrivalProcess;
    use crate::workload::DiurnalCurve;
    if !(cfg.rate_per_s > 0.0) || !(cfg.duration_s > 0.0) || cfg.nodes == 0 {
        return Err(Error::Coordinator("trace needs positive rate/duration/nodes".into()));
    }
    let process = if cfg.diurnal {
        ArrivalProcess::Diurnal(DiurnalCurve::new(
            cfg.rate_per_s / 2.0,
            1.0,
            Time::s(cfg.duration_s),
        )?)
    } else {
        ArrivalProcess::Poisson { rate: cfg.rate_per_s }
    };
    process.generate(Time::s(cfg.duration_s), cfg.nodes, cfg.seed)
}

/// Latency distribution summary.
///
/// Empty-sample convention (ISSUE 8 bugfix): [`LatencyStats::from_samples`]
/// rejects an empty batch with a typed error — that is the one
/// fallible step.  Every accessor is nevertheless total and panic-free
/// on an empty sample set, returning the *vacuous* sentinel: quantiles,
/// `max` and `mean` are [`Time::ZERO`] and `fraction_within` is `1.0`
/// ("all zero of the samples met the SLO"), never NaN.  Previously
/// `quantile` underflowed `len()-1`, `max` unwrapped, and
/// `fraction_within` returned `0/0 = NaN` — which must never reach a
/// report field a controller thresholds on.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    sorted: Vec<Time>,
}

impl LatencyStats {
    pub fn from_samples(mut samples: Vec<Time>) -> Result<LatencyStats> {
        if samples.is_empty() {
            return Err(Error::Coordinator("no latency samples".into()));
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(LatencyStats { sorted: samples })
    }

    pub fn count(&self) -> usize {
        self.sorted.len()
    }

    /// The sorted samples (ascending).
    pub fn samples(&self) -> &[Time] {
        &self.sorted
    }

    /// Quantile by **nearest rank**: the sample at index
    /// `ceil(n·q) − 1` of the ascending sort, clamped into range.
    ///
    /// Nearest rank never interpolates or extrapolates — on small
    /// samples high quantiles simply *saturate at the max*: with
    /// n < 100, `p99` equals `max` (the 99th-percentile rank rounds to
    /// the last sample), and with n < 2 every quantile is the single
    /// sample.  Degraded fault windows routinely produce such tiny
    /// samples; callers that need a resolved tail must check
    /// [`LatencyStats::resolves`] rather than trust a saturated `p99`.
    /// The E14 sweep therefore reports SLO attainment
    /// ([`LatencyStats::fraction_within`] — exact at any n) alongside
    /// quantiles.
    pub fn quantile(&self, q: f64) -> Time {
        let Some(&last) = self.sorted.last() else {
            return Time::ZERO;
        };
        let q = q.clamp(0.0, 1.0);
        let idx = ((self.sorted.len() as f64 * q).ceil() as usize).saturating_sub(1);
        if idx + 1 >= self.sorted.len() {
            last
        } else {
            self.sorted[idx]
        }
    }

    /// Whether `quantile(q)` ranks a genuine tail order statistic
    /// rather than saturating at the max: at least one sample ranks
    /// *above* the returned one.  Shares `quantile`'s exact
    /// `ceil(n·q)` arithmetic (float boundaries included), so the two
    /// can never disagree; `resolves(0.99)` needs n ≥ 100.
    pub fn resolves(&self, q: f64) -> bool {
        let q = q.clamp(0.0, 1.0);
        let idx = ((self.sorted.len() as f64 * q).ceil() as usize).saturating_sub(1);
        idx + 1 < self.sorted.len()
    }

    pub fn p50(&self) -> Time {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> Time {
        self.quantile(0.90)
    }

    pub fn p95(&self) -> Time {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> Time {
        self.quantile(0.99)
    }

    /// Largest sample ([`Time::ZERO`] when empty — vacuous sentinel).
    pub fn max(&self) -> Time {
        self.sorted.last().copied().unwrap_or(Time::ZERO)
    }

    /// Fraction of samples at or under `limit` (SLO attainment).
    /// Vacuously `1.0` when empty — never `0/0 = NaN`.
    pub fn fraction_within(&self, limit: Time) -> f64 {
        if self.sorted.is_empty() {
            return 1.0;
        }
        let within = self.sorted.partition_point(|&t| t <= limit);
        within as f64 / self.sorted.len() as f64
    }

    /// Mean sample ([`Time::ZERO`] when empty — vacuous sentinel).
    pub fn mean(&self) -> Time {
        if self.sorted.is_empty() {
            return Time::ZERO;
        }
        self.sorted.iter().copied().sum::<Time>() * (1.0 / self.sorted.len() as f64)
    }
}

/// Replay a trace through the size-or-deadline batching policy.
///
/// Virtual time: a batch closes when it reaches `max_batch` requests or
/// when the next arrival (or trace end) passes the oldest member's
/// deadline.  `exec` is charged per batch (its argument is the batch's
/// node list; its result the execution duration — measured PJRT wall on
/// the real path).  A request's latency = queueing wait + its batch's
/// execution time.  The server is sequential: a batch cannot start before
/// the previous one finished.
pub fn replay_trace<F>(
    trace: &[Arrival],
    max_batch: usize,
    max_wait: Time,
    mut exec: F,
) -> Result<LatencyStats>
where
    F: FnMut(&[usize]) -> Result<Time>,
{
    if max_batch == 0 {
        return Err(Error::Coordinator("batch size must be > 0".into()));
    }
    if trace.is_empty() {
        return Err(Error::Coordinator("empty trace".into()));
    }
    let mut latencies = Vec::with_capacity(trace.len());
    let mut pending: Vec<Arrival> = Vec::with_capacity(max_batch);
    let mut server_free = Time::ZERO;

    let mut close = |pending: &mut Vec<Arrival>,
                     close_at: Time,
                     server_free: &mut Time,
                     latencies: &mut Vec<Time>|
     -> Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let nodes: Vec<usize> = pending.iter().map(|a| a.node).collect();
        let start = close_at.max(*server_free);
        let dur = exec(&nodes)?;
        let done = start + dur;
        *server_free = done;
        for a in pending.drain(..) {
            latencies.push(done - a.at);
        }
        Ok(())
    };

    for (i, a) in trace.iter().enumerate() {
        // Deadline closes strictly before this arrival joins.
        if let Some(oldest) = pending.first().map(|p| p.at) {
            if a.at > oldest + max_wait {
                let at = oldest + max_wait;
                close(&mut pending, at, &mut server_free, &mut latencies)?;
            }
        }
        pending.push(*a);
        if pending.len() >= max_batch {
            close(&mut pending, a.at, &mut server_free, &mut latencies)?;
        }
        let _ = i;
    }
    if let Some(oldest) = pending.first().map(|p| p.at) {
        let at = oldest + max_wait;
        close(&mut pending, at, &mut server_free, &mut latencies)?;
    }
    LatencyStats::from_samples(latencies)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    fn cfg() -> TraceConfig {
        TraceConfig { rate_per_s: 500.0, duration_s: 2.0, diurnal: false, nodes: 64, seed: 3 }
    }

    #[test]
    fn trace_has_poisson_like_rate_and_sorted_arrivals() {
        let t = generate_trace(&cfg()).unwrap();
        let expected = 500.0 * 2.0;
        assert!(
            (t.len() as f64 - expected).abs() < 0.15 * expected,
            "got {} arrivals, expected ~{expected}",
            t.len()
        );
        assert!(t.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(t.iter().all(|a| a.node < 64));
    }

    #[test]
    fn diurnal_thinning_reduces_volume_deterministically() {
        let base = generate_trace(&cfg()).unwrap();
        let diurnal =
            generate_trace(&TraceConfig { diurnal: true, ..cfg() }).unwrap();
        assert!(diurnal.len() < base.len());
        let again = generate_trace(&TraceConfig { diurnal: true, ..cfg() }).unwrap();
        assert_eq!(diurnal.len(), again.len());
    }

    #[test]
    fn trace_rejects_bad_configs() {
        assert!(generate_trace(&TraceConfig { rate_per_s: 0.0, ..cfg() }).is_err());
        assert!(generate_trace(&TraceConfig { nodes: 0, ..cfg() }).is_err());
    }

    #[test]
    fn stats_quantiles_nearest_rank() {
        let s = LatencyStats::from_samples(
            (1..=100).map(|i| Time::ms(i as f64)).collect(),
        )
        .unwrap();
        assert_close(s.p50().as_ms(), 50.0, 1e-12);
        assert_close(s.p90().as_ms(), 90.0, 1e-12);
        assert_close(s.p95().as_ms(), 95.0, 1e-12);
        assert_close(s.p99().as_ms(), 99.0, 1e-12);
        assert_close(s.max().as_ms(), 100.0, 1e-12);
        assert_close(s.mean().as_ms(), 50.5, 1e-12);
        assert!(LatencyStats::from_samples(vec![]).is_err());
        // fraction_within counts the sorted prefix directly (no
        // quantile-rank reconstruction): 1..=100 ms samples.
        assert_close(s.fraction_within(Time::ms(7.0)), 0.07, 1e-12);
        assert_close(s.fraction_within(Time::ms(6.5)), 0.06, 1e-12);
        assert_close(s.fraction_within(Time::ZERO), 0.0, 1e-12);
        assert_close(s.fraction_within(Time::s(1.0)), 1.0, 1e-12);
        // n = 100 is exactly enough to resolve p99 (one sample above).
        assert!(s.resolves(0.99));
        assert!(s.resolves(0.5));
        assert_eq!(s.samples().len(), 100);
    }

    /// Regression (ISSUE 8): the empty-sample paths used to panic
    /// (`quantile` indexed past a `len()-1` underflow, `max` unwrapped
    /// a `None`) or poison downstream math (`fraction_within` returned
    /// `0/0 = NaN`).  One convention now: the constructor is the typed
    /// error; accessors are total with vacuous sentinels.
    #[test]
    fn stats_empty_sample_paths_are_total_and_nan_free() {
        // The public constructor still refuses empty input…
        assert!(LatencyStats::from_samples(vec![]).is_err());
        // …but the accessors themselves must be panic- and NaN-free
        // (same-module construction bypasses the constructor guard).
        let empty = LatencyStats { sorted: vec![] };
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.quantile(0.5), Time::ZERO);
        assert_eq!(empty.p99(), Time::ZERO);
        assert_eq!(empty.max(), Time::ZERO);
        assert_eq!(empty.mean(), Time::ZERO);
        let f = empty.fraction_within(Time::ms(1.0));
        assert!(f.is_finite(), "attainment must never be NaN");
        assert_eq!(f, 1.0);
        assert!(!empty.resolves(0.5));
    }

    /// Tiny degraded-window samples (n < 100): nearest rank saturates
    /// at the max instead of extrapolating — documented behavior, and
    /// `resolves` tells callers when that happens.
    #[test]
    fn stats_tiny_samples_saturate_not_extrapolate() {
        let samples: Vec<Time> = (1..=10).map(|i| Time::ms(i as f64)).collect();
        let s = LatencyStats::from_samples(samples).unwrap();
        // ceil(10·0.5) − 1 = 4 → the 5th sample.
        assert_close(s.p50().as_ms(), 5.0, 1e-12);
        // ceil(10·0.9) − 1 = 8 → the 9th sample still ranks.
        assert_close(s.p90().as_ms(), 9.0, 1e-12);
        // p95/p99 saturate at the max: no sample ranks above them.
        assert_eq!(s.p95(), s.max());
        assert_eq!(s.p99(), s.max());
        assert!(s.resolves(0.5) && s.resolves(0.9));
        assert!(!s.resolves(0.95) && !s.resolves(0.99));
        // fraction_within stays exact at any n — the SLO metric the
        // fault sweep leans on for tiny windows.
        assert_close(s.fraction_within(Time::ms(5.0)), 0.5, 1e-12);
        // n = 1: every quantile is the single sample.
        let one = LatencyStats::from_samples(vec![Time::ms(3.0)]).unwrap();
        assert_eq!(one.p50(), one.p99());
        assert_eq!(one.p99(), Time::ms(3.0));
        assert!(!one.resolves(0.5));
    }

    #[test]
    fn replay_full_batches_have_no_deadline_wait() {
        // 8 arrivals at t=0, batch 4, instant server -> latency = exec only.
        let trace: Vec<Arrival> =
            (0..8).map(|i| Arrival { at: Time::ZERO, node: i }).collect();
        let stats = replay_trace(&trace, 4, Time::ms(100.0), |nodes| {
            assert_eq!(nodes.len(), 4);
            Ok(Time::ms(2.0))
        })
        .unwrap();
        assert_eq!(stats.count(), 8);
        // first batch: 2 ms; second waits for the server: 4 ms.
        assert_close(stats.p50().as_ms(), 2.0, 1e-9);
        assert_close(stats.max().as_ms(), 4.0, 1e-9);
    }

    #[test]
    fn replay_deadline_closes_partial_batches() {
        let trace = vec![
            Arrival { at: Time::ZERO, node: 0 },
            Arrival { at: Time::ms(500.0), node: 1 },
        ];
        let stats = replay_trace(&trace, 64, Time::ms(10.0), |nodes| {
            assert_eq!(nodes.len(), 1);
            Ok(Time::ms(1.0))
        })
        .unwrap();
        // each waits its own 10 ms deadline + 1 ms exec
        assert_close(stats.max().as_ms(), 11.0, 1e-9);
        assert_eq!(stats.count(), 2);
    }

    #[test]
    fn replay_overload_grows_queueing_delay() {
        // 1000 req/s into a server needing 4 ms per 2-batch: overloaded 2x.
        let trace: Vec<Arrival> = (0..200)
            .map(|i| Arrival { at: Time::ms(i as f64), node: 0 })
            .collect();
        let light = replay_trace(&trace[..50], 2, Time::ms(1.0), |_| Ok(Time::ms(1.0)))
            .unwrap();
        let heavy =
            replay_trace(&trace, 2, Time::ms(1.0), |_| Ok(Time::ms(4.0))).unwrap();
        assert!(heavy.p99() > light.p99() * 4.0, "queueing must dominate under overload");
    }

    #[test]
    fn replay_rejects_degenerate_inputs() {
        let trace = vec![Arrival { at: Time::ZERO, node: 0 }];
        assert!(replay_trace(&[], 4, Time::ZERO, |_| Ok(Time::ZERO)).is_err());
        assert!(replay_trace(&trace, 0, Time::ZERO, |_| Ok(Time::ZERO)).is_err());
    }
}
