//! Semi-decentralized coordinator (the paper's conclusion / ref [26], E8):
//! cluster *heads* each serve their region in a centralized fashion
//! (members upload features over fast V2X links, the head runs the GNN),
//! while the graph level stays decentralized — heads exchange boundary
//! embeddings with adjacent heads.
//!
//! The round itself runs on the shared [`RoundEngine`]: clusters map onto
//! table-sized shards (a head's members never span shards), the feature
//! table and the weight tensor are round-constant cached per shard, and
//! the modeled per-cluster latency comes from the engine's
//! [`LatencyProvider`] — the boundary-aware clustered E8 by default, a
//! packet-level `netsim` figure on demand.
//!
//! DESIGN.md: §7 (serving coordinator).

use std::time::Duration;

use crate::cores::{FeatureMatrix, GnnWorkload};
use crate::error::{Error, Result};
use crate::graph::{Clustering, Csr, ShardPlan};
use crate::netmodel::{NetModel, Topology};
use crate::units::Time;

use super::engine::{Deployment, GcnLayerBinding, LatencyProvider, RoundEngine};
use super::service::InferenceService;

/// Per-member output of one semi-decentralized round.
#[derive(Debug, Clone)]
pub struct SemiResult {
    pub node: usize,
    pub head: usize,
    pub output: Vec<f32>,
    /// Modeled round latency for this node's cluster (E8 model).
    pub modeled: Time,
    /// Wall time of the head's PJRT execution(s) for its cluster.
    pub wall: Duration,
}

/// The semi-decentralized deployment over one graph: cluster bookkeeping
/// over the shared round engine.
pub struct SemiCoordinator {
    clustering: Clustering,
    engine: RoundEngine,
    model: NetModel,
    head_capacity: f64,
    /// Fraction of graph edges the clustering keeps intra-cluster; drives
    /// the boundary term of the modeled round latency (E11's clustered E8
    /// variant — the same score the autotuner selects points with).
    intra_fraction: f64,
    /// Packet-level round completion when the `netsim` mode is active;
    /// `None` = the clustered E8 closed form.  The [`LatencyProvider`] is
    /// derived on demand ([`SemiCoordinator::latency_provider`]) so the
    /// intra-edge fraction has a single source of truth.
    simulated: Option<Time>,
}

impl SemiCoordinator {
    pub fn new(
        binding: GcnLayerBinding,
        graph: Csr,
        clustering: Clustering,
        weights: Vec<f32>,
        workload: &GnnWorkload,
    ) -> Result<SemiCoordinator> {
        if clustering.assignment.len() != graph.num_nodes() {
            return Err(Error::Coordinator("clustering does not cover the graph".into()));
        }
        if weights.len() != binding.feature * binding.hidden {
            return Err(Error::Coordinator("weight arity mismatch".into()));
        }
        let head_capacity = clustering.avg_size().max(1.0);
        let intra_fraction = clustering.intra_edge_fraction(&graph);
        let plan =
            ShardPlan::from_clustering(&graph, &binding.sampler(), binding.table, &clustering)?;
        let model = NetModel::paper(workload)?;
        let engine = RoundEngine::new(binding, plan, weights)?;
        Ok(SemiCoordinator {
            clustering,
            engine,
            model,
            head_capacity,
            intra_fraction,
            simulated: None,
        })
    }

    /// Build the coordinator a tuned [`OperatingPoint`] describes, through
    /// the same [`Deployment::build`] funnel every setting configures
    /// with: the point's partitioner produces the clustering and the
    /// point's head capacity replaces the avg-size default — so the
    /// serving path runs exactly the configuration the E11 autotuner
    /// scored.  Rejects non-semi points (the centralized leader has its
    /// own constructor).
    ///
    /// [`OperatingPoint`]: crate::autotune::OperatingPoint
    pub fn from_operating_point(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        point: &crate::autotune::OperatingPoint,
    ) -> Result<SemiCoordinator> {
        if point.setting != crate::autotune::SettingKind::Semi {
            return Err(Error::Coordinator(format!(
                "operating point `{}` is not semi-decentralized",
                point.label()
            )));
        }
        match Deployment::build(binding, graph, weights, workload, Duration::ZERO, point)? {
            Deployment::Semi(semi) => Ok(semi),
            _ => unreachable!("a semi point builds a semi deployment"),
        }
    }

    /// Override the cluster-head capacity multiple (the default is the
    /// clustering's average size).
    pub fn with_head_capacity(mut self, head_capacity: f64) -> Result<SemiCoordinator> {
        if !head_capacity.is_finite() || head_capacity < 1.0 {
            return Err(Error::Coordinator("head capacity must be >= 1".into()));
        }
        self.head_capacity = head_capacity;
        Ok(self)
    }

    pub fn head_capacity(&self) -> f64 {
        self.head_capacity
    }

    pub fn num_heads(&self) -> usize {
        self.clustering.num_clusters()
    }

    /// The engine this coordinator serves through (shard plan,
    /// tensor-cache counters, per-shard state).
    pub fn engine(&self) -> &RoundEngine {
        &self.engine
    }

    /// Switch per-result `modeled` latency from the closed-form clustered
    /// E8 model to a packet-level `netsim` overlay round — head
    /// receive-port contention and the boundary exchange included.  The
    /// simulated topology uses the largest cluster (the straggler that
    /// closes the round).  `None` returns to the analytic model.
    pub fn use_simulated_latency(
        &mut self,
        cfg: Option<&crate::netsim::NetSimConfig>,
    ) -> Result<()> {
        self.simulated = match cfg {
            None => None,
            Some(c) => {
                let worst = self.clustering.max_size().max(1);
                let topo =
                    Topology { nodes: self.engine.num_nodes(), cluster_size: worst };
                Some(
                    crate::netsim::simulate_fabric(
                        &self.model,
                        crate::netsim::Scenario::SemiOverlay {
                            head_capacity: self.head_capacity,
                        },
                        topo,
                        c,
                    )?
                    .completion,
                )
            }
        };
        Ok(())
    }

    /// The round latency currently attached to results (`None` = the
    /// closed-form E8 model is in effect, evaluated per cluster).
    pub fn simulated_round_latency(&self) -> Option<Time> {
        self.simulated
    }

    /// The provider the round prices modeled latencies with — derived on
    /// demand so the intra-edge fraction has one source of truth.
    pub fn latency_provider(&self) -> LatencyProvider {
        match self.simulated {
            Some(t) => LatencyProvider::Netsim(t),
            None => LatencyProvider::Clustered { intra_fraction: self.intra_fraction },
        }
    }

    /// Run one round: every head batches its members through the artifact.
    /// `features.row(node)` is each node's current feature vector; the
    /// engine stages the full matrix behind its double-buffer barrier,
    /// then serves each cluster against the round-constant per-shard
    /// tensor caches — the table gather, shape validation and tensor
    /// construction the seed paid per member chunk now happen once per
    /// round per shard (§Perf; the per-batch cost that remains is the
    /// owned-tensor handoff to the PJRT service thread, as on the seed
    /// leader path).
    pub fn round(
        &mut self,
        svc: &InferenceService,
        features: &FeatureMatrix,
    ) -> Result<Vec<SemiResult>> {
        self.engine.set_features(features)?;
        let n = self.engine.num_nodes();
        let provider = self.latency_provider();
        let mut results = Vec::with_capacity(n);
        for (head, members) in self.clustering.clusters.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let topo = Topology { nodes: n, cluster_size: members.len() };
            // Boundary-aware E8 (E11): the same clustered score the
            // autotuner selects operating points with, so the served
            // `modeled` latency matches the figure that justified the
            // configuration.
            let modeled = provider.semi(&self.model, topo, self.head_capacity);
            let out = self.engine.serve(svc, members)?;
            let wall = out.wall;
            for (&node, output) in members.iter().zip(out.outputs) {
                results.push(SemiResult { node, head, output, modeled, wall });
            }
        }
        results.sort_by_key(|r| r.node);
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{fixed_size, generate};
    use crate::testing::gcn_layer_binding;

    fn binding() -> GcnLayerBinding {
        gcn_layer_binding()
    }

    #[test]
    fn construction_validates_shapes() {
        let g = generate::regular(48, 6, 3).unwrap();
        let c = fixed_size(48, 8).unwrap();
        let ok = SemiCoordinator::new(
            binding(),
            g.clone(),
            c.clone(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().num_heads(), 6);

        // clustering mismatch
        let bad = SemiCoordinator::new(
            binding(),
            g.clone(),
            fixed_size(40, 8).unwrap(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(bad.is_err());

        // weight arity
        let bad = SemiCoordinator::new(
            binding(),
            g,
            c,
            vec![0.0; 3],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(bad.is_err());
    }

    #[test]
    fn oversized_graphs_shard_with_whole_clusters() {
        // 256 nodes against the 64-row table: the seed rejected this; the
        // engine shards it, never splitting a head's members.
        let g = generate::regular(256, 6, 3).unwrap();
        let c = fixed_size(256, 8).unwrap();
        let semi = SemiCoordinator::new(
            binding(),
            g,
            c.clone(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        )
        .unwrap();
        assert_eq!(semi.num_heads(), 32);
        let plan = semi.engine().plan();
        assert!(plan.num_shards() > 1);
        for members in &c.clusters {
            let s0 = plan.home(members[0]).0;
            assert!(members.iter().all(|&v| plan.home(v).0 == s0));
        }
    }

    /// E11: a coordinator built from a tuned operating point is
    /// configured identically to the hand-constructed equivalent (the
    /// PJRT round itself is compared bit-for-bit in rust/tests/serving.rs).
    #[test]
    fn from_operating_point_matches_hand_construction() {
        use crate::autotune::{OperatingPoint, Partitioner};
        let g = generate::regular(48, 6, 3).unwrap();
        let w = vec![0.25f32; 64 * 32];
        let wl = GnnWorkload::gcn("t", 64, 8);
        let point = OperatingPoint::semi(8, 10.0, Partitioner::FixedSize);
        let tuned =
            SemiCoordinator::from_operating_point(binding(), g.clone(), w.clone(), &wl, &point)
                .unwrap();
        let hand = SemiCoordinator::new(
            binding(),
            g.clone(),
            fixed_size(48, 8).unwrap(),
            w.clone(),
            &wl,
        )
        .unwrap()
        .with_head_capacity(10.0)
        .unwrap();
        assert_eq!(tuned.num_heads(), hand.num_heads());
        assert_eq!(tuned.head_capacity(), 10.0);
        assert_eq!(tuned.clustering, hand.clustering);
        assert_eq!(tuned.intra_fraction, hand.intra_fraction);
        // Same shard plan, hence the same serving path.
        assert_eq!(tuned.engine().plan(), hand.engine().plan());
        // Same modeled round latency for every cluster.
        let topo = Topology { nodes: 48, cluster_size: 8 };
        assert_eq!(
            tuned.model.semi_latency(topo, tuned.head_capacity).total(),
            hand.model.semi_latency(topo, hand.head_capacity).total()
        );
        assert_eq!(
            tuned.latency_provider().semi(&tuned.model, topo, tuned.head_capacity),
            hand.latency_provider().semi(&hand.model, topo, hand.head_capacity)
        );

        // Non-semi points are rejected, as are sub-unit head capacities.
        let cent = OperatingPoint::centralized();
        assert!(SemiCoordinator::from_operating_point(
            binding(),
            g.clone(),
            w.clone(),
            &wl,
            &cent
        )
        .is_err());
        let semi = SemiCoordinator::new(
            binding(),
            g,
            fixed_size(48, 8).unwrap(),
            w,
            &wl,
        )
        .unwrap();
        assert!(semi.with_head_capacity(0.5).is_err());
    }

    #[test]
    fn simulated_latency_mode_tracks_the_overlay_fabric() {
        use crate::netsim::NetSimConfig;
        let g = generate::regular(48, 6, 3).unwrap();
        let c = fixed_size(48, 8).unwrap();
        let mut semi = SemiCoordinator::new(
            binding(),
            g,
            c,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        )
        .unwrap();
        assert!(semi.simulated_round_latency().is_none());

        semi.use_simulated_latency(Some(&NetSimConfig::default())).unwrap();
        let sim = semi.simulated_round_latency().unwrap();
        // Uncongested overlay coincides with the closed-form E8 model
        // (48 nodes in six full clusters of 8, heads 8× a member).
        let analytic = semi
            .model
            .semi_latency(Topology { nodes: 48, cluster_size: 8 }, semi.head_capacity)
            .total();
        assert!(
            (sim.as_s() - analytic.as_s()).abs() / analytic.as_s() < 1e-6,
            "sim {sim} vs analytic {analytic}"
        );

        // One receive port per head makes member uploads queue.
        semi.use_simulated_latency(Some(&NetSimConfig {
            rx_ports: Some(1),
            ..Default::default()
        }))
        .unwrap();
        assert!(semi.simulated_round_latency().unwrap() > sim);

        semi.use_simulated_latency(None).unwrap();
        assert!(semi.simulated_round_latency().is_none());
        // ... and the default provider is the boundary-aware clustered E8,
        // derived from the single stored intra-edge fraction.
        assert_eq!(
            semi.latency_provider(),
            LatencyProvider::Clustered { intra_fraction: semi.intra_fraction }
        );
    }

    /// §Perf satellite: the round-constant tensors are cached — many
    /// cluster serves per round reuse one table tensor per shard (the
    /// seed rebuilt table + weight tensors for every member chunk).
    #[test]
    fn round_constant_tensors_are_cached_per_shard() {
        let g = generate::regular(48, 6, 3).unwrap();
        let c = fixed_size(48, 8).unwrap();
        let mut semi = SemiCoordinator::new(
            binding(),
            g,
            c,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        )
        .unwrap();
        let shards = semi.engine().plan().num_shards() as u64;
        let features = FeatureMatrix::zeros(48, 64);
        semi.engine.set_features(&features).unwrap();
        assert_eq!(semi.engine().table_builds(), shards);
        // Assembling every cluster's batches hits the cache only.
        for members in semi.clustering.clusters.clone() {
            for _ in 0..3 {
                semi.engine.assemble(&members).unwrap();
            }
        }
        assert_eq!(semi.engine().table_builds(), shards, "serving must not rebuild");
        // The next round rebuilds exactly once per shard.
        semi.engine.set_features(&features).unwrap();
        assert_eq!(semi.engine().table_builds(), 2 * shards);
    }

    // The `round` execution path needs built artifacts + a PJRT service;
    // covered by rust/tests/serving.rs, rust/tests/sharded_serving.rs and
    // examples/semi_decentralized.rs.
}
