//! Semi-decentralized coordinator (the paper's conclusion / ref [26], E8):
//! cluster *heads* each serve their region in a centralized fashion
//! (members upload features over fast V2X links, the head runs the GNN),
//! while the graph level stays decentralized — heads exchange boundary
//! embeddings with adjacent heads.

use std::time::{Duration, Instant};

use crate::cores::GnnWorkload;
use crate::error::{Error, Result};
use crate::graph::{Clustering, Csr, NeighborSampler};
use crate::netmodel::{NetModel, Topology};
use crate::runtime::Tensor;
use crate::units::Time;

use super::leader::GcnLayerBinding;
use super::service::InferenceService;

/// Per-member output of one semi-decentralized round.
#[derive(Debug, Clone)]
pub struct SemiResult {
    pub node: usize,
    pub head: usize,
    pub output: Vec<f32>,
    /// Modeled round latency for this node's cluster (E8 model).
    pub modeled: Time,
    /// Wall time of the head's PJRT execution.
    pub wall: Duration,
}

/// The semi-decentralized deployment over one graph.
pub struct SemiCoordinator {
    binding: GcnLayerBinding,
    graph: Csr,
    clustering: Clustering,
    weights: Vec<f32>,
    sampler: NeighborSampler,
    model: NetModel,
    head_capacity: f64,
}

impl SemiCoordinator {
    pub fn new(
        binding: GcnLayerBinding,
        graph: Csr,
        clustering: Clustering,
        weights: Vec<f32>,
        workload: &GnnWorkload,
    ) -> Result<SemiCoordinator> {
        if clustering.assignment.len() != graph.num_nodes() {
            return Err(Error::Coordinator("clustering does not cover the graph".into()));
        }
        if graph.num_nodes() > binding.table {
            return Err(Error::Coordinator(format!(
                "graph has {} nodes but artifact table holds {}",
                graph.num_nodes(),
                binding.table
            )));
        }
        if weights.len() != binding.feature * binding.hidden {
            return Err(Error::Coordinator("weight arity mismatch".into()));
        }
        let head_capacity = clustering.avg_size().max(1.0);
        Ok(SemiCoordinator {
            sampler: NeighborSampler::new(binding.sample, 7),
            model: NetModel::paper(workload)?,
            binding,
            graph,
            clustering,
            weights,
            head_capacity,
        })
    }

    pub fn num_heads(&self) -> usize {
        self.clustering.num_clusters()
    }

    /// Run one round: every head batches its members through the artifact.
    /// `features[node]` is each node's current feature vector.
    pub fn round(&self, svc: &InferenceService, features: &[Vec<f32>]) -> Result<Vec<SemiResult>> {
        let b = &self.binding;
        let n = self.graph.num_nodes();
        if features.len() != n {
            return Err(Error::Coordinator("feature rows != nodes".into()));
        }
        if features.iter().any(|f| f.len() != b.feature) {
            return Err(Error::Coordinator("feature width mismatch".into()));
        }
        // Shared feature table (heads exchange boundary rows, so the table
        // every head sees is consistent).
        let mut x_table = vec![0.0f32; b.table * b.feature];
        for (node, f) in features.iter().enumerate() {
            x_table[node * b.feature..(node + 1) * b.feature].copy_from_slice(f);
        }

        let mut results = Vec::with_capacity(n);
        for (head, members) in self.clustering.clusters.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let topo = Topology { nodes: n, cluster_size: members.len() };
            let modeled = self.model.semi_latency(topo, self.head_capacity).total();
            // Heads batch their members, padding to the artifact batch.
            for chunk in members.chunks(b.batch) {
                let mut nodes = chunk.to_vec();
                let pad = *nodes.last().unwrap();
                nodes.resize(b.batch, pad);

                let mut x_self = Vec::with_capacity(b.batch * b.feature);
                for &node in &nodes {
                    x_self.extend_from_slice(&features[node]);
                }
                let nbr_idx = self.sampler.sample_batch(&self.graph, &nodes);
                let inputs = vec![
                    Tensor::f32(&[b.batch, b.feature], x_self)?,
                    Tensor::i32(&[b.batch, b.sample], nbr_idx)?,
                    Tensor::f32(&[b.table, b.feature], x_table.clone())?,
                    Tensor::f32(&[b.feature, b.hidden], self.weights.clone())?,
                ];
                let t0 = Instant::now();
                let outputs = svc.infer(&b.artifact, inputs)?;
                let wall = t0.elapsed();
                let flat = outputs
                    .first()
                    .ok_or_else(|| Error::Coordinator("no outputs".into()))?
                    .as_f32()?
                    .to_vec();
                for (i, &node) in chunk.iter().enumerate() {
                    results.push(SemiResult {
                        node,
                        head,
                        output: flat[i * b.hidden..(i + 1) * b.hidden].to_vec(),
                        modeled,
                        wall,
                    });
                }
            }
        }
        results.sort_by_key(|r| r.node);
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{fixed_size, generate};
    use crate::runtime::Manifest;
    use std::path::Path;

    fn binding() -> GcnLayerBinding {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "gcn_layer_small", "file": "f", "inputs": [], "outputs": [],
             "config": {"batch": 16, "sample": 4, "feature": 64,
                        "hidden": 32, "table": 64}}]}"#;
        let m = Manifest::parse(Path::new("/x"), doc).unwrap();
        GcnLayerBinding::from_spec(m.get("gcn_layer_small").unwrap()).unwrap()
    }

    #[test]
    fn construction_validates_shapes() {
        let g = generate::regular(48, 6, 3).unwrap();
        let c = fixed_size(48, 8).unwrap();
        let ok = SemiCoordinator::new(
            binding(),
            g.clone(),
            c.clone(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().num_heads(), 6);

        // clustering mismatch
        let bad = SemiCoordinator::new(
            binding(),
            g.clone(),
            fixed_size(40, 8).unwrap(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(bad.is_err());

        // weight arity
        let bad = SemiCoordinator::new(
            binding(),
            g,
            c,
            vec![0.0; 3],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(bad.is_err());
    }

    // The `round` execution path needs built artifacts + a PJRT service;
    // covered by rust/tests/serving.rs and examples/semi_decentralized.rs.
}
