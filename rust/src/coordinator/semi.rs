//! Semi-decentralized coordinator (the paper's conclusion / ref [26], E8):
//! cluster *heads* each serve their region in a centralized fashion
//! (members upload features over fast V2X links, the head runs the GNN),
//! while the graph level stays decentralized — heads exchange boundary
//! embeddings with adjacent heads.

use std::time::{Duration, Instant};

use crate::cores::{FeatureMatrix, GnnWorkload};
use crate::error::{Error, Result};
use crate::graph::{Clustering, Csr, NeighborSampler};
use crate::netmodel::{NetModel, Topology};
use crate::runtime::Tensor;
use crate::units::Time;

use super::leader::GcnLayerBinding;
use super::service::InferenceService;

/// Per-member output of one semi-decentralized round.
#[derive(Debug, Clone)]
pub struct SemiResult {
    pub node: usize,
    pub head: usize,
    pub output: Vec<f32>,
    /// Modeled round latency for this node's cluster (E8 model).
    pub modeled: Time,
    /// Wall time of the head's PJRT execution.
    pub wall: Duration,
}

/// The semi-decentralized deployment over one graph.
pub struct SemiCoordinator {
    binding: GcnLayerBinding,
    graph: Csr,
    clustering: Clustering,
    weights: Vec<f32>,
    sampler: NeighborSampler,
    model: NetModel,
    head_capacity: f64,
    /// Fraction of graph edges the clustering keeps intra-cluster; drives
    /// the boundary term of the modeled round latency (E11's clustered E8
    /// variant — the same score the autotuner selects points with).
    intra_fraction: f64,
    /// When set, per-result `modeled` latency comes from a packet-level
    /// `netsim` overlay round instead of the closed-form E8 model.
    simulated_latency: Option<Time>,
}

impl SemiCoordinator {
    pub fn new(
        binding: GcnLayerBinding,
        graph: Csr,
        clustering: Clustering,
        weights: Vec<f32>,
        workload: &GnnWorkload,
    ) -> Result<SemiCoordinator> {
        if clustering.assignment.len() != graph.num_nodes() {
            return Err(Error::Coordinator("clustering does not cover the graph".into()));
        }
        if graph.num_nodes() > binding.table {
            return Err(Error::Coordinator(format!(
                "graph has {} nodes but artifact table holds {}",
                graph.num_nodes(),
                binding.table
            )));
        }
        if weights.len() != binding.feature * binding.hidden {
            return Err(Error::Coordinator("weight arity mismatch".into()));
        }
        let head_capacity = clustering.avg_size().max(1.0);
        let intra_fraction = clustering.intra_edge_fraction(&graph);
        Ok(SemiCoordinator {
            sampler: NeighborSampler::new(binding.sample, 7),
            model: NetModel::paper(workload)?,
            binding,
            graph,
            clustering,
            weights,
            head_capacity,
            intra_fraction,
            simulated_latency: None,
        })
    }

    /// Build the coordinator a tuned [`OperatingPoint`] describes: the
    /// point's partitioner produces the clustering and the point's head
    /// capacity replaces the avg-size default — so the serving path runs
    /// exactly the configuration the E11 autotuner scored.  Rejects
    /// non-semi points (the centralized leader has its own constructor).
    ///
    /// [`OperatingPoint`]: crate::autotune::OperatingPoint
    pub fn from_operating_point(
        binding: GcnLayerBinding,
        graph: Csr,
        weights: Vec<f32>,
        workload: &GnnWorkload,
        point: &crate::autotune::OperatingPoint,
    ) -> Result<SemiCoordinator> {
        if point.setting != crate::autotune::SettingKind::Semi {
            return Err(Error::Coordinator(format!(
                "operating point `{}` is not semi-decentralized",
                point.label()
            )));
        }
        let clustering = point.partitioner.partition(&graph, point.cluster_size)?;
        SemiCoordinator::new(binding, graph, clustering, weights, workload)?
            .with_head_capacity(point.head_capacity)
    }

    /// Override the cluster-head capacity multiple (the default is the
    /// clustering's average size).
    pub fn with_head_capacity(mut self, head_capacity: f64) -> Result<SemiCoordinator> {
        if !head_capacity.is_finite() || head_capacity < 1.0 {
            return Err(Error::Coordinator("head capacity must be >= 1".into()));
        }
        self.head_capacity = head_capacity;
        Ok(self)
    }

    pub fn head_capacity(&self) -> f64 {
        self.head_capacity
    }

    pub fn num_heads(&self) -> usize {
        self.clustering.num_clusters()
    }

    /// Switch per-result `modeled` latency from the closed-form E8 model
    /// to a packet-level `netsim` overlay round — head receive-port
    /// contention and the boundary exchange included.  The simulated
    /// topology uses the largest cluster (the straggler that closes the
    /// round).  `None` returns to the analytic model.
    pub fn use_simulated_latency(
        &mut self,
        cfg: Option<&crate::netsim::NetSimConfig>,
    ) -> Result<()> {
        self.simulated_latency = match cfg {
            None => None,
            Some(c) => {
                let worst = self
                    .clustering
                    .clusters
                    .iter()
                    .map(Vec::len)
                    .max()
                    .unwrap_or(1)
                    .max(1);
                let topo = Topology { nodes: self.graph.num_nodes(), cluster_size: worst };
                Some(
                    crate::netsim::simulate_fabric(
                        &self.model,
                        crate::netsim::Scenario::SemiOverlay {
                            head_capacity: self.head_capacity,
                        },
                        topo,
                        c,
                    )?
                    .completion,
                )
            }
        };
        Ok(())
    }

    /// The round latency currently attached to results (`None` = the
    /// closed-form E8 model is in effect, evaluated per cluster).
    pub fn simulated_round_latency(&self) -> Option<Time> {
        self.simulated_latency
    }

    /// Run one round: every head batches its members through the artifact.
    /// `features.row(node)` is each node's current feature vector.
    pub fn round(
        &self,
        svc: &InferenceService,
        features: &FeatureMatrix,
    ) -> Result<Vec<SemiResult>> {
        let b = &self.binding;
        let n = self.graph.num_nodes();
        if features.rows() != n {
            return Err(Error::Coordinator("feature rows != nodes".into()));
        }
        if features.cols() != b.feature {
            return Err(Error::Coordinator("feature width mismatch".into()));
        }
        // Shared feature table (heads exchange boundary rows, so the table
        // every head sees is consistent).  The flat feature matrix is
        // already the table's row-major prefix — one contiguous copy.
        let mut x_table = vec![0.0f32; b.table * b.feature];
        x_table[..n * b.feature].copy_from_slice(features.as_slice());

        let mut results = Vec::with_capacity(n);
        for (head, members) in self.clustering.clusters.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let topo = Topology { nodes: n, cluster_size: members.len() };
            // Boundary-aware E8 (E11): the same clustered score the
            // autotuner selects operating points with, so the served
            // `modeled` latency matches the figure that justified the
            // configuration.
            let modeled = self.simulated_latency.unwrap_or_else(|| {
                self.model
                    .semi_latency_clustered(topo, self.head_capacity, self.intra_fraction)
                    .total()
            });
            // Heads batch their members, padding to the artifact batch.
            for chunk in members.chunks(b.batch) {
                let mut nodes = chunk.to_vec();
                let pad = *nodes.last().unwrap();
                nodes.resize(b.batch, pad);

                let mut x_self = Vec::with_capacity(b.batch * b.feature);
                for &node in &nodes {
                    x_self.extend_from_slice(features.row(node));
                }
                let nbr_idx = self.sampler.sample_batch(&self.graph, &nodes);
                let inputs = vec![
                    Tensor::f32(&[b.batch, b.feature], x_self)?,
                    Tensor::i32(&[b.batch, b.sample], nbr_idx)?,
                    Tensor::f32(&[b.table, b.feature], x_table.clone())?,
                    Tensor::f32(&[b.feature, b.hidden], self.weights.clone())?,
                ];
                let t0 = Instant::now();
                let outputs = svc.infer(&b.artifact, inputs)?;
                let wall = t0.elapsed();
                let flat = outputs
                    .first()
                    .ok_or_else(|| Error::Coordinator("no outputs".into()))?
                    .as_f32()?
                    .to_vec();
                for (i, &node) in chunk.iter().enumerate() {
                    results.push(SemiResult {
                        node,
                        head,
                        output: flat[i * b.hidden..(i + 1) * b.hidden].to_vec(),
                        modeled,
                        wall,
                    });
                }
            }
        }
        results.sort_by_key(|r| r.node);
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{fixed_size, generate};
    use crate::runtime::Manifest;
    use std::path::Path;

    fn binding() -> GcnLayerBinding {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "gcn_layer_small", "file": "f", "inputs": [], "outputs": [],
             "config": {"batch": 16, "sample": 4, "feature": 64,
                        "hidden": 32, "table": 64}}]}"#;
        let m = Manifest::parse(Path::new("/x"), doc).unwrap();
        GcnLayerBinding::from_spec(m.get("gcn_layer_small").unwrap()).unwrap()
    }

    #[test]
    fn construction_validates_shapes() {
        let g = generate::regular(48, 6, 3).unwrap();
        let c = fixed_size(48, 8).unwrap();
        let ok = SemiCoordinator::new(
            binding(),
            g.clone(),
            c.clone(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().num_heads(), 6);

        // clustering mismatch
        let bad = SemiCoordinator::new(
            binding(),
            g.clone(),
            fixed_size(40, 8).unwrap(),
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(bad.is_err());

        // weight arity
        let bad = SemiCoordinator::new(
            binding(),
            g,
            c,
            vec![0.0; 3],
            &GnnWorkload::gcn("t", 64, 8),
        );
        assert!(bad.is_err());
    }

    /// E11: a coordinator built from a tuned operating point is
    /// configured identically to the hand-constructed equivalent (the
    /// PJRT round itself is compared bit-for-bit in rust/tests/serving.rs).
    #[test]
    fn from_operating_point_matches_hand_construction() {
        use crate::autotune::{OperatingPoint, Partitioner};
        let g = generate::regular(48, 6, 3).unwrap();
        let w = vec![0.25f32; 64 * 32];
        let wl = GnnWorkload::gcn("t", 64, 8);
        let point = OperatingPoint::semi(8, 10.0, Partitioner::FixedSize);
        let tuned =
            SemiCoordinator::from_operating_point(binding(), g.clone(), w.clone(), &wl, &point)
                .unwrap();
        let hand = SemiCoordinator::new(
            binding(),
            g.clone(),
            fixed_size(48, 8).unwrap(),
            w.clone(),
            &wl,
        )
        .unwrap()
        .with_head_capacity(10.0)
        .unwrap();
        assert_eq!(tuned.num_heads(), hand.num_heads());
        assert_eq!(tuned.head_capacity(), 10.0);
        assert_eq!(tuned.clustering, hand.clustering);
        assert_eq!(tuned.intra_fraction, hand.intra_fraction);
        // Same modeled round latency for every cluster.
        let topo = Topology { nodes: 48, cluster_size: 8 };
        assert_eq!(
            tuned.model.semi_latency(topo, tuned.head_capacity).total(),
            hand.model.semi_latency(topo, hand.head_capacity).total()
        );

        // Non-semi points are rejected, as are sub-unit head capacities.
        let cent = OperatingPoint::centralized();
        assert!(SemiCoordinator::from_operating_point(
            binding(),
            g.clone(),
            w.clone(),
            &wl,
            &cent
        )
        .is_err());
        let semi = SemiCoordinator::new(
            binding(),
            g,
            fixed_size(48, 8).unwrap(),
            w,
            &wl,
        )
        .unwrap();
        assert!(semi.with_head_capacity(0.5).is_err());
    }

    #[test]
    fn simulated_latency_mode_tracks_the_overlay_fabric() {
        use crate::netsim::NetSimConfig;
        let g = generate::regular(48, 6, 3).unwrap();
        let c = fixed_size(48, 8).unwrap();
        let mut semi = SemiCoordinator::new(
            binding(),
            g,
            c,
            vec![0.0; 64 * 32],
            &GnnWorkload::gcn("t", 64, 8),
        )
        .unwrap();
        assert!(semi.simulated_round_latency().is_none());

        semi.use_simulated_latency(Some(&NetSimConfig::default())).unwrap();
        let sim = semi.simulated_round_latency().unwrap();
        // Uncongested overlay coincides with the closed-form E8 model
        // (48 nodes in six full clusters of 8, heads 8× a member).
        let analytic = semi
            .model
            .semi_latency(Topology { nodes: 48, cluster_size: 8 }, semi.head_capacity)
            .total();
        assert!(
            (sim.as_s() - analytic.as_s()).abs() / analytic.as_s() < 1e-6,
            "sim {sim} vs analytic {analytic}"
        );

        // One receive port per head makes member uploads queue.
        semi.use_simulated_latency(Some(&NetSimConfig {
            rx_ports: Some(1),
            ..Default::default()
        }))
        .unwrap();
        assert!(semi.simulated_round_latency().unwrap() > sim);

        semi.use_simulated_latency(None).unwrap();
        assert!(semi.simulated_round_latency().is_none());
    }

    // The `round` execution path needs built artifacts + a PJRT service;
    // covered by rust/tests/serving.rs and examples/semi_decentralized.rs.
}
