//! Dynamic batcher: coalesces node-inference requests into fixed-size
//! batches for the PJRT artifacts (whose leading dimension is static).
//!
//! Size-or-deadline policy: a batch closes when it reaches `max_batch`
//! requests or when its oldest request has waited `max_wait`.  Short
//! batches are padded by the executor path (repeat-last), so a closed
//! batch is always artifact-shaped.
//!
//! DESIGN.md: §7 (serving coordinator).

use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// One queued inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub node: usize,
}

/// A closed batch ready for execution.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// Wait of the oldest member at close time.
    pub queued_for: Duration,
}

impl Batch {
    pub fn nodes(&self) -> Vec<usize> {
        self.requests.iter().map(|r| r.node).collect()
    }
}

/// Size-or-deadline dynamic batcher.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait: Duration,
    pending: Vec<Request>,
    oldest: Option<Instant>,
    /// Closed-batch statistics.
    batches_closed: u64,
    requests_seen: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Result<Batcher> {
        if max_batch == 0 {
            return Err(Error::Coordinator("batch size must be > 0".into()));
        }
        Ok(Batcher {
            max_batch,
            max_wait,
            pending: Vec::with_capacity(max_batch),
            oldest: None,
            batches_closed: 0,
            requests_seen: 0,
        })
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Enqueue a request; returns a closed batch when full.
    pub fn push(&mut self, req: Request) -> Option<Batch> {
        self.push_at(req, Instant::now())
    }

    /// `push` with an explicit clock (testable).
    pub fn push_at(&mut self, req: Request, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(req);
        self.requests_seen += 1;
        if self.pending.len() >= self.max_batch {
            return Some(self.close(now));
        }
        None
    }

    /// Close the batch if the deadline expired (call from the poll loop).
    pub fn poll(&mut self) -> Option<Batch> {
        self.poll_at(Instant::now())
    }

    /// `poll` with an explicit clock.
    pub fn poll_at(&mut self, now: Instant) -> Option<Batch> {
        match self.oldest {
            Some(t0) if !self.pending.is_empty() && now.duration_since(t0) >= self.max_wait => {
                Some(self.close(now))
            }
            _ => None,
        }
    }

    /// Force-close whatever is pending (shutdown / drain).
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.close(Instant::now()))
        }
    }

    fn close(&mut self, now: Instant) -> Batch {
        let queued_for =
            self.oldest.map(|t0| now.saturating_duration_since(t0)).unwrap_or_default();
        self.oldest = None;
        self.batches_closed += 1;
        Batch { requests: std::mem::take(&mut self.pending), queued_for }
    }

    /// (batches closed, requests seen) counters.
    pub fn stats(&self) -> (u64, u64) {
        (self.batches_closed, self.requests_seen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Rng};

    fn req(id: u64) -> Request {
        Request { id, node: id as usize }
    }

    #[test]
    fn closes_on_size() {
        let mut b = Batcher::new(3, Duration::from_secs(10)).unwrap();
        assert!(b.push(req(1)).is_none());
        assert!(b.push(req(2)).is_none());
        let batch = b.push(req(3)).expect("third request closes the batch");
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.nodes(), vec![1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn closes_on_deadline() {
        let mut b = Batcher::new(100, Duration::from_millis(5)).unwrap();
        let t0 = Instant::now();
        assert!(b.push_at(req(1), t0).is_none());
        assert!(b.poll_at(t0 + Duration::from_millis(1)).is_none());
        let batch = b.poll_at(t0 + Duration::from_millis(6)).expect("deadline expired");
        assert_eq!(batch.requests.len(), 1);
        assert!(batch.queued_for >= Duration::from_millis(5));
    }

    #[test]
    fn deadline_tracks_oldest_not_newest() {
        let mut b = Batcher::new(100, Duration::from_millis(10)).unwrap();
        let t0 = Instant::now();
        b.push_at(req(1), t0);
        b.push_at(req(2), t0 + Duration::from_millis(9));
        let batch = b.poll_at(t0 + Duration::from_millis(10)).expect("oldest expired");
        assert_eq!(batch.requests.len(), 2);
    }

    #[test]
    fn empty_poll_and_flush_yield_nothing() {
        let mut b = Batcher::new(4, Duration::from_millis(1)).unwrap();
        assert!(b.poll().is_none());
        assert!(b.flush().is_none());
    }

    #[test]
    fn flush_drains_partial_batches() {
        let mut b = Batcher::new(10, Duration::from_secs(1)).unwrap();
        b.push(req(1));
        b.push(req(2));
        let batch = b.flush().unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(b.stats(), (1, 2));
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        forall(16, |rng: &mut Rng| {
            let max = rng.index(8) + 1;
            let mut b = Batcher::new(max, Duration::from_secs(100)).unwrap();
            let n = rng.index(100) + 1;
            let mut seen = Vec::new();
            for id in 0..n as u64 {
                if let Some(batch) = b.push(req(id)) {
                    assert!(batch.requests.len() == max);
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            if let Some(batch) = b.flush() {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            let want: Vec<u64> = (0..n as u64).collect();
            assert_eq!(seen, want, "requests lost/duplicated/reordered");
        });
    }

    #[test]
    fn zero_batch_rejected() {
        assert!(Batcher::new(0, Duration::ZERO).is_err());
    }
}
