//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls keep the crate dependency-free (the
//! offline build has no `thiserror`); the variants and messages match the
//! original derive exactly.
//!
//! DESIGN.md: §1 (crate layering; every layer returns this type).

use std::fmt;

use crate::pjrt as xla;

/// Unified error for every IMA-GNN subsystem.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / value errors (parser, validation, presets).
    Config(String),

    /// Malformed JSON (artifact manifest).
    Json { offset: usize, message: String },

    /// Graph construction / CSR validation errors.
    Graph(String),

    /// Hardware-model errors (invalid crossbar mapping, sizing).
    Hardware(String),

    /// Runtime (PJRT / artifact) errors.
    Runtime(String),

    /// Coordinator / serving-path errors.
    Coordinator(String),

    /// Simulation errors.
    Sim(String),

    /// CLI usage errors.
    Usage(String),

    Io(std::io::Error),

    /// Errors surfaced by the PJRT backend.
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json { offset, message } => {
                write!(f, "json error at byte {offset}: {message}")
            }
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Hardware(m) => write!(f, "hardware model error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("missing key `rows`".into());
        assert!(e.to_string().contains("missing key"));
        let e = Error::Json { offset: 17, message: "unexpected `}`".into() };
        assert!(e.to_string().contains("byte 17"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    #[cfg(not(feature = "pjrt"))] // the stub Error is a plain tuple struct
    fn pjrt_error_converts_to_xla_variant() {
        let e: Error = xla::Error("backend missing".to_string()).into();
        assert!(matches!(&e, Error::Xla(m) if m.contains("backend missing")));
    }
}
