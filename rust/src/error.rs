//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every IMA-GNN subsystem.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration file / value errors (parser, validation, presets).
    #[error("config error: {0}")]
    Config(String),

    /// Malformed JSON (artifact manifest).
    #[error("json error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    /// Graph construction / CSR validation errors.
    #[error("graph error: {0}")]
    Graph(String),

    /// Hardware-model errors (invalid crossbar mapping, sizing).
    #[error("hardware model error: {0}")]
    Hardware(String),

    /// Runtime (PJRT / artifact) errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / serving-path errors.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Simulation errors.
    #[error("simulation error: {0}")]
    Sim(String),

    /// CLI usage errors.
    #[error("usage error: {0}")]
    Usage(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Errors surfaced by the `xla` crate (PJRT).
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("missing key `rows`".into());
        assert!(e.to_string().contains("missing key"));
        let e = Error::Json { offset: 17, message: "unexpected `}`".into() };
        assert!(e.to_string().contains("byte 17"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
