//! Command-line argument parsing (offline `clap` substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options and
//! positional arguments, with generated usage text.
//!
//! DESIGN.md: §1 (the L3 binary surface this parser fronts).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name} expects a number, got `{v}`"))),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A parser for one command with options/flags.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    specs: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Command {
        self.specs.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Command {
        self.specs.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for spec in &self.specs {
            let val = if spec.takes_value { " <value>" } else { "" };
            let def = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{:<18} {}{}\n", spec.name, val, spec.help, def));
        }
        s
    }

    /// Parse raw argv (without the command name itself).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let (true, Some(d)) = (spec.takes_value, spec.default) {
                args.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| Error::Usage(format!("unknown option `--{name}`\n\n{}", self.usage())))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Usage(format!("--{name} requires a value")))?
                        }
                    };
                    args.opts.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(Error::Usage(format!("--{name} does not take a value")));
                    }
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("table1", "reproduce Table 1")
            .opt("nodes", "graph size", Some("10000"))
            .opt("cluster", "cluster size", Some("10"))
            .flag("verbose", "print details")
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 10000);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd().parse(&argv(&["--nodes", "500", "--cluster=7", "--verbose"])).unwrap();
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 500);
        assert_eq!(a.usize_or("cluster", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positional_args_collected() {
        let a = cmd().parse(&argv(&["cora", "--nodes", "5", "citeseer"])).unwrap();
        assert_eq!(a.positional(), &["cora".to_string(), "citeseer".to_string()]);
    }

    #[test]
    fn underscores_in_integers() {
        let a = cmd().parse(&argv(&["--nodes", "4_847_571"])).unwrap();
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 4_847_571);
    }

    #[test]
    fn errors_are_usage_errors() {
        assert!(matches!(cmd().parse(&argv(&["--bogus"])), Err(Error::Usage(_))));
        assert!(matches!(cmd().parse(&argv(&["--nodes"])), Err(Error::Usage(_))));
        assert!(matches!(cmd().parse(&argv(&["--verbose=1"])), Err(Error::Usage(_))));
        let a = cmd().parse(&argv(&["--nodes", "abc"])).unwrap();
        assert!(a.usize_or("nodes", 0).is_err());
    }

    #[test]
    fn usage_lists_options() {
        let u = cmd().usage();
        assert!(u.contains("--nodes"));
        assert!(u.contains("default: 10000"));
    }
}
