//! Deterministic Pareto-frontier filter over (latency, energy,
//! per-device power) — minimize all three.
//!
//! The frontier is returned as indices into the input slice, in input
//! order.  Exact duplicates keep only the earliest occurrence, so the
//! result is a pure function of the input sequence (the autotuner's
//! determinism contract, DESIGN.md §9).

use super::Score;

/// `a` dominates `b` when it is no worse on every objective and strictly
/// better on at least one.
pub fn dominates(a: &Score, b: &Score) -> bool {
    let no_worse = a.latency <= b.latency
        && a.energy <= b.energy
        && a.per_device_power <= b.per_device_power;
    let better = a.latency < b.latency
        || a.energy < b.energy
        || a.per_device_power < b.per_device_power;
    no_worse && better
}

/// Indices of the non-dominated points, in input order.
pub fn pareto_frontier(scores: &[Score]) -> Vec<usize> {
    let mut out = Vec::new();
    'outer: for (i, s) in scores.iter().enumerate() {
        for (j, other) in scores.iter().enumerate() {
            if j == i {
                continue;
            }
            // Strict dominance from anywhere, or an identical score seen
            // earlier, knocks `i` off the frontier.
            if dominates(other, s) || (other == s && j < i) {
                continue 'outer;
            }
        }
        out.push(i);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::{Energy, Power, Time};

    fn s(l: f64, e: f64, p: f64) -> Score {
        Score {
            latency: Time::s(l),
            energy: Energy::j(e),
            per_device_power: Power::w(p),
        }
    }

    #[test]
    fn dominance_requires_strict_improvement_somewhere() {
        assert!(dominates(&s(1.0, 1.0, 1.0), &s(2.0, 1.0, 1.0)));
        assert!(dominates(&s(1.0, 0.5, 1.0), &s(1.0, 1.0, 1.0)));
        assert!(!dominates(&s(1.0, 1.0, 1.0), &s(1.0, 1.0, 1.0))); // equal
        assert!(!dominates(&s(0.5, 2.0, 1.0), &s(1.0, 1.0, 1.0))); // trade-off
    }

    #[test]
    fn frontier_keeps_tradeoffs_and_drops_dominated() {
        let pts = [
            s(1.0, 9.0, 1.0), // fast but hungry       → frontier
            s(9.0, 1.0, 1.0), // slow but frugal        → frontier
            s(5.0, 5.0, 5.0), // middle, dominated by 3 → out
            s(4.0, 4.0, 1.0), // dominates 2            → frontier
        ];
        assert_eq!(pareto_frontier(&pts), vec![0, 1, 3]);
    }

    #[test]
    fn duplicates_keep_only_the_first() {
        let pts = [s(1.0, 1.0, 1.0), s(2.0, 0.5, 1.0), s(1.0, 1.0, 1.0)];
        assert_eq!(pareto_frontier(&pts), vec![0, 1]);
    }

    #[test]
    fn singleton_and_empty_inputs() {
        assert_eq!(pareto_frontier(&[]), Vec::<usize>::new());
        assert_eq!(pareto_frontier(&[s(3.0, 3.0, 3.0)]), vec![0]);
    }

    #[test]
    fn every_point_is_on_or_dominated_by_the_frontier() {
        // Pseudo-random small cloud; property: completeness of the filter.
        let mut rng = crate::testing::Rng::new(7);
        let pts: Vec<Score> = (0..40)
            .map(|_| s(rng.f64_in(0.0, 4.0), rng.f64_in(0.0, 4.0), rng.f64_in(0.0, 4.0)))
            .collect();
        let front = pareto_frontier(&pts);
        assert!(!front.is_empty());
        for (i, p) in pts.iter().enumerate() {
            if front.contains(&i) {
                continue;
            }
            assert!(
                front.iter().any(|&j| dominates(&pts[j], p) || pts[j] == *p),
                "point {i} neither on nor covered by the frontier"
            );
        }
    }
}
